// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! END-TO-END DRIVER (the repository's full-system validation, recorded in
//! EXPERIMENTS.md): runs the complete three-layer stack on a real small
//! workload and reports the paper's headline metrics.
//!
//! Flow:
//!   1. generate a NanoAOD-like dataset (gen::nanoaod — Fig 6 workload);
//!   2. load the AOT-compiled XLA basket analyzer (L2+L1 artifacts built by
//!      `make artifacts`; falls back to the native mirror if absent);
//!   3. plan per-branch compression with the adaptive planner (paper §3
//!      future work) for the `analysis` and `production` use cases;
//!   4. write through the parallel compression pipeline (L3);
//!   5. read everything back, verify bit-exactness, and report
//!      ratio / write MB/s / scan MB/s for fixed vs adaptive configs.
//!
//! ```text
//! cargo run --release --example adaptive_e2e [-- <n_events>]
//! ```

use rootio::bench::figures::collect_baskets;
use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{
    write_tree_parallel, FeatureSource, PipelineConfig, Planner, UseCase,
};
use rootio::gen::nanoaod;
use rootio::precond::Precond;
use rootio::rfile::{BranchDef, TreeReader};
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

fn feature_source() -> FeatureSource {
    let dir = Path::new("artifacts");
    if dir.join("analyzer_4096.hlo.txt").exists() {
        match rootio::runtime::cpu_client()
            .and_then(|c| rootio::runtime::Analyzer::load(&c, dir))
        {
            Ok(a) => {
                println!("analyzer: XLA artifacts loaded from {}", dir.display());
                return FeatureSource::Xla(a);
            }
            Err(e) => eprintln!("analyzer: XLA load failed ({e}), using native mirror"),
        }
    } else {
        eprintln!("analyzer: artifacts/ not built, using native mirror");
    }
    FeatureSource::Native
}

struct RunResult {
    label: String,
    file_bytes: u64,
    ratio: f64,
    write_mbps: f64,
    scan_mbps: f64,
}

fn run_config(
    label: &str,
    schema: Vec<BranchDef>,
    default: Settings,
    events: &[Vec<rootio::rfile::Value>],
) -> anyhow::Result<RunResult> {
    let path = std::env::temp_dir().join("rootio_adaptive_e2e.rfil");
    let t0 = Instant::now();
    let (_, snap) = write_tree_parallel(
        &path,
        "Events",
        schema,
        default,
        32 * 1024,
        PipelineConfig::default(),
        events.iter().cloned(),
    )?;
    let write_wall = t0.elapsed().as_secs_f64();
    let file_bytes = std::fs::metadata(&path)?.len();

    let t0 = Instant::now();
    let mut reader = TreeReader::open(&path)?;
    let back = reader.read_all_events()?;
    let scan_wall = t0.elapsed().as_secs_f64();
    anyhow::ensure!(back == *events, "{label}: read-back mismatch!");

    std::fs::remove_file(&path).ok();
    Ok(RunResult {
        label: label.into(),
        file_bytes,
        ratio: snap.ratio(),
        write_mbps: snap.bytes_in as f64 / 1e6 / write_wall,
        scan_mbps: snap.bytes_in as f64 / 1e6 / scan_wall,
    })
}

fn adaptive_schema(use_case: UseCase, events: &[Vec<rootio::rfile::Value>]) -> (Vec<BranchDef>, usize) {
    let mut planner = Planner::new(use_case, feature_source());
    let mut schema = nanoaod::schema();
    // Plan per branch from its first basket's logical payload.
    let baskets = collect_baskets(schema.clone(), events, 32 * 1024);
    let mut chosen: HashMap<u32, Settings> = HashMap::new();
    for b in &baskets {
        chosen
            .entry(b.branch_id)
            .or_insert_with(|| planner.plan(&b.logical_payload()));
    }
    let mut preconditioned = 0usize;
    for (i, def) in schema.iter_mut().enumerate() {
        if let Some(s) = chosen.get(&(i as u32)) {
            if s.precond != Precond::None {
                preconditioned += 1;
            }
            def.settings = Some(*s);
        }
    }
    (schema, preconditioned)
}

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(3000);
    let events = nanoaod::events(n, 0xE2E);
    let raw_mb: f64 = {
        let baskets = collect_baskets(nanoaod::schema(), &events, 32 * 1024);
        baskets.iter().map(|b| b.logical_len()).sum::<usize>() as f64 / 1e6
    };
    println!(
        "e2e driver: {n} NanoAOD-like events, {} branches, {raw_mb:.1} MB raw\n",
        nanoaod::schema().len()
    );

    let mut results = Vec::new();
    // Fixed baselines (what an experiment would configure today).
    for s in [
        Settings::new(Algorithm::Zlib, 1),   // ROOT's historical default
        Settings::new(Algorithm::Lz4, 1),    // analysis default since 6.14
        Settings::new(Algorithm::Zstd, 5),   // the paper's Run-3 candidate
    ] {
        results.push(run_config(&format!("fixed {}", s.label()), nanoaod::schema(), s, &events)?);
    }
    // Adaptive configs (paper §3 future work, served by the XLA analyzer).
    for (uc, name) in [(UseCase::Analysis, "analysis"), (UseCase::Production, "production")] {
        let (schema, preconditioned) = adaptive_schema(uc, &events);
        println!("adaptive({name}): {preconditioned} branches got a preconditioner");
        results.push(run_config(
            &format!("adaptive {name}"),
            schema,
            Settings::new(Algorithm::Zstd, 5),
            &events,
        )?);
    }

    println!(
        "\n{:<22} {:>12} {:>7} {:>12} {:>12}",
        "config", "file_bytes", "ratio", "write_MB_s", "scan_MB_s"
    );
    for r in &results {
        println!(
            "{:<22} {:>12} {:>7.3} {:>12.1} {:>12.1}",
            r.label, r.file_bytes, r.ratio, r.write_mbps, r.scan_mbps
        );
    }

    // Headline checks (the paper's qualitative claims on this workload).
    let fixed_lz4 = results.iter().find(|r| r.label.contains("LZ4-1")).unwrap();
    let adaptive_analysis = results.iter().find(|r| r.label == "adaptive analysis").unwrap();
    println!(
        "\nadaptive-analysis vs fixed LZ4-1: ratio {:+.1}%, scan speed {:+.1}%",
        (adaptive_analysis.ratio / fixed_lz4.ratio - 1.0) * 100.0,
        (adaptive_analysis.scan_mbps / fixed_lz4.scan_mbps - 1.0) * 100.0,
    );
    println!("all configs verified bit-exact on read-back");
    Ok(())
}
