// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

// profiling driver: inflate + deflate over paper baskets
use rootio::bench::figures::paper_baskets;
use rootio::compression::{Algorithm, Engine, Settings};
fn main() {
    let baskets = paper_baskets(32 * 1024);
    let mut engine = Engine::new();
    let s = Settings::new(Algorithm::Zlib, 6);
    let compressed: Vec<Vec<u8>> = baskets.iter().map(|b| engine.compress(b, &s)).collect();
    let mode = std::env::args().nth(1).unwrap_or_default();
    let t0 = std::time::Instant::now();
    let mut total = 0usize;
    if mode == "inflate" {
        while t0.elapsed().as_secs_f64() < 5.0 {
            for c in &compressed { total += engine.decompress(c).unwrap().len(); }
        }
    } else {
        while t0.elapsed().as_secs_f64() < 5.0 {
            for b in &baskets { total += engine.compress(b, &s).len(); }
        }
    }
    println!("{} bytes", total);
}
