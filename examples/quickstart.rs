// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Quickstart: write a small tree with two compression settings, read it
//! back, and print per-branch compression statistics.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use rootio::compression::{Algorithm, Settings};
use rootio::precond::Precond;
use rootio::rfile::{write_tree_serial, BranchDef, BranchType, TreeReader, Value};
use rootio::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let path = std::env::temp_dir().join("rootio_quickstart.rfil");

    // 1. Define a schema: a scalar, a jagged array (note the offset-array
    //    machinery this creates — the paper's Fig-6 subject), and a flag.
    let branches = vec![
        BranchDef::new("nHit", BranchType::I32),
        // Per-branch override: LZ4 with the BitShuffle preconditioner.
        BranchDef::new("Hit_energy", BranchType::VarF32)
            .with_settings(Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4))),
        BranchDef::new("is_calibrated", BranchType::Bool),
    ];

    // 2. Generate and write 5000 events (tree default: ZSTD-5).
    let mut rng = Rng::new(7);
    let events: Vec<Vec<Value>> = (0..5000)
        .map(|_| {
            let n = rng.poisson(4.0) as usize;
            vec![
                Value::I32(n as i32),
                Value::AF32((0..n).map(|_| rng.exponential(0.1) as f32).collect()),
                Value::Bool(rng.chance(0.9)),
            ]
        })
        .collect();
    let meta = write_tree_serial(
        &path,
        "Hits",
        branches,
        Settings::new(Algorithm::Zstd, 5),
        16 * 1024,
        events.iter().cloned(),
    )?;
    println!("wrote {} events in {} baskets to {}", meta.n_entries, meta.baskets.len(), path.display());

    // 3. Read back and verify.
    let mut reader = TreeReader::open(&path)?;
    let back = reader.read_all_events()?;
    assert_eq!(back, events);
    println!("read back OK ({} events)", back.len());

    // 4. Per-branch stats.
    println!("\n{:<16} {:>10} {:>12} {:>7}", "branch", "raw", "compressed", "ratio");
    for (i, b) in reader.meta.branches.iter().enumerate() {
        let (raw, comp): (u64, u64) = reader
            .baskets_for(i as u32)
            .iter()
            .map(|l| (l.uncompressed_len as u64, l.compressed_len as u64))
            .fold((0, 0), |acc, x| (acc.0 + x.0, acc.1 + x.1));
        println!(
            "{:<16} {:>10} {:>12} {:>7.3}   [{}]",
            b.name,
            raw,
            comp,
            raw as f64 / comp.max(1) as f64,
            b.settings.map(|s| s.label()).unwrap_or("tree default".into()),
        );
    }
    std::fs::remove_file(&path).ok();
    Ok(())
}
