// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Parallel pipeline demo: stream a large synthetic dataset through the L3
//! compression pipeline at several worker counts, showing scaling and
//! backpressure behaviour, then verify the output file.
//!
//! ```text
//! cargo run --release --example parallel_pipeline [-- <n_events>]
//! ```

use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{write_tree_parallel, PipelineConfig};
use rootio::gen::synthetic;
use rootio::rfile::TreeReader;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let events = synthetic::events(n, 0xBEEF);
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
    println!("{n} events, host has {cores} cores\n");

    println!(
        "{:>7} {:>10} {:>10} {:>9} {:>8}  {}",
        "workers", "wall_s", "MB_s", "ratio", "baskets", "latency histogram [<0.1ms,<1ms,<10ms,<100ms,>=100ms]"
    );
    let mut baseline = None;
    for workers in [1usize, 2, 4, cores.max(1)] {
        let path = std::env::temp_dir().join(format!("rootio_pipe_demo_{workers}.rfil"));
        let t0 = Instant::now();
        let (meta, snap) = write_tree_parallel(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Zstd, 6), // CPU-heavy codec: shows scaling
            32 * 1024,
            PipelineConfig { workers, queue_depth: workers * 4, dictionary: Vec::new() },
            events.iter().cloned(),
        )?;
        let wall = t0.elapsed().as_secs_f64();
        let mbps = snap.bytes_in as f64 / 1e6 / wall;
        let speedup = baseline.get_or_insert(wall).max(1e-9) / wall;
        println!(
            "{:>7} {:>10.2} {:>10.1} {:>9.3} {:>8}  {:?}  ({speedup:.2}x vs 1 worker)",
            workers,
            wall,
            mbps,
            snap.ratio(),
            meta.baskets.len(),
            snap.lat_buckets,
        );

        // Verify the last file fully.
        if workers == cores.max(1) {
            let mut reader = TreeReader::open(&path)?;
            let back = reader.read_all_events()?;
            assert_eq!(back.len(), n);
            println!("\nverified: {} events decode identically from the parallel-written file", n);
        }
        std::fs::remove_file(&path).ok();
    }
    Ok(())
}
