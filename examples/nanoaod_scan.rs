// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! NanoAOD algorithm scan — the paper's analysis-use-case study on one
//! file: write the same NanoAOD-like dataset under every algorithm, then
//! report file size, write throughput, and full-scan (read) throughput.
//!
//! This is Fig 2/3/6 condensed into the decision an experiment actually
//! faces: "which setting do I put in my production config?"
//!
//! ```text
//! cargo run --release --example nanoaod_scan [-- <n_events>]
//! ```

use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{write_tree_parallel, PipelineConfig};
use rootio::gen::nanoaod;
use rootio::precond::Precond;
use rootio::rfile::TreeReader;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2000);
    let events = nanoaod::events(n, 42);
    println!("NanoAOD-like sample: {n} events, {} branches\n", nanoaod::schema().len());

    let candidates = vec![
        Settings::new(Algorithm::Zlib, 1),
        Settings::new(Algorithm::CfZlib, 1),
        Settings::new(Algorithm::Zlib, 6),
        Settings::new(Algorithm::Lz4, 1),
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        Settings::new(Algorithm::Lz4, 9).with_precond(Precond::BitShuffle(4)),
        Settings::new(Algorithm::Zstd, 1),
        Settings::new(Algorithm::Zstd, 5),
        Settings::new(Algorithm::Lzma, 6),
    ];

    println!(
        "{:<22} {:>12} {:>7} {:>12} {:>12}",
        "setting", "file_bytes", "ratio", "write_MB_s", "scan_MB_s"
    );
    for s in candidates {
        let path = std::env::temp_dir().join("rootio_nanoaod_scan.rfil");
        let t0 = Instant::now();
        let (_, snap) = write_tree_parallel(
            &path,
            "Events",
            nanoaod::schema(),
            s,
            32 * 1024,
            PipelineConfig::default(),
            events.iter().cloned(),
        )?;
        let write_wall = t0.elapsed().as_secs_f64();
        let file_len = std::fs::metadata(&path)?.len();

        let t0 = Instant::now();
        let mut reader = TreeReader::open(&path)?;
        let back = reader.read_all_events()?;
        let scan_wall = t0.elapsed().as_secs_f64();
        assert_eq!(back.len(), n);

        println!(
            "{:<22} {:>12} {:>7.3} {:>12.1} {:>12.1}",
            s.label(),
            file_len,
            snap.ratio(),
            snap.bytes_in as f64 / 1e6 / write_wall,
            snap.bytes_in as f64 / 1e6 / scan_wall,
        );
        std::fs::remove_file(&path).ok();
    }
    println!("\n(the paper's Fig-6 point: LZ4+BitShuffle beats ZLIB's ratio while keeping fast scans)");
    Ok(())
}
