// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Columnar projection demo: write a NanoAOD-like tree, then read an
//! analysis-style subset of branches in ONE offset-sorted pass through
//! the parallel basket pipeline — comparing the prefetch plan against
//! the branch-major baseline, and consuming aligned row batches.
//!
//! Run: `cargo run --release --example projection_scan`

use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{ParallelTreeReader, PrefetchOrder, ProjectionPlan, ReadAhead};
use rootio::gen::nanoaod;
use rootio::precond::Precond;
use rootio::rfile::write_tree_serial;

fn main() -> anyhow::Result<()> {
    let path = std::env::temp_dir().join(format!("rootio_example_proj_{}.rfil", std::process::id()));
    let events = nanoaod::events(4000, 0x90D);
    write_tree_serial(
        &path,
        "Events",
        nanoaod::schema(),
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        32 * 1024,
        events.iter().cloned(),
    )?;

    let reader = ParallelTreeReader::open(&path, ReadAhead::with_workers(4))?;
    let branches = ["Muon_pt", "Muon_eta", "nMuon"];
    let ids = ProjectionPlan::resolve_names(&reader.meta, &branches)?;

    // The seek-pattern story: offset-sorted vs branch-major plans over the
    // exact same baskets.
    let offset_plan = ProjectionPlan::new(&reader.meta, &ids, PrefetchOrder::FileOffset)?;
    let submission_plan = ProjectionPlan::new(&reader.meta, &ids, PrefetchOrder::Submission)?;
    println!(
        "projecting {} of {} branches: {} baskets, {:.2} MB logical",
        branches.len(),
        reader.meta.branches.len(),
        offset_plan.locs().len(),
        offset_plan.logical_bytes() as f64 / 1e6,
    );
    println!(
        "  offset-sorted plan:    monotonic sweep = {}, backward seeks = {}",
        offset_plan.is_monotonic_sweep(),
        offset_plan.backward_seeks(),
    );
    println!(
        "  submission-order plan: monotonic sweep = {}, backward seeks = {}",
        submission_plan.is_monotonic_sweep(),
        submission_plan.backward_seeks(),
    );

    // Analyzer-style consumption: aligned row batches. Count events with
    // at least one muon above 30 GeV without materializing full columns.
    let mut proj = reader.project_plan(&offset_plan)?;
    let mut selected = 0u64;
    while let Some(batch) = proj.next_batch() {
        let batch = batch?;
        for row in &batch.rows {
            if let rootio::rfile::Value::AF32(pts) = &row[0] {
                if pts.iter().any(|&pt| pt > 30.0) {
                    selected += 1;
                }
            }
        }
    }
    println!("selected {selected} / {} events (Muon_pt > 30)", reader.meta.n_entries);

    println!("\nper-branch read stats:");
    for st in proj.branch_stats() {
        println!(
            "  {:<12} {:>4} baskets {:>9} raw bytes {:>9} compressed",
            st.name, st.baskets, st.logical_bytes, st.compressed_bytes
        );
    }
    println!("{}", reader.metrics_snapshot().report_decode("projection[4w]"));

    std::fs::remove_file(&path).ok();
    Ok(())
}
