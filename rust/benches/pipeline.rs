// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! `cargo bench --bench pipeline` — L3 pipeline scaling + serial-vs-parallel
//! comparison on the NanoAOD workload (the end-to-end throughput the
//! paper's Run-3 motivation cares about).

use rootio::bench::figures::run_figure;
use rootio::bench::BenchConfig;

fn main() {
    let cfg = BenchConfig::from_env();
    match run_figure("scaling", &cfg) {
        Ok((out, _)) => println!("== pipeline scaling ==\n{out}"),
        Err(e) => {
            eprintln!("scaling failed: {e:#}");
            std::process::exit(1);
        }
    }
}
