// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! `cargo bench --bench codecs` — microbenchmarks of the codec substrates:
//! per-(codec × level × preconditioner) compress/decompress throughput on
//! canonical payload classes (including the synthetic NanoAOD workload),
//! fast-path-vs-naive-reference speedups for every §Perf hot loop, and
//! end-to-end read-pipeline scaling (serial oracle vs 1/2/4 decode
//! workers).
//!
//! Outputs:
//!  * human-readable tables on stdout,
//!  * `results/codecs.csv` + `results/precond.csv` (historical columns)
//!    + `results/fastpath.csv` (fast-vs-reference speedups)
//!    + `results/entropy.csv` (fse2/fse4/huff0 entropy-lane throughput)
//!    + `results/read_pipeline.csv` (read-side scaling)
//!    + `results/projection.csv` (columnar projection lanes)
//!    + `results/projection_range.csv` (entry-range slice lanes)
//!    + `results/concurrent.csv` (scan-server waves, cold vs warm cache)
//!    + `results/repack.csv` (profile-driven repack: size + read MB/s
//!      before/after)
//!    + `results/io_backends.csv` (physical reads per sweep per I/O
//!      backend + the remote-sim latency × prefetch-depth surface),
//!  * `BENCH_codecs.json` at the repo root — the machine-readable perf
//!    trajectory consumed by CI and future PRs (schema documented in
//!    `docs/BENCHMARKS.md`). Set BENCH_QUICK=1 for a smoke run.

use rootio::bench::figures::collect_baskets;
use rootio::bench::{bench, json_array, json_escape, json_num, BenchConfig, Table};
use rootio::compression::{Algorithm, Engine, Settings};
use rootio::deflate::compress::{deflate, deflate_reference};
use rootio::deflate::inflate::{inflate, inflate_reference};
use rootio::deflate::{Flavor, Tuning};
use rootio::gen::nanoaod;
use rootio::lz4::Lz4Fast;
use rootio::precond::{self, Precond};
use rootio::util::bitio::{reference::NaiveBitWriter, BitReader, BitWriter};
use rootio::util::rng::Rng;
use rootio::zstd::{fse, huff0};

fn nanoaod_payload() -> Vec<u8> {
    // Concatenated logical basket payloads (data + big-endian offset
    // arrays) of the synthetic NanoAOD generator — the paper's workload.
    let events = nanoaod::events(2000, 0xA0D);
    let baskets = collect_baskets(nanoaod::schema(), &events, 32 * 1024);
    let mut buf = Vec::new();
    for b in baskets {
        buf.extend_from_slice(&b.logical_payload());
        if buf.len() >= 256 * 1024 {
            break;
        }
    }
    buf.truncate(256 * 1024);
    buf
}

fn payloads() -> Vec<(&'static str, Vec<u8>)> {
    let mut rng = Rng::new(0xC0DEC);
    let mut v: Vec<(&'static str, Vec<u8>)> = Vec::new();
    // Offset-array class (Fig 6 pathology).
    v.push(("offsets", (1u32..=65_536).flat_map(|i| i.to_be_bytes()).collect()));
    // Serialized floats (kinematics).
    v.push((
        "floats",
        (0..65_536).flat_map(|i| ((i as f32 * 0.37).sin() * 50.0).to_be_bytes()).collect(),
    ));
    // Text-ish (labels / json-like).
    let mut text = Vec::new();
    while text.len() < 256 * 1024 {
        text.extend_from_slice(b"\"Muon_pt\": [31.4, 17.2], \"HLT_IsoMu24\": true, ");
    }
    v.push(("text", text));
    // Incompressible.
    v.push(("noise", rng.bytes(256 * 1024)));
    // The real thing.
    v.push(("nanoaod", nanoaod_payload()));
    v
}

/// The (codec × level × preconditioner) grid. Preconditioners are applied
/// where the paper does (byte-aligned + entropy codecs).
fn settings_grid() -> Vec<Settings> {
    let mut grid = Vec::new();
    for (alg, levels) in [
        (Algorithm::Zlib, &[1u8, 6][..]),
        (Algorithm::CfZlib, &[1, 6]),
        (Algorithm::Lz4, &[1, 9]),
        (Algorithm::Zstd, &[1, 5]),
        (Algorithm::Lzma, &[6]),
        (Algorithm::OldRoot, &[6]),
    ] {
        for &level in levels {
            grid.push(Settings::new(alg, level));
            if matches!(alg, Algorithm::Lz4 | Algorithm::Zlib | Algorithm::CfZlib | Algorithm::Zstd) {
                grid.push(Settings::new(alg, level).with_precond(Precond::BitShuffle(4)));
                grid.push(Settings::new(alg, level).with_precond(Precond::Shuffle(4)));
            }
        }
    }
    grid
}

/// Look up a payload class by name so reordering `payloads()` cannot
/// silently mislabel the published speedup rows.
fn payload_by_name<'a>(all: &'a [(&'static str, Vec<u8>)], name: &str) -> &'a Vec<u8> {
    &all.iter().find(|(n, _)| *n == name).expect("payload class").1
}

struct Row {
    payload: &'static str,
    setting: Settings,
    ratio: f64,
    compress_mbps: f64,
    decompress_mbps: f64,
}

struct Speedup {
    name: &'static str,
    payload: &'static str,
    fast_mbps: f64,
    reference_mbps: f64,
}

struct EntropyRow {
    /// Entropy lane: "fse2" (dual-state), "fse4" (quad-state), "huff0"
    /// (4-stream Huffman literals).
    lane: &'static str,
    payload: &'static str,
    /// Entropy-coded payload ratio (input bytes / coded bytes). For the
    /// FSE lanes the denominator is the bitstream only (state words and
    /// the shared norm table are per-section constants); for huff0 it is
    /// the full blob including the code-length table and jump header.
    ratio: f64,
    encode_mbps: f64,
    decode_mbps: f64,
}

struct ReadRow {
    setting: String,
    /// 0 = the serial `TreeReader` oracle; otherwise pipeline worker count.
    workers: usize,
    mbps: f64,
}

struct ProjRow {
    /// Projection width: "2of8" or "8of8".
    branches: &'static str,
    /// "serial" (k independent `read_branch` sweeps), "offset"
    /// (offset-sorted single-pass plan), or "submission" (branch-major
    /// single-pipeline baseline).
    order: &'static str,
    /// 0 for the serial baseline; pipeline decode workers otherwise.
    workers: usize,
    mbps: f64,
}

struct ProjRangeRow {
    /// Entry window: "full" (whole tree) or "mid50" (middle 50% slice).
    range: &'static str,
    /// "offset" or "submission" prefetch order.
    order: &'static str,
    workers: usize,
    mbps: f64,
}

struct ConcRow {
    /// Concurrent queries in the wave: 1, 8, or 64.
    queries: usize,
    /// "cold" (first wave on a fresh server) or "warm" (identical second
    /// wave against the populated decoded-basket cache).
    cache: &'static str,
    /// Aggregate uncompressed MB/s across the whole wave.
    mbps: f64,
    /// 99th-percentile per-query latency, milliseconds.
    p99_ms: f64,
}

struct RepackRow {
    /// "before" (the zlib-6 production-style source) or "after" (the
    /// profile-driven rewrite).
    lane: &'static str,
    /// On-disk file size in bytes.
    file_bytes: u64,
    /// Whole-tree read throughput at 2 decode workers, uncompressed MB/s.
    read_mbps: f64,
    /// Hot-subset projection throughput — the access pattern the recorded
    /// profile describes.
    hot_mbps: f64,
}

struct IoRow {
    /// I/O backend lane: "pread", "coalesced", "mmap", or "remote-sim".
    backend: &'static str,
    /// Simulated per-request round-trip latency (remote-sim lanes only;
    /// 0 on the local backends).
    latency_ms: u64,
    /// Prefetch queue depth — on the remote lanes this is the pipeline
    /// window, i.e. the latency-hiding knob.
    depth: usize,
    /// Physical reads the backend issued for one full-tree sweep.
    reads: u64,
    /// Full-sweep throughput, uncompressed MB/s.
    mbps: f64,
}

fn codec_grid(cfg: &BenchConfig) -> Vec<Row> {
    let mut engine = Engine::new();
    let mut rows = Vec::new();
    for (pname, data) in payloads() {
        for s in settings_grid() {
            let c = engine.compress(&data, &s);
            let rc = bench("c", data.len(), cfg, || engine.compress(&data, &s).len());
            let rd = bench("d", data.len(), cfg, || engine.decompress(&c).unwrap().len());
            rows.push(Row {
                payload: pname,
                setting: s,
                ratio: data.len() as f64 / c.len() as f64,
                compress_mbps: rc.mbps(),
                decompress_mbps: rd.mbps(),
            });
        }
    }
    rows
}

/// §Perf regression anchors: each optimized hot loop against the naive
/// reference implementation it replaced (and stays bit-identical to).
fn fast_path_speedups(cfg: &BenchConfig) -> Vec<Speedup> {
    let mut out = Vec::new();
    let all = payloads();
    let offsets = payload_by_name(&all, "offsets");
    let nanoaod = payload_by_name(&all, "nanoaod");

    // 1. Fused Huffman emission + word-flush BitWriter vs per-field
    // emission + byte-at-a-time flushing (whole-deflate compress path).
    for (payload, data) in [("nanoaod", nanoaod), ("offsets", offsets)] {
        let t = Tuning::new(Flavor::Cloudflare, 6);
        let fast = bench("deflate-fast", data.len(), cfg, || deflate(data, &t).len());
        let refr = bench("deflate-ref", data.len(), cfg, || deflate_reference(data, &t).len());
        out.push(Speedup {
            name: "deflate_compress_fused_vs_reference",
            payload,
            fast_mbps: fast.mbps(),
            reference_mbps: refr.mbps(),
        });
    }

    // 2. BitShuffle: 8×8 u64 bit-matrix transpose vs bit-at-a-time scalar.
    let fast = bench("bitshuffle-fast", offsets.len(), cfg, || precond::bitshuffle(offsets, 4).len());
    let refr = bench("bitshuffle-naive", offsets.len(), cfg, || {
        precond::bitshuffle::reference::bitshuffle_naive(offsets, 4).len()
    });
    out.push(Speedup {
        name: "bitshuffle_u64_transpose_vs_naive",
        payload: "offsets",
        fast_mbps: fast.mbps(),
        reference_mbps: refr.mbps(),
    });
    let shuffled = precond::bitshuffle(offsets, 4);
    let fast = bench("unbitshuffle-fast", shuffled.len(), cfg, || {
        precond::unbitshuffle(&shuffled, 4).len()
    });
    let refr = bench("unbitshuffle-naive", shuffled.len(), cfg, || {
        precond::bitshuffle::reference::unbitshuffle_naive(&shuffled, 4).len()
    });
    out.push(Speedup {
        name: "unbitshuffle_u64_transpose_vs_naive",
        payload: "offsets",
        fast_mbps: fast.mbps(),
        reference_mbps: refr.mbps(),
    });

    // 3. Byte shuffle: stride-4 single-pass specialization vs per-plane.
    let fast = bench("shuffle4-fast", offsets.len(), cfg, || precond::shuffle(offsets, 4).len());
    let refr = bench("shuffle4-naive", offsets.len(), cfg, || {
        precond::shuffle::reference::shuffle_naive(offsets, 4).len()
    });
    out.push(Speedup {
        name: "shuffle4_specialized_vs_generic",
        payload: "offsets",
        fast_mbps: fast.mbps(),
        reference_mbps: refr.mbps(),
    });

    // 4. BitWriter word flush vs byte-at-a-time flushing (pure bit I/O).
    let mut rng = Rng::new(0xB17);
    let tokens: Vec<(u64, u32)> = (0..100_000)
        .map(|_| {
            let w = rng.range(1, 48) as u32;
            (rng.next_u64() & ((1u64 << w) - 1), w)
        })
        .collect();
    let bits: usize = tokens.iter().map(|&(_, w)| w as usize).sum();
    let fast = bench("bitwriter-word", bits / 8, cfg, || {
        let mut w = BitWriter::with_capacity(bits / 8 + 8);
        for &(v, n) in &tokens {
            w.write_bits(v, n);
        }
        w.finish().len()
    });
    let refr = bench("bitwriter-naive", bits / 8, cfg, || {
        let mut w = NaiveBitWriter::new();
        for &(v, n) in &tokens {
            w.write_bits(v, n);
        }
        w.finish().len()
    });
    out.push(Speedup {
        name: "bitwriter_word_flush_vs_naive",
        payload: "random-tokens",
        fast_mbps: fast.mbps(),
        reference_mbps: refr.mbps(),
    });

    // 5. LZ4 wild-copy decode vs the Vec-growth naive decoder (PR 2) — the
    // paper's headline LZ4 property is decompression speed, so this is the
    // lane that matters most.
    let text = payload_by_name(&all, "text");
    for (payload, data) in [("text", text), ("nanoaod", nanoaod)] {
        let mut c = Lz4Fast::new();
        let mut blk = Vec::new();
        c.compress(data, 1, &mut blk);
        let mut scratch = Vec::new();
        let fast = bench("lz4-decode-fast", data.len(), cfg, || {
            rootio::lz4::decode::decompress_block_into(&blk, data.len(), &mut scratch).unwrap();
            scratch.len()
        });
        let refr = bench("lz4-decode-naive", data.len(), cfg, || {
            rootio::lz4::decode::reference::decompress_block_naive(&blk, &[], data.len())
                .unwrap()
                .len()
        });
        out.push(Speedup {
            name: "lz4_decode_wildcopy_vs_naive",
            payload,
            fast_mbps: fast.mbps(),
            reference_mbps: refr.mbps(),
        });
    }

    // 6. FSE interleaved dual-state encode/decode vs the single-symbol
    // naive coder (byte-identical streams).
    {
        let data = text;
        let hist = fse::histogram(data);
        let present = hist.iter().filter(|&&c| c > 0).count();
        let log = fse::optimal_table_log(data.len(), present, 11);
        let norm = fse::normalize_counts(&hist, data.len() as u64, log).expect("norm");
        let enc = fse::EncTable::new(&norm, log).expect("enc table");
        let dec = fse::DecTable::new(&norm, log).expect("dec table");
        let syms: Vec<u16> = data.iter().map(|&b| b as u16).collect();
        let fast = bench("fse-encode-fast", data.len(), cfg, || enc.encode_interleaved(&syms).0.len());
        let refr = bench("fse-encode-naive", data.len(), cfg, || {
            fse::reference::encode_interleaved_naive(&enc, &syms).0.len()
        });
        out.push(Speedup {
            name: "fse_encode_interleaved2_vs_naive",
            payload: "text",
            fast_mbps: fast.mbps(),
            reference_mbps: refr.mbps(),
        });
        let (payload_bits, states) = enc.encode_interleaved(&syms);
        let mut sym_buf: Vec<u16> = Vec::with_capacity(data.len());
        let fast = bench("fse-decode-fast", data.len(), cfg, || {
            sym_buf.clear();
            let mut r = BitReader::new(&payload_bits);
            dec.decode_interleaved(&mut r, states, data.len(), &mut sym_buf).unwrap();
            sym_buf.len()
        });
        let refr = bench("fse-decode-naive", data.len(), cfg, || {
            sym_buf.clear();
            let mut r = BitReader::new(&payload_bits);
            fse::reference::decode_interleaved_naive(&dec, &mut r, states, data.len(), &mut sym_buf)
                .unwrap();
            sym_buf.len()
        });
        out.push(Speedup {
            name: "fse_decode_interleaved2_vs_naive",
            payload: "text",
            fast_mbps: fast.mbps(),
            reference_mbps: refr.mbps(),
        });
    }

    // 6b. FSE quad-state interleave (PR 8): four independent ANS states
    // hide the state-update latency chain the dual-state coder still has.
    {
        let data = text;
        let hist = fse::histogram(data);
        let present = hist.iter().filter(|&&c| c > 0).count();
        let log = fse::optimal_table_log(data.len(), present, 11);
        let norm = fse::normalize_counts(&hist, data.len() as u64, log).expect("norm");
        let enc = fse::EncTable::new(&norm, log).expect("enc table");
        let dec = fse::DecTable::new(&norm, log).expect("dec table");
        let syms: Vec<u16> = data.iter().map(|&b| b as u16).collect();
        let fast = bench("fse4-encode-fast", data.len(), cfg, || enc.encode_interleaved4(&syms).0.len());
        let refr = bench("fse4-encode-naive", data.len(), cfg, || {
            fse::reference::encode_interleaved4_naive(&enc, &syms).0.len()
        });
        out.push(Speedup {
            name: "fse_encode_interleaved4_vs_naive",
            payload: "text",
            fast_mbps: fast.mbps(),
            reference_mbps: refr.mbps(),
        });
        let (payload_bits, states) = enc.encode_interleaved4(&syms);
        let mut sym_buf: Vec<u16> = Vec::with_capacity(data.len());
        let fast = bench("fse4-decode-fast", data.len(), cfg, || {
            sym_buf.clear();
            let mut r = BitReader::new(&payload_bits);
            dec.decode_interleaved4(&mut r, states, data.len(), &mut sym_buf).unwrap();
            sym_buf.len()
        });
        let refr = bench("fse4-decode-naive", data.len(), cfg, || {
            sym_buf.clear();
            let mut r = BitReader::new(&payload_bits);
            fse::reference::decode_interleaved4_naive(&dec, &mut r, states, data.len(), &mut sym_buf)
                .unwrap();
            sym_buf.len()
        });
        out.push(Speedup {
            name: "fse_decode_interleaved4_vs_naive",
            payload: "text",
            fast_mbps: fast.mbps(),
            reference_mbps: refr.mbps(),
        });
    }

    // 6c. Huff0-style 4-stream Huffman literals (PR 8) vs the retained
    // single-stream naive coder (byte-identical blobs).
    {
        let data = text;
        let fast = bench("huff0-compress-fast", data.len(), cfg, || {
            huff0::compress(data).expect("text compresses").len()
        });
        let refr = bench("huff0-compress-naive", data.len(), cfg, || {
            huff0::reference::compress_naive(data).expect("text compresses").len()
        });
        out.push(Speedup {
            name: "huff0_compress_4stream_vs_naive",
            payload: "text",
            fast_mbps: fast.mbps(),
            reference_mbps: refr.mbps(),
        });
        let blob = huff0::compress(data).expect("text compresses");
        let fast = bench("huff0-decompress-fast", data.len(), cfg, || {
            huff0::decompress(&blob, data.len()).unwrap().len()
        });
        let refr = bench("huff0-decompress-naive", data.len(), cfg, || {
            huff0::reference::decompress_naive(&blob, data.len()).unwrap().len()
        });
        out.push(Speedup {
            name: "huff0_decompress_4stream_vs_naive",
            payload: "text",
            fast_mbps: fast.mbps(),
            reference_mbps: refr.mbps(),
        });
    }

    // 7. 4-lane histogram vs scalar (feeds normalize_counts on every FSE
    // section build).
    let fast = bench("histogram-4lane", nanoaod.len(), cfg, || {
        fse::histogram(nanoaod)[0] as usize
    });
    let refr = bench("histogram-naive", nanoaod.len(), cfg, || {
        fse::reference::histogram_naive(nanoaod)[0] as usize
    });
    out.push(Speedup {
        name: "histogram_4lane_vs_naive",
        payload: "nanoaod",
        fast_mbps: fast.mbps(),
        reference_mbps: refr.mbps(),
    });

    // 8. Inflate fast loop (with PR-2 literal-run batching) vs the
    // careful-only reference decoder.
    {
        let t = Tuning::new(Flavor::Cloudflare, 6);
        let c = deflate(text, &t);
        let fast = bench("inflate-fast", text.len(), cfg, || {
            inflate(&c, text.len(), 64 << 20).unwrap().len()
        });
        let refr = bench("inflate-careful", text.len(), cfg, || {
            inflate_reference(&c, text.len(), 64 << 20).unwrap().len()
        });
        out.push(Speedup {
            name: "inflate_fastloop_litbatch_vs_careful",
            payload: "text",
            fast_mbps: fast.mbps(),
            reference_mbps: refr.mbps(),
        });
    }
    out
}

/// Entropy lanes (PR 8): raw coder throughput of the three RZS1 literal
/// entropy choices — dual-state FSE, quad-state FSE, and the 4-stream
/// Huff0 literals coder — on the NanoAOD workload and a high-entropy
/// noise slice. FSE table build happens outside the timer (tables are
/// per-section constants on the real path); huff0's blob necessarily
/// includes its own table build.
fn entropy_lanes(cfg: &BenchConfig) -> Vec<EntropyRow> {
    let all = payloads();
    let nanoaod = payload_by_name(&all, "nanoaod");
    let noise = payload_by_name(&all, "noise");
    // 128 KiB noise slice: keeps every huff0 stream segment below the
    // u16 jump-header limit even at ~8 bits/symbol.
    let lanes: [(&'static str, &[u8]); 2] = [("nanoaod", nanoaod), ("noise", &noise[..128 << 10])];
    let mut out = Vec::new();
    for (pname, data) in lanes {
        let hist = fse::histogram(data);
        let present = hist.iter().filter(|&&c| c > 0).count();
        let log = fse::optimal_table_log(data.len(), present, 11);
        let norm = fse::normalize_counts(&hist, data.len() as u64, log).expect("norm");
        let enc = fse::EncTable::new(&norm, log).expect("enc table");
        let dec = fse::DecTable::new(&norm, log).expect("dec table");
        let mut sym_buf: Vec<u16> = Vec::with_capacity(data.len());

        let (p2, s2) = enc.encode_interleaved(data);
        let e = bench("entropy-fse2-enc", data.len(), cfg, || enc.encode_interleaved(data).0.len());
        let d = bench("entropy-fse2-dec", data.len(), cfg, || {
            sym_buf.clear();
            dec.decode_interleaved(&mut BitReader::new(&p2), s2, data.len(), &mut sym_buf).unwrap();
            sym_buf.len()
        });
        out.push(EntropyRow {
            lane: "fse2",
            payload: pname,
            ratio: data.len() as f64 / p2.len() as f64,
            encode_mbps: e.mbps(),
            decode_mbps: d.mbps(),
        });

        let (p4, s4) = enc.encode_interleaved4(data);
        let e = bench("entropy-fse4-enc", data.len(), cfg, || enc.encode_interleaved4(data).0.len());
        let d = bench("entropy-fse4-dec", data.len(), cfg, || {
            sym_buf.clear();
            dec.decode_interleaved4(&mut BitReader::new(&p4), s4, data.len(), &mut sym_buf).unwrap();
            sym_buf.len()
        });
        out.push(EntropyRow {
            lane: "fse4",
            payload: pname,
            ratio: data.len() as f64 / p4.len() as f64,
            encode_mbps: e.mbps(),
            decode_mbps: d.mbps(),
        });

        let blob = huff0::compress(data).expect("entropy bench payload compresses");
        let e = bench("entropy-huff0-enc", data.len(), cfg, || huff0::compress(data).unwrap().len());
        let d = bench("entropy-huff0-dec", data.len(), cfg, || {
            huff0::decompress(&blob, data.len()).unwrap().len()
        });
        out.push(EntropyRow {
            lane: "huff0",
            payload: pname,
            ratio: data.len() as f64 / blob.len() as f64,
            encode_mbps: e.mbps(),
            decode_mbps: d.mbps(),
        });
    }
    out
}

/// End-to-end read-side scaling: decode a synthetic-NanoAOD tree file
/// through the serial oracle and through the parallel basket read pipeline
/// at 1/2/4 workers. Two representative settings: the paper's analysis
/// read lane (LZ4 + BitShuffle) and the balanced ZSTD lane.
fn read_pipeline_lanes(cfg: &BenchConfig) -> Vec<ReadRow> {
    use rootio::coordinator::{ParallelTreeReader, ReadAhead};
    use rootio::rfile::{write_tree_serial, TreeReader};
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_events = if quick { 1200 } else { 6000 };
    let mut out = Vec::new();
    for (tag, settings) in [
        ("lz4", Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4))),
        ("zstd", Settings::new(Algorithm::Zstd, 5)),
    ] {
        let path = std::env::temp_dir().join(format!(
            "rootio_bench_read_{}_{}.rfil",
            std::process::id(),
            tag
        ));
        let events = nanoaod::events(n_events, 0xBE7C);
        write_tree_serial(
            &path,
            "Events",
            nanoaod::schema(),
            settings,
            32 * 1024,
            events.iter().cloned(),
        )
        .expect("writing read-pipeline bench file");
        let bytes: usize = TreeReader::open(&path)
            .unwrap()
            .meta
            .baskets
            .iter()
            .map(|l| l.uncompressed_len as usize)
            .sum();
        let r = bench("read-serial", bytes, cfg, || {
            let mut reader = TreeReader::open(&path).unwrap();
            reader.read_all_events().unwrap().len()
        });
        out.push(ReadRow { setting: settings.label(), workers: 0, mbps: r.mbps() });
        for workers in [1usize, 2, 4] {
            let r = bench(&format!("read-{workers}w"), bytes, cfg, || {
                ParallelTreeReader::open(&path, ReadAhead::with_workers(workers))
                    .unwrap()
                    .read_all_events()
                    .unwrap()
                    .len()
            });
            out.push(ReadRow { setting: settings.label(), workers, mbps: r.mbps() });
        }
        std::fs::remove_file(&path).ok();
    }
    out
}

/// Columnar projection lanes: read k of 8 branches off a NanoAOD-like
/// LZ4+BitShuffle file (the paper's analysis read lane) three ways — k
/// independent serial `read_branch` sweeps (the pre-projection behaviour),
/// one offset-sorted projection pass, and the submission-order (branch-
/// major) projection baseline that quantifies what the seek-free sweep
/// buys. MB/s is uncompressed bytes of the *projected* branches only.
fn projection_lanes(cfg: &BenchConfig) -> Vec<ProjRow> {
    use rootio::coordinator::{ParallelTreeReader, PrefetchOrder, ProjectionPlan, ReadAhead};
    use rootio::rfile::{write_tree_serial, TreeReader};
    let branches8: [&str; 8] = [
        "Muon_pt", "Muon_eta", "Jet_pt", "Jet_eta", "nJet", "MET_pt", "HLT_IsoMu24", "event",
    ];
    const WORKERS: usize = 4;
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_events = if quick { 1200 } else { 6000 };
    let path = std::env::temp_dir().join(format!("rootio_bench_proj_{}.rfil", std::process::id()));
    let events = nanoaod::events(n_events, 0x920A);
    write_tree_serial(
        &path,
        "Events",
        nanoaod::schema(),
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        32 * 1024,
        events.iter().cloned(),
    )
    .expect("writing projection bench file");
    let mut out = Vec::new();
    for (tag, names) in [("2of8", &branches8[..2]), ("8of8", &branches8[..])] {
        let reader = TreeReader::open(&path).unwrap();
        let ids: Vec<u32> = names
            .iter()
            .map(|n| reader.branch_id(n).expect("bench branch in nanoaod schema"))
            .collect();
        let bytes: usize = reader
            .meta
            .baskets_for_branches(&ids)
            .iter()
            .map(|l| l.uncompressed_len as usize)
            .sum();
        let r = bench(&format!("proj-{tag}-serial"), bytes, cfg, || {
            let mut reader = TreeReader::open(&path).unwrap();
            let mut n = 0usize;
            for &id in &ids {
                n += reader.read_branch(id).unwrap().len();
            }
            n
        });
        out.push(ProjRow { branches: tag, order: "serial", workers: 0, mbps: r.mbps() });
        for (order_tag, order) in [
            ("offset", PrefetchOrder::FileOffset),
            ("submission", PrefetchOrder::Submission),
        ] {
            {
                let probe = ParallelTreeReader::open(&path, ReadAhead::with_workers(WORKERS)).unwrap();
                let plan = ProjectionPlan::new(&probe.meta, &ids, order).unwrap();
                if order == PrefetchOrder::FileOffset {
                    assert!(plan.is_monotonic_sweep(), "offset plan must be one forward sweep");
                }
            }
            // Symmetry with the serial lane (and read_pipeline_lanes): file
            // open + metadata parse + plan build all inside the timer on
            // both sides, so the lanes compare end-to-end read strategies,
            // not setup amortization.
            let r = bench(&format!("proj-{tag}-{order_tag}"), bytes, cfg, || {
                let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(WORKERS)).unwrap();
                let plan = ProjectionPlan::new(&par.meta, &ids, order).unwrap();
                par.project_plan(&plan).unwrap().read_columns().unwrap().len()
            });
            out.push(ProjRow { branches: tag, order: order_tag, workers: WORKERS, mbps: r.mbps() });
        }
    }
    std::fs::remove_file(&path).ok();
    out
}

/// Entry-range projection lanes: the same 2-branch NanoAOD projection read
/// over the whole tree vs its middle-50% entry slice, at both prefetch
/// orders. The slice's MB/s denominator is the *sliced plan's* logical
/// bytes (what the range actually decodes, boundary baskets included), so
/// the lanes expose per-byte cost of a partial read, not just its smaller
/// size — replan/distributed workloads read slices all day
/// (docs/BENCHMARKS.md §projection_range).
fn projection_range_lanes(cfg: &BenchConfig) -> Vec<ProjRangeRow> {
    use rootio::coordinator::{ParallelTreeReader, PrefetchOrder, ProjectionPlan, ReadAhead};
    use rootio::rfile::{write_tree_serial, TreeReader};
    let names = ["Muon_pt", "Muon_eta"];
    const WORKERS: usize = 4;
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_events = if quick { 1200 } else { 6000 };
    let path =
        std::env::temp_dir().join(format!("rootio_bench_projrange_{}.rfil", std::process::id()));
    let events = nanoaod::events(n_events, 0x5A1C);
    write_tree_serial(
        &path,
        "Events",
        nanoaod::schema(),
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        32 * 1024,
        events.iter().cloned(),
    )
    .expect("writing projection-range bench file");
    let reader = TreeReader::open(&path).unwrap();
    let ids: Vec<u32> = names
        .iter()
        .map(|n| reader.branch_id(n).expect("bench branch in nanoaod schema"))
        .collect();
    let n = reader.meta.n_entries;
    let mut out = Vec::new();
    for (range_tag, (a, b)) in [("full", (0, n)), ("mid50", (n / 4, n / 4 + n / 2))] {
        for (order_tag, order) in [
            ("offset", PrefetchOrder::FileOffset),
            ("submission", PrefetchOrder::Submission),
        ] {
            let probe = ProjectionPlan::new(&reader.meta, &ids, order).unwrap().slice(a, b);
            if order == PrefetchOrder::FileOffset {
                assert!(probe.is_monotonic_sweep(), "sliced offset plan must stay one sweep");
            }
            let bytes = probe.logical_bytes() as usize;
            // File open + plan build + slice inside the timer, matching
            // the projection lanes: end-to-end read strategy comparison.
            let r = bench(&format!("projrange-{range_tag}-{order_tag}"), bytes, cfg, || {
                let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(WORKERS)).unwrap();
                let plan = ProjectionPlan::new(&par.meta, &ids, order).unwrap().slice(a, b);
                par.project_plan(&plan).unwrap().read_columns().unwrap().len()
            });
            out.push(ProjRangeRow {
                range: range_tag,
                order: order_tag,
                workers: WORKERS,
                mbps: r.mbps(),
            });
        }
    }
    std::fs::remove_file(&path).ok();
    out
}

/// Concurrent serving lanes: waves of 1 / 8 / 64 identical all-branch
/// queries over a two-file NanoAOD corpus through the scan server, cold
/// (fresh server, empty cache) then warm (identical wave, populated
/// cache). Aggregate MB/s is the wave's total uncompressed bytes over its
/// wall time; p99 is per-query latency. Every query's prefetch plan is
/// asserted to be one monotonic offset sweep — concurrency must not cost
/// the seek-free property (docs/BENCHMARKS.md §concurrent).
fn concurrent_lanes() -> Vec<ConcRow> {
    use rootio::coordinator::{Query, ScanServer, ServeConfig};
    use rootio::rfile::write_tree_serial;
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_events = if quick { 1200 } else { 6000 };
    let dir = std::env::temp_dir().join(format!("rootio_bench_conc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench corpus dir");
    let mut paths = Vec::new();
    for (i, name) in ["a", "b"].iter().enumerate() {
        let path = dir.join(format!("nanoaod_{name}.rfil"));
        let events = nanoaod::events(n_events, 0xC0C0 + i as u64);
        write_tree_serial(
            &path,
            "Events",
            nanoaod::schema(),
            Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
            32 * 1024,
            events.iter().cloned(),
        )
        .expect("writing concurrent bench corpus");
        paths.push(path);
    }
    let mut out = Vec::new();
    for queries in [1usize, 8, 64] {
        // Fresh server per lane so "cold" is actually cold.
        let server = ScanServer::from_paths(&paths, ServeConfig::default()).expect("scan server");
        let names: Vec<String> =
            server.files().iter().map(|f| f.name.clone()).collect();
        let mut wave = |cache: &'static str| {
            let t0 = std::time::Instant::now();
            let mut bytes = 0u64;
            let mut lats: Vec<f64> = Vec::with_capacity(queries);
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..queries)
                    .map(|i| {
                        let file = names[i % names.len()].clone();
                        let server = &server;
                        scope.spawn(move || {
                            let q0 = std::time::Instant::now();
                            let mut sq = server.query(&Query::all(&file)).expect("query");
                            assert!(
                                sq.plan().is_monotonic_sweep(),
                                "concurrent plan must stay one forward sweep"
                            );
                            let logical = sq.plan().logical_bytes();
                            sq.read_columns().expect("concurrent read");
                            (logical, q0.elapsed().as_secs_f64())
                        })
                    })
                    .collect();
                for h in handles {
                    let (b, lat) = h.join().expect("bench query thread");
                    bytes += b;
                    lats.push(lat);
                }
            });
            let wall = t0.elapsed().as_secs_f64();
            lats.sort_by(|a, b| a.total_cmp(b));
            let p99 = lats[((lats.len() as f64 * 0.99).ceil() as usize).clamp(1, lats.len()) - 1];
            out.push(ConcRow {
                queries,
                cache,
                mbps: bytes as f64 / 1e6 / wall,
                p99_ms: p99 * 1e3,
            });
        };
        wave("cold");
        wave("warm");
    }
    std::fs::remove_dir_all(&dir).ok();
    out
}

/// Closing the adaptive loop end-to-end: write a production-style source
/// (zlib-6, 32 KiB baskets), record an analysis-style profile against it
/// (the hot kinematics subset scanned repeatedly, everything else once),
/// `repack_file` under that profile, and measure file size plus full-tree
/// and hot-subset read throughput on both sides. docs/REPACK.md's
/// before/after table is this lane.
fn repack_lanes(cfg: &BenchConfig) -> Vec<RepackRow> {
    use rootio::coordinator::repack::{repack_file, RepackOptions};
    use rootio::coordinator::{ParallelTreeReader, ReadAhead};
    use rootio::rfile::write_tree_serial;
    use rootio::runtime::ReadFeedback;
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_events = if quick { 1500 } else { 8000 };
    let hot: [&str; 4] = ["Muon_pt", "Muon_eta", "MET_pt", "nMuon"];
    let dir = std::env::temp_dir();
    let src = dir.join(format!("rootio_bench_repack_src_{}.rfil", std::process::id()));
    let dst = dir.join(format!("rootio_bench_repack_dst_{}.rfil", std::process::id()));
    let events = nanoaod::events(n_events, 0x9A7);
    write_tree_serial(
        &src,
        "Events",
        nanoaod::schema(),
        Settings::new(Algorithm::Zlib, 6),
        32 * 1024,
        events.iter().cloned(),
    )
    .expect("writing repack bench source");

    // The profile the repack is steered by: nine hot-subset scans plus one
    // full scan — intensity ~1 on the hot branches, ~0.1 on the rest.
    let reader = ParallelTreeReader::open(&src, ReadAhead::with_workers(2)).expect("open source");
    let mut profile = ReadFeedback::new();
    for _ in 0..9 {
        let mut proj = reader.project(&hot).expect("hot projection");
        proj.read_columns().expect("hot scan");
        profile.record_scan(proj.branch_stats());
    }
    let mut full = reader.project_all_range(0..reader.meta.n_entries).expect("full projection");
    full.read_columns().expect("full scan");
    profile.record_scan(full.branch_stats());
    drop(full);
    drop(reader);

    let opts = RepackOptions { profile: Some(profile), ..RepackOptions::default() };
    let report = repack_file(&src, &dst, &opts).expect("repack under recorded profile");
    assert_eq!(report.n_entries_out, n_events as u64, "repack must keep every entry");

    let mut out = Vec::new();
    for (lane, path) in [("before", &src), ("after", &dst)] {
        let file_bytes = std::fs::metadata(path).expect("bench file size").len();
        let reader = ParallelTreeReader::open(path, ReadAhead::with_workers(2)).expect("open");
        let logical: usize =
            reader.meta.baskets.iter().map(|l| l.uncompressed_len as usize).sum();
        let full = bench(&format!("repack-{lane}-full"), logical, cfg, || {
            reader.read_all_events().expect("full read").len()
        });
        let hot_ids: Vec<u32> = hot
            .iter()
            .map(|n| reader.branch_id(n).expect("hot branch in nanoaod schema"))
            .collect();
        let hot_logical: usize = reader
            .meta
            .baskets_for_branches(&hot_ids)
            .iter()
            .map(|l| l.uncompressed_len as usize)
            .sum();
        let hot_r = bench(&format!("repack-{lane}-hot"), hot_logical, cfg, || {
            let mut proj = reader.project(&hot).expect("hot projection");
            proj.read_columns().expect("hot read").len()
        });
        out.push(RepackRow { lane, file_bytes, read_mbps: full.mbps(), hot_mbps: hot_r.mbps() });
    }
    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&dst).ok();
    out
}

/// I/O backend lanes (PR 10). Two questions, one corpus:
///
///  * how many physical reads does one full-tree sweep cost on each
///    local backend (pread's 2-per-record floor vs coalesced merge
///    groups vs the one-time mmap image load), and
///  * on the simulated remote store, how much of a fixed per-request
///    latency does prefetch depth hide — the latency × depth surface
///    docs/BENCHMARKS.md plots.
///
/// Small (8 KiB) baskets on purpose: the sweep must carry enough
/// records that both coalescing and the remote pipeline window have
/// something to batch.
fn io_backend_lanes() -> Vec<IoRow> {
    use rootio::coordinator::{ParallelTreeReader, ReadAhead};
    use rootio::rfile::{write_tree_serial, IoBackend, IoConfig};
    use std::time::Duration;
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_events = if quick { 1500 } else { 6000 };
    let path = std::env::temp_dir().join(format!("rootio_bench_io_{}.rfil", std::process::id()));
    let events = nanoaod::events(n_events, 0x10BE);
    write_tree_serial(
        &path,
        "Events",
        nanoaod::schema(),
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        8 * 1024,
        events.iter().cloned(),
    )
    .expect("writing io bench corpus");

    let mut out = Vec::new();
    // One timed sweep per lane, not bench()'s repeat-until-stable loop:
    // the remote lanes are dominated by the simulated wire, which is
    // deterministic by construction, so repetition would only multiply
    // the sleeping without tightening the estimate.
    let mut sweep = |backend: IoBackend, latency: Duration, depth: usize| {
        let mut io = IoConfig::for_backend(backend);
        io.latency = latency;
        let reader = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth })
            .expect("open io bench corpus")
            .with_io(io);
        let logical: usize =
            reader.meta.baskets.iter().map(|l| l.uncompressed_len as usize).sum();
        let t0 = std::time::Instant::now();
        let n = reader.read_all_events().expect("io backend sweep").len();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(n, n_events, "io sweep dropped events ({backend})");
        out.push(IoRow {
            backend: backend.as_str(),
            latency_ms: latency.as_millis() as u64,
            depth,
            reads: reader.metrics_snapshot().io_syscalls,
            mbps: logical as f64 / 1e6 / wall,
        });
    };
    for backend in [IoBackend::Pread, IoBackend::Coalesced, IoBackend::Mmap] {
        sweep(backend, Duration::ZERO, 8);
    }
    for latency_ms in [0u64, 1, 10] {
        for depth in [2usize, 8, 32] {
            sweep(IoBackend::RemoteSim, Duration::from_millis(latency_ms), depth);
        }
    }
    std::fs::remove_file(&path).ok();
    out
}

#[allow(clippy::too_many_arguments)] // one slice per schema section, called once
fn write_json(
    rows: &[Row],
    speedups: &[Speedup],
    entropy: &[EntropyRow],
    reads: &[ReadRow],
    projections: &[ProjRow],
    projection_ranges: &[ProjRangeRow],
    concurrent: &[ConcRow],
    repack: &[RepackRow],
    io: &[IoRow],
    quick: bool,
) -> std::io::Result<()> {
    let result_items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"payload\": \"{}\", \"setting\": \"{}\", \"codec\": \"{}\", \"level\": {}, \"precond\": \"{}\", \"ratio\": {}, \"compress_MBps\": {}, \"decompress_MBps\": {}}}",
                json_escape(r.payload),
                json_escape(&r.setting.label()),
                json_escape(r.setting.algorithm.label()),
                r.setting.level,
                json_escape(&r.setting.precond.label()),
                json_num(r.ratio),
                json_num(r.compress_mbps),
                json_num(r.decompress_mbps),
            )
        })
        .collect();
    let speedup_items: Vec<String> = speedups
        .iter()
        .map(|s| {
            format!(
                "{{\"name\": \"{}\", \"payload\": \"{}\", \"fast_MBps\": {}, \"reference_MBps\": {}, \"speedup\": {}}}",
                json_escape(s.name),
                json_escape(s.payload),
                json_num(s.fast_mbps),
                json_num(s.reference_mbps),
                json_num(s.fast_mbps / s.reference_mbps),
            )
        })
        .collect();
    let entropy_items: Vec<String> = entropy
        .iter()
        .map(|e| {
            format!(
                "{{\"lane\": \"{}\", \"payload\": \"{}\", \"ratio\": {}, \"encode_MBps\": {}, \"decode_MBps\": {}}}",
                json_escape(e.lane),
                json_escape(e.payload),
                json_num(e.ratio),
                json_num(e.encode_mbps),
                json_num(e.decode_mbps),
            )
        })
        .collect();
    let read_items: Vec<String> = reads
        .iter()
        .map(|r| {
            format!(
                "{{\"setting\": \"{}\", \"workers\": {}, \"MBps\": {}}}",
                json_escape(&r.setting),
                r.workers,
                json_num(r.mbps),
            )
        })
        .collect();
    let proj_items: Vec<String> = projections
        .iter()
        .map(|p| {
            format!(
                "{{\"branches\": \"{}\", \"order\": \"{}\", \"workers\": {}, \"MBps\": {}}}",
                json_escape(p.branches),
                json_escape(p.order),
                p.workers,
                json_num(p.mbps),
            )
        })
        .collect();
    let proj_range_items: Vec<String> = projection_ranges
        .iter()
        .map(|p| {
            format!(
                "{{\"range\": \"{}\", \"order\": \"{}\", \"workers\": {}, \"MBps\": {}}}",
                json_escape(p.range),
                json_escape(p.order),
                p.workers,
                json_num(p.mbps),
            )
        })
        .collect();
    let conc_items: Vec<String> = concurrent
        .iter()
        .map(|c| {
            format!(
                "{{\"queries\": {}, \"cache\": \"{}\", \"MBps\": {}, \"p99_ms\": {}}}",
                c.queries,
                json_escape(c.cache),
                json_num(c.mbps),
                json_num(c.p99_ms),
            )
        })
        .collect();
    let repack_items: Vec<String> = repack
        .iter()
        .map(|r| {
            format!(
                "{{\"lane\": \"{}\", \"file_bytes\": {}, \"read_MBps\": {}, \"hot_MBps\": {}}}",
                json_escape(r.lane),
                r.file_bytes,
                json_num(r.read_mbps),
                json_num(r.hot_mbps),
            )
        })
        .collect();
    let io_items: Vec<String> = io
        .iter()
        .map(|r| {
            format!(
                "{{\"backend\": \"{}\", \"latency_ms\": {}, \"depth\": {}, \"reads\": {}, \"MBps\": {}}}",
                json_escape(r.backend),
                r.latency_ms,
                r.depth,
                r.reads,
                json_num(r.mbps),
            )
        })
        .collect();
    let doc = format!(
        "{{\n  \"schema\": \"bench-codecs/v8\",\n  \"generated_by\": \"cargo bench --bench codecs\",\n  \"quick_mode\": {},\n  \"corpus\": \"offsets/floats/text/noise + synthetic NanoAOD baskets\",\n  \"results\": {},\n  \"fast_path_speedups\": {},\n  \"entropy\": {},\n  \"read_pipeline\": {},\n  \"projection\": {},\n  \"projection_range\": {},\n  \"concurrent\": {},\n  \"repack\": {},\n  \"io_backends\": {}\n}}\n",
        quick,
        json_array(&result_items, "  "),
        json_array(&speedup_items, "  "),
        json_array(&entropy_items, "  "),
        json_array(&read_items, "  "),
        json_array(&proj_items, "  "),
        json_array(&proj_range_items, "  "),
        json_array(&conc_items, "  "),
        json_array(&repack_items, "  "),
        json_array(&io_items, "  "),
    );
    // Land next to Cargo.toml (the repo root) regardless of CWD.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_codecs.json");
    std::fs::write(path, doc)?;
    println!("wrote {path}");
    Ok(())
}

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("BENCH_QUICK").is_ok();

    let rows = codec_grid(&cfg);
    let mut table = Table::new(&["payload", "setting", "ratio", "compress_MB_s", "decompress_MB_s"]);
    for r in &rows {
        table.row(vec![
            r.payload.into(),
            r.setting.label(),
            format!("{:.3}", r.ratio),
            format!("{:.1}", r.compress_mbps),
            format!("{:.1}", r.decompress_mbps),
        ]);
    }
    println!("{}", table.render());
    table.save_csv("codecs").unwrap();

    // Preconditioner transform throughput (hot path on both write & read);
    // keeps results/precond.csv's historical [transform, MB_s] columns.
    let data = payloads().swap_remove(0).1;
    let mut t2 = Table::new(&["transform", "MB_s"]);
    for (name, f) in [
        ("shuffle4-fwd", Box::new(|d: &[u8]| precond::shuffle(d, 4)) as Box<dyn Fn(&[u8]) -> Vec<u8>>),
        ("shuffle4-inv", Box::new(|d: &[u8]| precond::unshuffle(d, 4))),
        ("bitshuffle4-fwd", Box::new(|d: &[u8]| precond::bitshuffle(d, 4))),
        ("bitshuffle4-inv", Box::new(|d: &[u8]| precond::unbitshuffle(d, 4))),
        ("delta4-fwd", Box::new(|d: &[u8]| precond::delta(d, 4))),
    ] {
        let r = bench(name, data.len(), &cfg, || f(&data).len());
        t2.row(vec![name.into(), format!("{:.0}", r.mbps())]);
    }
    println!("{}", t2.render());
    t2.save_csv("precond").unwrap();

    let speedups = fast_path_speedups(&cfg);
    let mut t3 = Table::new(&["fast path", "payload", "fast_MB_s", "reference_MB_s", "speedup"]);
    for s in &speedups {
        t3.row(vec![
            s.name.into(),
            s.payload.into(),
            format!("{:.1}", s.fast_mbps),
            format!("{:.1}", s.reference_mbps),
            format!("{:.2}x", s.fast_mbps / s.reference_mbps),
        ]);
    }
    println!("{}", t3.render());
    t3.save_csv("fastpath").unwrap();

    // Entropy lanes: fse2 vs fse4 vs huff0 coder throughput (PR 8).
    let entropy = entropy_lanes(&cfg);
    let mut t3b = Table::new(&["lane", "payload", "ratio", "encode_MB_s", "decode_MB_s"]);
    for e in &entropy {
        t3b.row(vec![
            e.lane.into(),
            e.payload.into(),
            format!("{:.3}", e.ratio),
            format!("{:.1}", e.encode_mbps),
            format!("{:.1}", e.decode_mbps),
        ]);
    }
    println!("{}", t3b.render());
    t3b.save_csv("entropy").unwrap();

    // Read-pipeline scaling: serial oracle vs 1/2/4 decode workers.
    let reads = read_pipeline_lanes(&cfg);
    let mut t4 = Table::new(&["setting", "workers", "read_MB_s"]);
    for r in &reads {
        t4.row(vec![
            r.setting.clone(),
            if r.workers == 0 { "serial".into() } else { format!("{}", r.workers) },
            format!("{:.1}", r.mbps),
        ]);
    }
    println!("{}", t4.render());
    t4.save_csv("read_pipeline").unwrap();

    // Columnar projection: 2-of-8 / 8-of-8 branch reads, serial vs
    // offset-sorted vs submission-order prefetch.
    let projections = projection_lanes(&cfg);
    let mut t5 = Table::new(&["projection", "order", "workers", "read_MB_s"]);
    for p in &projections {
        t5.row(vec![
            p.branches.into(),
            p.order.into(),
            if p.workers == 0 { "serial".into() } else { format!("{}", p.workers) },
            format!("{:.1}", p.mbps),
        ]);
    }
    println!("{}", t5.render());
    t5.save_csv("projection").unwrap();

    // Entry-range projection: full tree vs middle-50% slice, both
    // prefetch orders.
    let projection_ranges = projection_range_lanes(&cfg);
    let mut t6 = Table::new(&["range", "order", "workers", "read_MB_s"]);
    for p in &projection_ranges {
        t6.row(vec![
            p.range.into(),
            p.order.into(),
            format!("{}", p.workers),
            format!("{:.1}", p.mbps),
        ]);
    }
    println!("{}", t6.render());
    t6.save_csv("projection_range").unwrap();

    // Concurrent serving: 1/8/64-query waves, cold vs warm cache.
    let concurrent = concurrent_lanes();
    let mut t7 = Table::new(&["queries", "cache", "aggregate_MB_s", "p99_ms"]);
    for c in &concurrent {
        t7.row(vec![
            format!("{}", c.queries),
            c.cache.into(),
            format!("{:.1}", c.mbps),
            format!("{:.2}", c.p99_ms),
        ]);
    }
    println!("{}", t7.render());
    t7.save_csv("concurrent").unwrap();

    // Profile-driven repack: file size + read throughput before/after
    // rewriting under a recorded analysis-style profile.
    let repack = repack_lanes(&cfg);
    let mut t8 = Table::new(&["lane", "file_KB", "full_read_MB_s", "hot_read_MB_s"]);
    for r in &repack {
        t8.row(vec![
            r.lane.into(),
            format!("{:.1}", r.file_bytes as f64 / 1024.0),
            format!("{:.1}", r.read_mbps),
            format!("{:.1}", r.hot_mbps),
        ]);
    }
    println!("{}", t8.render());
    t8.save_csv("repack").unwrap();

    // I/O backends: physical reads per sweep, plus the remote-sim
    // latency × prefetch-depth surface.
    let io = io_backend_lanes();
    let mut t9 = Table::new(&["backend", "latency_ms", "depth", "reads", "read_MB_s"]);
    for r in &io {
        t9.row(vec![
            r.backend.into(),
            format!("{}", r.latency_ms),
            format!("{}", r.depth),
            format!("{}", r.reads),
            format!("{:.1}", r.mbps),
        ]);
    }
    println!("{}", t9.render());
    t9.save_csv("io_backends").unwrap();

    write_json(&rows, &speedups, &entropy, &reads, &projections, &projection_ranges, &concurrent, &repack, &io, quick)
        .expect("writing BENCH_codecs.json");
}
