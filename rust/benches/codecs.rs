//! `cargo bench --bench codecs` — microbenchmarks of the codec substrates:
//! per-codec compress/decompress on canonical payload classes, plus the
//! preconditioner transforms themselves. These are the profiling anchors
//! for the §Perf optimization pass.

use rootio::bench::{bench, BenchConfig, Table};
use rootio::compression::{Algorithm, Engine, Settings};
use rootio::precond;
use rootio::util::rng::Rng;

fn payloads() -> Vec<(&'static str, Vec<u8>)> {
    let mut rng = Rng::new(0xC0DEC);
    let mut v: Vec<(&'static str, Vec<u8>)> = Vec::new();
    // Offset-array class (Fig 6 pathology).
    v.push(("offsets", (1u32..=65_536).flat_map(|i| i.to_be_bytes()).collect()));
    // Serialized floats (kinematics).
    v.push((
        "floats",
        (0..65_536).flat_map(|i| ((i as f32 * 0.37).sin() * 50.0).to_be_bytes()).collect(),
    ));
    // Text-ish (labels / json-like).
    let mut text = Vec::new();
    while text.len() < 256 * 1024 {
        text.extend_from_slice(b"\"Muon_pt\": [31.4, 17.2], \"HLT_IsoMu24\": true, ");
    }
    v.push(("text", text));
    // Incompressible.
    v.push(("noise", rng.bytes(256 * 1024)));
    v
}

fn main() {
    let cfg = BenchConfig::from_env();
    let mut engine = Engine::new();
    let mut table = Table::new(&["payload", "setting", "ratio", "compress_MB_s", "decompress_MB_s"]);
    for (pname, data) in payloads() {
        for s in [
            Settings::new(Algorithm::Zlib, 6),
            Settings::new(Algorithm::CfZlib, 6),
            Settings::new(Algorithm::Lz4, 1),
            Settings::new(Algorithm::Zstd, 5),
            Settings::new(Algorithm::Lzma, 6),
            Settings::new(Algorithm::OldRoot, 6),
        ] {
            let c = engine.compress(&data, &s);
            let rc = bench("c", data.len(), &cfg, || engine.compress(&data, &s).len());
            let rd = bench("d", data.len(), &cfg, || engine.decompress(&c).unwrap().len());
            table.row(vec![
                pname.into(),
                s.label(),
                format!("{:.3}", data.len() as f64 / c.len() as f64),
                format!("{:.1}", rc.mbps()),
                format!("{:.1}", rd.mbps()),
            ]);
        }
    }
    println!("{}", table.render());
    table.save_csv("codecs").unwrap();

    // Preconditioner transform throughput (hot path on both write & read).
    let mut t2 = Table::new(&["transform", "MB_s"]);
    let data = payloads().swap_remove(0).1;
    for (name, f) in [
        ("shuffle4-fwd", Box::new(|d: &[u8]| precond::shuffle(d, 4)) as Box<dyn Fn(&[u8]) -> Vec<u8>>),
        ("shuffle4-inv", Box::new(|d: &[u8]| precond::unshuffle(d, 4))),
        ("bitshuffle4-fwd", Box::new(|d: &[u8]| precond::bitshuffle(d, 4))),
        ("bitshuffle4-inv", Box::new(|d: &[u8]| precond::unbitshuffle(d, 4))),
        ("delta4-fwd", Box::new(|d: &[u8]| precond::delta(d, 4))),
    ] {
        let r = bench(name, data.len(), &cfg, || f(&data).len());
        t2.row(vec![name.into(), format!("{:.0}", r.mbps())]);
    }
    println!("{}", t2.render());
    t2.save_csv("precond").unwrap();
}
