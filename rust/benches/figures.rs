// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! `cargo bench --bench figures` — regenerates every paper figure
//! (Fig 2-6 + the dict study + pipeline scaling). Set BENCH_QUICK=1 for a
//! fast smoke run. CSVs land in results/.

use rootio::bench::figures::run_figure;
use rootio::bench::BenchConfig;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let all = ["fig2", "fig3", "fig4", "fig5", "fig6", "dict", "scaling"];
    let wanted: Vec<&str> = if args.is_empty() {
        all.to_vec()
    } else {
        all.iter().copied().filter(|n| args.iter().any(|a| a == n)).collect()
    };
    let cfg = BenchConfig::from_env();
    for name in wanted {
        match run_figure(name, &cfg) {
            Ok((out, _)) => println!("== {name} ==\n{out}\n"),
            Err(e) => {
                eprintln!("{name} failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
