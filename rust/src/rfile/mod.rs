//! ROOT-like columnar file format: keyed container (TFile/TKey analogue),
//! trees with typed branches and baskets (TTree/TBranch/TBasket), per-branch
//! compression settings, and the serialized offset arrays for variable-size
//! branches that drive the paper's Fig 6.

pub mod basket;
pub mod branch;
pub mod format;
pub mod meta;
pub mod reader;
pub mod scrub;
pub mod source;
pub mod writer;

pub use basket::{BasketContent, PendingBasket};
pub use branch::{BranchDef, BranchType, Value};
pub use meta::{push_gap, BasketLoc, GapSpan, TreeMeta};
pub use reader::TreeReader;
pub use scrub::{scrub_file, DamageKind, ScrubFinding, ScrubReport};
pub use source::{
    compose_chain, read_full_at, read_record_from, CoalescedSource, CountingSource, FaultSource,
    FaultSpec, FaultStats, FileId, FileSource, IoBackend, IoConfig, IoStats, MmapSource,
    RangeSource, RemotePacing, RemoteSource, RemoteSpec, RetryPolicy, RetrySource, SourceChain,
    SourceError,
};
pub use writer::{
    frame_basket_record, frame_basket_record_prefix, write_tree_serial, BasketSink, RecordWriter,
    SerialSink, TreeWriter,
    DEFAULT_BASKET_SIZE,
};
