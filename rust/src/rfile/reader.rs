//! Tree reader: opens an RFIL file, loads the metadata, and decompresses
//! baskets on demand — the read path whose decompression cost is the
//! paper's Fig 3 (and the reason analysis use cases prefer LZ4).

use super::basket::{decode_basket, BasketContent};
use super::branch::{BranchType, Value};
use super::format::{self, RecordKind};
use super::meta::{BasketLoc, TreeMeta};
use super::source::{read_record_from, FileSource};
use crate::compression::Engine;
use crate::util::varint::Cursor;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::BufReader;
use std::path::Path;

/// An open tree file (serial read path — the byte-identity oracle for the
/// parallel reader in [`crate::coordinator::read_pipeline`]).
///
/// ```
/// use rootio::compression::{Algorithm, Settings};
/// use rootio::gen::synthetic;
/// use rootio::rfile::{write_tree_serial, TreeReader};
///
/// let path = std::env::temp_dir().join(format!("rootio_doc_reader_{}.rfil", std::process::id()));
/// let events = synthetic::events(100, 1);
/// write_tree_serial(&path, "Events", synthetic::schema(),
///                   Settings::new(Algorithm::Zstd, 5), 4096, events.iter().cloned()).unwrap();
///
/// let mut reader = TreeReader::open(&path).unwrap();
/// assert_eq!(reader.meta.n_entries, 100);
/// assert_eq!(reader.read_all_events().unwrap(), events);
/// std::fs::remove_file(&path).ok();
/// ```
pub struct TreeReader {
    /// Basket reads go through the
    /// [`RangeSource`](crate::rfile::source::RangeSource) seam
    /// ([`crate::rfile::source`]); the serial reader always rides a plain
    /// [`FileSource`] — no retries, no fault injection — which keeps it an
    /// unambiguous oracle for the fault-tolerant pipeline.
    source: FileSource,
    path: std::path::PathBuf,
    pub meta: TreeMeta,
    engine: Engine,
}

impl TreeReader {
    /// Open an RFIL file: validate the header, locate the metadata record
    /// via the trailer, and load the dictionary blob if the tree carries
    /// one. Rejects non-RFIL files and unsupported container versions.
    pub fn open(path: &Path) -> Result<Self> {
        let f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut file = BufReader::new(f);
        format::read_header(&mut file)?;
        let meta_off = format::read_trailer(&mut file)?;
        let (kind, payload) = format::read_record_at(&mut file, meta_off)?;
        if kind != RecordKind::TreeMeta {
            bail!("trailer does not point at tree metadata");
        }
        let meta = TreeMeta::deserialize(&payload)?;
        let mut engine = Engine::new();
        // Load the dictionary blob if the tree carries one.
        if let Some(doff) = meta.dictionary_offset {
            let (k, dict) = format::read_record_at(&mut file, doff)?;
            if k != RecordKind::Dictionary {
                bail!("dictionary offset does not point at a dictionary record");
            }
            engine.set_dictionary(dict);
        }
        // The open phase is sequential (header → trailer → directory), so
        // it buffers; basket reads are positioned, so the handle drops the
        // buffer and becomes a RangeSource.
        let source = FileSource::from_file(file.into_inner(), path)?;
        Ok(Self { source, path: path.to_path_buf(), meta, engine })
    }

    /// The dictionary blob the tree carries (empty if none) — shared with
    /// the parallel reader so both paths decode identically.
    pub fn dictionary(&self) -> &[u8] {
        self.engine.dictionary()
    }

    /// Upgrade to the multi-worker read pipeline: prefetched raw baskets,
    /// parallel decompression, in-order delivery. The metadata and
    /// dictionary already parsed by this reader are reused; this serial
    /// reader stays valid (and is the oracle the pipeline is tested
    /// against).
    pub fn read_ahead(&self, config: crate::coordinator::ReadAhead) -> crate::coordinator::ParallelTreeReader {
        crate::coordinator::ParallelTreeReader::from_parts(
            self.path.clone(),
            self.meta.clone(),
            self.dictionary().to_vec(),
            config,
        )
    }

    /// Project a subset of branches through the parallel pipeline: one
    /// offset-sorted pass over the file, per-branch event-order columns or
    /// aligned row batches. Convenience for
    /// [`read_ahead`](TreeReader::read_ahead) followed by
    /// [`ParallelTreeReader::project`](crate::coordinator::ParallelTreeReader::project).
    pub fn project(
        &self,
        branches: &[&str],
        config: crate::coordinator::ReadAhead,
    ) -> Result<crate::coordinator::ProjectionReader> {
        self.read_ahead(config).project(branches)
    }

    pub fn branch_id(&self, name: &str) -> Option<u32> {
        self.meta.branch_id(name)
    }

    /// Basket directory for one branch (ordered by basket_index).
    pub fn baskets_for(&self, branch_id: u32) -> Vec<BasketLoc> {
        self.meta.baskets_for(branch_id)
    }

    /// Read + decompress one basket.
    pub fn read_basket(&mut self, loc: &BasketLoc) -> Result<BasketContent> {
        let mut payload = Vec::new();
        let kind = read_record_from(&mut self.source, loc.file_offset, &mut payload)
            .with_context(|| {
                format!(
                    "basket {} of branch id {} at file offset {}",
                    loc.basket_index, loc.branch_id, loc.file_offset
                )
            })?;
        if kind != RecordKind::Basket {
            bail!("expected basket record at {}", loc.file_offset);
        }
        let mut c = Cursor::new(&payload);
        let branch_id = c.uvarint().context("basket branch id")? as u32;
        let basket_index = c.uvarint().context("basket index")? as u32;
        if branch_id != loc.branch_id || basket_index != loc.basket_index {
            bail!(
                "basket identity mismatch: found ({branch_id},{basket_index}), expected ({},{})",
                loc.branch_id,
                loc.basket_index
            );
        }
        let content = decode_basket(&payload[c.pos()..], &mut self.engine)
            .map_err(|e| anyhow::anyhow!("basket decode: {e}"))?;
        if content.n_entries != loc.n_entries {
            bail!("basket entry count mismatch");
        }
        Ok(content)
    }

    /// Read an entire branch back as per-entry values.
    pub fn read_branch(&mut self, branch_id: u32) -> Result<Vec<Value>> {
        let ty = self
            .meta
            .branches
            .get(branch_id as usize)
            .ok_or_else(|| anyhow::anyhow!("no branch {branch_id}"))?
            .ty;
        let locs = self.baskets_for(branch_id);
        let mut out = Vec::with_capacity(self.meta.n_entries as usize);
        for loc in &locs {
            let content = self.read_basket(loc)?;
            decode_values(&content, ty, &mut out)?;
        }
        if out.len() as u64 != self.meta.n_entries {
            bail!(
                "branch {branch_id}: {} entries decoded, tree has {}",
                out.len(),
                self.meta.n_entries
            );
        }
        Ok(out)
    }

    /// Read one branch over the entry window `[range.start, range.end)`
    /// only: decode just the baskets whose entry spans overlap the window
    /// (per-basket spans come from the directory — no wire change) and
    /// trim head/tail rows of boundary baskets, so the result equals
    /// [`read_branch`](TreeReader::read_branch) followed by an in-memory
    /// slice. The range is clamped to the tree: past-EOF and empty windows
    /// yield zero values, not errors. This is the serial oracle for the
    /// pipelined range reads
    /// ([`ParallelTreeReader::read_range`](crate::coordinator::ParallelTreeReader::read_range),
    /// [`ParallelTreeReader::project_range`](crate::coordinator::ParallelTreeReader::project_range)).
    pub fn read_range(&mut self, branch_id: u32, range: std::ops::Range<u64>) -> Result<Vec<Value>> {
        let ty = self
            .meta
            .branches
            .get(branch_id as usize)
            .ok_or_else(|| anyhow::anyhow!("no branch {branch_id}"))?
            .ty;
        let (start, end) = self.meta.clamp_entry_range(range.start, range.end);
        let locs = self.meta.baskets_for_range(branch_id, start, end);
        let mut out = Vec::with_capacity((end - start) as usize);
        let mut scratch = Vec::new();
        for loc in &locs {
            let content = self.read_basket(loc)?;
            let (from, to) = loc.trim_bounds(start, end);
            if from == 0 && to == loc.n_entries as usize {
                decode_values(&content, ty, &mut out)?;
            } else {
                scratch.clear();
                decode_values(&content, ty, &mut scratch)?;
                out.extend(scratch.drain(..to).skip(from));
            }
        }
        if out.len() as u64 != end - start {
            bail!(
                "branch {branch_id}: {} entries decoded for range [{start}, {end}), expected {}",
                out.len(),
                end - start
            );
        }
        Ok(out)
    }

    /// Iterate all events (row-wise reconstruction across all branches).
    /// Memory-heavy for wide trees; used by examples and tests on small
    /// files. Returns `events[entry][branch]`.
    pub fn read_all_events(&mut self) -> Result<Vec<Vec<Value>>> {
        let n_branches = self.meta.branches.len();
        let n = self.meta.n_entries as usize;
        let mut columns = Vec::with_capacity(n_branches);
        for b in 0..n_branches {
            columns.push(self.read_branch(b as u32)?);
        }
        // (vec![..; n] would clone away the capacity — Vec::clone starts
        // from an empty buffer.)
        let mut events: Vec<Vec<Value>> = (0..n).map(|_| Vec::with_capacity(n_branches)).collect();
        for col in columns {
            for (ev, v) in events.iter_mut().zip(col) {
                ev.push(v);
            }
        }
        Ok(events)
    }

    /// Row-wise reconstruction of the entry window
    /// `[range.start, range.end)` across all branches: equals
    /// [`read_all_events`](TreeReader::read_all_events) followed by an
    /// in-memory slice, but only decodes baskets overlapping the window.
    /// The range is clamped to the tree. Serial oracle for
    /// [`ParallelTreeReader::read_all_events_range`](crate::coordinator::ParallelTreeReader::read_all_events_range)
    /// and the scan server's all-branch range queries.
    pub fn read_all_events_range(
        &mut self,
        range: std::ops::Range<u64>,
    ) -> Result<Vec<Vec<Value>>> {
        let n_branches = self.meta.branches.len();
        let (start, end) = self.meta.clamp_entry_range(range.start, range.end);
        let n = (end - start) as usize;
        let mut columns = Vec::with_capacity(n_branches);
        for b in 0..n_branches {
            columns.push(self.read_range(b as u32, start..end)?);
        }
        let mut events: Vec<Vec<Value>> = (0..n).map(|_| Vec::with_capacity(n_branches)).collect();
        for col in columns {
            for (ev, v) in events.iter_mut().zip(col) {
                ev.push(v);
            }
        }
        Ok(events)
    }
}

/// Decode a basket's raw content into typed per-entry values.
pub fn decode_values(content: &BasketContent, ty: BranchType, out: &mut Vec<Value>) -> Result<()> {
    let data = &content.data;
    if ty.is_var() {
        let mut start = 0usize;
        if content.offsets.len() != content.n_entries as usize {
            bail!("offset array length mismatch");
        }
        for &end in &content.offsets {
            let end = end as usize;
            if end < start || end > data.len() {
                bail!("corrupt offset array");
            }
            let slice = &data[start..end];
            out.push(match ty {
                BranchType::VarF32 => {
                    if slice.len() % 4 != 0 {
                        bail!("var-f32 entry not multiple of 4");
                    }
                    Value::AF32(
                        slice
                            .chunks_exact(4)
                            .map(|c| f32::from_be_bytes(c.try_into().unwrap()))
                            .collect(),
                    )
                }
                BranchType::VarI32 => {
                    if slice.len() % 4 != 0 {
                        bail!("var-i32 entry not multiple of 4");
                    }
                    Value::AI32(
                        slice
                            .chunks_exact(4)
                            .map(|c| i32::from_be_bytes(c.try_into().unwrap()))
                            .collect(),
                    )
                }
                BranchType::VarU8 => Value::AU8(slice.to_vec()),
                _ => unreachable!(),
            });
            start = end;
        }
        if start != data.len() {
            bail!("trailing bytes after last offset");
        }
    } else {
        let esz = ty.elem_size();
        if data.len() != esz * content.n_entries as usize {
            bail!("fixed-width basket size mismatch");
        }
        for chunk in data.chunks_exact(esz) {
            out.push(match ty {
                BranchType::F32 => Value::F32(f32::from_be_bytes(chunk.try_into().unwrap())),
                BranchType::F64 => Value::F64(f64::from_be_bytes(chunk.try_into().unwrap())),
                BranchType::I32 => Value::I32(i32::from_be_bytes(chunk.try_into().unwrap())),
                BranchType::I64 => Value::I64(i64::from_be_bytes(chunk.try_into().unwrap())),
                BranchType::U8 => Value::U8(chunk[0]),
                BranchType::Bool => Value::Bool(chunk[0] != 0),
                _ => unreachable!(),
            });
        }
    }
    Ok(())
}
