//! Pluggable byte-range sources: the seam between basket plans and the
//! physical read path (the I/O-backend ROADMAP item — "Increasing
//! Parallelism in the ROOT I/O Subsystem" motivates decoupling logical
//! scans from physical I/O resources).
//!
//! A [`RangeSource`] serves positioned reads. Three implementations:
//!
//! * [`FileSource`] — the production path: positional `pread`-style reads
//!   against a local file (no shared cursor, so one handle per thread
//!   needs no seeking discipline).
//! * [`FaultSource`] — a seeded deterministic wrapper that injects
//!   transient I/O errors, short reads, added latency and payload
//!   bit-flips. This is the fault-tolerance test substrate; it reuses
//!   [`crate::util::rng`] so every failure is reproducible from a seed.
//! * [`RetrySource`] — a policy layer ([`RetryPolicy`]) that transparently
//!   retries *transient* errors with bounded exponential backoff and
//!   counts retry attempts into a shared counter (surfaced through
//!   the coordinator's metrics snapshot).
//!
//! Errors are classified by [`SourceError`]: `Transient` failures are
//! worth retrying (EINTR, injected EIO, a remote hiccup); `Permanent`
//! failures are not (truncation, a hole in the file, a decode-level
//! rejection). Short reads are legal for `read_at`; callers that need an
//! exact fill loop through [`read_full_at`], which converts lack of
//! progress into an explicit truncation error.

use super::format::RecordKind;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::fmt;
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// A read failure, classified by whether retrying could help.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SourceError {
    /// Worth retrying: the same read may succeed later (interrupted
    /// syscall, injected fault, remote hiccup).
    Transient(String),
    /// Not worth retrying: the bytes are not there or are wrong.
    Permanent(String),
}

impl SourceError {
    pub fn is_transient(&self) -> bool {
        matches!(self, SourceError::Transient(_))
    }
}

impl fmt::Display for SourceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SourceError::Transient(m) | SourceError::Permanent(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for SourceError {}

/// A source of positioned byte-range reads.
///
/// `read_at` may return fewer bytes than requested (a short read); zero
/// means end-of-source at `offset`. Implementations must be `Send` so a
/// source can be moved onto the read pipeline's prefetch thread.
pub trait RangeSource: Send {
    /// Total size of the source in bytes.
    fn size(&mut self) -> Result<u64, SourceError>;

    /// Read up to `buf.len()` bytes at absolute `offset`; returns the
    /// number of bytes read (0 = end of source).
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError>;
}

impl<S: RangeSource + ?Sized> RangeSource for Box<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        (**self).size()
    }
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        (**self).read_at(offset, buf)
    }
}

/// Fill `buf` exactly from `offset`, looping over short reads. End of
/// source before the fill completes becomes an explicit truncation error.
pub fn read_full_at<S: RangeSource + ?Sized>(
    src: &mut S,
    offset: u64,
    buf: &mut [u8],
) -> Result<(), SourceError> {
    let mut done = 0usize;
    while done < buf.len() {
        let n = src.read_at(offset + done as u64, &mut buf[done..])?;
        if n == 0 {
            return Err(SourceError::Permanent(format!(
                "file truncated: expected {} bytes at offset {}, got {}",
                buf.len(),
                offset,
                done
            )));
        }
        done += n;
    }
    Ok(())
}

/// Read the record at `offset` through a [`RangeSource`], mirroring the
/// validation in [`crate::rfile::format::read_record_at_into`]: 5-byte
/// header, plausible total length, known kind, full payload. The payload
/// buffer is reused (capacity kept across calls).
pub fn read_record_from<S: RangeSource + ?Sized>(
    src: &mut S,
    offset: u64,
    payload: &mut Vec<u8>,
) -> Result<RecordKind, SourceError> {
    let mut hdr = [0u8; 5];
    read_full_at(src, offset, &mut hdr)
        .map_err(|e| with_detail(e, format!("reading record header at offset {offset}")))?;
    let total = u32::from_be_bytes(hdr[..4].try_into().unwrap()) as usize;
    if !(5..=(1 << 30)).contains(&total) {
        return Err(SourceError::Permanent(format!(
            "implausible record length {total} at offset {offset}"
        )));
    }
    let kind = RecordKind::from_u8(hdr[4]).ok_or_else(|| {
        SourceError::Permanent(format!("unknown record kind {} at offset {offset}", hdr[4]))
    })?;
    let body_len = total - 5;
    payload.clear();
    // resize() zero-fills bytes about to be overwritten; unlike the
    // BufReader path in `format`, a positioned read needs an initialized
    // slice. The memset is noise next to the per-basket decompression,
    // and the recycled buffer's capacity is still reused (§Perf).
    payload.resize(body_len, 0);
    read_full_at(src, offset + 5, payload)
        .map_err(|e| with_detail(e, format!("reading record payload at offset {offset}")))?;
    Ok(kind)
}

/// Prefix a classification-preserving context line onto a source error.
fn with_detail(e: SourceError, ctx: String) -> SourceError {
    match e {
        SourceError::Transient(m) => SourceError::Transient(format!("{ctx}: {m}")),
        SourceError::Permanent(m) => SourceError::Permanent(format!("{ctx}: {m}")),
    }
}

// ---------------------------------------------------------------------------
// FileId
// ---------------------------------------------------------------------------

/// Stable identity of a file's *contents* for cross-scan cache keys.
///
/// Two opens of the same unmodified file yield the same `FileId`; replacing
/// or appending to the file changes it (the hash covers device/inode — or a
/// canonicalized path off unix — plus length and mtime). This is what a
/// decoded-basket cache wants: identity follows the bytes on disk, not the
/// path string, so `./a.rfil` and its absolute spelling share cache entries
/// while a rewritten file never serves stale baskets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FileId(pub u64);

impl FileId {
    /// Derive the identity of the file at `path` from its metadata.
    pub fn of_path(path: &Path) -> Result<Self> {
        let meta = std::fs::metadata(path)
            .with_context(|| format!("stat {} for file identity", path.display()))?;
        let mut h = Fnv::new();
        #[cfg(unix)]
        {
            use std::os::unix::fs::MetadataExt;
            h.write_u64(meta.dev());
            h.write_u64(meta.ino());
        }
        #[cfg(not(unix))]
        {
            let canon = std::fs::canonicalize(path)
                .unwrap_or_else(|_| path.to_path_buf());
            h.write_bytes(canon.to_string_lossy().as_bytes());
        }
        h.write_u64(meta.len());
        if let Ok(mtime) = meta.modified() {
            if let Ok(d) = mtime.duration_since(std::time::UNIX_EPOCH) {
                h.write_u64(d.as_secs());
                h.write_u64(d.subsec_nanos() as u64);
            }
        }
        Ok(FileId(h.finish()))
    }
}

impl fmt::Display for FileId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// Minimal FNV-1a, enough to mix metadata words into one u64.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }
    fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
    fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }
    fn finish(&self) -> u64 {
        self.0
    }
}

// ---------------------------------------------------------------------------
// FileSource
// ---------------------------------------------------------------------------

/// Positional reads against a local file: the production source. On unix
/// this is `pread(2)` (no shared-cursor seeks); elsewhere it falls back to
/// seek-and-read on the owned handle.
pub struct FileSource {
    file: File,
    path: PathBuf,
    len: u64,
}

impl FileSource {
    /// Open `path` for range reads.
    pub fn open(path: &Path) -> Result<Self> {
        let file =
            File::open(path).with_context(|| format!("opening {} for read", path.display()))?;
        Self::from_file(file, path)
    }

    /// Wrap an already-open handle (e.g. after the tree-open phase read
    /// the header and directory through a `BufReader`).
    pub fn from_file(file: File, path: &Path) -> Result<Self> {
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        Ok(Self { file, path: path.to_path_buf(), len })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl RangeSource for FileSource {
    fn size(&mut self) -> Result<u64, SourceError> {
        Ok(self.len)
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        #[cfg(unix)]
        let res = {
            use std::os::unix::fs::FileExt;
            self.file.read_at(buf, offset)
        };
        #[cfg(not(unix))]
        let res = {
            use std::io::{Read, Seek, SeekFrom};
            self.file
                .seek(SeekFrom::Start(offset))
                .and_then(|_| self.file.read(buf))
        };
        res.map_err(|e| {
            let msg = format!(
                "reading {} bytes at offset {} from {}: {e}",
                buf.len(),
                offset,
                self.path.display()
            );
            if e.kind() == std::io::ErrorKind::Interrupted {
                SourceError::Transient(msg)
            } else {
                SourceError::Permanent(msg)
            }
        })
    }
}

// ---------------------------------------------------------------------------
// FaultSource
// ---------------------------------------------------------------------------

/// Deterministic fault-injection plan for a [`FaultSource`]. All
/// probabilities are per `read_at` call; the RNG stream depends only on
/// `seed` and the call sequence, so a single-threaded caller (the read
/// pipeline's prefetcher) sees a reproducible fault schedule.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// RNG seed for the fault schedule.
    pub seed: u64,
    /// P(inject a transient I/O error) per read.
    pub transient: f64,
    /// P(truncate a multi-byte read to a random shorter length) per read.
    pub short_read: f64,
    /// P(flip one random bit of the bytes just read) per read.
    pub bit_flip: f64,
    /// P(sleep `latency` before serving) per read.
    pub delay: f64,
    /// Sleep duration for injected latency.
    pub latency: Duration,
    /// Cap on back-to-back transient injections: after this many
    /// consecutive failures the next read is served, so a retry policy
    /// with `max_attempts > max_consecutive` always recovers.
    pub max_consecutive: u32,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self {
            seed: 0,
            transient: 0.0,
            short_read: 0.0,
            bit_flip: 0.0,
            delay: 0.0,
            latency: Duration::ZERO,
            max_consecutive: 2,
        }
    }
}

/// Counters for faults actually injected, shared with the test harness so
/// a property run can assert its fault plan really fired.
#[derive(Debug, Default)]
pub struct FaultStats {
    pub transient: AtomicU64,
    pub short_reads: AtomicU64,
    pub bit_flips: AtomicU64,
    pub delays: AtomicU64,
}

impl FaultStats {
    /// Total faults injected across all categories.
    pub fn total(&self) -> u64 {
        self.transient.load(Ordering::Relaxed)
            + self.short_reads.load(Ordering::Relaxed)
            + self.bit_flips.load(Ordering::Relaxed)
            + self.delays.load(Ordering::Relaxed)
    }
}

/// Seeded deterministic fault injector wrapping any inner source.
pub struct FaultSource<S> {
    inner: S,
    spec: FaultSpec,
    rng: Rng,
    consecutive: u32,
    stats: Arc<FaultStats>,
}

impl<S: RangeSource> FaultSource<S> {
    pub fn new(inner: S, spec: FaultSpec) -> Self {
        Self::with_stats(inner, spec, Arc::new(FaultStats::default()))
    }

    /// Share the injection counters with the caller (tests assert on them).
    pub fn with_stats(inner: S, spec: FaultSpec, stats: Arc<FaultStats>) -> Self {
        Self { inner, spec, rng: Rng::new(spec.seed), consecutive: 0, stats }
    }

    pub fn stats(&self) -> Arc<FaultStats> {
        Arc::clone(&self.stats)
    }
}

impl<S: RangeSource> RangeSource for FaultSource<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        // Metadata plumbing is not under attack; only payload reads are.
        self.inner.size()
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        // Draw every category each call so the RNG stream depends only on
        // the call count, never on which probabilities are non-zero.
        let delay = self.rng.chance(self.spec.delay);
        let transient = self.rng.chance(self.spec.transient);
        let short = self.rng.chance(self.spec.short_read);
        let flip = self.rng.chance(self.spec.bit_flip);

        if delay && !self.spec.latency.is_zero() {
            self.stats.delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.spec.latency);
        }
        if transient && self.consecutive < self.spec.max_consecutive {
            self.consecutive += 1;
            self.stats.transient.fetch_add(1, Ordering::Relaxed);
            return Err(SourceError::Transient(format!(
                "injected transient I/O error at offset {offset}"
            )));
        }
        self.consecutive = 0;

        let mut want = buf.len();
        if short && want > 1 {
            want = 1 + self.rng.below(want as u64 - 1) as usize;
            self.stats.short_reads.fetch_add(1, Ordering::Relaxed);
        }
        let n = self.inner.read_at(offset, &mut buf[..want])?;
        if flip && n > 0 {
            let at = self.rng.below(n as u64) as usize;
            buf[at] ^= 1 << self.rng.below(8);
            self.stats.bit_flips.fetch_add(1, Ordering::Relaxed);
        }
        Ok(n)
    }
}

// ---------------------------------------------------------------------------
// Retry layer
// ---------------------------------------------------------------------------

/// Bounded exponential backoff for transient read failures.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts per read (1 = no retries).
    pub max_attempts: u32,
    /// Delay before the first retry.
    pub base_delay: Duration,
    /// Multiplier applied to the delay after each failed attempt.
    pub backoff: f64,
    /// Upper bound on any single delay.
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            base_delay: Duration::from_millis(1),
            backoff: 2.0,
            max_delay: Duration::from_millis(100),
        }
    }
}

impl RetryPolicy {
    /// No retries: every transient failure surfaces immediately.
    pub fn disabled() -> Self {
        Self { max_attempts: 1, ..Self::default() }
    }

    pub fn is_disabled(&self) -> bool {
        self.max_attempts <= 1
    }

    /// Backoff delay before retry number `retry` (1-based), capped.
    pub fn delay_for(&self, retry: u32) -> Duration {
        let factor = self.backoff.max(1.0).powi(retry.saturating_sub(1) as i32);
        let secs = (self.base_delay.as_secs_f64() * factor).min(self.max_delay.as_secs_f64());
        Duration::from_secs_f64(secs.max(0.0))
    }
}

/// Retry wrapper: replays transient failures per [`RetryPolicy`] and
/// counts every retry into a shared counter. Permanent errors pass
/// through untouched.
pub struct RetrySource<S> {
    inner: S,
    policy: RetryPolicy,
    retries: Arc<AtomicU64>,
}

impl<S: RangeSource> RetrySource<S> {
    pub fn new(inner: S, policy: RetryPolicy, retries: Arc<AtomicU64>) -> Self {
        Self { inner, policy, retries }
    }

    /// Retries performed so far (shared counter).
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    fn run<T>(
        &mut self,
        mut op: impl FnMut(&mut S) -> Result<T, SourceError>,
    ) -> Result<T, SourceError> {
        let attempts = self.policy.max_attempts.max(1);
        let mut attempt = 1u32;
        loop {
            match op(&mut self.inner) {
                Ok(v) => return Ok(v),
                Err(e) if e.is_transient() && attempt < attempts => {
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    let delay = self.policy.delay_for(attempt);
                    if !delay.is_zero() {
                        std::thread::sleep(delay);
                    }
                    attempt += 1;
                }
                Err(SourceError::Transient(m)) if attempt > 1 => {
                    return Err(SourceError::Transient(format!(
                        "{m} (after {attempt} attempts)"
                    )));
                }
                Err(e) => return Err(e),
            }
        }
    }
}

impl<S: RangeSource> RangeSource for RetrySource<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        self.run(|s| s.size())
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        // The closure re-borrows `buf` each attempt; a failed attempt may
        // have scribbled on it, which is fine — only the final successful
        // read's bytes are reported to the caller.
        self.run(|s| s.read_at(offset, buf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfile::format;
    use std::io::Cursor;

    /// In-memory source for deterministic unit tests.
    struct MemSource(Vec<u8>);

    impl RangeSource for MemSource {
        fn size(&mut self) -> Result<u64, SourceError> {
            Ok(self.0.len() as u64)
        }
        fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
            let off = (offset as usize).min(self.0.len());
            let n = buf.len().min(self.0.len() - off);
            buf[..n].copy_from_slice(&self.0[off..off + n]);
            Ok(n)
        }
    }

    /// Serves at most `chunk` bytes per read — exercises the fill loop.
    struct ChunkySource {
        inner: MemSource,
        chunk: usize,
    }

    impl RangeSource for ChunkySource {
        fn size(&mut self) -> Result<u64, SourceError> {
            self.inner.size()
        }
        fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
            let want = buf.len().min(self.chunk);
            self.inner.read_at(offset, &mut buf[..want])
        }
    }

    /// Fails transiently `fail` times, then serves.
    struct FlakySource {
        inner: MemSource,
        fail: u32,
    }

    impl RangeSource for FlakySource {
        fn size(&mut self) -> Result<u64, SourceError> {
            self.inner.size()
        }
        fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
            if self.fail > 0 {
                self.fail -= 1;
                return Err(SourceError::Transient("flaky".into()));
            }
            self.inner.read_at(offset, buf)
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rootio_source_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn read_full_at_loops_over_short_reads() {
        let data: Vec<u8> = (0..=255u8).collect();
        let mut src = ChunkySource { inner: MemSource(data.clone()), chunk: 7 };
        let mut buf = vec![0u8; 100];
        read_full_at(&mut src, 30, &mut buf).unwrap();
        assert_eq!(buf, &data[30..130]);
    }

    #[test]
    fn truncation_is_an_explicit_permanent_error() {
        let mut src = MemSource((0..64u8).collect());
        let mut buf = vec![0u8; 32];
        let err = read_full_at(&mut src, 48, &mut buf).unwrap_err();
        assert!(!err.is_transient());
        let msg = err.to_string();
        assert!(
            msg.contains("expected 32 bytes at offset 48") && msg.contains("got 16"),
            "unhelpful truncation error: {msg}"
        );
    }

    #[test]
    fn file_source_serves_ranges_and_reports_eof() {
        let path = tmp("filesource");
        std::fs::write(&path, (0..200u32).flat_map(|i| i.to_be_bytes()).collect::<Vec<_>>())
            .unwrap();
        let mut src = FileSource::open(&path).unwrap();
        assert_eq!(src.size().unwrap(), 800);
        let mut buf = [0u8; 4];
        read_full_at(&mut src, 4 * 7, &mut buf).unwrap();
        assert_eq!(u32::from_be_bytes(buf), 7);
        // Past-EOF fill is a truncation error, not a panic or a hang.
        let mut big = vec![0u8; 16];
        assert!(read_full_at(&mut src, 792, &mut big).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn record_read_parity_with_format_layer() {
        // A record stream built by the format layer parses identically
        // through a RangeSource.
        let mut buf = Cursor::new(Vec::<u8>::new());
        let pos = format::write_header(&mut buf).unwrap();
        format::write_record(&mut buf, pos, RecordKind::Basket, b"the payload").unwrap();
        let bytes = buf.into_inner();

        let mut src = ChunkySource { inner: MemSource(bytes.clone()), chunk: 3 };
        let mut payload = Vec::new();
        let kind = read_record_from(&mut src, pos, &mut payload).unwrap();
        assert_eq!(kind, RecordKind::Basket);
        assert_eq!(payload, b"the payload");

        let mut oracle = Cursor::new(bytes);
        let mut expect = Vec::new();
        let k2 = format::read_record_at_into(&mut oracle, pos, &mut expect).unwrap();
        assert_eq!((kind, &payload), (k2, &expect));
    }

    #[test]
    fn record_read_rejects_garbage_frames() {
        // Implausible length.
        let mut bad = vec![0xFFu8; 16];
        bad[4] = 1;
        let mut payload = Vec::new();
        let err = read_record_from(&mut MemSource(bad), 0, &mut payload).unwrap_err();
        assert!(err.to_string().contains("implausible record length"), "{err}");
        // Unknown kind.
        let mut frame = 9u32.to_be_bytes().to_vec();
        frame.push(200);
        frame.extend_from_slice(b"body");
        let err = read_record_from(&mut MemSource(frame), 0, &mut payload).unwrap_err();
        assert!(err.to_string().contains("unknown record kind"), "{err}");
        // Truncated payload.
        let mut frame = 105u32.to_be_bytes().to_vec();
        frame.push(1);
        frame.extend_from_slice(&[7u8; 10]);
        let err = read_record_from(&mut MemSource(frame), 0, &mut payload).unwrap_err();
        assert!(err.to_string().contains("file truncated"), "{err}");
    }

    #[test]
    fn retry_recovers_transient_failures_and_counts_them() {
        let data: Vec<u8> = (0..99u8).collect();
        let counter = Arc::new(AtomicU64::new(0));
        let flaky = FlakySource { inner: MemSource(data.clone()), fail: 2 };
        let policy = RetryPolicy {
            max_attempts: 4,
            base_delay: Duration::ZERO,
            backoff: 2.0,
            max_delay: Duration::ZERO,
        };
        let mut src = RetrySource::new(flaky, policy, Arc::clone(&counter));
        let mut buf = vec![0u8; 10];
        read_full_at(&mut src, 5, &mut buf).unwrap();
        assert_eq!(buf, &data[5..15]);
        assert_eq!(counter.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn retry_exhaustion_and_disabled_policy_surface_the_error() {
        let counter = Arc::new(AtomicU64::new(0));
        let flaky = FlakySource { inner: MemSource(vec![0; 8]), fail: 10 };
        let policy = RetryPolicy {
            max_attempts: 3,
            base_delay: Duration::ZERO,
            backoff: 1.0,
            max_delay: Duration::ZERO,
        };
        let mut src = RetrySource::new(flaky, policy, Arc::clone(&counter));
        let mut buf = [0u8; 4];
        let err = src.read_at(0, &mut buf).unwrap_err();
        assert!(err.is_transient());
        assert!(err.to_string().contains("after 3 attempts"), "{err}");
        assert_eq!(counter.load(Ordering::Relaxed), 2, "two retries for three attempts");

        let flaky = FlakySource { inner: MemSource(vec![0; 8]), fail: 1 };
        let mut src =
            RetrySource::new(flaky, RetryPolicy::disabled(), Arc::new(AtomicU64::new(0)));
        assert!(src.read_at(0, &mut buf).is_err(), "disabled policy must not retry");
    }

    #[test]
    fn retry_does_not_touch_permanent_errors() {
        let counter = Arc::new(AtomicU64::new(0));
        // MemSource returns 0 bytes past EOF; read_full_at turns that into
        // a Permanent truncation which the retry layer must pass through.
        let mut src =
            RetrySource::new(MemSource(vec![1; 4]), RetryPolicy::default(), Arc::clone(&counter));
        let mut buf = [0u8; 8];
        let err = read_full_at(&mut src, 0, &mut buf).unwrap_err();
        assert!(!err.is_transient());
        assert_eq!(counter.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn backoff_delays_grow_and_cap() {
        let p = RetryPolicy {
            max_attempts: 8,
            base_delay: Duration::from_millis(2),
            backoff: 3.0,
            max_delay: Duration::from_millis(20),
        };
        assert_eq!(p.delay_for(1), Duration::from_millis(2));
        assert_eq!(p.delay_for(2), Duration::from_millis(6));
        assert_eq!(p.delay_for(3), Duration::from_millis(18));
        assert_eq!(p.delay_for(4), Duration::from_millis(20), "capped");
        assert_eq!(p.delay_for(30), Duration::from_millis(20), "still capped");
    }

    #[test]
    fn fault_schedule_is_deterministic_for_a_seed() {
        let data: Vec<u8> = (0..255u8).cycle().take(4096).collect();
        let spec = FaultSpec {
            seed: 0xFA_017,
            transient: 0.3,
            short_read: 0.4,
            bit_flip: 0.2,
            max_consecutive: 2,
            ..FaultSpec::default()
        };
        let run = |spec: FaultSpec| {
            let mut src = FaultSource::new(MemSource(data.clone()), spec);
            let stats = src.stats();
            let mut outcomes = Vec::new();
            let mut buf = vec![0u8; 64];
            for i in 0..200u64 {
                match src.read_at((i * 13) % 4000, &mut buf) {
                    Ok(n) => outcomes.push((n as i64, buf[..n].to_vec())),
                    Err(e) => outcomes.push((-1, e.to_string().into_bytes())),
                }
            }
            (outcomes, stats.total())
        };
        let (a, fa) = run(spec);
        let (b, fb) = run(spec);
        assert_eq!(a, b, "same seed must replay the same fault schedule");
        assert_eq!(fa, fb);
        assert!(fa > 0, "fault plan never fired");
        let (c, _) = run(FaultSpec { seed: 0xFA_018, ..spec });
        assert_ne!(a, c, "different seed should change the schedule");
    }

    #[test]
    fn consecutive_transient_cap_guarantees_retry_recovery() {
        // With transient probability 1.0 the cap forces every third read
        // to succeed, so a retry policy with more attempts always wins.
        let data = vec![42u8; 256];
        let spec = FaultSpec {
            seed: 7,
            transient: 1.0,
            max_consecutive: 2,
            ..FaultSpec::default()
        };
        let faulty = FaultSource::new(MemSource(data.clone()), spec);
        let policy = RetryPolicy {
            max_attempts: 4,
            base_delay: Duration::ZERO,
            backoff: 1.0,
            max_delay: Duration::ZERO,
        };
        let mut src = RetrySource::new(faulty, policy, Arc::new(AtomicU64::new(0)));
        let mut buf = vec![0u8; 16];
        for i in 0..20 {
            read_full_at(&mut src, i * 8, &mut buf).unwrap();
            assert_eq!(buf, vec![42u8; 16]);
        }
    }

    #[test]
    fn file_id_is_stable_until_the_file_changes() {
        let path = tmp("fileid");
        std::fs::write(&path, b"original contents").unwrap();
        let a = FileId::of_path(&path).unwrap();
        let b = FileId::of_path(&path).unwrap();
        assert_eq!(a, b, "re-stat of an unmodified file must agree");
        assert_eq!(format!("{a}").len(), 16, "display is fixed-width hex");

        // Rewriting the file (different length) must change the identity:
        // a cache keyed on FileId can never serve stale baskets.
        std::fs::write(&path, b"rewritten with different length").unwrap();
        let c = FileId::of_path(&path).unwrap();
        assert_ne!(a, c, "rewritten file must get a new identity");

        // A different file gets a different identity.
        let other = tmp("fileid_other");
        std::fs::write(&other, b"original contents").unwrap();
        let d = FileId::of_path(&other).unwrap();
        assert_ne!(c, d);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&other).ok();
    }

    #[test]
    fn bit_flips_corrupt_payloads() {
        let data = vec![0u8; 1024];
        let spec = FaultSpec { seed: 99, bit_flip: 1.0, ..FaultSpec::default() };
        let mut src = FaultSource::new(MemSource(data), spec);
        let stats = src.stats();
        let mut buf = vec![0u8; 128];
        let n = src.read_at(0, &mut buf).unwrap();
        assert!(buf[..n].iter().any(|&b| b != 0), "flip must land in the returned bytes");
        assert_eq!(stats.bit_flips.load(Ordering::Relaxed), 1);
    }
}
