//! Pluggable byte-range sources: the seam between basket plans and the
//! physical read path (the I/O-backend ROADMAP item — "Increasing
//! Parallelism in the ROOT I/O Subsystem" motivates decoupling logical
//! scans from physical I/O resources).
//!
//! A [`RangeSource`] serves positioned reads. The implementations:
//!
//! * [`FileSource`] — the production path: positional `pread`-style reads
//!   against a local file (no shared cursor, so one handle per thread
//!   needs no seeking discipline).
//! * [`FaultSource`] — a seeded deterministic wrapper that injects
//!   transient I/O errors, short reads, added latency and payload
//!   bit-flips. This is the fault-tolerance test substrate; it reuses
//!   [`crate::util::rng`] so every failure is reproducible from a seed.
//! * [`RetrySource`] — a policy layer ([`RetryPolicy`]) that transparently
//!   retries *transient* errors with bounded exponential backoff and
//!   counts retry attempts into per-chain counters (surfaced through
//!   the coordinator's metrics snapshot).
//!
//! On top of the decorators sit the selectable **I/O backends**
//! ([`IoBackend`], wired by [`compose_chain`]): [`CountingSource`] (the
//! instrumented `pread` baseline), [`CoalescedSource`] (plan-aware
//! request merging — k adjacent basket reads become one physical read),
//! [`MmapSource`] (a whole-file mapped image behind the same positioned
//! contract), and [`RemoteSource`] (a simulated high-latency remote
//! byte-range store where the prefetch window is the latency-hiding
//! knob). All of them report into a shared [`IoStats`].
//!
//! Errors are classified by [`SourceError`]: `Transient` failures are
//! worth retrying (EINTR, injected EIO, a remote hiccup); `Permanent`
//! failures are not (truncation, a hole in the file, a decode-level
//! rejection). Short reads are legal for `read_at`; callers that need an
//! exact fill loop through [`read_full_at`], which converts lack of
//! progress into an explicit truncation error.

use super::format::RecordKind;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::fmt;
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A read failure, classified by whether retrying could help.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SourceError {
    /// Worth retrying: the same read may succeed later (interrupted
    /// syscall, injected fault, remote hiccup).
    Transient(String),
    /// Not worth retrying: the bytes are not there or are wrong.
    Permanent(String),
}

impl SourceError {
    pub fn is_transient(&self) -> bool {
        matches!(self, SourceError::Transient(_))
    }
}

impl fmt::Display for SourceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SourceError::Transient(m) | SourceError::Permanent(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for SourceError {}

/// A source of positioned byte-range reads.
///
/// `read_at` may return fewer bytes than requested (a short read); zero
/// means end-of-source at `offset`. Implementations must be `Send` so a
/// source can be moved onto the read pipeline's prefetch thread.
pub trait RangeSource: Send {
    /// Total size of the source in bytes.
    fn size(&mut self) -> Result<u64, SourceError>;

    /// Read up to `buf.len()` bytes at absolute `offset`; returns the
    /// number of bytes read (0 = end of source).
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError>;
}

impl<S: RangeSource + ?Sized> RangeSource for Box<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        (**self).size()
    }
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        (**self).read_at(offset, buf)
    }
}

/// Fill `buf` exactly from `offset`, looping over short reads. End of
/// source before the fill completes becomes an explicit truncation error.
pub fn read_full_at<S: RangeSource + ?Sized>(
    src: &mut S,
    offset: u64,
    buf: &mut [u8],
) -> Result<(), SourceError> {
    let mut done = 0usize;
    while done < buf.len() {
        let n = src.read_at(offset + done as u64, &mut buf[done..])?;
        if n == 0 {
            return Err(SourceError::Permanent(format!(
                "file truncated: expected {} bytes at offset {}, got {}",
                buf.len(),
                offset,
                done
            )));
        }
        done += n;
    }
    Ok(())
}

/// Read the record at `offset` through a [`RangeSource`], mirroring the
/// validation in [`crate::rfile::format::read_record_at_into`]: 5-byte
/// header, plausible total length, known kind, full payload. The payload
/// buffer is reused (capacity kept across calls).
pub fn read_record_from<S: RangeSource + ?Sized>(
    src: &mut S,
    offset: u64,
    payload: &mut Vec<u8>,
) -> Result<RecordKind, SourceError> {
    let mut hdr = [0u8; 5];
    read_full_at(src, offset, &mut hdr)
        .map_err(|e| with_detail(e, format!("reading record header at offset {offset}")))?;
    let total = u32::from_be_bytes(hdr[..4].try_into().unwrap()) as usize;
    if !(5..=(1 << 30)).contains(&total) {
        return Err(SourceError::Permanent(format!(
            "implausible record length {total} at offset {offset}"
        )));
    }
    let kind = RecordKind::from_u8(hdr[4]).ok_or_else(|| {
        SourceError::Permanent(format!("unknown record kind {} at offset {offset}", hdr[4]))
    })?;
    let body_len = total - 5;
    payload.clear();
    // resize() zero-fills bytes about to be overwritten; unlike the
    // BufReader path in `format`, a positioned read needs an initialized
    // slice. The memset is noise next to the per-basket decompression,
    // and the recycled buffer's capacity is still reused (§Perf).
    payload.resize(body_len, 0);
    read_full_at(src, offset + 5, payload)
        .map_err(|e| with_detail(e, format!("reading record payload at offset {offset}")))?;
    Ok(kind)
}

/// Prefix a classification-preserving context line onto a source error.
fn with_detail(e: SourceError, ctx: String) -> SourceError {
    match e {
        SourceError::Transient(m) => SourceError::Transient(format!("{ctx}: {m}")),
        SourceError::Permanent(m) => SourceError::Permanent(format!("{ctx}: {m}")),
    }
}

// ---------------------------------------------------------------------------
// FileId
// ---------------------------------------------------------------------------

/// Stable identity of a file's *contents* for cross-scan cache keys.
///
/// Two opens of the same unmodified file yield the same `FileId`; replacing
/// or appending to the file changes it (the hash covers device/inode — or a
/// canonicalized path off unix — plus length and mtime). This is what a
/// decoded-basket cache wants: identity follows the bytes on disk, not the
/// path string, so `./a.rfil` and its absolute spelling share cache entries
/// while a rewritten file never serves stale baskets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FileId(pub u64);

impl FileId {
    /// Derive the identity of the file at `path` from its metadata.
    pub fn of_path(path: &Path) -> Result<Self> {
        let meta = std::fs::metadata(path)
            .with_context(|| format!("stat {} for file identity", path.display()))?;
        let mut h = Fnv::new();
        #[cfg(unix)]
        {
            use std::os::unix::fs::MetadataExt;
            h.write_u64(meta.dev());
            h.write_u64(meta.ino());
        }
        #[cfg(not(unix))]
        {
            let canon = std::fs::canonicalize(path)
                .unwrap_or_else(|_| path.to_path_buf());
            h.write_bytes(canon.to_string_lossy().as_bytes());
        }
        h.write_u64(meta.len());
        if let Ok(mtime) = meta.modified() {
            if let Ok(d) = mtime.duration_since(std::time::UNIX_EPOCH) {
                h.write_u64(d.as_secs());
                h.write_u64(d.subsec_nanos() as u64);
            }
        }
        Ok(FileId(h.finish()))
    }
}

impl fmt::Display for FileId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// Minimal FNV-1a, enough to mix metadata words into one u64.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }
    fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
    fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }
    fn finish(&self) -> u64 {
        self.0
    }
}

// ---------------------------------------------------------------------------
// FileSource
// ---------------------------------------------------------------------------

/// Positional reads against a local file: the production source. On unix
/// this is `pread(2)` (no shared-cursor seeks); elsewhere it falls back to
/// seek-and-read on the owned handle.
pub struct FileSource {
    file: File,
    path: PathBuf,
    len: u64,
}

impl FileSource {
    /// Open `path` for range reads.
    pub fn open(path: &Path) -> Result<Self> {
        let file =
            File::open(path).with_context(|| format!("opening {} for read", path.display()))?;
        Self::from_file(file, path)
    }

    /// Wrap an already-open handle (e.g. after the tree-open phase read
    /// the header and directory through a `BufReader`).
    pub fn from_file(file: File, path: &Path) -> Result<Self> {
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        Ok(Self { file, path: path.to_path_buf(), len })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl RangeSource for FileSource {
    fn size(&mut self) -> Result<u64, SourceError> {
        Ok(self.len)
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        #[cfg(unix)]
        let res = {
            use std::os::unix::fs::FileExt;
            self.file.read_at(buf, offset)
        };
        #[cfg(not(unix))]
        let res = {
            use std::io::{Read, Seek, SeekFrom};
            self.file
                .seek(SeekFrom::Start(offset))
                .and_then(|_| self.file.read(buf))
        };
        res.map_err(|e| {
            let msg = format!(
                "reading {} bytes at offset {} from {}: {e}",
                buf.len(),
                offset,
                self.path.display()
            );
            if e.kind() == std::io::ErrorKind::Interrupted {
                SourceError::Transient(msg)
            } else {
                SourceError::Permanent(msg)
            }
        })
    }
}

// ---------------------------------------------------------------------------
// FaultSource
// ---------------------------------------------------------------------------

/// Deterministic fault-injection plan for a [`FaultSource`]. All
/// probabilities are per `read_at` call; the RNG stream depends only on
/// `seed` and the call sequence, so a single-threaded caller (the read
/// pipeline's prefetcher) sees a reproducible fault schedule.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// RNG seed for the fault schedule.
    pub seed: u64,
    /// P(inject a transient I/O error) per read.
    pub transient: f64,
    /// P(truncate a multi-byte read to a random shorter length) per read.
    pub short_read: f64,
    /// P(flip one random bit of the bytes just read) per read.
    pub bit_flip: f64,
    /// P(sleep `latency` before serving) per read.
    pub delay: f64,
    /// Sleep duration for injected latency.
    pub latency: Duration,
    /// Cap on back-to-back transient injections: after this many
    /// consecutive failures the next read is served, so a retry policy
    /// with `max_attempts > max_consecutive` always recovers.
    pub max_consecutive: u32,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self {
            seed: 0,
            transient: 0.0,
            short_read: 0.0,
            bit_flip: 0.0,
            delay: 0.0,
            latency: Duration::ZERO,
            max_consecutive: 2,
        }
    }
}

/// Counters for faults actually injected, shared with the test harness so
/// a property run can assert its fault plan really fired.
#[derive(Debug, Default)]
pub struct FaultStats {
    pub transient: AtomicU64,
    pub short_reads: AtomicU64,
    pub bit_flips: AtomicU64,
    pub delays: AtomicU64,
}

impl FaultStats {
    /// Total faults injected across all categories.
    pub fn total(&self) -> u64 {
        self.transient.load(Ordering::Relaxed)
            + self.short_reads.load(Ordering::Relaxed)
            + self.bit_flips.load(Ordering::Relaxed)
            + self.delays.load(Ordering::Relaxed)
    }
}

/// Seeded deterministic fault injector wrapping any inner source.
pub struct FaultSource<S> {
    inner: S,
    spec: FaultSpec,
    rng: Rng,
    consecutive: u32,
    stats: Arc<FaultStats>,
}

impl<S: RangeSource> FaultSource<S> {
    pub fn new(inner: S, spec: FaultSpec) -> Self {
        Self::with_stats(inner, spec, Arc::new(FaultStats::default()))
    }

    /// Share the injection counters with the caller (tests assert on them).
    pub fn with_stats(inner: S, spec: FaultSpec, stats: Arc<FaultStats>) -> Self {
        Self { inner, spec, rng: Rng::new(spec.seed), consecutive: 0, stats }
    }

    pub fn stats(&self) -> Arc<FaultStats> {
        Arc::clone(&self.stats)
    }
}

impl<S: RangeSource> RangeSource for FaultSource<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        // Metadata plumbing is not under attack; only payload reads are.
        self.inner.size()
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        // Draw every category each call so the RNG stream depends only on
        // the call count, never on which probabilities are non-zero.
        let delay = self.rng.chance(self.spec.delay);
        let transient = self.rng.chance(self.spec.transient);
        let short = self.rng.chance(self.spec.short_read);
        let flip = self.rng.chance(self.spec.bit_flip);

        if delay && !self.spec.latency.is_zero() {
            self.stats.delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.spec.latency);
        }
        if transient && self.consecutive < self.spec.max_consecutive {
            self.consecutive += 1;
            self.stats.transient.fetch_add(1, Ordering::Relaxed);
            return Err(SourceError::Transient(format!(
                "injected transient I/O error at offset {offset}"
            )));
        }
        self.consecutive = 0;

        let mut want = buf.len();
        if short && want > 1 {
            want = 1 + self.rng.below(want as u64 - 1) as usize;
            self.stats.short_reads.fetch_add(1, Ordering::Relaxed);
        }
        let n = self.inner.read_at(offset, &mut buf[..want])?;
        if flip && n > 0 {
            let at = self.rng.below(n as u64) as usize;
            buf[at] ^= 1 << self.rng.below(8);
            self.stats.bit_flips.fetch_add(1, Ordering::Relaxed);
        }
        Ok(n)
    }
}

// ---------------------------------------------------------------------------
// Retry layer
// ---------------------------------------------------------------------------

/// Bounded exponential backoff for transient read failures.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts per read (1 = no retries).
    pub max_attempts: u32,
    /// Delay before the first retry.
    pub base_delay: Duration,
    /// Multiplier applied to the delay after each failed attempt.
    pub backoff: f64,
    /// Upper bound on any single delay.
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            base_delay: Duration::from_millis(1),
            backoff: 2.0,
            max_delay: Duration::from_millis(100),
        }
    }
}

impl RetryPolicy {
    /// No retries: every transient failure surfaces immediately.
    pub fn disabled() -> Self {
        Self { max_attempts: 1, ..Self::default() }
    }

    pub fn is_disabled(&self) -> bool {
        self.max_attempts <= 1
    }

    /// Backoff delay before retry number `retry` (1-based), capped.
    pub fn delay_for(&self, retry: u32) -> Duration {
        let factor = self.backoff.max(1.0).powi(retry.saturating_sub(1) as i32);
        let secs = (self.base_delay.as_secs_f64() * factor).min(self.max_delay.as_secs_f64());
        Duration::from_secs_f64(secs.max(0.0))
    }
}

/// Retry wrapper: replays transient failures per [`RetryPolicy`] and
/// counts every retry into the chain's own counter (plus any extra
/// sinks registered via [`RetrySource::also_count`] — e.g. a
/// reader-lifetime cumulative). Permanent errors pass through untouched.
///
/// The primary counter is **per chain** by construction: two readers (or
/// two server queries) over the same file never share one, so per-query
/// retry metrics cannot double-count each other's recoveries.
pub struct RetrySource<S> {
    inner: S,
    policy: RetryPolicy,
    retries: Arc<AtomicU64>,
    extra: Vec<Arc<AtomicU64>>,
}

impl<S: RangeSource> RetrySource<S> {
    pub fn new(inner: S, policy: RetryPolicy, retries: Arc<AtomicU64>) -> Self {
        Self { inner, policy, retries, extra: Vec::new() }
    }

    /// Bill every retry to `sink` as well as the per-chain counter.
    pub fn also_count(mut self, sink: Arc<AtomicU64>) -> Self {
        self.extra.push(sink);
        self
    }

    /// Retries performed so far (per-chain counter).
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    fn run<T>(
        &mut self,
        mut op: impl FnMut(&mut S) -> Result<T, SourceError>,
    ) -> Result<T, SourceError> {
        let attempts = self.policy.max_attempts.max(1);
        let mut attempt = 1u32;
        loop {
            match op(&mut self.inner) {
                Ok(v) => return Ok(v),
                Err(e) if e.is_transient() && attempt < attempts => {
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    for sink in &self.extra {
                        sink.fetch_add(1, Ordering::Relaxed);
                    }
                    let delay = self.policy.delay_for(attempt);
                    if !delay.is_zero() {
                        std::thread::sleep(delay);
                    }
                    attempt += 1;
                }
                Err(SourceError::Transient(m)) if attempt > 1 => {
                    return Err(SourceError::Transient(format!(
                        "{m} (after {attempt} attempts)"
                    )));
                }
                Err(e) => return Err(e),
            }
        }
    }
}

impl<S: RangeSource> RangeSource for RetrySource<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        self.run(|s| s.size())
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        // The closure re-borrows `buf` each attempt; a failed attempt may
        // have scribbled on it, which is fine — only the final successful
        // read's bytes are reported to the caller.
        self.run(|s| s.read_at(offset, buf))
    }
}

// ---------------------------------------------------------------------------
// I/O backends
// ---------------------------------------------------------------------------

/// Which physical read strategy backs a scan's source chain.
///
/// The chain keeps its shape regardless of backend —
/// `FileSource → FaultSource? → backend layer → RetrySource?` — the
/// backend layer is what turns logical plan requests into physical I/O.
/// Faults inject *below* the backend (so merged/mapped reads observe
/// damage exactly where it lies on disk) and retries sit *above* it (so
/// a failed merge fill or image load is simply redone).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IoBackend {
    /// One positional `pread` per request (the production default;
    /// [`CountingSource`] over [`FileSource`]).
    #[default]
    Pread,
    /// Plan-aware coalescing: adjacent / near-adjacent plan entries are
    /// fetched in one large read and sliced back per basket
    /// ([`CoalescedSource`]).
    Coalesced,
    /// Whole-file in-memory image behind the same positioned-read
    /// contract ([`MmapSource`] — a simulated mapping, see its docs).
    Mmap,
    /// Simulated high-latency remote byte-range store
    /// ([`RemoteSource`], HTTP/xrootd-shaped).
    RemoteSim,
}

impl IoBackend {
    /// Stable CLI / bench-lane spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            IoBackend::Pread => "pread",
            IoBackend::Coalesced => "coalesced",
            IoBackend::Mmap => "mmap",
            IoBackend::RemoteSim => "remote-sim",
        }
    }

    /// Parse a CLI spelling (`--io pread|coalesced|mmap|remote-sim`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "pread" => Some(IoBackend::Pread),
            "coalesced" => Some(IoBackend::Coalesced),
            "mmap" => Some(IoBackend::Mmap),
            "remote-sim" | "remote" => Some(IoBackend::RemoteSim),
            _ => None,
        }
    }

    /// Every backend, for test grids and bench lanes.
    pub fn all() -> [IoBackend; 4] {
        [IoBackend::Pread, IoBackend::Coalesced, IoBackend::Mmap, IoBackend::RemoteSim]
    }
}

impl fmt::Display for IoBackend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Backend selection plus the knobs each backend reads. One value
/// configures a whole source chain; [`compose_chain`] assembles it.
#[derive(Debug, Clone, Copy)]
pub struct IoConfig {
    pub backend: IoBackend,
    /// `remote-sim`: fixed per-request latency of the simulated store.
    pub latency: Duration,
    /// `remote-sim`: link bandwidth in bytes/second (0 = unmetered).
    pub bandwidth: u64,
    /// `coalesced`: merge neighboring plan spans whose gap is at most
    /// this many bytes (0 = strictly adjacent only).
    pub gap_tolerance: u64,
    /// `coalesced`: upper bound on a single merged read, so pathological
    /// plans cannot buffer an entire file at once.
    pub max_merged: u64,
    /// Optional deterministic fault injection *below* the backend layer.
    pub faults: Option<FaultSpec>,
    /// Transient-failure retry policy *above* the backend layer.
    pub retry: RetryPolicy,
}

impl Default for IoConfig {
    fn default() -> Self {
        Self {
            backend: IoBackend::Pread,
            latency: Duration::ZERO,
            bandwidth: 0,
            gap_tolerance: 4096,
            max_merged: 8 << 20,
            faults: None,
            retry: RetryPolicy::disabled(),
        }
    }
}

impl IoConfig {
    /// Default knobs for `backend`.
    pub fn for_backend(backend: IoBackend) -> Self {
        Self { backend, ..Self::default() }
    }
}

/// Physical-I/O counters, shared across a chain (and, in the scan
/// server, across every chain of a corpus) the way [`FaultStats`] is.
#[derive(Debug, Default)]
pub struct IoStats {
    /// Logical `read_at` requests arriving at the backend layer.
    pub reads_requested: AtomicU64,
    /// Physical reads the backend issued downstream: one per syscall on
    /// the pread path, one per merge-group fill (plus fallbacks) on the
    /// coalesced path, one per image-load chunk on the mmap path, one
    /// per simulated range request on the remote path.
    pub syscalls: AtomicU64,
    /// Logical requests served out of a coalesced merge buffer instead
    /// of their own physical read.
    pub requests_coalesced: AtomicU64,
    /// Bytes handed out of merge buffers.
    pub bytes_merged: AtomicU64,
}

impl IoStats {
    pub fn syscalls(&self) -> u64 {
        self.syscalls.load(Ordering::Relaxed)
    }
    pub fn requests_coalesced(&self) -> u64 {
        self.requests_coalesced.load(Ordering::Relaxed)
    }
    pub fn bytes_merged(&self) -> u64 {
        self.bytes_merged.load(Ordering::Relaxed)
    }
}

/// Thin pass-through that bills every request as one physical read — the
/// `pread` backend's bookkeeping layer, and the baseline the coalescing
/// counters are judged against.
pub struct CountingSource<S> {
    inner: S,
    stats: Arc<IoStats>,
}

impl<S: RangeSource> CountingSource<S> {
    pub fn new(inner: S, stats: Arc<IoStats>) -> Self {
        Self { inner, stats }
    }
}

impl<S: RangeSource> RangeSource for CountingSource<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        self.inner.size()
    }
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        self.stats.reads_requested.fetch_add(1, Ordering::Relaxed);
        let n = self.inner.read_at(offset, buf)?;
        self.stats.syscalls.fetch_add(1, Ordering::Relaxed);
        Ok(n)
    }
}

/// Plan-aware request coalescing over any inner source.
///
/// Construction takes the scan's plan — the exact `(offset, len)` disk
/// extents of the records the caller will read (see
/// [`crate::rfile::meta::BasketLoc::record_span`]), in any order — and
/// greedily merges offset-sorted neighbors into *merge groups*: a span
/// joins the current group while the gap to the group's end is at most
/// `gap_tolerance` bytes and the group stays within `max_merged`. The
/// first request landing in a group fetches the whole group with one
/// inner read; every further request inside the buffered group is sliced
/// out of memory. The offset-sorted prefetch sweep therefore turns k
/// adjacent record reads (2k `read_at` calls — header + body each) into
/// one physical read per group.
///
/// Requests outside any plan span, or past the buffered bytes, fall back
/// to a direct inner read — the layer is transparent to correctness,
/// only the batching changes. A failed group fill invalidates the
/// buffer, so a retry layer above simply re-requests and the fill is
/// redone from scratch.
pub struct CoalescedSource<S> {
    inner: S,
    /// Merged `(offset, len)` groups, offset-sorted.
    groups: Vec<(u64, u64)>,
    buf: Vec<u8>,
    /// Absolute offset of `buf[0]`.
    buf_off: u64,
    /// Usable prefix of `buf` (the fill tolerates end-of-source inside a
    /// group, e.g. a truncated final record).
    buf_valid: usize,
    stats: Arc<IoStats>,
}

impl<S: RangeSource> CoalescedSource<S> {
    /// `plan`: exact disk extents of the records the caller will read.
    pub fn new(
        inner: S,
        plan: &[(u64, u64)],
        gap_tolerance: u64,
        max_merged: u64,
        stats: Arc<IoStats>,
    ) -> Self {
        let mut spans: Vec<(u64, u64)> =
            plan.iter().copied().filter(|&(_, len)| len > 0).collect();
        spans.sort_unstable();
        let max_merged = max_merged.max(1);
        let mut groups: Vec<(u64, u64)> = Vec::with_capacity(spans.len());
        for (off, len) in spans {
            let end = off.saturating_add(len);
            if let Some(last) = groups.last_mut() {
                let last_end = last.0 + last.1;
                let new_end = end.max(last_end);
                if off <= last_end.saturating_add(gap_tolerance) && new_end - last.0 <= max_merged
                {
                    last.1 = new_end - last.0;
                    continue;
                }
            }
            groups.push((off, len));
        }
        Self { inner, groups, buf: Vec::new(), buf_off: 0, buf_valid: 0, stats }
    }

    /// Merge groups the plan collapsed to (tests assert on the count).
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    fn group_for(&self, offset: u64) -> Option<(u64, u64)> {
        let idx = self.groups.partition_point(|&(off, _)| off <= offset);
        let (off, len) = *self.groups.get(idx.checked_sub(1)?)?;
        (offset < off + len).then_some((off, len))
    }

    /// Serve `buf` from the resident merge buffer if `offset` lies in its
    /// valid range; a request extending past the buffer gets a legal
    /// short read (the caller's fill loop continues past the group).
    fn serve_from_buffer(&mut self, offset: u64, buf: &mut [u8]) -> Option<usize> {
        let valid_end = self.buf_off + self.buf_valid as u64;
        if self.buf_valid == 0 || offset < self.buf_off || offset >= valid_end {
            return None;
        }
        let start = (offset - self.buf_off) as usize;
        let n = buf.len().min(self.buf_valid - start);
        buf[..n].copy_from_slice(&self.buf[start..start + n]);
        self.stats.requests_coalesced.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_merged.fetch_add(n as u64, Ordering::Relaxed);
        Some(n)
    }

    fn fill(&mut self, group_off: u64, group_len: u64) -> Result<(), SourceError> {
        self.buf_valid = 0; // invalidate first: a failed fill must not serve stale bytes
        self.buf.clear();
        self.buf.resize(group_len as usize, 0);
        self.buf_off = group_off;
        let mut done = 0usize;
        while done < group_len as usize {
            let n = self
                .inner
                .read_at(group_off + done as u64, &mut self.buf[done..])
                .map_err(|e| {
                    with_detail(e, format!("coalesced fill of {group_len} bytes at offset {group_off}"))
                })?;
            self.stats.syscalls.fetch_add(1, Ordering::Relaxed);
            if n == 0 {
                break; // end of source inside the group (truncated file)
            }
            done += n;
        }
        self.buf_valid = done;
        Ok(())
    }
}

impl<S: RangeSource> RangeSource for CoalescedSource<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        self.inner.size()
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        self.stats.reads_requested.fetch_add(1, Ordering::Relaxed);
        if buf.is_empty() {
            return Ok(0);
        }
        if let Some(n) = self.serve_from_buffer(offset, buf) {
            return Ok(n);
        }
        if let Some((group_off, group_len)) = self.group_for(offset) {
            self.fill(group_off, group_len)?;
            if let Some(n) = self.serve_from_buffer(offset, buf) {
                return Ok(n);
            }
            // The fill hit end-of-source before `offset`; fall through so
            // the inner source reports EOF authoritatively.
        }
        // Out-of-plan request (metadata probes, gap bytes between groups,
        // truncation tails): pass straight through.
        let n = self.inner.read_at(offset, buf)?;
        self.stats.syscalls.fetch_add(1, Ordering::Relaxed);
        Ok(n)
    }
}

/// Chunk size for materializing the [`MmapSource`] image.
const MMAP_LOAD_CHUNK: usize = 1 << 20;

/// Memory-mapped-style backend: the whole file is presented as one
/// in-memory image and every positioned read is a bounds-checked copy.
///
/// This is a **simulated** mapping — the offline build links no OS mmap
/// bindings, so the image is materialized once with large sequential
/// inner reads (1 MiB chunks, resumable across transient faults) rather
/// than `mmap(2)`. The observable contract is the mapped one: after the
/// image is resident no read touches the descriptor again, a read whose
/// range lies inside the file always succeeds in full, and a read past
/// the end observes end-of-source so [`read_full_at`] classifies
/// truncation as [`SourceError::Permanent`] instead of looping.
pub struct MmapSource<S> {
    inner: S,
    image: Vec<u8>,
    /// Progress cursor: a transient fault mid-load resumes here on the
    /// next call instead of rereading from zero.
    loaded: usize,
    len: Option<u64>,
    stats: Arc<IoStats>,
}

impl<S: RangeSource> MmapSource<S> {
    pub fn new(inner: S, stats: Arc<IoStats>) -> Self {
        Self { inner, image: Vec::new(), loaded: 0, len: None, stats }
    }

    fn ensure_resident(&mut self) -> Result<(), SourceError> {
        let len = match self.len {
            Some(len) => len,
            None => {
                let len = self.inner.size()?;
                self.image.resize(len as usize, 0);
                self.len = Some(len);
                len
            }
        };
        while (self.loaded as u64) < len {
            let end = self.image.len().min(self.loaded + MMAP_LOAD_CHUNK);
            let n = self
                .inner
                .read_at(self.loaded as u64, &mut self.image[self.loaded..end])
                .map_err(|e| {
                    with_detail(e, format!("mapping file image at offset {}", self.loaded))
                })?;
            self.stats.syscalls.fetch_add(1, Ordering::Relaxed);
            if n == 0 {
                // File shorter than its stat length: clamp the image so
                // the missing tail reads as end-of-source.
                self.image.truncate(self.loaded);
                self.len = Some(self.loaded as u64);
                break;
            }
            self.loaded += n;
        }
        Ok(())
    }
}

impl<S: RangeSource> RangeSource for MmapSource<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        self.ensure_resident()?;
        Ok(self.image.len() as u64)
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        self.ensure_resident()?;
        self.stats.reads_requested.fetch_add(1, Ordering::Relaxed);
        if offset >= self.image.len() as u64 {
            return Ok(0);
        }
        let start = offset as usize;
        let n = buf.len().min(self.image.len() - start);
        buf[..n].copy_from_slice(&self.image[start..start + n]);
        Ok(n)
    }
}

/// Pacing discipline for [`RemoteSource`] — *where* simulated wire time
/// is spent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RemotePacing {
    /// Block the calling thread. Right for a per-scan prefetch thread,
    /// which owns its chain outright: only that scan pays.
    Sleep,
    /// Never block: bank the wait into a shared nanosecond debt counter
    /// the caller settles where it chooses. The scan server uses this so
    /// a slow file charges its own query's delivery, never the shared
    /// worker pool.
    Deferred,
}

/// Connection model for the simulated remote store.
#[derive(Debug, Clone, Copy)]
pub struct RemoteSpec {
    /// Fixed per-request round-trip latency.
    pub latency: Duration,
    /// Link bandwidth in bytes/second (0 = unmetered).
    pub bandwidth: u64,
    /// Pipeline window: how many range requests may be in flight at
    /// once. Wired from the scan's prefetch depth — the latency-hiding
    /// knob.
    pub window: usize,
}

/// Mock high-latency byte-range store (HTTP/xrootd-shaped), grown out of
/// [`FaultSource`]'s latency injection into a connection model: every
/// `read_at` is one range request costing `latency + len/bandwidth`, and
/// up to `window` requests overlap on the simulated wire.
///
/// Request *i* completes at
/// `d_i = max(issue_i, d_(i-window)) + latency + len/bandwidth`, and the
/// caller only waits for the `(i-window)`-th deadline — the pipeline
/// slot freeing up — so a window of `w` sustains `w` requests per
/// latency period and the first `w` requests are free. Prefetch depth
/// therefore converts directly into hidden latency, which is what the
/// `io_backends` bench lanes measure.
pub struct RemoteSource<S> {
    inner: S,
    spec: RemoteSpec,
    pacing: RemotePacing,
    deadlines: VecDeque<Instant>,
    owed: Arc<AtomicU64>,
    stats: Arc<IoStats>,
}

impl<S: RangeSource> RemoteSource<S> {
    pub fn new(inner: S, spec: RemoteSpec, pacing: RemotePacing, stats: Arc<IoStats>) -> Self {
        Self::with_debt(inner, spec, pacing, Arc::new(AtomicU64::new(0)), stats)
    }

    /// Share the deferred-pacing debt counter (nanoseconds) with the
    /// caller. Only [`RemotePacing::Deferred`] accumulates into it.
    pub fn with_debt(
        inner: S,
        spec: RemoteSpec,
        pacing: RemotePacing,
        owed: Arc<AtomicU64>,
        stats: Arc<IoStats>,
    ) -> Self {
        Self {
            inner,
            spec: RemoteSpec { window: spec.window.max(1), ..spec },
            pacing,
            deadlines: VecDeque::new(),
            owed,
            stats,
        }
    }

    fn service_time(&self, bytes: usize) -> Duration {
        let wire = if self.spec.bandwidth > 0 {
            Duration::from_secs_f64(bytes as f64 / self.spec.bandwidth as f64)
        } else {
            Duration::ZERO
        };
        self.spec.latency + wire
    }

    /// Advance the pipeline clock for one request of `bytes` and pay (or
    /// bank) the wait for its slot.
    fn pace(&mut self, bytes: usize) {
        let service = self.service_time(bytes);
        if service.is_zero() {
            return;
        }
        let now = Instant::now();
        let gate = if self.deadlines.len() >= self.spec.window {
            self.deadlines.pop_front()
        } else {
            None
        };
        let start = match gate {
            Some(g) => g.max(now),
            None => now,
        };
        self.deadlines.push_back(start + service);
        let wait = start.saturating_duration_since(now);
        if wait.is_zero() {
            return;
        }
        match self.pacing {
            RemotePacing::Sleep => std::thread::sleep(wait),
            RemotePacing::Deferred => {
                self.owed.fetch_add(wait.as_nanos() as u64, Ordering::Relaxed);
            }
        }
    }
}

impl<S: RangeSource> RangeSource for RemoteSource<S> {
    fn size(&mut self) -> Result<u64, SourceError> {
        // Metadata probe, not a range request.
        self.inner.size()
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
        self.stats.reads_requested.fetch_add(1, Ordering::Relaxed);
        let n = self.inner.read_at(offset, buf)?;
        self.stats.syscalls.fetch_add(1, Ordering::Relaxed);
        self.pace(n.max(1));
        Ok(n)
    }
}

/// A composed source chain plus its per-chain observation handles.
pub struct SourceChain {
    pub source: Box<dyn RangeSource>,
    /// Retries performed by THIS chain only — never shared with another
    /// concurrently open chain over the same file.
    pub retries: Arc<AtomicU64>,
    /// Deferred remote-pacing debt in nanoseconds (stays 0 unless the
    /// backend is `remote-sim` under [`RemotePacing::Deferred`]).
    pub owed: Arc<AtomicU64>,
}

/// Assemble `FileSource → FaultSource? → backend → RetrySource?` for
/// `path` under `io`.
///
/// * `plan` — exact `(offset, len)` disk extents the caller will read
///   (only the coalesced backend consumes it).
/// * `window` — the scan's prefetch depth (only remote-sim consumes it).
/// * `extra_retry_sinks` — additional counters every retry is billed to
///   (e.g. a reader-lifetime cumulative), on top of the fresh per-chain
///   counter returned in [`SourceChain::retries`].
pub fn compose_chain(
    path: &Path,
    io: &IoConfig,
    plan: &[(u64, u64)],
    window: usize,
    pacing: RemotePacing,
    io_stats: Arc<IoStats>,
    fault_stats: Arc<FaultStats>,
    extra_retry_sinks: &[Arc<AtomicU64>],
) -> Result<SourceChain> {
    let mut source: Box<dyn RangeSource> = Box::new(FileSource::open(path)?);
    if let Some(spec) = io.faults {
        source = Box::new(FaultSource::with_stats(source, spec, fault_stats));
    }
    let owed = Arc::new(AtomicU64::new(0));
    source = match io.backend {
        IoBackend::Pread => Box::new(CountingSource::new(source, io_stats)),
        IoBackend::Coalesced => Box::new(CoalescedSource::new(
            source,
            plan,
            io.gap_tolerance,
            io.max_merged,
            io_stats,
        )),
        IoBackend::Mmap => Box::new(MmapSource::new(source, io_stats)),
        IoBackend::RemoteSim => Box::new(RemoteSource::with_debt(
            source,
            RemoteSpec { latency: io.latency, bandwidth: io.bandwidth, window },
            pacing,
            Arc::clone(&owed),
            io_stats,
        )),
    };
    let retries = Arc::new(AtomicU64::new(0));
    if !io.retry.is_disabled() {
        let mut retry = RetrySource::new(source, io.retry, Arc::clone(&retries));
        for sink in extra_retry_sinks {
            retry = retry.also_count(Arc::clone(sink));
        }
        source = Box::new(retry);
    }
    Ok(SourceChain { source, retries, owed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfile::format;
    use std::io::Cursor;

    /// In-memory source for deterministic unit tests.
    struct MemSource(Vec<u8>);

    impl RangeSource for MemSource {
        fn size(&mut self) -> Result<u64, SourceError> {
            Ok(self.0.len() as u64)
        }
        fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
            let off = (offset as usize).min(self.0.len());
            let n = buf.len().min(self.0.len() - off);
            buf[..n].copy_from_slice(&self.0[off..off + n]);
            Ok(n)
        }
    }

    /// Serves at most `chunk` bytes per read — exercises the fill loop.
    struct ChunkySource {
        inner: MemSource,
        chunk: usize,
    }

    impl RangeSource for ChunkySource {
        fn size(&mut self) -> Result<u64, SourceError> {
            self.inner.size()
        }
        fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
            let want = buf.len().min(self.chunk);
            self.inner.read_at(offset, &mut buf[..want])
        }
    }

    /// Fails transiently `fail` times, then serves.
    struct FlakySource {
        inner: MemSource,
        fail: u32,
    }

    impl RangeSource for FlakySource {
        fn size(&mut self) -> Result<u64, SourceError> {
            self.inner.size()
        }
        fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize, SourceError> {
            if self.fail > 0 {
                self.fail -= 1;
                return Err(SourceError::Transient("flaky".into()));
            }
            self.inner.read_at(offset, buf)
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rootio_source_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn read_full_at_loops_over_short_reads() {
        let data: Vec<u8> = (0..=255u8).collect();
        let mut src = ChunkySource { inner: MemSource(data.clone()), chunk: 7 };
        let mut buf = vec![0u8; 100];
        read_full_at(&mut src, 30, &mut buf).unwrap();
        assert_eq!(buf, &data[30..130]);
    }

    #[test]
    fn truncation_is_an_explicit_permanent_error() {
        let mut src = MemSource((0..64u8).collect());
        let mut buf = vec![0u8; 32];
        let err = read_full_at(&mut src, 48, &mut buf).unwrap_err();
        assert!(!err.is_transient());
        let msg = err.to_string();
        assert!(
            msg.contains("expected 32 bytes at offset 48") && msg.contains("got 16"),
            "unhelpful truncation error: {msg}"
        );
    }

    #[test]
    fn file_source_serves_ranges_and_reports_eof() {
        let path = tmp("filesource");
        std::fs::write(&path, (0..200u32).flat_map(|i| i.to_be_bytes()).collect::<Vec<_>>())
            .unwrap();
        let mut src = FileSource::open(&path).unwrap();
        assert_eq!(src.size().unwrap(), 800);
        let mut buf = [0u8; 4];
        read_full_at(&mut src, 4 * 7, &mut buf).unwrap();
        assert_eq!(u32::from_be_bytes(buf), 7);
        // Past-EOF fill is a truncation error, not a panic or a hang.
        let mut big = vec![0u8; 16];
        assert!(read_full_at(&mut src, 792, &mut big).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn record_read_parity_with_format_layer() {
        // A record stream built by the format layer parses identically
        // through a RangeSource.
        let mut buf = Cursor::new(Vec::<u8>::new());
        let pos = format::write_header(&mut buf).unwrap();
        format::write_record(&mut buf, pos, RecordKind::Basket, b"the payload").unwrap();
        let bytes = buf.into_inner();

        let mut src = ChunkySource { inner: MemSource(bytes.clone()), chunk: 3 };
        let mut payload = Vec::new();
        let kind = read_record_from(&mut src, pos, &mut payload).unwrap();
        assert_eq!(kind, RecordKind::Basket);
        assert_eq!(payload, b"the payload");

        let mut oracle = Cursor::new(bytes);
        let mut expect = Vec::new();
        let k2 = format::read_record_at_into(&mut oracle, pos, &mut expect).unwrap();
        assert_eq!((kind, &payload), (k2, &expect));
    }

    #[test]
    fn record_read_rejects_garbage_frames() {
        // Implausible length.
        let mut bad = vec![0xFFu8; 16];
        bad[4] = 1;
        let mut payload = Vec::new();
        let err = read_record_from(&mut MemSource(bad), 0, &mut payload).unwrap_err();
        assert!(err.to_string().contains("implausible record length"), "{err}");
        // Unknown kind.
        let mut frame = 9u32.to_be_bytes().to_vec();
        frame.push(200);
        frame.extend_from_slice(b"body");
        let err = read_record_from(&mut MemSource(frame), 0, &mut payload).unwrap_err();
        assert!(err.to_string().contains("unknown record kind"), "{err}");
        // Truncated payload.
        let mut frame = 105u32.to_be_bytes().to_vec();
        frame.push(1);
        frame.extend_from_slice(&[7u8; 10]);
        let err = read_record_from(&mut MemSource(frame), 0, &mut payload).unwrap_err();
        assert!(err.to_string().contains("file truncated"), "{err}");
    }

    #[test]
    fn retry_recovers_transient_failures_and_counts_them() {
        let data: Vec<u8> = (0..99u8).collect();
        let counter = Arc::new(AtomicU64::new(0));
        let flaky = FlakySource { inner: MemSource(data.clone()), fail: 2 };
        let policy = RetryPolicy {
            max_attempts: 4,
            base_delay: Duration::ZERO,
            backoff: 2.0,
            max_delay: Duration::ZERO,
        };
        let mut src = RetrySource::new(flaky, policy, Arc::clone(&counter));
        let mut buf = vec![0u8; 10];
        read_full_at(&mut src, 5, &mut buf).unwrap();
        assert_eq!(buf, &data[5..15]);
        assert_eq!(counter.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn retry_exhaustion_and_disabled_policy_surface_the_error() {
        let counter = Arc::new(AtomicU64::new(0));
        let flaky = FlakySource { inner: MemSource(vec![0; 8]), fail: 10 };
        let policy = RetryPolicy {
            max_attempts: 3,
            base_delay: Duration::ZERO,
            backoff: 1.0,
            max_delay: Duration::ZERO,
        };
        let mut src = RetrySource::new(flaky, policy, Arc::clone(&counter));
        let mut buf = [0u8; 4];
        let err = src.read_at(0, &mut buf).unwrap_err();
        assert!(err.is_transient());
        assert!(err.to_string().contains("after 3 attempts"), "{err}");
        assert_eq!(counter.load(Ordering::Relaxed), 2, "two retries for three attempts");

        let flaky = FlakySource { inner: MemSource(vec![0; 8]), fail: 1 };
        let mut src =
            RetrySource::new(flaky, RetryPolicy::disabled(), Arc::new(AtomicU64::new(0)));
        assert!(src.read_at(0, &mut buf).is_err(), "disabled policy must not retry");
    }

    #[test]
    fn retry_does_not_touch_permanent_errors() {
        let counter = Arc::new(AtomicU64::new(0));
        // MemSource returns 0 bytes past EOF; read_full_at turns that into
        // a Permanent truncation which the retry layer must pass through.
        let mut src =
            RetrySource::new(MemSource(vec![1; 4]), RetryPolicy::default(), Arc::clone(&counter));
        let mut buf = [0u8; 8];
        let err = read_full_at(&mut src, 0, &mut buf).unwrap_err();
        assert!(!err.is_transient());
        assert_eq!(counter.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn backoff_delays_grow_and_cap() {
        let p = RetryPolicy {
            max_attempts: 8,
            base_delay: Duration::from_millis(2),
            backoff: 3.0,
            max_delay: Duration::from_millis(20),
        };
        assert_eq!(p.delay_for(1), Duration::from_millis(2));
        assert_eq!(p.delay_for(2), Duration::from_millis(6));
        assert_eq!(p.delay_for(3), Duration::from_millis(18));
        assert_eq!(p.delay_for(4), Duration::from_millis(20), "capped");
        assert_eq!(p.delay_for(30), Duration::from_millis(20), "still capped");
    }

    #[test]
    fn fault_schedule_is_deterministic_for_a_seed() {
        let data: Vec<u8> = (0..255u8).cycle().take(4096).collect();
        let spec = FaultSpec {
            seed: 0xFA_017,
            transient: 0.3,
            short_read: 0.4,
            bit_flip: 0.2,
            max_consecutive: 2,
            ..FaultSpec::default()
        };
        let run = |spec: FaultSpec| {
            let mut src = FaultSource::new(MemSource(data.clone()), spec);
            let stats = src.stats();
            let mut outcomes = Vec::new();
            let mut buf = vec![0u8; 64];
            for i in 0..200u64 {
                match src.read_at((i * 13) % 4000, &mut buf) {
                    Ok(n) => outcomes.push((n as i64, buf[..n].to_vec())),
                    Err(e) => outcomes.push((-1, e.to_string().into_bytes())),
                }
            }
            (outcomes, stats.total())
        };
        let (a, fa) = run(spec);
        let (b, fb) = run(spec);
        assert_eq!(a, b, "same seed must replay the same fault schedule");
        assert_eq!(fa, fb);
        assert!(fa > 0, "fault plan never fired");
        let (c, _) = run(FaultSpec { seed: 0xFA_018, ..spec });
        assert_ne!(a, c, "different seed should change the schedule");
    }

    #[test]
    fn consecutive_transient_cap_guarantees_retry_recovery() {
        // With transient probability 1.0 the cap forces every third read
        // to succeed, so a retry policy with more attempts always wins.
        let data = vec![42u8; 256];
        let spec = FaultSpec {
            seed: 7,
            transient: 1.0,
            max_consecutive: 2,
            ..FaultSpec::default()
        };
        let faulty = FaultSource::new(MemSource(data.clone()), spec);
        let policy = RetryPolicy {
            max_attempts: 4,
            base_delay: Duration::ZERO,
            backoff: 1.0,
            max_delay: Duration::ZERO,
        };
        let mut src = RetrySource::new(faulty, policy, Arc::new(AtomicU64::new(0)));
        let mut buf = vec![0u8; 16];
        for i in 0..20 {
            read_full_at(&mut src, i * 8, &mut buf).unwrap();
            assert_eq!(buf, vec![42u8; 16]);
        }
    }

    #[test]
    fn file_id_is_stable_until_the_file_changes() {
        let path = tmp("fileid");
        std::fs::write(&path, b"original contents").unwrap();
        let a = FileId::of_path(&path).unwrap();
        let b = FileId::of_path(&path).unwrap();
        assert_eq!(a, b, "re-stat of an unmodified file must agree");
        assert_eq!(format!("{a}").len(), 16, "display is fixed-width hex");

        // Rewriting the file (different length) must change the identity:
        // a cache keyed on FileId can never serve stale baskets.
        std::fs::write(&path, b"rewritten with different length").unwrap();
        let c = FileId::of_path(&path).unwrap();
        assert_ne!(a, c, "rewritten file must get a new identity");

        // A different file gets a different identity.
        let other = tmp("fileid_other");
        std::fs::write(&other, b"original contents").unwrap();
        let d = FileId::of_path(&other).unwrap();
        assert_ne!(c, d);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&other).ok();
    }

    #[test]
    fn bit_flips_corrupt_payloads() {
        let data = vec![0u8; 1024];
        let spec = FaultSpec { seed: 99, bit_flip: 1.0, ..FaultSpec::default() };
        let mut src = FaultSource::new(MemSource(data), spec);
        let stats = src.stats();
        let mut buf = vec![0u8; 128];
        let n = src.read_at(0, &mut buf).unwrap();
        assert!(buf[..n].iter().any(|&b| b != 0), "flip must land in the returned bytes");
        assert_eq!(stats.bit_flips.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn file_source_exact_eof_boundary() {
        // A fill whose last byte is the file's last byte succeeds; one
        // byte past must classify as Permanent truncation — and neither
        // may loop.
        let path = tmp("exact_eof");
        let data: Vec<u8> = (0..64u8).collect();
        std::fs::write(&path, &data).unwrap();
        let mut src = FileSource::open(&path).unwrap();

        let mut last16 = [0u8; 16];
        read_full_at(&mut src, 48, &mut last16).unwrap();
        assert_eq!(last16, &data[48..64]);

        let mut past = [0u8; 16];
        let err = read_full_at(&mut src, 49, &mut past).unwrap_err();
        assert!(!err.is_transient(), "EOF shortfall must be Permanent: {err}");
        assert!(err.to_string().contains("file truncated"), "{err}");

        // Raw read_at at and past EOF reports end-of-source, not an error.
        let mut buf = [0u8; 8];
        assert_eq!(src.read_at(64, &mut buf).unwrap(), 0, "read at len is EOF");
        assert_eq!(src.read_at(65, &mut buf).unwrap(), 0, "read past len is EOF");
        // A read straddling EOF serves exactly the remaining bytes.
        assert_eq!(src.read_at(60, &mut buf).unwrap(), 4);
        assert_eq!(buf[..4], data[60..64]);
        std::fs::remove_file(&path).ok();
    }

    fn io_stats() -> Arc<IoStats> {
        Arc::new(IoStats::default())
    }

    #[test]
    fn coalesced_merges_adjacent_plan_entries_into_one_read() {
        // Three back-to-back records read the way the prefetcher reads
        // them (header + body each): 6 logical requests, ONE syscall.
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let plan = [(100u64, 50u64), (150, 60), (210, 40)];
        let stats = io_stats();
        let mut src =
            CoalescedSource::new(MemSource(data.clone()), &plan, 0, 1 << 20, Arc::clone(&stats));
        assert_eq!(src.group_count(), 1, "adjacent spans must merge");
        for &(off, len) in &plan {
            let mut hdr = [0u8; 5];
            read_full_at(&mut src, off, &mut hdr).unwrap();
            assert_eq!(hdr, &data[off as usize..off as usize + 5]);
            let mut body = vec![0u8; len as usize - 5];
            read_full_at(&mut src, off + 5, &mut body).unwrap();
            assert_eq!(body, &data[off as usize + 5..(off + len) as usize]);
        }
        assert_eq!(stats.syscalls(), 1, "k adjacent plan entries must coalesce to 1 read");
        assert_eq!(stats.reads_requested.load(Ordering::Relaxed), 6);
        assert_eq!(stats.requests_coalesced(), 6);
        assert_eq!(stats.bytes_merged(), 150);
    }

    #[test]
    fn coalesced_gap_tolerance_and_max_merged_split_groups() {
        let data = vec![7u8; 8192];
        // Gaps of 10 bytes between spans: tolerance 9 splits, 10 merges.
        let plan = [(0u64, 100u64), (110, 100), (220, 100)];
        let tight = CoalescedSource::new(MemSource(data.clone()), &plan, 9, 1 << 20, io_stats());
        assert_eq!(tight.group_count(), 3);
        let loose = CoalescedSource::new(MemSource(data.clone()), &plan, 10, 1 << 20, io_stats());
        assert_eq!(loose.group_count(), 1);
        // max_merged caps group growth even with a permissive gap.
        let capped = CoalescedSource::new(MemSource(data), &plan, 1 << 20, 250, io_stats());
        assert_eq!(capped.group_count(), 2, "320-byte merge exceeds the 250-byte cap");
    }

    #[test]
    fn coalesced_is_byte_identical_to_inner_including_fallbacks() {
        // Requests inside, straddling, and outside plan spans all return
        // the same bytes the inner source would — through a chunky inner
        // that forces the fill loop to iterate.
        let data: Vec<u8> = (0..4096u32).map(|i| (i * 31 % 251) as u8).collect();
        let plan = [(64u64, 200u64), (300, 120), (1000, 500)];
        let stats = io_stats();
        let inner = ChunkySource { inner: MemSource(data.clone()), chunk: 37 };
        let mut src = CoalescedSource::new(inner, &plan, 16, 1 << 20, Arc::clone(&stats));
        let cases: &[(u64, usize)] = &[
            (64, 200),   // exact span
            (300, 120),  // second group (may refill)
            (100, 400),  // straddles group end into gap + next group
            (0, 64),     // before any span
            (3000, 300), // far outside the plan
            (1100, 100), // interior slice of a span
        ];
        for &(off, len) in cases {
            let mut got = vec![0u8; len];
            read_full_at(&mut src, off, &mut got).unwrap();
            assert_eq!(got, &data[off as usize..off as usize + len], "range {off}+{len}");
        }
        // Truncation past EOF still classifies Permanent through the layer.
        let mut tail = vec![0u8; 64];
        let err = read_full_at(&mut src, 4090, &mut tail).unwrap_err();
        assert!(!err.is_transient());
    }

    #[test]
    fn coalesced_fill_failures_are_retryable() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1024).collect();
        let plan = [(0u64, 512u64)];
        let stats = io_stats();
        let flaky = FlakySource { inner: MemSource(data.clone()), fail: 2 };
        let coalesced = CoalescedSource::new(flaky, &plan, 0, 1 << 20, Arc::clone(&stats));
        let policy = RetryPolicy {
            max_attempts: 4,
            base_delay: Duration::ZERO,
            backoff: 1.0,
            max_delay: Duration::ZERO,
        };
        let counter = Arc::new(AtomicU64::new(0));
        let mut src = RetrySource::new(coalesced, policy, Arc::clone(&counter));
        let mut buf = vec![0u8; 128];
        read_full_at(&mut src, 100, &mut buf).unwrap();
        assert_eq!(buf, &data[100..228]);
        assert_eq!(counter.load(Ordering::Relaxed), 2, "both transient fills retried");
    }

    #[test]
    fn mmap_source_serves_image_and_classifies_truncation() {
        let data: Vec<u8> = (0..2000u32).flat_map(|i| (i as u16).to_le_bytes()).collect();
        let stats = io_stats();
        let mut src = MmapSource::new(MemSource(data.clone()), Arc::clone(&stats));
        assert_eq!(src.size().unwrap(), data.len() as u64);
        let load_syscalls = stats.syscalls();
        assert!(load_syscalls >= 1);
        let mut buf = vec![0u8; 333];
        for pass in 0..10u64 {
            read_full_at(&mut src, pass * 137, &mut buf).unwrap();
            let off = (pass * 137) as usize;
            assert_eq!(buf, &data[off..off + 333]);
        }
        assert_eq!(stats.syscalls(), load_syscalls, "resident image must not re-read");
        // At-EOF and past-EOF behave exactly like pread.
        let len = data.len() as u64;
        let mut probe = [0u8; 8];
        assert_eq!(src.read_at(len, &mut probe).unwrap(), 0);
        assert_eq!(src.read_at(len + 10, &mut probe).unwrap(), 0);
        let err = read_full_at(&mut src, len - 4, &mut probe).unwrap_err();
        assert!(!err.is_transient(), "truncation through mmap must stay Permanent");
    }

    #[test]
    fn mmap_load_resumes_after_transient_faults() {
        let data: Vec<u8> = (0..=255u8).cycle().take(3000).collect();
        let flaky = FlakySource { inner: MemSource(data.clone()), fail: 1 };
        let mut src = MmapSource::new(flaky, io_stats());
        let mut buf = vec![0u8; 100];
        let err = src.read_at(0, &mut buf).unwrap_err();
        assert!(err.is_transient(), "load fault must surface as retryable: {err}");
        // The next attempt resumes the load and serves correct bytes.
        read_full_at(&mut src, 2900, &mut buf).unwrap();
        assert_eq!(buf, &data[2900..3000]);
    }

    #[test]
    fn remote_window_hides_latency() {
        let data = vec![1u8; 4096];
        let run = |window: usize| {
            let spec = RemoteSpec {
                latency: Duration::from_millis(4),
                bandwidth: 0,
                window,
            };
            let mut src = RemoteSource::new(
                MemSource(data.clone()),
                spec,
                RemotePacing::Sleep,
                io_stats(),
            );
            let mut buf = vec![0u8; 64];
            let t0 = Instant::now();
            for i in 0..12u64 {
                src.read_at(i * 64, &mut buf).unwrap();
            }
            t0.elapsed()
        };
        let narrow = run(1);
        let wide = run(16);
        // 12 requests at window 1 serialize ~11 waits of 4 ms; window 16
        // never gates. Compare relatively so CI jitter cannot flake it.
        assert!(
            wide * 3 < narrow,
            "wide window must hide latency: narrow={narrow:?} wide={wide:?}"
        );
        assert!(narrow >= Duration::from_millis(20), "narrow window must pay: {narrow:?}");
    }

    #[test]
    fn remote_deferred_banks_debt_instead_of_sleeping() {
        let data = vec![1u8; 1024];
        let spec = RemoteSpec { latency: Duration::from_millis(5), bandwidth: 0, window: 1 };
        let owed = Arc::new(AtomicU64::new(0));
        let mut src = RemoteSource::with_debt(
            MemSource(data),
            spec,
            RemotePacing::Deferred,
            Arc::clone(&owed),
            io_stats(),
        );
        let mut buf = vec![0u8; 32];
        for i in 0..4u64 {
            src.read_at(i * 32, &mut buf).unwrap();
        }
        let banked = Duration::from_nanos(owed.load(Ordering::Relaxed));
        assert!(
            banked >= Duration::from_millis(12),
            "3 gated requests at 5 ms must bank >=12 ms, got {banked:?}"
        );
    }

    #[test]
    fn remote_bandwidth_charges_bytes() {
        // 1 MiB/s link, 100 KiB read, window 1: the second request waits
        // for the first's wire time (~100 ms) even with zero latency.
        let data = vec![9u8; 300 * 1024];
        let spec = RemoteSpec { latency: Duration::ZERO, bandwidth: 1 << 20, window: 1 };
        let owed = Arc::new(AtomicU64::new(0));
        let mut src = RemoteSource::with_debt(
            MemSource(data),
            spec,
            RemotePacing::Deferred,
            Arc::clone(&owed),
            io_stats(),
        );
        let mut buf = vec![0u8; 100 * 1024];
        src.read_at(0, &mut buf).unwrap();
        src.read_at(100 * 1024, &mut buf).unwrap();
        let banked = Duration::from_nanos(owed.load(Ordering::Relaxed));
        assert!(banked >= Duration::from_millis(80), "wire time must gate: {banked:?}");
    }

    #[test]
    fn io_backend_parse_roundtrips() {
        for backend in IoBackend::all() {
            assert_eq!(IoBackend::parse(backend.as_str()), Some(backend));
            assert_eq!(format!("{backend}"), backend.as_str());
        }
        assert_eq!(IoBackend::parse("remote"), Some(IoBackend::RemoteSim));
        assert_eq!(IoBackend::parse("o_direct"), None);
    }

    #[test]
    fn compose_chain_keeps_retry_counters_per_chain() {
        let path = tmp("compose_chain");
        let data: Vec<u8> = (0..=255u8).cycle().take(2048).collect();
        std::fs::write(&path, &data).unwrap();
        let io = IoConfig {
            faults: Some(FaultSpec {
                seed: 11,
                transient: 0.6,
                max_consecutive: 2,
                ..FaultSpec::default()
            }),
            retry: RetryPolicy {
                max_attempts: 4,
                base_delay: Duration::ZERO,
                backoff: 1.0,
                max_delay: Duration::ZERO,
            },
            ..IoConfig::default()
        };
        let cumulative = Arc::new(AtomicU64::new(0));
        let drive = |seed: u64| {
            let io = IoConfig {
                faults: Some(FaultSpec { seed, ..io.faults.unwrap() }),
                ..io
            };
            let chain = compose_chain(
                &path,
                &io,
                &[],
                4,
                RemotePacing::Sleep,
                Arc::new(IoStats::default()),
                Arc::new(FaultStats::default()),
                &[Arc::clone(&cumulative)],
            )
            .unwrap();
            let mut source = chain.source;
            let mut buf = vec![0u8; 64];
            for i in 0..16u64 {
                read_full_at(&mut source, i * 100, &mut buf).unwrap();
                assert_eq!(buf, &data[(i * 100) as usize..(i * 100) as usize + 64]);
            }
            chain.retries.load(Ordering::Relaxed)
        };
        let a = drive(11);
        let b = drive(12);
        assert!(a > 0 && b > 0, "fault plans must have fired: a={a} b={b}");
        assert_eq!(
            cumulative.load(Ordering::Relaxed),
            a + b,
            "extra sink accumulates across chains while per-chain counters stay isolated"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compose_chain_backends_read_identical_bytes() {
        let path = tmp("compose_backends");
        let data: Vec<u8> = (0..5000u32).map(|i| (i * 17 % 241) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let plan = [(0u64, 1000u64), (1000, 1000), (2500, 800)];
        for backend in IoBackend::all() {
            let io = IoConfig { backend, ..IoConfig::default() };
            let chain = compose_chain(
                &path,
                &io,
                &plan,
                8,
                RemotePacing::Sleep,
                Arc::new(IoStats::default()),
                Arc::new(FaultStats::default()),
                &[],
            )
            .unwrap();
            let mut source = chain.source;
            assert_eq!(source.size().unwrap(), data.len() as u64, "{backend}");
            let mut buf = vec![0u8; 800];
            for &(off, _) in &plan {
                read_full_at(&mut source, off, &mut buf).unwrap();
                assert_eq!(buf, &data[off as usize..off as usize + 800], "{backend} at {off}");
            }
        }
        std::fs::remove_file(&path).ok();
    }
}
