//! Baskets: the unit of compression in ROOT I/O (paper Fig 1 — "buffers
//! are then compressed and written into disk ... referred to as
//! 'baskets'").
//!
//! A basket's *logical* payload is the serialized branch data followed by
//! the per-entry byte-offset array for variable-sized branches — the exact
//! two-array layout whose offset half defeats plain LZ4 (paper §2.2). The
//! logical payload is compressed as one unit through the engine.

use crate::compression::{Engine, EngineError, Settings};
use crate::util::varint::{put_uvarint, Cursor};

/// An uncompressed basket ready for compression + commit.
#[derive(Debug, Clone, PartialEq)]
pub struct PendingBasket {
    pub branch_id: u32,
    pub basket_index: u32,
    /// First entry number in this basket.
    pub first_entry: u64,
    pub n_entries: u32,
    /// Serialized element data (big-endian).
    pub data: Vec<u8>,
    /// End-of-entry byte offsets within `data` (one per entry), present for
    /// variable-sized branches; empty otherwise.
    pub offsets: Vec<u32>,
}

impl PendingBasket {
    /// Logical (pre-compression) payload: data then big-endian offsets.
    /// ROOT serializes the offset array as 32-bit ints in the same buffer;
    /// the paper's "1, 2, 3, 4" example is exactly this array.
    pub fn logical_payload(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.logical_len());
        self.logical_payload_into(&mut out);
        out
    }

    /// Append the logical payload to a caller-provided (reusable) buffer.
    pub fn logical_payload_into(&self, out: &mut Vec<u8>) {
        out.reserve(self.logical_len());
        out.extend_from_slice(&self.data);
        for &o in &self.offsets {
            out.extend_from_slice(&o.to_be_bytes());
        }
    }

    pub fn logical_len(&self) -> usize {
        self.data.len() + self.offsets.len() * 4
    }

    /// Tear down a consumed basket into its two backing buffers (cleared,
    /// capacity kept) so sinks can recycle them through the pipeline's
    /// [`crate::util::pool::BufferPool`] / [`crate::util::pool::OffsetPool`]
    /// instead of freeing and re-growing them once per basket (§Perf).
    pub fn into_buffers(self) -> (Vec<u8>, Vec<u32>) {
        let PendingBasket { mut data, mut offsets, .. } = self;
        data.clear();
        offsets.clear();
        (data, offsets)
    }
}

/// On-disk basket payload (after the record-key framing):
/// `[uvarint n_entries][uvarint data_len][uvarint n_offsets][engine blob]`.
pub fn encode_basket(
    b: &PendingBasket,
    settings: &Settings,
    engine: &mut Engine,
) -> Vec<u8> {
    let mut logical = Vec::new();
    let mut out = Vec::with_capacity(b.logical_len() / 2 + 16);
    encode_basket_into(b, settings, engine, &mut logical, &mut out);
    out
}

/// Zero-alloc variant (§Perf): appends the encoded basket to `out` using
/// `logical_scratch` for the intermediate logical payload. Both buffers are
/// caller-owned so pipeline workers can recycle them across baskets; `out`
/// is appended to (not cleared) so record framing can precede it.
pub fn encode_basket_into(
    b: &PendingBasket,
    settings: &Settings,
    engine: &mut Engine,
    logical_scratch: &mut Vec<u8>,
    out: &mut Vec<u8>,
) {
    logical_scratch.clear();
    b.logical_payload_into(logical_scratch);
    put_uvarint(out, b.n_entries as u64);
    put_uvarint(out, b.data.len() as u64);
    put_uvarint(out, b.offsets.len() as u64);
    engine.compress_append(logical_scratch, settings, out);
}

/// Decoded basket content.
#[derive(Debug, Clone, PartialEq)]
pub struct BasketContent {
    pub n_entries: u32,
    pub data: Vec<u8>,
    pub offsets: Vec<u32>,
}

/// Decode + decompress an on-disk basket payload.
pub fn decode_basket(payload: &[u8], engine: &mut Engine) -> Result<BasketContent, EngineError> {
    let mut content = BasketContent { n_entries: 0, data: Vec::new(), offsets: Vec::new() };
    let mut logical_scratch = Vec::new();
    decode_basket_into(payload, engine, &mut logical_scratch, &mut content)?;
    Ok(content)
}

/// Zero-alloc variant (§Perf): decodes into caller-owned buffers, the read
/// twin of [`encode_basket_into`]. `logical_scratch` holds the decompressed
/// logical payload between the engine and the data/offset split;
/// `content.data` / `content.offsets` are cleared and refilled, so
/// read-pipeline workers can rent them from a
/// [`crate::util::pool::BufferPool`] / [`crate::util::pool::OffsetPool`] and
/// consumers can recycle them after use.
pub fn decode_basket_into(
    payload: &[u8],
    engine: &mut Engine,
    logical_scratch: &mut Vec<u8>,
    content: &mut BasketContent,
) -> Result<(), EngineError> {
    let mut c = Cursor::new(payload);
    let n_entries = c.uvarint().ok_or_else(|| EngineError("basket header truncated".into()))? as u32;
    let data_len = c.uvarint().ok_or_else(|| EngineError("basket header truncated".into()))? as usize;
    let n_offsets = c.uvarint().ok_or_else(|| EngineError("basket header truncated".into()))? as usize;
    let blob = &payload[c.pos()..];
    engine.decompress_into(blob, logical_scratch)?;
    if logical_scratch.len() != data_len + n_offsets * 4 {
        return Err(EngineError(format!(
            "basket logical size mismatch: {} != {} + 4*{}",
            logical_scratch.len(),
            data_len,
            n_offsets
        )));
    }
    let (data, off_bytes) = logical_scratch.split_at(data_len);
    content.n_entries = n_entries;
    content.data.clear();
    content.data.extend_from_slice(data);
    content.offsets.clear();
    content.offsets.reserve(n_offsets);
    for ch in off_bytes.chunks_exact(4) {
        content.offsets.push(u32::from_be_bytes(ch.try_into().unwrap()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Algorithm, Settings};
    use crate::precond::Precond;
    use crate::util::rng::Rng;

    fn sample_basket(seed: u64) -> PendingBasket {
        let mut rng = Rng::new(seed);
        let n = rng.range(1, 500);
        let mut data = Vec::new();
        let mut offsets = Vec::new();
        for _ in 0..n {
            let k = rng.range(0, 5);
            for _ in 0..k {
                data.extend_from_slice(&(rng.f32() * 100.0).to_be_bytes());
            }
            offsets.push(data.len() as u32);
        }
        PendingBasket {
            branch_id: 3,
            basket_index: 7,
            first_entry: 1000,
            n_entries: n as u32,
            data,
            offsets,
        }
    }

    #[test]
    fn roundtrip_with_various_settings() {
        let mut engine = Engine::new();
        let b = sample_basket(42);
        for s in [
            Settings::new(Algorithm::Zlib, 6),
            Settings::new(Algorithm::Lz4, 1),
            Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
            Settings::new(Algorithm::Zstd, 5),
            Settings::new(Algorithm::None, 0),
        ] {
            let enc = encode_basket(&b, &s, &mut engine);
            let dec = decode_basket(&enc, &mut engine).unwrap();
            assert_eq!(dec.n_entries, b.n_entries);
            assert_eq!(dec.data, b.data);
            assert_eq!(dec.offsets, b.offsets);
        }
    }

    #[test]
    fn offset_array_is_big_endian_in_payload() {
        // The paper's example: single-byte entries produce offsets 1,2,3...
        let b = PendingBasket {
            branch_id: 0,
            basket_index: 0,
            first_entry: 0,
            n_entries: 3,
            data: vec![b'a', b'b', b'c'],
            offsets: vec![1, 2, 3],
        };
        let logical = b.logical_payload();
        assert_eq!(
            logical,
            vec![b'a', b'b', b'c', 0, 0, 0, 1, 0, 0, 0, 2, 0, 0, 0, 3]
        );
    }

    #[test]
    fn corrupt_basket_rejected() {
        let mut engine = Engine::new();
        let b = sample_basket(7);
        let mut enc = encode_basket(&b, &Settings::new(Algorithm::Zlib, 1), &mut engine);
        let n = enc.len();
        enc[n / 2] ^= 0x5A;
        match decode_basket(&enc, &mut engine) {
            Err(_) => {}
            Ok(d) => assert_ne!(d.data, b.data),
        }
    }
}
