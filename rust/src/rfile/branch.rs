//! Branch schema and value serialization.
//!
//! Mirrors ROOT's TTree semantics at the level the paper depends on:
//! columnar branches serialized big-endian into baskets (Fig 1), with
//! variable-sized branches producing *two* internal arrays — the element
//! data and the per-entry byte offsets — whose interaction with LZ4 drives
//! the paper's Fig 6.

use crate::compression::Settings;
use crate::util::varint::{put_lp_bytes, put_uvarint, Cursor};

/// Element type of a branch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BranchType {
    F32,
    F64,
    I32,
    I64,
    U8,
    /// Variable-length array of f32 per entry (jagged).
    VarF32,
    /// Variable-length array of i32 per entry (jagged).
    VarI32,
    /// Variable-length byte array per entry (e.g. strings).
    VarU8,
    /// Boolean flags stored as one byte (HLT bits etc.).
    Bool,
}

impl BranchType {
    pub fn is_var(&self) -> bool {
        matches!(self, BranchType::VarF32 | BranchType::VarI32 | BranchType::VarU8)
    }

    /// Element width in bytes (the natural preconditioner stride).
    pub fn elem_size(&self) -> usize {
        match self {
            BranchType::F32 | BranchType::I32 | BranchType::VarF32 | BranchType::VarI32 => 4,
            BranchType::F64 | BranchType::I64 => 8,
            BranchType::U8 | BranchType::VarU8 | BranchType::Bool => 1,
        }
    }

    pub fn code(&self) -> u8 {
        match self {
            BranchType::F32 => 0,
            BranchType::F64 => 1,
            BranchType::I32 => 2,
            BranchType::I64 => 3,
            BranchType::U8 => 4,
            BranchType::VarF32 => 5,
            BranchType::VarI32 => 6,
            BranchType::VarU8 => 7,
            BranchType::Bool => 8,
        }
    }

    pub fn from_code(c: u8) -> Option<Self> {
        Some(match c {
            0 => BranchType::F32,
            1 => BranchType::F64,
            2 => BranchType::I32,
            3 => BranchType::I64,
            4 => BranchType::U8,
            5 => BranchType::VarF32,
            6 => BranchType::VarI32,
            7 => BranchType::VarU8,
            8 => BranchType::Bool,
            _ => return None,
        })
    }
}

/// One value for one entry of one branch.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32(f32),
    F64(f64),
    I32(i32),
    I64(i64),
    U8(u8),
    Bool(bool),
    AF32(Vec<f32>),
    AI32(Vec<i32>),
    AU8(Vec<u8>),
}

impl Value {
    pub fn matches(&self, ty: BranchType) -> bool {
        matches!(
            (self, ty),
            (Value::F32(_), BranchType::F32)
                | (Value::F64(_), BranchType::F64)
                | (Value::I32(_), BranchType::I32)
                | (Value::I64(_), BranchType::I64)
                | (Value::U8(_), BranchType::U8)
                | (Value::Bool(_), BranchType::Bool)
                | (Value::AF32(_), BranchType::VarF32)
                | (Value::AI32(_), BranchType::VarI32)
                | (Value::AU8(_), BranchType::VarU8)
        )
    }

    /// Serialize big-endian (ROOT network order) onto `out`; returns the
    /// number of bytes written.
    pub fn serialize(&self, out: &mut Vec<u8>) -> usize {
        let start = out.len();
        match self {
            Value::F32(v) => out.extend_from_slice(&v.to_be_bytes()),
            Value::F64(v) => out.extend_from_slice(&v.to_be_bytes()),
            Value::I32(v) => out.extend_from_slice(&v.to_be_bytes()),
            Value::I64(v) => out.extend_from_slice(&v.to_be_bytes()),
            Value::U8(v) => out.push(*v),
            Value::Bool(v) => out.push(*v as u8),
            Value::AF32(a) => {
                for v in a {
                    out.extend_from_slice(&v.to_be_bytes());
                }
            }
            Value::AI32(a) => {
                for v in a {
                    out.extend_from_slice(&v.to_be_bytes());
                }
            }
            Value::AU8(a) => out.extend_from_slice(a),
        }
        out.len() - start
    }
}

/// Branch definition.
#[derive(Debug, Clone, PartialEq)]
pub struct BranchDef {
    pub name: String,
    pub ty: BranchType,
    /// Per-branch compression override (None = tree default), mirroring
    /// ROOT's per-branch compression settings.
    pub settings: Option<Settings>,
}

impl BranchDef {
    pub fn new(name: impl Into<String>, ty: BranchType) -> Self {
        Self { name: name.into(), ty, settings: None }
    }

    pub fn with_settings(mut self, s: Settings) -> Self {
        self.settings = Some(s);
        self
    }

    pub(crate) fn serialize(&self, out: &mut Vec<u8>) {
        put_lp_bytes(out, self.name.as_bytes());
        out.push(self.ty.code());
        match &self.settings {
            None => out.push(0),
            Some(s) => {
                out.push(1);
                put_uvarint(out, s.to_root_setting() as u64);
                let (pt, ps) = s.precond.encode();
                out.push((pt << 4) | (ps & 0x0F));
            }
        }
    }

    pub(crate) fn deserialize(c: &mut Cursor) -> Option<Self> {
        let name = c.lp_str()?.to_string();
        let ty = BranchType::from_code(c.u8()?)?;
        let has = c.u8()?;
        let settings = if has == 1 {
            let packed = c.uvarint()? as u16;
            let pbyte = c.u8()?;
            let mut s = Settings::from_root_setting(packed)?;
            s.precond = crate::precond::Precond::decode(pbyte >> 4, pbyte & 0x0F)?;
            Some(s)
        } else {
            None
        };
        Some(Self { name, ty, settings })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::Algorithm;
    use crate::precond::Precond;

    #[test]
    fn value_serialization_is_big_endian() {
        let mut out = Vec::new();
        Value::I32(1).serialize(&mut out);
        assert_eq!(out, vec![0, 0, 0, 1]);
        out.clear();
        Value::F32(1.0).serialize(&mut out);
        assert_eq!(out, vec![0x3F, 0x80, 0, 0]);
    }

    #[test]
    fn branch_def_roundtrip() {
        let defs = [
            BranchDef::new("Muon_pt", BranchType::VarF32),
            BranchDef::new("nMuon", BranchType::I32).with_settings(
                Settings::new(Algorithm::Lz4, 4).with_precond(Precond::BitShuffle(4)),
            ),
            BranchDef::new("HLT_IsoMu24", BranchType::Bool),
        ];
        for d in &defs {
            let mut buf = Vec::new();
            d.serialize(&mut buf);
            let mut c = Cursor::new(&buf);
            let back = BranchDef::deserialize(&mut c).unwrap();
            assert_eq!(&back, d);
        }
    }

    #[test]
    fn type_checks() {
        assert!(Value::AF32(vec![1.0]).matches(BranchType::VarF32));
        assert!(!Value::F32(1.0).matches(BranchType::F64));
        assert!(BranchType::VarF32.is_var());
        assert_eq!(BranchType::F64.elem_size(), 8);
    }
}
