//! Tree writer: accumulates entries column-wise into per-branch baskets
//! (paper Fig 1), flushing each basket through a [`BasketSink`] when it
//! reaches the basket size. The sink abstraction is the seam where the
//! parallel compression pipeline (coordinator) plugs in; the default
//! [`SerialSink`] compresses inline.

use super::basket::{encode_basket_into, PendingBasket};
use super::branch::{BranchDef, Value};
use super::format::{self, RecordKind};
use super::meta::{BasketLoc, TreeMeta};
use crate::compression::{Engine, Settings};
use crate::util::varint::put_uvarint;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Default basket size (ROOT's default TBasket buffer is 32 KiB).
pub const DEFAULT_BASKET_SIZE: usize = 32 * 1024;

/// Where finished (uncompressed) baskets go. Implementations must commit
/// baskets to the file *in submission order per branch* and return the
/// locations at finish.
pub trait BasketSink {
    fn submit(&mut self, basket: PendingBasket, settings: Settings) -> Result<()>;
    /// Flush everything; returns committed basket locations.
    fn finish(&mut self) -> Result<Vec<BasketLoc>>;
    /// Hand back a recycled `(data, offsets)` buffer pair from an already
    /// consumed basket, if the sink pools them (§Perf: the fill thread
    /// re-seeds its per-branch accumulation buffers from this instead of
    /// growing fresh `Vec`s for every basket). Buffers are cleared; `None`
    /// means allocate as before.
    fn recycle_buffers(&mut self) -> Option<(Vec<u8>, Vec<u32>)> {
        None
    }
}

/// Record-level writer shared by sinks: owns the output file and the
/// running offset.
pub struct RecordWriter {
    out: BufWriter<File>,
    pos: u64,
}

impl RecordWriter {
    pub fn create(path: &Path) -> Result<Self> {
        let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        let mut out = BufWriter::new(f);
        let pos = format::write_header(&mut out)?;
        Ok(Self { out, pos })
    }

    /// Append a record, returning its offset.
    pub fn append(&mut self, kind: RecordKind, payload: &[u8]) -> Result<u64> {
        let off = self.pos;
        format::write_record(&mut self.out, self.pos, kind, payload)?;
        self.pos += 5 + payload.len() as u64;
        Ok(off)
    }

    /// Write metadata + trailer and flush.
    pub fn close(mut self, meta: &TreeMeta) -> Result<u64> {
        let meta_off = self.append(RecordKind::TreeMeta, &meta.serialize())?;
        format::write_trailer(&mut self.out, meta_off)?;
        self.out.flush()?;
        Ok(self.pos + format::TRAILER_LEN)
    }
}

/// Basket record payload framing shared by all sinks:
/// `[uvarint branch_id][uvarint basket_index][encoded basket]`.
pub fn frame_basket_record(branch_id: u32, basket_index: u32, encoded: &[u8]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(encoded.len() + 8);
    frame_basket_record_prefix(&mut payload, branch_id, basket_index);
    payload.extend_from_slice(encoded);
    payload
}

/// Append just the framing prefix (`[uvarint branch_id][uvarint
/// basket_index]`) — the zero-alloc sinks write this then encode the basket
/// directly into the same buffer. Single source of truth for the layout.
pub fn frame_basket_record_prefix(out: &mut Vec<u8>, branch_id: u32, basket_index: u32) {
    put_uvarint(out, branch_id as u64);
    put_uvarint(out, basket_index as u64);
}

/// Serial sink: compress + write inline on the caller's thread. The two
/// scratch buffers are reused across submits, so steady state allocates
/// nothing per basket (§Perf, same discipline as the parallel pipeline).
pub struct SerialSink {
    writer: RecordWriter,
    engine: Engine,
    locs: Vec<BasketLoc>,
    logical_scratch: Vec<u8>,
    payload_scratch: Vec<u8>,
    /// Most recently consumed basket's buffers, parked for `recycle_buffers`.
    spare_buffers: Option<(Vec<u8>, Vec<u32>)>,
}

impl SerialSink {
    pub fn new(writer: RecordWriter) -> Self {
        Self {
            writer,
            engine: Engine::new(),
            locs: Vec::new(),
            logical_scratch: Vec::new(),
            payload_scratch: Vec::new(),
            spare_buffers: None,
        }
    }

    pub fn with_dictionary(writer: RecordWriter, dict: Vec<u8>) -> Self {
        let mut sink = Self::new(writer);
        sink.engine.set_dictionary(dict);
        sink
    }

    /// Hand back the record writer to close the file (after finish()).
    pub fn into_writer(self) -> RecordWriter {
        self.writer
    }
}

impl BasketSink for SerialSink {
    fn submit(&mut self, basket: PendingBasket, settings: Settings) -> Result<()> {
        let uncompressed_len = basket.logical_len() as u32;
        self.payload_scratch.clear();
        frame_basket_record_prefix(&mut self.payload_scratch, basket.branch_id, basket.basket_index);
        encode_basket_into(
            &basket,
            &settings,
            &mut self.engine,
            &mut self.logical_scratch,
            &mut self.payload_scratch,
        );
        let off = self.writer.append(RecordKind::Basket, &self.payload_scratch)?;
        self.locs.push(BasketLoc {
            branch_id: basket.branch_id,
            basket_index: basket.basket_index,
            first_entry: basket.first_entry,
            n_entries: basket.n_entries,
            file_offset: off,
            compressed_len: self.payload_scratch.len() as u32,
            uncompressed_len,
        });
        self.spare_buffers = Some(basket.into_buffers());
        Ok(())
    }

    fn finish(&mut self) -> Result<Vec<BasketLoc>> {
        Ok(std::mem::take(&mut self.locs))
    }

    fn recycle_buffers(&mut self) -> Option<(Vec<u8>, Vec<u32>)> {
        self.spare_buffers.take()
    }
}

/// Per-branch accumulation state.
struct BranchState {
    def: BranchDef,
    data: Vec<u8>,
    offsets: Vec<u32>,
    basket_index: u32,
    first_entry: u64,
    entries_in_basket: u32,
}

/// The tree writer.
pub struct TreeWriter<S: BasketSink> {
    name: String,
    branches: Vec<BranchState>,
    default_settings: Settings,
    basket_size: usize,
    n_entries: u64,
    sink: S,
    dictionary_offset: Option<u64>,
}

impl<S: BasketSink> TreeWriter<S> {
    pub fn new(
        name: impl Into<String>,
        branches: Vec<BranchDef>,
        default_settings: Settings,
        basket_size: usize,
        sink: S,
    ) -> Self {
        let branches = branches
            .into_iter()
            .map(|def| BranchState {
                def,
                data: Vec::new(),
                offsets: Vec::new(),
                basket_index: 0,
                first_entry: 0,
                entries_in_basket: 0,
            })
            .collect();
        Self {
            name: name.into(),
            branches,
            default_settings,
            basket_size,
            n_entries: 0,
            sink,
            dictionary_offset: None,
        }
    }

    pub fn set_dictionary_offset(&mut self, off: u64) {
        self.dictionary_offset = Some(off);
    }

    pub fn n_entries(&self) -> u64 {
        self.n_entries
    }

    /// Fill one event: one [`Value`] per branch, in schema order.
    pub fn fill(&mut self, values: &[Value]) -> Result<()> {
        if values.len() != self.branches.len() {
            bail!(
                "fill() got {} values for {} branches",
                values.len(),
                self.branches.len()
            );
        }
        for (b, v) in self.branches.iter_mut().zip(values) {
            if !v.matches(b.def.ty) {
                bail!("type mismatch on branch '{}'", b.def.name);
            }
            v.serialize(&mut b.data);
            if b.def.ty.is_var() {
                b.offsets.push(b.data.len() as u32);
            }
            b.entries_in_basket += 1;
        }
        self.n_entries += 1;
        // Flush any branch whose basket is full.
        for i in 0..self.branches.len() {
            if self.branches[i].data.len() + self.branches[i].offsets.len() * 4
                >= self.basket_size
            {
                self.flush_branch(i)?;
            }
        }
        Ok(())
    }

    fn flush_branch(&mut self, i: usize) -> Result<()> {
        let settings = self.branches[i]
            .def
            .settings
            .unwrap_or(self.default_settings);
        let b = &mut self.branches[i];
        if b.entries_in_basket == 0 {
            return Ok(());
        }
        let basket = PendingBasket {
            branch_id: i as u32,
            basket_index: b.basket_index,
            first_entry: b.first_entry,
            n_entries: b.entries_in_basket,
            data: std::mem::take(&mut b.data),
            offsets: std::mem::take(&mut b.offsets),
        };
        b.basket_index += 1;
        b.first_entry += b.entries_in_basket as u64;
        b.entries_in_basket = 0;
        self.sink.submit(basket, settings)?;
        // §Perf: re-seed the branch accumulators with buffers recycled by
        // the sink (same capacity the branch just grew) instead of starting
        // the next basket from empty allocations.
        if let Some((data, offsets)) = self.sink.recycle_buffers() {
            let b = &mut self.branches[i];
            b.data = data;
            b.offsets = offsets;
        }
        Ok(())
    }

    /// Flush remaining baskets and produce the tree metadata. Returns
    /// (metadata, sink) — the caller closes the file via the sink's writer.
    pub fn finalize(mut self) -> Result<(TreeMeta, S)> {
        for i in 0..self.branches.len() {
            self.flush_branch(i)?;
        }
        let mut baskets = self.sink.finish()?;
        baskets.sort_by_key(|l| (l.branch_id, l.basket_index));
        let meta = TreeMeta {
            name: self.name,
            branches: self.branches.into_iter().map(|b| b.def).collect(),
            default_settings: self.default_settings,
            n_entries: self.n_entries,
            baskets,
            dictionary_offset: self.dictionary_offset,
        };
        Ok((meta, self.sink))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::Algorithm;

    #[test]
    fn serial_sink_recycles_basket_buffers() {
        let mut path = std::env::temp_dir();
        path.push(format!("rootio_writer_recycle_{}", std::process::id()));
        let writer = RecordWriter::create(&path).unwrap();
        let mut sink = SerialSink::new(writer);
        // Nothing to recycle before the first submit.
        assert!(sink.recycle_buffers().is_none());
        let basket = PendingBasket {
            branch_id: 0,
            basket_index: 0,
            first_entry: 0,
            n_entries: 3,
            data: vec![1, 2, 3, 4, 5, 6, 7, 8],
            offsets: vec![2, 4, 8],
        };
        let data_cap = basket.data.capacity();
        sink.submit(basket, Settings::new(Algorithm::None, 0)).unwrap();
        let (data, offsets) = sink.recycle_buffers().expect("buffers recycled");
        assert!(data.is_empty() && offsets.is_empty(), "recycled buffers must be cleared");
        assert_eq!(data.capacity(), data_cap, "capacity must survive recycling");
        // take() semantics: a second call has nothing to hand back.
        assert!(sink.recycle_buffers().is_none());
        let _ = std::fs::remove_file(&path);
    }
}

/// Convenience: write a whole tree serially to `path` (compress inline on
/// the caller's thread through a [`SerialSink`]).
///
/// ```
/// use rootio::compression::{Algorithm, Settings};
/// use rootio::rfile::{write_tree_serial, BranchDef, BranchType, TreeReader, Value};
///
/// let path = std::env::temp_dir().join(format!("rootio_doc_writer_{}.rfil", std::process::id()));
/// let branches = vec![
///     BranchDef::new("energy", BranchType::F32),
///     // A jagged branch: per-entry f32 arrays (serialized with an offset
///     // array, the structure the preconditioners exist for).
///     BranchDef::new("hits", BranchType::VarF32),
/// ];
/// let events: Vec<Vec<Value>> = (0..100)
///     .map(|i| vec![Value::F32(i as f32), Value::AF32(vec![1.0; (i % 5) as usize])])
///     .collect();
/// let meta = write_tree_serial(
///     &path,
///     "Events",
///     branches,
///     Settings::new(Algorithm::Lz4, 1),
///     1024,
///     events.iter().cloned(),
/// )
/// .unwrap();
/// assert_eq!(meta.n_entries, 100);
///
/// let mut reader = TreeReader::open(&path).unwrap();
/// assert_eq!(reader.read_all_events().unwrap(), events);
/// std::fs::remove_file(&path).ok();
/// ```
pub fn write_tree_serial(
    path: &Path,
    name: &str,
    branches: Vec<BranchDef>,
    default_settings: Settings,
    basket_size: usize,
    events: impl Iterator<Item = Vec<Value>>,
) -> Result<TreeMeta> {
    let writer = RecordWriter::create(path)?;
    let sink = SerialSink::new(writer);
    let mut tw = TreeWriter::new(name, branches, default_settings, basket_size, sink);
    for ev in events {
        tw.fill(&ev)?;
    }
    let (meta, sink) = tw.finalize()?;
    sink.into_writer().close(&meta)?;
    Ok(meta)
}
