//! File scrubbing: walk header → records → trailer, verify every record
//! frame and every basket payload (decompression + CRC where the codec
//! carries one), and classify the damage. This is the offline half of the
//! fault-tolerance story — `rootio scrub FILE` prints the damage map a
//! salvage-mode read will have to skip around, with an exit code suitable
//! for CI (`0` clean, `1` damaged-but-usable, `2` unusable).
//!
//! Damage classes (docs/FORMAT.md §damage classification):
//!
//! * [`DamageKind::Truncation`] — bytes are missing: the file ends before
//!   a frame or the trailer completes.
//! * [`DamageKind::FrameCorruption`] — the record skeleton is wrong:
//!   implausible length, unknown kind, a basket that is not where the
//!   directory says it is.
//! * [`DamageKind::PayloadCorruption`] — the frame is intact but the
//!   compressed payload does not decode (codec structure error, CRC
//!   mismatch, entry-count mismatch).

use super::basket::decode_basket;
use super::format::{RecordKind, MAGIC, TRAILER_LEN, TRAILER_MAGIC, VERSION};
use super::meta::{BasketLoc, TreeMeta};
use super::source::{read_full_at, FileSource, RangeSource};
use crate::compression::Engine;
use crate::util::varint::Cursor;
use anyhow::{Context, Result};
use std::fmt;
use std::path::{Path, PathBuf};

/// What kind of damage a finding describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DamageKind {
    /// Bytes missing: the file ends before the structure completes.
    Truncation,
    /// Record skeleton wrong: lengths, kinds, identity don't line up.
    FrameCorruption,
    /// Frame intact, payload rotten: decode / CRC / count failures.
    PayloadCorruption,
}

impl fmt::Display for DamageKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DamageKind::Truncation => "truncation",
            DamageKind::FrameCorruption => "frame corruption",
            DamageKind::PayloadCorruption => "payload corruption",
        })
    }
}

/// One damaged location.
#[derive(Debug, Clone)]
pub struct ScrubFinding {
    /// File offset the damage was detected at.
    pub offset: u64,
    pub kind: DamageKind,
    pub detail: String,
    /// Branch name, when the finding is tied to a directory basket.
    pub branch: Option<String>,
    /// Basket index within the branch, when applicable.
    pub basket_index: Option<u32>,
}

/// Scrub result: damage map + overall verdict.
#[derive(Debug)]
pub struct ScrubReport {
    pub path: PathBuf,
    pub file_len: u64,
    /// Records seen by the sequential frame walk.
    pub records_walked: u64,
    /// Baskets deep-verified from the directory.
    pub baskets_checked: usize,
    pub findings: Vec<ScrubFinding>,
    /// False when header/trailer/metadata are unreadable — nothing can be
    /// salvaged without a directory.
    pub usable: bool,
}

impl ScrubReport {
    pub fn is_clean(&self) -> bool {
        self.usable && self.findings.is_empty()
    }

    /// CI contract: 0 = clean, 1 = damaged but the directory is intact
    /// (salvage can recover the complement), 2 = unusable.
    pub fn exit_code(&self) -> i32 {
        if !self.usable {
            2
        } else if self.findings.is_empty() {
            0
        } else {
            1
        }
    }

    /// Human-readable damage map.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "scrub {}: {} bytes, {} records walked, {} baskets checked",
            self.path.display(),
            self.file_len,
            self.records_walked,
            self.baskets_checked
        );
        if self.is_clean() {
            out.push_str("clean: every frame and basket verified\n");
            return out;
        }
        for f in &self.findings {
            let whom = match (&f.branch, f.basket_index) {
                (Some(b), Some(i)) => format!(" branch '{b}' basket {i}"),
                (Some(b), None) => format!(" branch '{b}'"),
                _ => String::new(),
            };
            let _ = writeln!(out, "  [{}] offset {}{whom}: {}", f.kind, f.offset, f.detail);
        }
        let _ = writeln!(
            out,
            "{} finding(s); file is {}",
            self.findings.len(),
            if self.usable { "usable (salvage mode can skip the damage)" } else { "NOT usable" }
        );
        out
    }
}

/// End of the data region: everything past this is the fixed trailer.
fn data_end(file_len: u64) -> u64 {
    file_len.saturating_sub(TRAILER_LEN)
}

/// Read one record frame with structured damage classification: bounds
/// and EOF problems are `Truncation`, malformed skeletons are
/// `FrameCorruption`. Returns `(kind, total_len)` with the payload in
/// `payload`.
fn read_frame(
    src: &mut FileSource,
    offset: u64,
    file_len: u64,
    payload: &mut Vec<u8>,
) -> std::result::Result<(RecordKind, u64), (DamageKind, String)> {
    let end = data_end(file_len);
    if offset + 5 > end {
        return Err((
            DamageKind::Truncation,
            format!("record header needs 5 bytes at offset {offset} but data region ends at {end}"),
        ));
    }
    let mut hdr = [0u8; 5];
    read_full_at(src, offset, &mut hdr)
        .map_err(|e| (DamageKind::Truncation, e.to_string()))?;
    let total = u32::from_be_bytes(hdr[..4].try_into().unwrap()) as u64;
    if !(5..=(1 << 30)).contains(&total) {
        return Err((
            DamageKind::FrameCorruption,
            format!("implausible record length {total} at offset {offset}"),
        ));
    }
    if offset + total > end {
        return Err((
            DamageKind::Truncation,
            format!(
                "record at offset {offset} claims {total} bytes but data region ends at {end}"
            ),
        ));
    }
    let kind = RecordKind::from_u8(hdr[4]).ok_or_else(|| {
        (
            DamageKind::FrameCorruption,
            format!("unknown record kind {} at offset {offset}", hdr[4]),
        )
    })?;
    payload.clear();
    payload.resize((total - 5) as usize, 0);
    read_full_at(src, offset + 5, payload)
        .map_err(|e| (DamageKind::Truncation, e.to_string()))?;
    Ok((kind, total))
}

/// Deep-verify one directory basket: frame, identity, decompression
/// (CRC where the codec stores one), entry count.
fn verify_basket(
    src: &mut FileSource,
    engine: &mut Engine,
    loc: &BasketLoc,
    file_len: u64,
    payload: &mut Vec<u8>,
) -> std::result::Result<(), (DamageKind, String)> {
    let (kind, _) = read_frame(src, loc.file_offset, file_len, payload)?;
    if kind != RecordKind::Basket {
        return Err((
            DamageKind::FrameCorruption,
            format!("directory points at a {kind:?} record, not a basket"),
        ));
    }
    let mut c = Cursor::new(payload);
    let (branch_id, basket_index) = match (c.uvarint(), c.uvarint()) {
        (Some(b), Some(i)) => (b as u32, i as u32),
        _ => {
            return Err((
                DamageKind::FrameCorruption,
                "basket identity varints truncated".to_string(),
            ))
        }
    };
    if branch_id != loc.branch_id || basket_index != loc.basket_index {
        return Err((
            DamageKind::FrameCorruption,
            format!(
                "basket identity mismatch: found ({branch_id},{basket_index}), expected ({},{})",
                loc.branch_id, loc.basket_index
            ),
        ));
    }
    let content = decode_basket(&payload[c.pos()..], engine)
        .map_err(|e| (DamageKind::PayloadCorruption, format!("basket decode: {e}")))?;
    if content.n_entries != loc.n_entries {
        return Err((
            DamageKind::PayloadCorruption,
            format!(
                "entry count mismatch: decoded {}, directory says {}",
                content.n_entries, loc.n_entries
            ),
        ));
    }
    Ok(())
}

/// Scrub a file: header, trailer, metadata, sequential frame walk, then a
/// deep verify of every directory basket. Never fails on damage — damage
/// goes into the report; `Err` means the file could not even be opened.
pub fn scrub_file(path: &Path) -> Result<ScrubReport> {
    let mut src = FileSource::open(path)?;
    let file_len = src
        .size()
        .with_context(|| format!("sizing {}", path.display()))?;
    let mut report = ScrubReport {
        path: path.to_path_buf(),
        file_len,
        records_walked: 0,
        baskets_checked: 0,
        findings: Vec::new(),
        usable: true,
    };
    fn fail(report: &mut ScrubReport, offset: u64, kind: DamageKind, detail: String) {
        report.findings.push(ScrubFinding { offset, kind, detail, branch: None, basket_index: None });
        report.usable = false;
    }

    // Header: magic + version.
    let mut hdr = [0u8; 6];
    if file_len < 6 {
        fail(
            &mut report,
            0,
            DamageKind::Truncation,
            format!("file truncated: expected 6 header bytes at offset 0, file is {file_len} bytes"),
        );
        return Ok(report);
    }
    if let Err(e) = read_full_at(&mut src, 0, &mut hdr) {
        fail(&mut report, 0, DamageKind::Truncation, e.to_string());
        return Ok(report);
    }
    if &hdr[..4] != MAGIC {
        fail(&mut report, 0, DamageKind::FrameCorruption, "not an RFIL file (bad magic)".into());
        return Ok(report);
    }
    let version = u16::from_be_bytes(hdr[4..6].try_into().unwrap());
    if version != VERSION {
        fail(
            &mut report,
            4,
            DamageKind::FrameCorruption,
            format!("unsupported RFIL version {version}"),
        );
        return Ok(report);
    }

    // Trailer: magic + metadata offset.
    if file_len < 6 + TRAILER_LEN {
        fail(
            &mut report,
            6,
            DamageKind::Truncation,
            format!(
                "file truncated: expected {TRAILER_LEN} trailer bytes, file is {file_len} bytes"
            ),
        );
        return Ok(report);
    }
    let mut tr = [0u8; 16];
    if let Err(e) = read_full_at(&mut src, file_len - TRAILER_LEN, &mut tr) {
        fail(&mut report, file_len - TRAILER_LEN, DamageKind::Truncation, e.to_string());
        return Ok(report);
    }
    if &tr[8..] != TRAILER_MAGIC {
        fail(
            &mut report,
            file_len - TRAILER_LEN + 8,
            DamageKind::FrameCorruption,
            "missing RFIL trailer (file not closed?)".into(),
        );
        return Ok(report);
    }
    let meta_off = u64::from_be_bytes(tr[..8].try_into().unwrap());

    // Metadata record.
    let mut payload = Vec::new();
    if meta_off < 6 || meta_off >= data_end(file_len) {
        fail(
            &mut report,
            file_len - TRAILER_LEN,
            DamageKind::FrameCorruption,
            format!("trailer points at offset {meta_off}, outside the data region"),
        );
        return Ok(report);
    }
    let meta = match read_frame(&mut src, meta_off, file_len, &mut payload) {
        Err((kind, detail)) => {
            fail(&mut report, meta_off, kind, detail);
            return Ok(report);
        }
        Ok((RecordKind::TreeMeta, _)) => match TreeMeta::deserialize(&payload) {
            Ok(m) => m,
            Err(e) => {
                fail(
                    &mut report,
                    meta_off,
                    DamageKind::PayloadCorruption,
                    format!("tree metadata does not parse: {e:#}"),
                );
                return Ok(report);
            }
        },
        Ok((kind, _)) => {
            fail(
                &mut report,
                meta_off,
                DamageKind::FrameCorruption,
                format!("trailer points at a {kind:?} record, not tree metadata"),
            );
            return Ok(report);
        }
    };

    // Dictionary record, if the tree carries one. A broken dictionary
    // does not make the file unusable by itself, but every basket that
    // needs it will fail below.
    let mut engine = Engine::new();
    if let Some(doff) = meta.dictionary_offset {
        match read_frame(&mut src, doff, file_len, &mut payload) {
            Ok((RecordKind::Dictionary, _)) => engine.set_dictionary(payload.clone()),
            Ok((kind, _)) => report.findings.push(ScrubFinding {
                offset: doff,
                kind: DamageKind::FrameCorruption,
                detail: format!("dictionary offset points at a {kind:?} record"),
                branch: None,
                basket_index: None,
            }),
            Err((kind, detail)) => report.findings.push(ScrubFinding {
                offset: doff,
                kind,
                detail,
                branch: None,
                basket_index: None,
            }),
        }
    }

    // Sequential frame walk: every record length must chain exactly onto
    // the trailer. One finding per break (the walk cannot resync).
    let mut off = 6u64;
    let end = data_end(file_len);
    while off < end {
        match read_frame(&mut src, off, file_len, &mut payload) {
            Ok((_, total)) => {
                report.records_walked += 1;
                off += total;
            }
            Err((kind, detail)) => {
                report.findings.push(ScrubFinding {
                    offset: off,
                    kind,
                    detail: format!("record chain breaks: {detail}"),
                    branch: None,
                    basket_index: None,
                });
                break;
            }
        }
    }

    // Deep verify every directory basket.
    let branch_name = |id: u32| {
        meta.branches
            .get(id as usize)
            .map(|b| b.name.clone())
            .unwrap_or_else(|| format!("#{id}"))
    };
    for loc in &meta.baskets {
        report.baskets_checked += 1;
        if let Err((kind, detail)) =
            verify_basket(&mut src, &mut engine, loc, file_len, &mut payload)
        {
            report.findings.push(ScrubFinding {
                offset: loc.file_offset,
                kind,
                detail,
                branch: Some(branch_name(loc.branch_id)),
                basket_index: Some(loc.basket_index),
            });
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Algorithm, Settings};
    use crate::gen::synthetic;
    use crate::rfile::write_tree_serial;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rootio_scrub_{}_{name}.rfil", std::process::id()));
        p
    }

    fn sample(path: &Path, settings: Settings) -> TreeMeta {
        let events = synthetic::events(300, 11);
        write_tree_serial(path, "Events", synthetic::schema(), settings, 2048, events.iter().cloned())
            .unwrap()
    }

    #[test]
    fn clean_file_scrubs_clean() {
        let path = tmp("clean");
        sample(&path, Settings::new(Algorithm::Zstd, 5));
        let report = scrub_file(&path).unwrap();
        assert!(report.is_clean(), "{}", report.render());
        assert_eq!(report.exit_code(), 0);
        assert!(report.records_walked > 1);
        assert!(report.baskets_checked > 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flipped_identity_is_frame_corruption() {
        let path = tmp("identity");
        let meta = sample(&path, Settings::new(Algorithm::Zstd, 5));
        let loc = meta.baskets[meta.baskets.len() / 2];
        let mut bytes = std::fs::read(&path).unwrap();
        // First payload byte of a basket record is the branch_id varint.
        bytes[loc.file_offset as usize + 5] ^= 0x3F;
        std::fs::write(&path, bytes).unwrap();
        let report = scrub_file(&path).unwrap();
        assert_eq!(report.exit_code(), 1, "{}", report.render());
        assert!(report.usable);
        let hits: Vec<_> = report
            .findings
            .iter()
            .filter(|f| f.offset == loc.file_offset)
            .collect();
        assert!(!hits.is_empty());
        assert!(hits.iter().all(|f| f.kind == DamageKind::FrameCorruption), "{}", report.render());
        assert_eq!(hits[0].basket_index, Some(loc.basket_index));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flipped_lz4_crc_is_payload_corruption() {
        let path = tmp("crc");
        let meta = sample(&path, Settings::new(Algorithm::Lz4, 1));
        let mut bytes = std::fs::read(&path).unwrap();
        // Walk the basket wire layout to a stored CRC: record payload is
        // [branch_id][basket_index][n_entries][data_len][n_offsets] varints,
        // then the 10-byte span header, then LZ4's 4-byte content CRC.
        // Incompressible baskets fall back to raw spans (no CRC), so scan
        // for one whose span header really says LZ4.
        let (loc, crc_at) = meta
            .baskets
            .iter()
            .find_map(|loc| {
                let payload = &bytes[loc.file_offset as usize + 5..];
                let mut c = Cursor::new(payload);
                for _ in 0..5 {
                    c.uvarint()?;
                }
                let span = c.pos();
                (payload.get(span..span + 2) == Some(&b"L4"[..]))
                    .then(|| (*loc, loc.file_offset as usize + 5 + span + 10))
            })
            .expect("no LZ4 span in the sample file");
        bytes[crc_at] ^= 0xA5;
        std::fs::write(&path, bytes).unwrap();
        let report = scrub_file(&path).unwrap();
        assert_eq!(report.exit_code(), 1, "{}", report.render());
        let f = report
            .findings
            .iter()
            .find(|f| f.offset == loc.file_offset)
            .expect("finding at corrupted basket");
        assert_eq!(f.kind, DamageKind::PayloadCorruption, "{}", report.render());
        assert_eq!(f.branch.as_deref(), Some(meta.branches[loc.branch_id as usize].name.as_str()));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_and_empty_files_are_unusable() {
        let path = tmp("trunc");
        sample(&path, Settings::new(Algorithm::Zstd, 5));
        let bytes = std::fs::read(&path).unwrap();
        // Cut inside the record stream: trailer gone.
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let report = scrub_file(&path).unwrap();
        assert_eq!(report.exit_code(), 2, "{}", report.render());
        assert!(!report.usable);
        // Ten bytes: header survives, trailer cannot.
        std::fs::write(&path, &bytes[..10]).unwrap();
        let report = scrub_file(&path).unwrap();
        assert_eq!(report.exit_code(), 2);
        assert!(report.findings.iter().any(|f| f.kind == DamageKind::Truncation));
        // Zero bytes.
        std::fs::write(&path, []).unwrap();
        let report = scrub_file(&path).unwrap();
        assert_eq!(report.exit_code(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_file_is_frame_corruption() {
        let path = tmp("garbage");
        std::fs::write(&path, vec![0x5Au8; 256]).unwrap();
        let report = scrub_file(&path).unwrap();
        assert_eq!(report.exit_code(), 2);
        assert!(report
            .findings
            .iter()
            .any(|f| f.kind == DamageKind::FrameCorruption && f.detail.contains("bad magic")));
        std::fs::remove_file(&path).ok();
    }
}
