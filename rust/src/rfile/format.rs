//! On-disk container: a keyed record stream with a trailer, the moral
//! equivalent of ROOT's TFile + TKey structure (simplified, versioned).
//!
//! ```text
//! file  := header record* trailer
//! header:= "RFIL" u16_version
//! record:= u32_be total_len, u8 kind, payload[total_len - 5]
//! trailer (fixed 16 bytes at EOF): u64_be metadata_offset "RFILEND1"
//! ```
//!
//! Record kinds: 1 = basket, 2 = tree metadata, 3 = dictionary blob.

use anyhow::{bail, Context, Result};
use std::io::{Read, Seek, SeekFrom, Write};

pub const MAGIC: &[u8; 4] = b"RFIL";
/// Container version written by this build. Bumped to 2 in PR 2 (RZS1 FSE
/// sections grew a second interleaved-lane initial state) and to 3 in PR 8
/// (quad-state FSE sections + the Huff0 literals mode). Each bump turns a
/// would-be garbled decode on an old reader into a clean "unsupported
/// version" rejection.
pub const VERSION: u16 = 3;
/// Oldest container version this build still reads. v2 files decode
/// unchanged: their dual-state FSE sections are a mode the v3 decoder
/// accepts natively (see `docs/FORMAT.md` §9), so the reader takes the
/// whole `MIN_VERSION..=VERSION` range while the writer always stamps
/// [`VERSION`]. v1 predates the dual-state stream layout and stays
/// rejected.
pub const MIN_VERSION: u16 = 2;
pub const TRAILER_MAGIC: &[u8; 8] = b"RFILEND1";
pub const TRAILER_LEN: u64 = 16;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordKind {
    Basket = 1,
    TreeMeta = 2,
    Dictionary = 3,
}

impl RecordKind {
    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => RecordKind::Basket,
            2 => RecordKind::TreeMeta,
            3 => RecordKind::Dictionary,
            _ => return None,
        })
    }
}

/// Write the file header; returns bytes written.
pub fn write_header(w: &mut impl Write) -> Result<u64> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_be_bytes())?;
    Ok(6)
}

/// Append one record; returns its file offset (caller tracks position).
pub fn write_record(w: &mut impl Write, pos: u64, kind: RecordKind, payload: &[u8]) -> Result<u64> {
    let total = payload.len() as u32 + 5;
    w.write_all(&total.to_be_bytes())?;
    w.write_all(&[kind as u8])?;
    w.write_all(payload)?;
    Ok(pos)
}

/// Write the trailer pointing at the metadata record.
pub fn write_trailer(w: &mut impl Write, meta_offset: u64) -> Result<()> {
    w.write_all(&meta_offset.to_be_bytes())?;
    w.write_all(TRAILER_MAGIC)?;
    Ok(())
}

/// Validate the header of an open file. A short or zero-length file gets
/// an explicit truncation error (byte counts, not raw io noise) so a
/// `scrub`/salvage report can cite exactly what is missing.
pub fn read_header(r: &mut impl Read) -> Result<u16> {
    read_header_versioned(r, MIN_VERSION, VERSION)
}

/// [`read_header`] with an explicit accepted version range — the seam the
/// cross-version compat tests use to emulate an old reader (e.g. a v2-only
/// build is `read_header_versioned(r, 2, 2)`) without keeping dead code
/// around.
pub fn read_header_versioned(r: &mut impl Read, min: u16, max: u16) -> Result<u16> {
    let mut buf = [0u8; 6];
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading file header"),
        }
    }
    if got < buf.len() {
        bail!(
            "file truncated: expected {} header bytes at offset 0, got {got}",
            buf.len()
        );
    }
    if &buf[..4] != MAGIC {
        bail!("not an RFIL file (bad magic)");
    }
    let version = u16::from_be_bytes(buf[4..6].try_into().unwrap());
    if version < min || version > max {
        bail!("unsupported RFIL version {version}");
    }
    Ok(version)
}

/// Read the trailer; returns the metadata record offset. Truncation is
/// reported with explicit byte counts (see [`read_header`]).
pub fn read_trailer(f: &mut (impl Read + Seek)) -> Result<u64> {
    let end = f.seek(SeekFrom::End(0))?;
    if end < TRAILER_LEN + 6 {
        bail!(
            "file truncated: expected {} trailer bytes at offset {} \
             (file is only {end} bytes)",
            TRAILER_LEN,
            end.saturating_sub(TRAILER_LEN).max(6),
        );
    }
    f.seek(SeekFrom::End(-(TRAILER_LEN as i64)))?;
    let mut buf = [0u8; 16];
    f.read_exact(&mut buf)?;
    if &buf[8..] != TRAILER_MAGIC {
        bail!("missing RFIL trailer (file not closed?)");
    }
    Ok(u64::from_be_bytes(buf[..8].try_into().unwrap()))
}

/// Read the record at `offset`; returns (kind, payload).
pub fn read_record_at(f: &mut (impl Read + Seek), offset: u64) -> Result<(RecordKind, Vec<u8>)> {
    let mut payload = Vec::new();
    let kind = read_record_at_into(f, offset, &mut payload)?;
    Ok((kind, payload))
}

/// Pooled-buffer variant (§Perf): reads the record payload into a
/// caller-owned buffer (cleared first, capacity kept), so the read
/// pipeline's prefetcher can recycle raw-payload buffers through a
/// [`crate::util::pool::BufferPool`] instead of allocating per basket.
pub fn read_record_at_into(
    f: &mut (impl Read + Seek),
    offset: u64,
    payload: &mut Vec<u8>,
) -> Result<RecordKind> {
    f.seek(SeekFrom::Start(offset))?;
    let mut hdr = [0u8; 5];
    f.read_exact(&mut hdr).context("reading record header")?;
    let total = u32::from_be_bytes(hdr[..4].try_into().unwrap()) as usize;
    if total < 5 || total > (1 << 30) {
        bail!("implausible record length {total}");
    }
    let kind = RecordKind::from_u8(hdr[4]).context("unknown record kind")?;
    payload.clear();
    // Read through `take` + `read_to_end` rather than resize + read_exact:
    // the recycled buffer's capacity is reused without zero-filling bytes
    // that are about to be overwritten (§Perf: this runs once per basket on
    // the read pipeline's prefetch thread).
    let body_len = total - 5;
    let n = f
        .by_ref()
        .take(body_len as u64)
        .read_to_end(payload)
        .context("reading record payload")?;
    if n != body_len {
        bail!("record payload truncated ({n} of {body_len} bytes)");
    }
    Ok(kind)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn file_structure_roundtrip() {
        let mut buf = Cursor::new(Vec::<u8>::new());
        let mut pos = write_header(&mut buf).unwrap();
        let r1 = pos;
        write_record(&mut buf, pos, RecordKind::Basket, b"payload-1").unwrap();
        pos += 5 + 9;
        let r2 = pos;
        write_record(&mut buf, pos, RecordKind::TreeMeta, b"meta").unwrap();
        pos += 5 + 4;
        write_trailer(&mut buf, r2).unwrap();
        let _ = pos;

        buf.set_position(0);
        assert_eq!(read_header(&mut buf).unwrap(), VERSION);
        let meta_off = read_trailer(&mut buf).unwrap();
        assert_eq!(meta_off, r2);
        let (k, p) = read_record_at(&mut buf, r2).unwrap();
        assert_eq!(k, RecordKind::TreeMeta);
        assert_eq!(p, b"meta");
        let (k, p) = read_record_at(&mut buf, r1).unwrap();
        assert_eq!(k, RecordKind::Basket);
        assert_eq!(p, b"payload-1");
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Cursor::new(b"NOPE00".to_vec());
        assert!(read_header(&mut buf).is_err());
    }

    fn header_bytes(version: u16) -> Vec<u8> {
        let mut h = MAGIC.to_vec();
        h.extend_from_slice(&version.to_be_bytes());
        h
    }

    #[test]
    fn version_range_acceptance() {
        // The v3 reader takes the whole MIN_VERSION..=VERSION range…
        for v in MIN_VERSION..=VERSION {
            let mut buf = Cursor::new(header_bytes(v));
            assert_eq!(read_header(&mut buf).unwrap(), v);
        }
        // …and rejects versions on either side with the versioned error.
        for v in [0u16, 1, VERSION + 1, 999] {
            let mut buf = Cursor::new(header_bytes(v));
            let err = read_header(&mut buf).unwrap_err().to_string();
            assert_eq!(err, format!("unsupported RFIL version {v}"), "v={v}");
        }
    }

    #[test]
    fn v3_header_rejected_by_v2_reader() {
        // The FORMAT.md §9 reject rule, from the old reader's point of
        // view: a v2-only build must refuse a v3 file cleanly, naming the
        // version it saw, not garble-decode it.
        let mut buf = Cursor::new(header_bytes(VERSION));
        let err = read_header_versioned(&mut buf, 2, 2).unwrap_err().to_string();
        assert_eq!(err, format!("unsupported RFIL version {VERSION}"));
        // And the same v2-only build still accepts a v2 file.
        let mut buf = Cursor::new(header_bytes(2));
        assert_eq!(read_header_versioned(&mut buf, 2, 2).unwrap(), 2);
    }

    #[test]
    fn short_and_empty_files_get_explicit_truncation_errors() {
        // Zero-length and short files through read_header…
        for len in [0usize, 1, 5] {
            let mut buf = Cursor::new(MAGIC[..len.min(4)].to_vec());
            buf.get_mut().resize(len, 0);
            let err = read_header(&mut buf).unwrap_err().to_string();
            assert!(
                err.contains("file truncated") && err.contains("expected 6 header bytes"),
                "len {len}: {err}"
            );
        }
        // …and through read_trailer: a valid header but nothing else.
        for len in [0usize, 6, 12, 21] {
            let mut bytes = Vec::new();
            write_header(&mut bytes).unwrap();
            bytes.resize(len, 0);
            let mut buf = Cursor::new(bytes);
            let err = read_trailer(&mut buf).unwrap_err().to_string();
            assert!(
                err.contains("file truncated") && err.contains("expected 16 trailer bytes"),
                "len {len}: {err}"
            );
        }
    }

    #[test]
    fn missing_trailer_rejected() {
        let mut buf = Cursor::new(Vec::<u8>::new());
        write_header(&mut buf).unwrap();
        write_record(&mut buf, 6, RecordKind::Basket, &vec![0u8; 64]).unwrap();
        buf.set_position(0);
        assert!(read_trailer(&mut buf).is_err());
    }
}
