//! Tree metadata: schema + basket directory, serialized into the TreeMeta
//! record that the trailer points at.

use super::branch::BranchDef;
use crate::compression::Settings;
use crate::util::varint::{put_lp_bytes, put_uvarint, Cursor};
use anyhow::{bail, Result};

/// Location + stats of one committed basket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BasketLoc {
    pub branch_id: u32,
    pub basket_index: u32,
    pub first_entry: u64,
    pub n_entries: u32,
    pub file_offset: u64,
    pub compressed_len: u32,
    pub uncompressed_len: u32,
}

impl BasketLoc {
    /// Entry span `[first, last)` this basket covers. Derived from the
    /// directory's `first_entry` + `n_entries` — entry-range reads need no
    /// wire-format change (docs/FORMAT.md §4).
    pub fn entry_span(&self) -> (u64, u64) {
        (self.first_entry, self.first_entry + self.n_entries as u64)
    }

    /// True iff this basket's entry span intersects `[first, last)`. An
    /// empty query window (`first >= last`) intersects nothing — without
    /// the guard, a point window falling strictly inside the span would
    /// report a hit and an "empty" range read would decode one basket.
    pub fn overlaps(&self, first: u64, last: u64) -> bool {
        let (a, b) = self.entry_span();
        first < last && a < last && first < b
    }

    /// Indices `[from, to)` into this basket's *decoded* values that fall
    /// inside the entry range `[first, last)` — the head/tail trim for
    /// boundary baskets of an entry-range read. Saturating at the span
    /// edges, so any `(first, last)` pair is safe (a non-overlapping span
    /// yields an empty `from == to` window).
    pub fn trim_bounds(&self, first: u64, last: u64) -> (usize, usize) {
        let (span_start, span_end) = self.entry_span();
        let lo = first.clamp(span_start, span_end);
        let hi = last.clamp(span_start, span_end).max(lo);
        ((lo - span_start) as usize, (hi - span_start) as usize)
    }

    /// Exact `(offset, len)` disk extent of this basket's record: the
    /// 5-byte record frame (u32 total length + kind byte) plus the framed
    /// payload, whose length the writer stores as `compressed_len`. This
    /// is what a plan-aware I/O layer (the coalesced backend) merges on —
    /// no heuristics, the directory knows each record's exact footprint.
    pub fn record_span(&self) -> (u64, u64) {
        (self.file_offset, 5 + self.compressed_len as u64)
    }

    /// The gap a *damaged* basket leaves inside the entry window
    /// `[first, last)`: the clamped intersection of this basket's span
    /// with the window, or `None` if they don't intersect. Salvage-mode
    /// scans report these so consumers know exactly which absolute entry
    /// ids are missing.
    pub fn gap_within(&self, first: u64, last: u64) -> Option<GapSpan> {
        if !self.overlaps(first, last) {
            return None;
        }
        let (span_start, span_end) = self.entry_span();
        let lo = first.max(span_start);
        let hi = last.min(span_end);
        Some(GapSpan { first_entry: lo, n_entries: hi - lo })
    }
}

/// A contiguous run of entries lost to damaged baskets — what a
/// salvage-mode scan reports alongside the intact rows. Entry ids are
/// absolute (tree coordinates), the span is `[first_entry,
/// first_entry + n_entries)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GapSpan {
    pub first_entry: u64,
    pub n_entries: u64,
}

impl GapSpan {
    /// Exclusive end of the span.
    pub fn end_entry(&self) -> u64 {
        self.first_entry + self.n_entries
    }

    /// Extend this span with an adjacent-or-overlapping follower; returns
    /// false (leaving `self` untouched) if `other` is disjoint beyond the
    /// end. Gap lists are built in entry order, so this is the only merge
    /// direction needed.
    pub fn absorb(&mut self, other: GapSpan) -> bool {
        if other.first_entry > self.end_entry() {
            return false;
        }
        let end = self.end_entry().max(other.end_entry());
        self.n_entries = end - self.first_entry;
        true
    }
}

/// Append `span` to an entry-ordered gap list, merging it into the tail
/// when adjacent or overlapping. Zero-length spans are dropped.
pub fn push_gap(gaps: &mut Vec<GapSpan>, span: GapSpan) {
    if span.n_entries == 0 {
        return;
    }
    if let Some(tail) = gaps.last_mut() {
        if tail.absorb(span) {
            return;
        }
    }
    gaps.push(span);
}

/// Full tree metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeMeta {
    pub name: String,
    pub branches: Vec<BranchDef>,
    pub default_settings: Settings,
    pub n_entries: u64,
    /// All baskets, ordered by (branch_id, basket_index).
    pub baskets: Vec<BasketLoc>,
    /// Offset of the dictionary record, if one was written.
    pub dictionary_offset: Option<u64>,
}

impl TreeMeta {
    /// Branch id for a branch name. Single source of truth for the lookup
    /// both the serial and the parallel reader expose.
    pub fn branch_id(&self, name: &str) -> Option<u32> {
        self.branches.iter().position(|b| b.name == name).map(|i| i as u32)
    }

    /// Basket directory for one branch (ordered by basket_index, since
    /// `baskets` is sorted by `(branch_id, basket_index)`).
    pub fn baskets_for(&self, branch_id: u32) -> Vec<BasketLoc> {
        self.baskets
            .iter()
            .copied()
            .filter(|l| l.branch_id == branch_id)
            .collect()
    }

    /// Merged basket directory for several branches, branch-major in the
    /// order given (each branch's run stays basket_index-ordered). This is
    /// the submission-order seed a
    /// [`ProjectionPlan`](crate::coordinator::ProjectionPlan) offset-sorts
    /// into its single-sweep prefetch plan.
    ///
    /// One pass over the directory (O(baskets + branches), not a rescan
    /// per requested branch). Ids outside the schema select nothing; if an
    /// id repeats, its baskets appear once, under the last occurrence.
    pub fn baskets_for_branches(&self, branch_ids: &[u32]) -> Vec<BasketLoc> {
        const UNSELECTED: usize = usize::MAX;
        let mut slot_of = vec![UNSELECTED; self.branches.len()];
        for (slot, &id) in branch_ids.iter().enumerate() {
            if let Some(s) = slot_of.get_mut(id as usize) {
                *s = slot;
            }
        }
        let mut buckets: Vec<Vec<BasketLoc>> = branch_ids.iter().map(|_| Vec::new()).collect();
        for loc in &self.baskets {
            match slot_of.get(loc.branch_id as usize) {
                Some(&slot) if slot != UNSELECTED => buckets[slot].push(*loc),
                _ => {}
            }
        }
        buckets.into_iter().flatten().collect()
    }

    /// Basket directory for one branch restricted to the baskets whose
    /// entry spans overlap `[first, last)` — the slice an entry-range read
    /// decodes. Order follows the directory (basket_index order).
    pub fn baskets_for_range(&self, branch_id: u32, first: u64, last: u64) -> Vec<BasketLoc> {
        self.baskets
            .iter()
            .copied()
            .filter(|l| l.branch_id == branch_id && l.overlaps(first, last))
            .collect()
    }

    /// Clamp a caller-supplied entry range to this tree: returns
    /// `[start, end)` with `start <= end <= n_entries`. Ranges past EOF
    /// collapse to empty at the tree's end.
    pub fn clamp_entry_range(&self, first: u64, last: u64) -> (u64, u64) {
        let start = first.min(self.n_entries);
        (start, last.min(self.n_entries).max(start))
    }

    /// First basket of every branch that has one, in `(branch_id)` order —
    /// what file profiling reads.
    pub fn first_baskets(&self) -> Vec<BasketLoc> {
        let mut firsts = Vec::with_capacity(self.branches.len());
        let mut seen: Option<u32> = None;
        for loc in &self.baskets {
            if seen != Some(loc.branch_id) {
                firsts.push(*loc);
                seen = Some(loc.branch_id);
            }
        }
        firsts
    }

    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_lp_bytes(&mut out, self.name.as_bytes());
        put_uvarint(&mut out, self.branches.len() as u64);
        for b in &self.branches {
            b.serialize(&mut out);
        }
        put_uvarint(&mut out, self.default_settings.to_root_setting() as u64);
        let (pt, ps) = self.default_settings.precond.encode();
        out.push((pt << 4) | (ps & 0x0F));
        put_uvarint(&mut out, self.n_entries);
        match self.dictionary_offset {
            None => out.push(0),
            Some(o) => {
                out.push(1);
                put_uvarint(&mut out, o);
            }
        }
        put_uvarint(&mut out, self.baskets.len() as u64);
        for l in &self.baskets {
            put_uvarint(&mut out, l.branch_id as u64);
            put_uvarint(&mut out, l.basket_index as u64);
            put_uvarint(&mut out, l.first_entry);
            put_uvarint(&mut out, l.n_entries as u64);
            put_uvarint(&mut out, l.file_offset);
            put_uvarint(&mut out, l.compressed_len as u64);
            put_uvarint(&mut out, l.uncompressed_len as u64);
        }
        out
    }

    pub fn deserialize(data: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(data);
        let fail = || anyhow::anyhow!("truncated tree metadata");
        let name = c.lp_str().ok_or_else(fail)?.to_string();
        let n_branches = c.uvarint().ok_or_else(fail)? as usize;
        if n_branches > 1_000_000 {
            bail!("implausible branch count");
        }
        let mut branches = Vec::with_capacity(n_branches);
        for _ in 0..n_branches {
            branches.push(BranchDef::deserialize(&mut c).ok_or_else(fail)?);
        }
        let packed = c.uvarint().ok_or_else(fail)? as u16;
        let pbyte = c.u8().ok_or_else(fail)?;
        let mut default_settings =
            Settings::from_root_setting(packed).ok_or_else(|| anyhow::anyhow!("bad settings"))?;
        default_settings.precond = crate::precond::Precond::decode(pbyte >> 4, pbyte & 0x0F)
            .ok_or_else(|| anyhow::anyhow!("bad precond"))?;
        let n_entries = c.uvarint().ok_or_else(fail)?;
        let dictionary_offset = match c.u8().ok_or_else(fail)? {
            0 => None,
            1 => Some(c.uvarint().ok_or_else(fail)?),
            _ => bail!("bad dictionary flag"),
        };
        let n_baskets = c.uvarint().ok_or_else(fail)? as usize;
        if n_baskets > 100_000_000 {
            bail!("implausible basket count");
        }
        let mut baskets = Vec::with_capacity(n_baskets);
        for _ in 0..n_baskets {
            baskets.push(BasketLoc {
                branch_id: c.uvarint().ok_or_else(fail)? as u32,
                basket_index: c.uvarint().ok_or_else(fail)? as u32,
                first_entry: c.uvarint().ok_or_else(fail)?,
                n_entries: c.uvarint().ok_or_else(fail)? as u32,
                file_offset: c.uvarint().ok_or_else(fail)?,
                compressed_len: c.uvarint().ok_or_else(fail)? as u32,
                uncompressed_len: c.uvarint().ok_or_else(fail)? as u32,
            });
        }
        Ok(Self {
            name,
            branches,
            default_settings,
            n_entries,
            baskets,
            dictionary_offset,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Algorithm, Settings};
    use crate::precond::Precond;
    use crate::rfile::branch::BranchType;

    #[test]
    fn meta_roundtrip() {
        let meta = TreeMeta {
            name: "Events".into(),
            branches: vec![
                BranchDef::new("nMuon", BranchType::I32),
                BranchDef::new("Muon_pt", BranchType::VarF32).with_settings(
                    Settings::new(Algorithm::Lz4, 4).with_precond(Precond::BitShuffle(4)),
                ),
            ],
            default_settings: Settings::new(Algorithm::Zstd, 5),
            n_entries: 2000,
            baskets: vec![
                BasketLoc {
                    branch_id: 0,
                    basket_index: 0,
                    first_entry: 0,
                    n_entries: 1000,
                    file_offset: 6,
                    compressed_len: 1234,
                    uncompressed_len: 4000,
                },
                BasketLoc {
                    branch_id: 1,
                    basket_index: 0,
                    first_entry: 0,
                    n_entries: 2000,
                    file_offset: 1300,
                    compressed_len: 999,
                    uncompressed_len: 8000,
                },
            ],
            dictionary_offset: Some(42),
        };
        let bytes = meta.serialize();
        let back = TreeMeta::deserialize(&bytes).unwrap();
        assert_eq!(back, meta);
    }

    #[test]
    fn multi_branch_directory_queries() {
        let loc = |branch_id: u32, basket_index: u32, file_offset: u64| BasketLoc {
            branch_id,
            basket_index,
            first_entry: 0,
            n_entries: 10,
            file_offset,
            compressed_len: 5,
            uncompressed_len: 9,
        };
        let meta = TreeMeta {
            name: "T".into(),
            branches: vec![
                BranchDef::new("a", BranchType::I32),
                BranchDef::new("b", BranchType::F32),
                BranchDef::new("c", BranchType::F64),
            ],
            default_settings: Settings::default(),
            n_entries: 20,
            // Interleaved file layout, branch-major directory order.
            baskets: vec![loc(0, 0, 6), loc(0, 1, 90), loc(1, 0, 30), loc(2, 0, 60), loc(2, 1, 120)],
            dictionary_offset: None,
        };
        // Branch-major merge in the order asked for.
        let merged = meta.baskets_for_branches(&[2, 0]);
        assert_eq!(
            merged.iter().map(|l| (l.branch_id, l.basket_index)).collect::<Vec<_>>(),
            vec![(2, 0), (2, 1), (0, 0), (0, 1)]
        );
        // First basket per branch, branch order.
        let firsts = meta.first_baskets();
        assert_eq!(
            firsts.iter().map(|l| (l.branch_id, l.file_offset)).collect::<Vec<_>>(),
            vec![(0, 6), (1, 30), (2, 60)]
        );
    }

    #[test]
    fn entry_spans_and_trim_bounds() {
        let loc = BasketLoc {
            branch_id: 0,
            basket_index: 1,
            first_entry: 100,
            n_entries: 50,
            file_offset: 0,
            compressed_len: 1,
            uncompressed_len: 1,
        };
        assert_eq!(loc.entry_span(), (100, 150));
        // Overlap is half-open on both the span and the query.
        assert!(loc.overlaps(0, 101));
        assert!(loc.overlaps(149, 1000));
        assert!(!loc.overlaps(0, 100));
        assert!(!loc.overlaps(150, 200));
        assert!(!loc.overlaps(120, 120)); // empty query
        // Interior basket of a wider range: no trim.
        assert_eq!(loc.trim_bounds(0, 1000), (0, 50));
        // Head trim only / tail trim only / both.
        assert_eq!(loc.trim_bounds(110, 1000), (10, 50));
        assert_eq!(loc.trim_bounds(0, 140), (0, 40));
        assert_eq!(loc.trim_bounds(110, 140), (10, 40));
        // Exact-boundary range: full basket, no trim.
        assert_eq!(loc.trim_bounds(100, 150), (0, 50));
        // Non-overlapping queries saturate to empty windows, no underflow.
        assert_eq!(loc.trim_bounds(0, 50), (0, 0));
        assert_eq!(loc.trim_bounds(200, 300), (50, 50));
        let (f, t) = loc.trim_bounds(170, 120); // backwards range
        assert_eq!(f, t);
    }

    #[test]
    fn range_directory_queries() {
        let loc = |basket_index: u32, first_entry: u64, n: u32| BasketLoc {
            branch_id: 0,
            basket_index,
            first_entry,
            n_entries: n,
            file_offset: basket_index as u64 * 10,
            compressed_len: 5,
            uncompressed_len: 9,
        };
        let meta = TreeMeta {
            name: "T".into(),
            branches: vec![BranchDef::new("a", BranchType::I32)],
            default_settings: Settings::default(),
            n_entries: 30,
            baskets: vec![loc(0, 0, 10), loc(1, 10, 10), loc(2, 20, 10)],
            dictionary_offset: None,
        };
        let idx = |v: &[BasketLoc]| v.iter().map(|l| l.basket_index).collect::<Vec<_>>();
        assert_eq!(idx(&meta.baskets_for_range(0, 0, 30)), vec![0, 1, 2]);
        assert_eq!(idx(&meta.baskets_for_range(0, 10, 20)), vec![1]); // exact boundaries
        assert_eq!(idx(&meta.baskets_for_range(0, 9, 11)), vec![0, 1]);
        assert_eq!(idx(&meta.baskets_for_range(0, 15, 15)), Vec::<u32>::new());
        assert_eq!(idx(&meta.baskets_for_range(0, 30, 99)), Vec::<u32>::new());
        assert_eq!(idx(&meta.baskets_for_range(7, 0, 30)), Vec::<u32>::new()); // unknown branch
        assert_eq!(meta.clamp_entry_range(5, 25), (5, 25));
        assert_eq!(meta.clamp_entry_range(5, 99), (5, 30));
        assert_eq!(meta.clamp_entry_range(40, 99), (30, 30));
        assert_eq!(meta.clamp_entry_range(20, 10), (20, 20));
    }

    #[test]
    fn gap_spans_clamp_merge_and_drop_empties() {
        let loc = BasketLoc {
            branch_id: 0,
            basket_index: 1,
            first_entry: 100,
            n_entries: 50,
            file_offset: 0,
            compressed_len: 1,
            uncompressed_len: 1,
        };
        // Clamped intersection with the query window.
        assert_eq!(
            loc.gap_within(0, 1000),
            Some(GapSpan { first_entry: 100, n_entries: 50 })
        );
        assert_eq!(
            loc.gap_within(120, 140),
            Some(GapSpan { first_entry: 120, n_entries: 20 })
        );
        assert_eq!(loc.gap_within(0, 100), None);
        assert_eq!(loc.gap_within(150, 300), None);
        assert_eq!(loc.gap_within(120, 120), None, "empty window");

        // Entry-ordered list building: adjacency and overlap merge,
        // disjoint spans append, empties vanish.
        let mut gaps = Vec::new();
        push_gap(&mut gaps, GapSpan { first_entry: 10, n_entries: 5 });
        push_gap(&mut gaps, GapSpan { first_entry: 15, n_entries: 5 }); // adjacent
        push_gap(&mut gaps, GapSpan { first_entry: 18, n_entries: 4 }); // overlapping
        push_gap(&mut gaps, GapSpan { first_entry: 30, n_entries: 0 }); // empty
        push_gap(&mut gaps, GapSpan { first_entry: 40, n_entries: 2 }); // disjoint
        assert_eq!(
            gaps,
            vec![
                GapSpan { first_entry: 10, n_entries: 12 },
                GapSpan { first_entry: 40, n_entries: 2 },
            ]
        );
        assert_eq!(gaps[0].end_entry(), 22);
    }

    #[test]
    fn truncated_meta_rejected() {
        let meta = TreeMeta {
            name: "T".into(),
            branches: vec![BranchDef::new("x", BranchType::F32)],
            default_settings: Settings::default(),
            n_entries: 1,
            baskets: vec![],
            dictionary_offset: None,
        };
        let bytes = meta.serialize();
        for cut in 1..bytes.len() - 1 {
            let _ = TreeMeta::deserialize(&bytes[..cut]); // no panic
        }
    }
}
