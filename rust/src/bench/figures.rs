//! Figure harnesses: regenerate every figure of the paper's evaluation.
//!
//! Each `fig*` function produces the same series the paper plots (as a
//! [`Table`] printed to stdout and saved as CSV under `results/`). See
//! DESIGN.md §4 for the per-experiment index and the substitution notes
//! (platforms → tuning profiles, hardware CRC32 → checksum backends).

use super::benchkit::{bench, BenchConfig, Table};
use crate::checksum::{adler32, crc32};
use crate::compression::{Algorithm, Engine, Settings};
use crate::deflate::tuning::{Flavor, Tuning};
use crate::deflate::zlib::zlib_compress_custom;
use crate::gen::{nanoaod, synthetic};
use crate::precond::Precond;
use crate::rfile::writer::BasketSink;
use crate::rfile::{BasketLoc, BranchDef, PendingBasket, TreeWriter, Value};
use anyhow::Result;

/// In-memory sink that captures uncompressed baskets (no file I/O), letting
/// the figure harnesses benchmark pure codec work over realistic baskets.
#[derive(Default)]
struct CollectSink {
    baskets: Vec<PendingBasket>,
}

impl BasketSink for CollectSink {
    fn submit(&mut self, basket: PendingBasket, _settings: Settings) -> Result<()> {
        self.baskets.push(basket);
        Ok(())
    }
    fn finish(&mut self) -> Result<Vec<BasketLoc>> {
        Ok(Vec::new())
    }
}

/// Serialize a workload into per-branch logical basket payloads.
pub fn collect_baskets(
    branches: Vec<BranchDef>,
    events: &[Vec<Value>],
    basket_size: usize,
) -> Vec<PendingBasket> {
    let mut tw = TreeWriter::new(
        "bench",
        branches,
        Settings::new(Algorithm::None, 0),
        basket_size,
        CollectSink::default(),
    );
    for ev in events {
        tw.fill(ev).expect("fill");
    }
    let (_, sink) = tw.finalize().expect("finalize");
    sink.baskets
}

/// The paper's §2 test workload as logical basket payloads.
pub fn paper_baskets(basket_size: usize) -> Vec<Vec<u8>> {
    let (schema, events) = synthetic::paper_tree();
    collect_baskets(schema, &events, basket_size)
        .into_iter()
        .map(|b| b.logical_payload())
        .collect()
}

fn total_len(bufs: &[Vec<u8>]) -> usize {
    bufs.iter().map(|b| b.len()).sum()
}

/// The (algorithm, level) grid of Fig 2/3. LZMA gets fewer levels (its
/// level axis barely moves ratio in our simplified model and it is slow).
pub fn survey_grid() -> Vec<(Algorithm, Vec<u8>)> {
    vec![
        (Algorithm::Zlib, vec![1, 3, 6, 9]),
        (Algorithm::CfZlib, vec![1, 3, 6, 9]),
        (Algorithm::Lz4, vec![1, 4, 6, 9]),
        (Algorithm::Zstd, vec![1, 3, 5, 9]),
        (Algorithm::Lzma, vec![1, 6, 9]),
        (Algorithm::OldRoot, vec![1, 6]),
    ]
}

/// Fig 2: compression speed vs compression ratio per {algorithm × level}
/// on the artificial 2000-event tree.
pub fn fig2(cfg: &BenchConfig) -> Table {
    let baskets = paper_baskets(32 * 1024);
    let raw = total_len(&baskets);
    let mut table = Table::new(&["algorithm", "level", "ratio", "compress_MB_s", "compressed_bytes"]);
    let mut engine = Engine::new();
    for (alg, levels) in survey_grid() {
        for level in levels {
            let s = Settings::new(alg, level);
            let compressed: usize = baskets.iter().map(|b| engine.compress(b, &s).len()).sum();
            let r = bench(&s.label(), raw, cfg, || {
                let mut total = 0usize;
                for b in &baskets {
                    total += engine.compress(b, &s).len();
                }
                total
            });
            let ratio = raw as f64 / compressed as f64;
            table.row(vec![
                alg.label().to_string(),
                level.to_string(),
                format!("{ratio:.3}"),
                format!("{:.1}", r.mbps()),
                compressed.to_string(),
            ]);
        }
    }
    table
}

/// Fig 3: decompression speed reading the file back, by algorithm at input
/// levels 0, 1, 6, 9. Key shape: decode speed ≈ f(algorithm), not level;
/// LZ4 far ahead.
pub fn fig3(cfg: &BenchConfig) -> Table {
    let baskets = paper_baskets(32 * 1024);
    let raw = total_len(&baskets);
    let mut table = Table::new(&["algorithm", "level", "decompress_MB_s"]);
    let mut engine = Engine::new();
    let algos = [
        Algorithm::None,
        Algorithm::Zlib,
        Algorithm::CfZlib,
        Algorithm::Lz4,
        Algorithm::Zstd,
        Algorithm::Lzma,
    ];
    for alg in algos {
        let levels: &[u8] = if alg == Algorithm::None { &[0] } else { &[1, 6, 9] };
        for &level in levels {
            let s = Settings::new(alg, level);
            let compressed: Vec<Vec<u8>> =
                baskets.iter().map(|b| engine.compress(b, &s)).collect();
            let r = bench(&format!("dec-{}", s.label()), raw, cfg, || {
                let mut total = 0usize;
                for c in &compressed {
                    total += engine.decompress(c).expect("decompress").len();
                }
                total
            });
            table.row(vec![
                alg.label().to_string(),
                level.to_string(),
                format!("{:.1}", r.mbps()),
            ]);
        }
    }
    table
}

/// Fig 4: CF-ZLIB patch-set speedup vs reference ZLIB, levels 1..9, two
/// workload regimes standing in for the paper's laptop/server platforms
/// (see DESIGN.md's substitution table).
pub fn fig4(cfg: &BenchConfig) -> Table {
    let regimes: Vec<(&str, Vec<Vec<u8>>)> = vec![
        ("laptop(32K baskets)", paper_baskets(32 * 1024)),
        ("server(256K baskets)", {
            let (schema, _) = synthetic::paper_tree();
            let events = synthetic::events(8000, 0x5E4E);
            collect_baskets(schema, &events, 256 * 1024)
                .into_iter()
                .map(|b| b.logical_payload())
                .collect()
        }),
    ];
    let mut table = Table::new(&["regime", "level", "ZLIB_MB_s", "CF_ZLIB_MB_s", "speedup"]);
    for (regime, baskets) in &regimes {
        let raw = total_len(baskets);
        for level in 1..=9u8 {
            let t_ref = Tuning::new(Flavor::Reference, level);
            let t_cf = Tuning::new(Flavor::Cloudflare, level);
            let r_ref = bench("zlib", raw, cfg, || {
                baskets.iter().map(|b| zlib_compress_custom(b, &t_ref).len()).sum::<usize>()
            });
            let r_cf = bench("cf", raw, cfg, || {
                baskets.iter().map(|b| zlib_compress_custom(b, &t_cf).len()).sum::<usize>()
            });
            table.row(vec![
                regime.to_string(),
                level.to_string(),
                format!("{:.1}", r_ref.mbps()),
                format!("{:.1}", r_cf.mbps()),
                format!("{:.2}x", r_cf.mbps() / r_ref.mbps()),
            ]);
        }
    }
    table
}

/// Fig 5: checksum hardware axis — CF-ZLIB with "hardware-class" checksum
/// kernels (SWAR adler32 / slice-by-8 crc32) vs software kernels (scalar /
/// table). Also reports raw checksum throughput per backend.
pub fn fig5(cfg: &BenchConfig) -> Table {
    let baskets = paper_baskets(32 * 1024);
    let raw = total_len(&baskets);
    let mut table = Table::new(&["config", "level", "metric", "MB_s"]);

    // Raw checksum kernel throughput (the paper's §2.1 hotspot).
    let blob: Vec<u8> = baskets.concat();
    for (name, backend) in [
        ("adler32-scalar(sw)", adler32::Backend::Scalar),
        ("adler32-unrolled16(zlib)", adler32::Backend::Unrolled),
        ("adler32-swar(hw-class)", adler32::Backend::Swar),
    ] {
        let r = bench(name, blob.len(), cfg, || crate::checksum::adler32_with(&blob, backend));
        table.row(vec![name.into(), "-".into(), "checksum".into(), format!("{:.0}", r.mbps())]);
    }
    for (name, backend) in [
        ("crc32-bitwise(sw)", crc32::Backend::Bitwise),
        ("crc32-table(sw)", crc32::Backend::Table),
        ("crc32-slice8(hw-class)", crc32::Backend::Slice8),
    ] {
        let r = bench(name, blob.len(), cfg, || crate::checksum::crc32_with(&blob, backend));
        table.row(vec![name.into(), "-".into(), "checksum".into(), format!("{:.0}", r.mbps())]);
    }

    // End-to-end CF-ZLIB with each checksum kernel (Fig 5's actual axis).
    for level in [1u8, 6, 9] {
        for (name, backend) in [
            ("CF-ZLIB+sw-checksum", adler32::Backend::Scalar),
            ("CF-ZLIB+hw-checksum", adler32::Backend::Swar),
        ] {
            let mut t = Tuning::new(Flavor::Cloudflare, level);
            t.adler_backend = backend;
            let r = bench(name, raw, cfg, || {
                baskets.iter().map(|b| zlib_compress_custom(b, &t).len()).sum::<usize>()
            });
            table.row(vec![
                name.into(),
                level.to_string(),
                "compress".into(),
                format!("{:.1}", r.mbps()),
            ]);
        }
    }
    table
}

/// Fig 6: NanoAOD compression ratio — LZ4, LZ4+BitShuffle, ZLIB — plus the
/// decode-speed column that motivates keeping LZ4.
pub fn fig6(cfg: &BenchConfig, n_events: usize) -> Table {
    let events = nanoaod::events(n_events, 0xF16);
    let schema = nanoaod::schema();
    let baskets = collect_baskets(schema.clone(), &events, 32 * 1024);
    let mut engine = Engine::new();

    let mut table = Table::new(&["setting", "file_ratio", "offsets_ratio", "decompress_MB_s"]);
    // Branch classes: jagged branches' offset share is where BitShuffle acts.
    let var_ids: Vec<u32> = schema
        .iter()
        .enumerate()
        .filter(|(_, b)| b.ty.is_var())
        .map(|(i, _)| i as u32)
        .collect();

    for s in [
        Settings::new(Algorithm::Lz4, 1),
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        Settings::new(Algorithm::Lz4, 9).with_precond(Precond::BitShuffle(4)),
        Settings::new(Algorithm::Zlib, 1),
        Settings::new(Algorithm::Zlib, 6),
        Settings::new(Algorithm::Zstd, 5),
    ] {
        let mut raw_total = 0usize;
        let mut comp_total = 0usize;
        let mut raw_off = 0usize;
        let mut comp_off = 0usize;
        let mut compressed: Vec<Vec<u8>> = Vec::with_capacity(baskets.len());
        for b in &baskets {
            let logical = b.logical_payload();
            let c = engine.compress(&logical, &s);
            raw_total += logical.len();
            comp_total += c.len();
            if var_ids.contains(&b.branch_id) {
                // Offset-array share: compress the offset half alone to
                // attribute ratio (diagnostic column).
                let off_bytes: Vec<u8> =
                    b.offsets.iter().flat_map(|o| o.to_be_bytes()).collect();
                if !off_bytes.is_empty() {
                    raw_off += off_bytes.len();
                    comp_off += engine.compress(&off_bytes, &s).len();
                }
            }
            compressed.push(c);
        }
        let r = bench(&format!("dec-{}", s.label()), raw_total, cfg, || {
            let mut total = 0usize;
            for c in &compressed {
                total += engine.decompress(c).expect("decompress").len();
            }
            total
        });
        table.row(vec![
            s.label(),
            format!("{:.3}", raw_total as f64 / comp_total as f64),
            if raw_off > 0 {
                format!("{:.3}", raw_off as f64 / comp_off as f64)
            } else {
                "-".into()
            },
            format!("{:.1}", r.mbps()),
        ]);
    }
    table
}

/// §2.3 / future work: dictionary study on small baskets. Covers the ZSTD
/// budget sweep AND the paper's cross-codec claim ("the generated
/// dictionaries are useable for ZLIB and LZ4 as well") with one
/// ZSTD-trained dictionary applied to all three codecs.
pub fn dict_study(_cfg: &BenchConfig) -> Table {
    let mut table =
        Table::new(&["codec", "basket_bytes", "dict_bytes", "ratio_plain", "ratio_dict", "gain"]);
    // ZSTD budget sweep.
    for &rec_len in &[256usize, 1024, 4096] {
        let corpus = crate::zstd::dict::synthetic_corpus(400, rec_len, 0xD1C7);
        let (train, test) = corpus.split_at(300);
        for &budget in &[1024usize, 4096, 16384] {
            let dict = crate::zstd::dict::train_from_corpus(&train.to_vec(), budget);
            let mut plain_total = 0usize;
            let mut dict_total = 0usize;
            let mut raw = 0usize;
            for sample in test {
                raw += sample.len();
                plain_total += crate::zstd::zstd_compress_dict(sample, &[], 6).len();
                dict_total += crate::zstd::zstd_compress_dict(sample, &dict, 6).len();
            }
            let rp = raw as f64 / plain_total as f64;
            let rd = raw as f64 / dict_total as f64;
            table.row(vec![
                "ZSTD".into(),
                rec_len.to_string(),
                dict.len().to_string(),
                format!("{rp:.3}"),
                format!("{rd:.3}"),
                format!("{:+.1}%", (rd / rp - 1.0) * 100.0),
            ]);
        }
    }
    // Cross-codec: one 8 KiB ZSTD-trained dictionary, 320-byte baskets.
    let corpus = crate::zstd::dict::synthetic_corpus(400, 320, 0xD1C8);
    let (train, test) = corpus.split_at(300);
    let dict = crate::zstd::dict::train_from_corpus(&train.to_vec(), 8192);
    let mut lz4 = crate::lz4::Lz4Encoder::new();
    let raw: usize = test.iter().map(|s| s.len()).sum();
    let mut rows: Vec<(&str, usize, usize)> = Vec::new();
    {
        let (mut p, mut d) = (0usize, 0usize);
        for s in test {
            p += crate::zstd::zstd_compress_dict(s, &[], 6).len();
            d += crate::zstd::zstd_compress_dict(s, &dict, 6).len();
        }
        rows.push(("ZSTD(shared-dict)", p, d));
    }
    {
        use crate::deflate::zlib::zlib_compress_dict;
        use crate::deflate::Flavor;
        let (mut p, mut d) = (0usize, 0usize);
        for s in test {
            p += crate::deflate::zlib_compress(s, Flavor::Cloudflare, 6).len();
            d += zlib_compress_dict(s, &dict, Flavor::Cloudflare, 6).len();
        }
        rows.push(("ZLIB(FDICT)", p, d));
    }
    {
        let (mut p, mut d) = (0usize, 0usize);
        for s in test {
            p += lz4.compress(s, crate::lz4::Lz4Method::Fast { accel: 1 }).len();
            d += lz4
                .compress_dict(s, &dict, crate::lz4::Lz4Method::Fast { accel: 1 })
                .len();
        }
        rows.push(("LZ4(prefix-dict)", p, d));
    }
    for (name, p, d) in rows {
        let rp = raw as f64 / p as f64;
        let rd = raw as f64 / d as f64;
        table.row(vec![
            name.into(),
            "320".into(),
            dict.len().to_string(),
            format!("{rp:.3}"),
            format!("{rd:.3}"),
            format!("{:+.1}%", (rd / rp - 1.0) * 100.0),
        ]);
    }
    table
}

/// Pipeline scaling study (the L3 contribution): events/s and MB/s vs
/// worker count on the NanoAOD workload.
pub fn pipeline_scaling(_cfg: &BenchConfig, n_events: usize) -> Table {
    use crate::coordinator::{write_tree_parallel, PipelineConfig};
    let events = nanoaod::events(n_events, 0x5CA1E);
    let mut table = Table::new(&["workers", "wall_s", "MB_s", "ratio", "baskets"]);
    let max_workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut counts = vec![1usize, 2, 4];
    if max_workers > 4 {
        counts.push(max_workers);
    }
    for workers in counts {
        let path = std::env::temp_dir().join(format!("rootio_scale_{workers}.rfil"));
        let t0 = std::time::Instant::now();
        let (_, snap) = write_tree_parallel(
            &path,
            "Events",
            nanoaod::schema(),
            Settings::new(Algorithm::Zstd, 5),
            32 * 1024,
            PipelineConfig { workers, queue_depth: workers * 4, dictionary: Vec::new() },
            events.iter().cloned(),
        )
        .expect("pipeline write");
        let wall = t0.elapsed().as_secs_f64();
        table.row(vec![
            workers.to_string(),
            format!("{wall:.2}"),
            format!("{:.1}", snap.bytes_in as f64 / 1e6 / wall),
            format!("{:.3}", snap.ratio()),
            snap.baskets.to_string(),
        ]);
        std::fs::remove_file(&path).ok();
    }
    table
}

/// Run a named figure; returns rendered output.
pub fn run_figure(name: &str, cfg: &BenchConfig) -> Result<(String, Table)> {
    let table = match name {
        "fig2" => fig2(cfg),
        "fig3" => fig3(cfg),
        "fig4" => fig4(cfg),
        "fig5" => fig5(cfg),
        "fig6" => fig6(cfg, 3000),
        "dict" => dict_study(cfg),
        "scaling" => pipeline_scaling(cfg, 2000),
        _ => anyhow::bail!("unknown figure '{name}'"),
    };
    let csv_path = table.save_csv(name)?;
    Ok((format!("{}\n(csv: {})", table.render(), csv_path.display()), table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_baskets_nonempty() {
        let b = paper_baskets(32 * 1024);
        assert!(b.len() >= 12, "at least one basket per branch: {}", b.len());
        assert!(total_len(&b) > 100_000);
    }

    #[test]
    fn collect_sink_covers_all_entries() {
        let (schema, events) = synthetic::paper_tree();
        let n_branches = schema.len();
        let baskets = collect_baskets(schema, &events, 4096);
        for br in 0..n_branches {
            let total: u32 = baskets
                .iter()
                .filter(|b| b.branch_id == br as u32)
                .map(|b| b.n_entries)
                .sum();
            assert_eq!(total as usize, events.len(), "branch {br}");
        }
    }

    #[test]
    fn fig6_smoke() {
        // Tiny config: correctness of the harness, not performance.
        let cfg = BenchConfig::quick();
        let t = fig6(&cfg, 100);
        let rendered = t.render();
        assert!(rendered.contains("LZ4-1+bitshuffle4"));
        assert!(rendered.contains("ZLIB-1"));
    }
}
