//! Benchmark support: the custom harness (criterion is unavailable in the
//! offline crate set) and the per-figure reproduction harnesses.

pub mod benchkit;
pub mod figures;

pub use benchkit::{bench, json_array, json_escape, json_num, BenchConfig, BenchResult, Table};
