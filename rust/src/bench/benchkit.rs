//! Minimal benchmarking harness (criterion is not in the offline crate
//! set): warmup, fixed-time sampling, robust statistics, CSV output.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 5,
            max_samples: 200,
        }
    }
}

impl BenchConfig {
    /// Quick mode for CI / smoke runs.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(120),
            min_samples: 3,
            max_samples: 30,
        }
    }

    /// Read BENCH_QUICK env to pick a profile (used by `cargo bench`).
    pub fn from_env() -> Self {
        if std::env::var("BENCH_QUICK").is_ok() {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time statistics (seconds).
    pub time: Summary,
    /// Bytes processed per iteration (for throughput).
    pub bytes: usize,
}

impl BenchResult {
    /// Median throughput in MB/s (decimal, as the paper plots).
    pub fn mbps(&self) -> f64 {
        if self.time.median == 0.0 {
            return f64::INFINITY;
        }
        self.bytes as f64 / 1e6 / self.time.median
    }
}

/// Run one benchmark: `f` is invoked repeatedly; it must do the whole unit
/// of work (e.g. compress one buffer) and return a value to keep the
/// optimizer honest.
pub fn bench<R>(name: &str, bytes: usize, cfg: &BenchConfig, mut f: impl FnMut() -> R) -> BenchResult {
    // Warmup.
    let t0 = Instant::now();
    while t0.elapsed() < cfg.warmup {
        std::hint::black_box(f());
    }
    // Measure.
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while (t0.elapsed() < cfg.measure || samples.len() < cfg.min_samples)
        && samples.len() < cfg.max_samples
    {
        let s = Instant::now();
        std::hint::black_box(f());
        samples.push(s.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        time: Summary::from_samples(&samples),
        bytes,
    }
}

/// Simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:>w$}  ", w = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV serialization (headers + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV under results/ (created on demand).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Escape a string for embedding in a JSON document (no serde in the
/// offline crate set; the bench artifacts hand-roll their JSON).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format a float as a JSON number (`null` for non-finite values).
pub fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

/// Render a list of pre-rendered JSON values as an array, one per line.
pub fn json_array(items: &[String], indent: &str) -> String {
    if items.is_empty() {
        return "[]".to_string();
    }
    let inner = items
        .iter()
        .map(|i| format!("{indent}  {i}"))
        .collect::<Vec<_>>()
        .join(",\n");
    format!("[\n{inner}\n{indent}]")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_helpers() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_num(1.5), "1.500");
        assert_eq!(json_num(f64::INFINITY), "null");
        let arr = json_array(&["1".into(), "2".into()], "");
        assert!(arr.starts_with("[\n"));
        assert!(arr.contains("  1,\n"));
        // Must parse as JSON (structure check only).
        assert_eq!(arr.matches(',').count(), 1);
    }

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 3,
            max_samples: 50,
        };
        let r = bench("noop-ish", 1_000_000, &cfg, || {
            std::hint::black_box((0..1000u64).sum::<u64>())
        });
        assert!(r.time.n >= 3);
        assert!(r.time.median > 0.0);
        assert!(r.mbps() > 0.0);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new(&["name", "MB/s"]);
        t.row(vec!["LZ4-1".into(), "800.5".into()]);
        t.row(vec!["ZLIB-6".into(), "35.2".into()]);
        let s = t.render();
        assert!(s.contains("LZ4-1"));
        let csv = t.to_csv();
        assert!(csv.starts_with("name,MB/s\n"));
        assert_eq!(csv.lines().count(), 3);
    }
}
