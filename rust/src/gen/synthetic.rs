//! The paper's benchmark workload: "a simple test case of an
//! artificially-generated ROOT tree with 2,000 events" (§2).
//!
//! The tree mixes the branch kinds that exercise every compression
//! behaviour the survey measures: smooth floats (Gaussian/exponential
//! physics quantities), small integers, monotone counters, booleans, and
//! C-style variable-length arrays whose serialized offset arrays are the
//! Fig-6 pathology. Deterministic for a given seed.

use crate::rfile::{BranchDef, BranchType, Value};
use crate::util::rng::Rng;

/// Number of events the paper's test case uses.
pub const PAPER_EVENTS: usize = 2000;

/// Schema of the artificial tree.
pub fn schema() -> Vec<BranchDef> {
    vec![
        BranchDef::new("event_id", BranchType::I64),
        BranchDef::new("run_number", BranchType::I32),
        BranchDef::new("energy", BranchType::F64),
        BranchDef::new("px", BranchType::F32),
        BranchDef::new("py", BranchType::F32),
        BranchDef::new("pz", BranchType::F32),
        BranchDef::new("nTrack", BranchType::I32),
        BranchDef::new("Track_pt", BranchType::VarF32),
        BranchDef::new("Track_charge", BranchType::VarI32),
        BranchDef::new("trigger_bits", BranchType::I32),
        BranchDef::new("is_good", BranchType::Bool),
        BranchDef::new("label", BranchType::VarU8),
    ]
}

/// Generate `n` events deterministically.
pub fn events(n: usize, seed: u64) -> Vec<Vec<Value>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let ntrack = rng.poisson(8.0) as usize;
            let e = rng.exponential(0.02);
            vec![
                Value::I64(1_000_000 + i as i64),
                Value::I32(300_000 + (i / 500) as i32),
                Value::F64(e),
                Value::F32(rng.gauss(0.0, 12.0) as f32),
                Value::F32(rng.gauss(0.0, 12.0) as f32),
                Value::F32(rng.gauss(0.0, 45.0) as f32),
                Value::I32(ntrack as i32),
                Value::AF32((0..ntrack).map(|_| rng.exponential(0.08) as f32).collect()),
                Value::AI32((0..ntrack).map(|_| if rng.chance(0.5) { 1 } else { -1 }).collect()),
                Value::I32((rng.next_u32() & 0x00FF_0F0F) as i32),
                Value::Bool(rng.chance(0.85)),
                Value::AU8(format!("evt_{:07}", i).into_bytes()),
            ]
        })
        .collect()
}

/// The paper's exact workload: 2000 events, fixed seed.
pub fn paper_tree() -> (Vec<BranchDef>, Vec<Vec<Value>>) {
    (schema(), events(PAPER_EVENTS, 0x2019_C4E9))
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = events(100, 7);
        let b = events(100, 7);
        assert_eq!(a, b);
        let c = events(100, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn schema_matches_events() {
        let s = schema();
        for ev in events(50, 3) {
            assert_eq!(ev.len(), s.len());
            for (v, b) in ev.iter().zip(&s) {
                assert!(v.matches(b.ty), "branch {}", b.name);
            }
        }
    }

    #[test]
    fn realistic_sizes() {
        let evs = events(PAPER_EVENTS, 1);
        let mut total = 0usize;
        let mut buf = Vec::new();
        for ev in &evs {
            for v in ev {
                buf.clear();
                total += v.serialize(&mut buf);
            }
        }
        // ~100 bytes/event ballpark: non-trivial but small, like the paper's
        // simple test tree.
        assert!(total > 50 * PAPER_EVENTS, "total {total}");
        assert!(total < 2000 * PAPER_EVENTS, "total {total}");
    }
}
