//! NanoAOD-like event generator — the Fig-6 workload.
//!
//! CMS NanoAOD is a flat ROOT tree of O(1000) branches: per-object
//! kinematic arrays (`Muon_pt[nMuon]`, `Jet_eta[nJet]`, ...), object
//! counts, event-level scalars, and trigger flags. We reproduce that
//! *structure* with physics-shaped distributions (exponential pT spectra,
//! uniform η/φ, Poisson multiplicities). What matters for the paper's
//! Fig 6 is (a) many jagged branches whose serialized offset arrays are
//! monotone integers, and (b) smooth floating-point payloads — both of
//! which this generator produces. See DESIGN.md's honesty box for the
//! substitution rationale.

use crate::rfile::{BranchDef, BranchType, Value};
use crate::util::rng::Rng;

/// Object collections and their per-event multiplicity means.
const COLLECTIONS: &[(&str, f64, &[&str])] = &[
    ("Muon", 1.2, &["pt", "eta", "phi", "mass", "dxy", "dz", "pfRelIso03_all"]),
    ("Electron", 0.9, &["pt", "eta", "phi", "mass", "dxy", "dz", "mvaFall17V2Iso"]),
    ("Jet", 5.5, &["pt", "eta", "phi", "mass", "btagDeepB", "chHEF", "neHEF"]),
    ("Tau", 0.4, &["pt", "eta", "phi", "mass", "rawIso"]),
    ("Photon", 0.7, &["pt", "eta", "phi", "r9", "sieie"]),
    ("SoftActivityJet", 3.0, &["pt", "eta", "phi"]),
];

/// Event-level scalar branches.
const SCALARS: &[&str] = &[
    "MET_pt", "MET_phi", "MET_sumEt", "PV_npvs", "PV_z", "fixedGridRhoFastjetAll",
    "Generator_weight", "LHE_HT",
];

/// Trigger flags.
const TRIGGERS: &[&str] = &[
    "HLT_IsoMu24", "HLT_Ele32_WPTight_Gsf", "HLT_PFHT1050", "HLT_PFMET120_PFMHT120_IDTight",
    "HLT_DoubleMu4_3_Bs", "Flag_goodVertices", "Flag_METFilters",
];

/// Build the NanoAOD-like schema. Branch order: per collection a count
/// branch (`nMuon`) + jagged kinematics; then scalars; then flags; then
/// run/lumi/event bookkeeping.
pub fn schema() -> Vec<BranchDef> {
    let mut v = Vec::new();
    for (coll, _, fields) in COLLECTIONS {
        v.push(BranchDef::new(format!("n{coll}"), BranchType::I32));
        for f in *fields {
            v.push(BranchDef::new(format!("{coll}_{f}"), BranchType::VarF32));
        }
        v.push(BranchDef::new(format!("{coll}_charge"), BranchType::VarI32));
    }
    for s in SCALARS {
        v.push(BranchDef::new(*s, BranchType::F32));
    }
    for t in TRIGGERS {
        v.push(BranchDef::new(*t, BranchType::Bool));
    }
    v.push(BranchDef::new("run", BranchType::I32));
    v.push(BranchDef::new("luminosityBlock", BranchType::I32));
    v.push(BranchDef::new("event", BranchType::I64));
    v
}

/// Generate one event's values for [`schema`].
fn event(rng: &mut Rng, index: u64) -> Vec<Value> {
    let mut v = Vec::new();
    for (_, mean, fields) in COLLECTIONS {
        let n = rng.poisson(*mean) as usize;
        v.push(Value::I32(n as i32));
        for f in *fields {
            let vals: Vec<f32> = (0..n)
                .map(|_| match *f {
                    "pt" => (20.0 + rng.exponential(0.04)) as f32,
                    "eta" => (rng.f64() * 5.0 - 2.5) as f32,
                    "phi" => (rng.f64() * std::f64::consts::TAU - std::f64::consts::PI) as f32,
                    "mass" => rng.gauss(0.3, 0.1).abs() as f32,
                    _ => rng.f32(),
                })
                .collect();
            v.push(Value::AF32(vals));
        }
        v.push(Value::AI32(
            (0..n).map(|_| if rng.chance(0.5) { 1 } else { -1 }).collect(),
        ));
    }
    for s in SCALARS {
        let val = match *s {
            "MET_pt" => rng.exponential(0.03) as f32,
            "PV_npvs" => rng.poisson(35.0) as f32,
            _ => rng.gauss(50.0, 20.0) as f32,
        };
        v.push(Value::F32(val));
    }
    for _ in TRIGGERS {
        v.push(Value::Bool(rng.chance(0.12)));
    }
    v.push(Value::I32(356_000));
    v.push(Value::I32((index / 1000) as i32 + 1));
    v.push(Value::I64(index as i64));
    v
}

/// Generate `n` NanoAOD-like events.
pub fn events(n: usize, seed: u64) -> Vec<Vec<Value>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|i| event(&mut rng, i as u64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_and_events_align() {
        let s = schema();
        assert!(s.len() > 60, "NanoAOD-like width: {}", s.len());
        for ev in events(20, 42) {
            assert_eq!(ev.len(), s.len());
            for (v, b) in ev.iter().zip(&s) {
                assert!(v.matches(b.ty), "branch {}", b.name);
            }
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(events(50, 1), events(50, 1));
    }

    #[test]
    fn counts_match_array_lengths() {
        let s = schema();
        for ev in events(50, 9) {
            let mut i = 0usize;
            for (coll, _, fields) in COLLECTIONS {
                let n = match ev[i] {
                    Value::I32(n) => n as usize,
                    _ => panic!("count branch"),
                };
                let _ = coll;
                for k in 0..fields.len() {
                    match &ev[i + 1 + k] {
                        Value::AF32(a) => assert_eq!(a.len(), n),
                        _ => panic!("kinematic branch"),
                    }
                }
                match &ev[i + 1 + fields.len()] {
                    Value::AI32(a) => assert_eq!(a.len(), n),
                    _ => panic!("charge branch"),
                }
                i += fields.len() + 2;
            }
            assert!(i < s.len());
        }
    }
}
