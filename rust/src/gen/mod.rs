//! Workload generators: the paper's artificial 2000-event tree (§2) and a
//! NanoAOD-like event sample (Fig 6). Both deterministic by seed.

pub mod nanoaod;
pub mod synthetic;
