//! Hand-rolled CLI (clap is not in the offline crate set).
//!
//! ```text
//! rootio write   --out f.rfil [--workload synthetic|nanoaod] [--events N]
//!                [--setting ZSTD-5] [--precond bitshuffle4] [--basket N]
//!                [--workers N] [--adaptive analysis|production|balanced]
//! rootio read    --in f.rfil [--branch NAME] [--branches A,B,C] [--workers N]
//!                [--prefetch offset|submission]
//! rootio inspect --in f.rfil [--replan analysis|production|balanced]
//! rootio fig2|fig3|fig4|fig5|fig6|dict|scaling [--quick]
//! rootio all-figures [--quick]
//! ```

use crate::bench::figures::run_figure;
use crate::bench::BenchConfig;
use crate::compression::{Algorithm, Settings};
use crate::coordinator::{write_tree_parallel, FeatureSource, PipelineConfig, Planner, ReadAhead, UseCase};
use crate::gen::{nanoaod, synthetic};
use crate::precond::Precond;
use crate::rfile::TreeReader;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Parsed flags: `--key value` pairs plus bare flags.
pub struct Args {
    pub flags: HashMap<String, String>,
    pub bare: Vec<String>,
}

pub fn parse_args(argv: &[String]) -> Args {
    let mut flags = HashMap::new();
    let mut bare = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            bare.push(a.clone());
            i += 1;
        }
    }
    Args { flags, bare }
}

/// Parse "ZSTD-5", "LZ4-1", "CF-ZLIB-6", "none" into Settings.
pub fn parse_setting(s: &str) -> Result<Settings> {
    if s.eq_ignore_ascii_case("none") {
        return Ok(Settings::new(Algorithm::None, 0));
    }
    let (alg_str, level_str) = s
        .rsplit_once('-')
        .with_context(|| format!("bad setting '{s}' (want e.g. ZSTD-5)"))?;
    let level: u8 = level_str.parse().with_context(|| format!("bad level in '{s}'"))?;
    let algorithm = match alg_str.to_uppercase().as_str() {
        "ZLIB" => Algorithm::Zlib,
        "CF-ZLIB" | "CFZLIB" | "CF" => Algorithm::CfZlib,
        "LZMA" | "XZ" => Algorithm::Lzma,
        "LZ4" => Algorithm::Lz4,
        "ZSTD" => Algorithm::Zstd,
        "OLD" | "LEGACY" => Algorithm::OldRoot,
        other => bail!("unknown algorithm '{other}'"),
    };
    Ok(Settings::new(algorithm, level))
}

/// Parse "bitshuffle4", "shuffle8", "delta4", "none".
pub fn parse_precond(s: &str) -> Result<Precond> {
    if s == "none" {
        return Ok(Precond::None);
    }
    let split = s.find(|c: char| c.is_ascii_digit()).unwrap_or(s.len());
    let (name, num) = s.split_at(split);
    let stride: u8 = if num.is_empty() { 4 } else { num.parse()? };
    Ok(match name {
        "bitshuffle" => Precond::BitShuffle(stride),
        "shuffle" => Precond::Shuffle(stride),
        "delta" => Precond::Delta(stride),
        _ => bail!("unknown preconditioner '{s}'"),
    })
}

pub fn usage() -> &'static str {
    "rootio — ROOT I/O compression survey reproduction (Shadura & Bockelman, CHEP 2019)

USAGE:
  rootio write --out FILE [--workload synthetic|nanoaod] [--events N]
               [--setting ZSTD-5] [--precond bitshuffle4] [--basket BYTES]
               [--workers N] [--adaptive analysis|production|balanced]
               [--artifacts DIR]
  rootio read --in FILE [--branch NAME] [--workers N]
               (--workers N > 0 reads through the parallel basket pipeline)
  rootio read --in FILE --branches A,B,C [--workers N] [--prefetch offset|submission]
               (columnar projection: one offset-sorted pass over the file,
                per-branch read metrics; submission = branch-major baseline)
  rootio inspect --in FILE [--replan analysis|production|balanced [--workers N]]
  rootio fig2|fig3|fig4|fig5|fig6|dict|scaling [--quick]
  rootio all-figures [--quick]

FIGURES (paper mapping — see DESIGN.md §4):
  fig2     compression speed vs ratio, all {algorithm x level}
  fig3     decompression speed by algorithm and input level
  fig4     CF-ZLIB patch-set speedup vs reference ZLIB
  fig5     hardware-class vs software checksum kernels
  fig6     NanoAOD: LZ4 vs LZ4+BitShuffle vs ZLIB
  dict     ZSTD dictionary study on small baskets
  scaling  parallel pipeline scaling (L3)
"
}

pub fn run(argv: Vec<String>) -> Result<i32> {
    let Some(cmd) = argv.first().cloned() else {
        println!("{}", usage());
        return Ok(2);
    };
    let args = parse_args(&argv[1..]);
    match cmd.as_str() {
        "write" => cmd_write(&args),
        "read" => cmd_read(&args),
        "inspect" => cmd_inspect(&args),
        "fig2" | "fig3" | "fig4" | "fig5" | "fig6" | "dict" | "scaling" => {
            let cfg = bench_cfg(&args);
            let (out, _) = run_figure(&cmd, &cfg)?;
            println!("== {cmd} ==\n{out}");
            Ok(0)
        }
        "all-figures" => {
            let cfg = bench_cfg(&args);
            for name in ["fig2", "fig3", "fig4", "fig5", "fig6", "dict", "scaling"] {
                let (out, _) = run_figure(name, &cfg)?;
                println!("== {name} ==\n{out}\n");
            }
            Ok(0)
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            Ok(2)
        }
    }
}

fn bench_cfg(args: &Args) -> BenchConfig {
    if args.flags.contains_key("quick") {
        BenchConfig::quick()
    } else {
        BenchConfig::from_env()
    }
}

fn cmd_write(args: &Args) -> Result<i32> {
    let out = PathBuf::from(args.flags.get("out").context("--out required")?);
    let workload = args.flags.get("workload").map(|s| s.as_str()).unwrap_or("synthetic");
    let n: usize = args
        .flags
        .get("events")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(synthetic::PAPER_EVENTS);
    let basket: usize = args
        .flags
        .get("basket")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(crate::rfile::DEFAULT_BASKET_SIZE);
    let workers: usize = args
        .flags
        .get("workers")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(|| PipelineConfig::default().workers);
    let mut settings = args
        .flags
        .get("setting")
        .map(|s| parse_setting(s))
        .transpose()?
        .unwrap_or(Settings::new(Algorithm::Zstd, 5));
    if let Some(p) = args.flags.get("precond") {
        settings.precond = parse_precond(p)?;
    }

    let (schema, events) = match workload {
        "synthetic" => (synthetic::schema(), synthetic::events(n, 0x2019_C4E9)),
        "nanoaod" => (nanoaod::schema(), nanoaod::events(n, 0x2019_C4E9)),
        other => bail!("unknown workload '{other}'"),
    };

    // Adaptive mode: plan per-branch settings from the first basket-sized
    // chunk of each branch (the planner also runs inside examples per
    // basket; the CLI applies per-branch choices for simplicity).
    let mut schema = schema;
    if let Some(mode) = args.flags.get("adaptive") {
        let use_case = match mode.as_str() {
            "analysis" => UseCase::Analysis,
            "production" => UseCase::Production,
            "balanced" => UseCase::Balanced,
            other => bail!("unknown use case '{other}'"),
        };
        let source = load_feature_source(args)?;
        let mut planner = Planner::new(use_case, source);
        let baskets = crate::bench::figures::collect_baskets(schema.clone(), &events, basket);
        let mut per_branch: HashMap<u32, Settings> = HashMap::new();
        for b in &baskets {
            per_branch
                .entry(b.branch_id)
                .or_insert_with(|| planner.plan(&b.logical_payload()));
        }
        for (i, def) in schema.iter_mut().enumerate() {
            if let Some(s) = per_branch.get(&(i as u32)) {
                def.settings = Some(*s);
            }
        }
        println!(
            "adaptive({mode}, {}): per-branch settings chosen for {} branches",
            planner.source.label(),
            per_branch.len()
        );
    }

    let t0 = std::time::Instant::now();
    let (meta, snap) = write_tree_parallel(
        &out,
        "Events",
        schema,
        settings,
        basket,
        PipelineConfig { workers, queue_depth: workers * 4, dictionary: Vec::new() },
        events.into_iter(),
    )?;
    let wall = t0.elapsed();
    let file_len = std::fs::metadata(&out)?.len();
    println!(
        "wrote {}: {} events, {} baskets, {} bytes ({:.3} ratio) in {:.2}s [{:.1} MB/s wall]",
        out.display(),
        meta.n_entries,
        meta.baskets.len(),
        file_len,
        snap.ratio(),
        wall.as_secs_f64(),
        snap.bytes_in as f64 / 1e6 / wall.as_secs_f64(),
    );
    println!("{}", snap.report("pipeline"));
    Ok(0)
}

fn load_feature_source(args: &Args) -> Result<FeatureSource> {
    let dir = args
        .flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    if dir.join("analyzer_4096.hlo.txt").exists() {
        let client = crate::runtime::cpu_client()?;
        let analyzer = crate::runtime::Analyzer::load(&client, &dir)?;
        Ok(FeatureSource::Xla(analyzer))
    } else {
        eprintln!(
            "note: {} missing XLA artifacts, using native analyzer mirror",
            dir.display()
        );
        Ok(FeatureSource::Native)
    }
}

fn cmd_read(args: &Args) -> Result<i32> {
    let path = PathBuf::from(args.flags.get("in").context("--in required")?);
    // --workers N engages the parallel read pipeline (0 or absent = the
    // serial oracle path).
    let workers: usize = args
        .flags
        .get("workers")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    let mut reader = TreeReader::open(&path)?;
    // --branches: the columnar projection path (multi-branch single-pass
    // scan with per-branch metrics).
    if let Some(list) = args.flags.get("branches") {
        return cmd_read_projection(args, &reader, list, workers);
    }
    // Both paths answer directory queries from the same TreeMeta; only the
    // value reads dispatch to the serial oracle or the pipeline.
    let par = (workers > 0).then(|| reader.read_ahead(ReadAhead::with_workers(workers)));
    let t0 = std::time::Instant::now();
    let bytes: usize;
    if let Some(branch) = args.flags.get("branch") {
        let id = reader
            .branch_id(branch)
            .with_context(|| format!("no branch '{branch}'"))?;
        let values = match &par {
            Some(p) => p.read_branch(id)?,
            None => reader.read_branch(id)?,
        };
        println!("branch '{branch}': {} entries", values.len());
        bytes = reader
            .baskets_for(id)
            .iter()
            .map(|l| l.uncompressed_len as usize)
            .sum();
    } else {
        let events = match &par {
            Some(p) => p.read_all_events()?,
            None => reader.read_all_events()?,
        };
        println!("read {} events x {} branches", events.len(), reader.meta.branches.len());
        bytes = reader.meta.baskets.iter().map(|l| l.uncompressed_len as usize).sum();
    }
    if let Some(p) = &par {
        println!("{}", p.metrics_snapshot().report_decode(&format!("read-pipeline[{workers}w]")));
    }
    let wall = t0.elapsed();
    println!(
        "decompressed {:.2} MB in {:.3}s ({:.1} MB/s)",
        bytes as f64 / 1e6,
        wall.as_secs_f64(),
        bytes as f64 / 1e6 / wall.as_secs_f64()
    );
    Ok(0)
}

/// `rootio read --branches A,B,C`: project a branch subset through one
/// pipelined pass (offset-sorted prefetch unless `--prefetch submission`
/// asks for the branch-major baseline) and report per-branch read metrics.
fn cmd_read_projection(args: &Args, reader: &TreeReader, list: &str, workers: usize) -> Result<i32> {
    use crate::coordinator::{PrefetchOrder, ProjectionPlan};
    let names: Vec<&str> = list.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if names.is_empty() {
        bail!("--branches needs a comma-separated list of branch names");
    }
    // Projection always rides the pipeline; --workers 0/absent means the
    // default worker count, not the serial path.
    let workers = if workers == 0 { ReadAhead::default().workers } else { workers };
    let order = match args.flags.get("prefetch").map(|s| s.as_str()) {
        None | Some("offset") => PrefetchOrder::FileOffset,
        Some("submission") => PrefetchOrder::Submission,
        Some(other) => bail!("unknown prefetch order '{other}' (want offset|submission)"),
    };
    let par = reader.read_ahead(ReadAhead::with_workers(workers));
    let ids = ProjectionPlan::resolve_names(&par.meta, &names)?;
    let plan = ProjectionPlan::new(&par.meta, &ids, order)?;
    println!(
        "projection: {} of {} branches, {} baskets, {} backward seeks ({})",
        names.len(),
        par.meta.branches.len(),
        plan.locs().len(),
        plan.backward_seeks(),
        match order {
            PrefetchOrder::FileOffset => "offset-sorted sweep",
            PrefetchOrder::Submission => "submission-order baseline",
        },
    );
    let t0 = std::time::Instant::now();
    let mut proj = par.project_plan(&plan)?;
    let columns = proj.read_columns()?;
    let wall = t0.elapsed();
    println!("read {} entries x {} projected branches", par.meta.n_entries, columns.len());
    println!(
        "{:<28} {:>8} {:>10} {:>12} {:>12} {:>7}",
        "branch", "baskets", "entries", "raw", "compressed", "ratio"
    );
    for st in proj.branch_stats() {
        println!(
            "{:<28} {:>8} {:>10} {:>12} {:>12} {:>7.3}",
            st.name,
            st.baskets,
            st.entries,
            st.logical_bytes,
            st.compressed_bytes,
            st.logical_bytes as f64 / st.compressed_bytes.max(1) as f64,
        );
    }
    println!("{}", par.metrics_snapshot().report_decode(&format!("projection[{workers}w]")));
    let bytes = plan.logical_bytes() as f64;
    println!(
        "decompressed {:.2} MB in {:.3}s ({:.1} MB/s)",
        bytes / 1e6,
        wall.as_secs_f64(),
        bytes / 1e6 / wall.as_secs_f64()
    );
    Ok(0)
}

fn cmd_inspect(args: &Args) -> Result<i32> {
    let path = PathBuf::from(args.flags.get("in").context("--in required")?);
    let reader = TreeReader::open(&path)?;
    // --replan USE_CASE: profile each branch's first basket through the
    // parallel read pipeline and print the settings the adaptive planner
    // would pick for a rewrite.
    if let Some(mode) = args.flags.get("replan") {
        let use_case = match mode.as_str() {
            "analysis" => UseCase::Analysis,
            "production" => UseCase::Production,
            "balanced" => UseCase::Balanced,
            other => bail!("unknown use case '{other}'"),
        };
        let workers: usize = args
            .flags
            .get("workers")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or_else(|| ReadAhead::default().workers);
        let planner = Planner::new(use_case, FeatureSource::Native);
        let profiles = crate::runtime::analyze_tree(&path, workers)?;
        println!(
            "replan({mode}) of {} — {} branches, analyzed via {}w read pipeline",
            path.display(),
            profiles.len(),
            workers
        );
        println!("{:<28} {:>8} {:>12} {:<24} {}", "branch", "baskets", "raw", "current", "suggested");
        for p in &profiles {
            let current = reader.meta.branches[p.branch_id as usize]
                .settings
                .map(|s| s.label())
                .unwrap_or_else(|| format!("(default {})", reader.meta.default_settings.label()));
            let suggested = match &p.features {
                Some(f) => planner.plan_from_features(f).label(),
                None => format!("{} (basket below analyzer bucket)", planner.default_settings().label()),
            };
            println!("{:<28} {:>8} {:>12} {:<24} {}", p.name, p.baskets, p.logical_bytes, current, suggested);
        }
        return Ok(0);
    }
    let m = &reader.meta;
    println!("tree '{}': {} entries, {} branches, {} baskets", m.name, m.n_entries, m.branches.len(), m.baskets.len());
    println!("default setting: {}", m.default_settings.label());
    if let Some(d) = m.dictionary_offset {
        println!("dictionary record at offset {d}");
    }
    let mut per_branch: HashMap<u32, (u64, u64, u32)> = HashMap::new();
    for l in &m.baskets {
        let e = per_branch.entry(l.branch_id).or_default();
        e.0 += l.uncompressed_len as u64;
        e.1 += l.compressed_len as u64;
        e.2 += 1;
    }
    let mut ids: Vec<u32> = per_branch.keys().copied().collect();
    ids.sort();
    println!("{:<28} {:>8} {:>12} {:>12} {:>7} {}", "branch", "baskets", "raw", "compressed", "ratio", "setting");
    for id in ids {
        let (raw, comp, n) = per_branch[&id];
        let def = &m.branches[id as usize];
        println!(
            "{:<28} {:>8} {:>12} {:>12} {:>7.3} {}",
            def.name,
            n,
            raw,
            comp,
            raw as f64 / comp.max(1) as f64,
            def.settings.map(|s| s.label()).unwrap_or_else(|| "(default)".into()),
        );
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn setting_parse() {
        assert_eq!(parse_setting("ZSTD-5").unwrap(), Settings::new(Algorithm::Zstd, 5));
        assert_eq!(parse_setting("CF-ZLIB-6").unwrap(), Settings::new(Algorithm::CfZlib, 6));
        assert_eq!(parse_setting("lz4-1").unwrap(), Settings::new(Algorithm::Lz4, 1));
        assert!(parse_setting("nope").is_err());
    }

    #[test]
    fn precond_parse() {
        assert_eq!(parse_precond("bitshuffle4").unwrap(), Precond::BitShuffle(4));
        assert_eq!(parse_precond("shuffle8").unwrap(), Precond::Shuffle(8));
        assert_eq!(parse_precond("delta").unwrap(), Precond::Delta(4));
        assert_eq!(parse_precond("none").unwrap(), Precond::None);
        assert!(parse_precond("xor4").is_err());
    }

    #[test]
    fn args_parse() {
        let argv: Vec<String> = ["--out", "f.rfil", "--quick", "--events", "100"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = parse_args(&argv);
        assert_eq!(a.flags.get("out").unwrap(), "f.rfil");
        assert_eq!(a.flags.get("quick").unwrap(), "true");
        assert_eq!(a.flags.get("events").unwrap(), "100");
    }
}
