//! Hand-rolled CLI (clap is not in the offline crate set).
//!
//! ```text
//! rootio write   --out f.rfil [--workload synthetic|nanoaod] [--events N]
//!                [--setting ZSTD-5] [--precond bitshuffle4] [--basket N]
//!                [--workers N] [--adaptive analysis|production|balanced]
//! rootio read    --in f.rfil [--branch NAME] [--branches A,B,C] [--workers N]
//!                [--prefetch offset|submission] [--entries A..B]
//!                [--feedback reads.profile]
//! rootio inspect --in f.rfil [--replan analysis|production|balanced|profile
//!                [--profile reads.profile]]
//! rootio repack  IN OUT [--profile reads.profile]
//!                [--use-case analysis|production|balanced]
//!                [--target-basket-kb N] [--dict-budget BYTES] [--salvage]
//!                [--workers N]
//! rootio scrub   --in f.rfil    (exit 0 clean / 1 damaged / 2 unreadable)
//! rootio fig2|fig3|fig4|fig5|fig6|dict|scaling [--quick]
//! rootio all-figures [--quick]
//! ```

use crate::bench::figures::run_figure;
use crate::bench::BenchConfig;
use crate::compression::{Algorithm, Settings};
use crate::coordinator::{
    write_tree_parallel, FeatureSource, PipelineConfig, Planner, ReadAhead, ScanMode, UseCase,
};
use crate::gen::{nanoaod, synthetic};
use crate::precond::Precond;
use crate::rfile::{IoBackend, IoConfig, TreeReader};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

/// Parsed flags: `--key value` pairs plus bare flags.
pub struct Args {
    pub flags: HashMap<String, String>,
    pub bare: Vec<String>,
}

pub fn parse_args(argv: &[String]) -> Args {
    let mut flags = HashMap::new();
    let mut bare = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            bare.push(a.clone());
            i += 1;
        }
    }
    Args { flags, bare }
}

/// Parse "ZSTD-5", "LZ4-1", "CF-ZLIB-6", "none" into Settings.
pub fn parse_setting(s: &str) -> Result<Settings> {
    if s.eq_ignore_ascii_case("none") {
        return Ok(Settings::new(Algorithm::None, 0));
    }
    let (alg_str, level_str) = s
        .rsplit_once('-')
        .with_context(|| format!("bad setting '{s}' (want e.g. ZSTD-5)"))?;
    let level: u8 = level_str.parse().with_context(|| format!("bad level in '{s}'"))?;
    let algorithm = match alg_str.to_uppercase().as_str() {
        "ZLIB" => Algorithm::Zlib,
        "CF-ZLIB" | "CFZLIB" | "CF" => Algorithm::CfZlib,
        "LZMA" | "XZ" => Algorithm::Lzma,
        "LZ4" => Algorithm::Lz4,
        "ZSTD" => Algorithm::Zstd,
        "OLD" | "LEGACY" => Algorithm::OldRoot,
        other => bail!("unknown algorithm '{other}'"),
    };
    Ok(Settings::new(algorithm, level))
}

/// Parse "bitshuffle4", "shuffle8", "delta4", "none".
pub fn parse_precond(s: &str) -> Result<Precond> {
    if s == "none" {
        return Ok(Precond::None);
    }
    let split = s.find(|c: char| c.is_ascii_digit()).unwrap_or(s.len());
    let (name, num) = s.split_at(split);
    let stride: u8 = if num.is_empty() { 4 } else { num.parse()? };
    Ok(match name {
        "bitshuffle" => Precond::BitShuffle(stride),
        "shuffle" => Precond::Shuffle(stride),
        "delta" => Precond::Delta(stride),
        _ => bail!("unknown preconditioner '{s}'"),
    })
}

/// Parse an entry range "A..B" (also "..B" from 0 and "A.." to EOF) into
/// the half-open `[first, last)` window entry-range reads consume. The
/// window is validated for order here and clamped to the tree by the
/// readers, so "0..1000000" on a small file just reads everything.
pub fn parse_entry_range(s: &str) -> Result<(u64, u64)> {
    let (a, b) = s
        .split_once("..")
        .with_context(|| format!("bad entry range '{s}' (want A..B, half-open)"))?;
    let first: u64 = if a.is_empty() {
        0
    } else {
        a.trim().parse().with_context(|| format!("bad range start in '{s}'"))?
    };
    let last: u64 = if b.is_empty() {
        u64::MAX
    } else {
        b.trim().parse().with_context(|| format!("bad range end in '{s}'"))?
    };
    if last < first {
        bail!("backwards entry range '{s}' ({last} < {first})");
    }
    Ok((first, last))
}

/// Parse the shared `--io BACKEND [--io-latency-ms N]` flags into an
/// [`IoConfig`]. `None` when no backend was requested (callers keep their
/// default). `--io-latency-ms` models the per-request round-trip of the
/// simulated remote store, so it demands `--io remote-sim`.
pub fn parse_io_config(args: &Args) -> Result<Option<IoConfig>> {
    let Some(s) = args.flags.get("io") else {
        if args.flags.contains_key("io-latency-ms") {
            bail!("--io-latency-ms only applies to --io remote-sim");
        }
        return Ok(None);
    };
    let backend = IoBackend::parse(s)
        .with_context(|| format!("unknown --io backend '{s}' (want pread|coalesced|mmap|remote-sim)"))?;
    let mut io = IoConfig::for_backend(backend);
    if let Some(ms) = args.flags.get("io-latency-ms") {
        if backend != IoBackend::RemoteSim {
            bail!("--io-latency-ms only applies to --io remote-sim (got --io {backend})");
        }
        let ms: u64 = ms.parse().context("bad --io-latency-ms")?;
        io.latency = Duration::from_millis(ms);
    }
    Ok(Some(io))
}

pub fn usage() -> &'static str {
    "rootio — ROOT I/O compression survey reproduction (Shadura & Bockelman, CHEP 2019)

USAGE:
  rootio write --out FILE [--workload synthetic|nanoaod] [--events N]
               [--setting ZSTD-5] [--precond bitshuffle4] [--basket BYTES]
               [--workers N] [--adaptive analysis|production|balanced]
               [--artifacts DIR]
  rootio read --in FILE [--branch NAME] [--workers N] [--entries A..B]
               [--io pread|coalesced|mmap|remote-sim] [--io-latency-ms N]
               (--workers N > 0 reads through the parallel basket pipeline;
                --entries A..B reads only that entry range — boundary
                baskets are trimmed, so you get exactly entries [A, B);
                --io selects the physical read backend: plan-aware request
                coalescing, a simulated memory map, or a simulated remote
                byte-range store with --io-latency-ms per-request latency
                that the prefetch depth hides)
  rootio read --in FILE --branches A,B,C [--workers N] [--prefetch offset|submission]
               [--entries A..B] [--feedback reads.profile]
               (columnar projection: one offset-sorted pass over the file,
                per-branch read metrics; submission = branch-major baseline;
                --entries slices the plan to the baskets overlapping [A, B);
                --feedback accumulates the scan's per-branch stats into a
                read profile for `inspect --replan profile`)
  rootio read --in FILE --salvage [--branch NAME | --branches A,B,C]
               [--workers N] [--entries A..B]
               (degraded scan of a damaged file: unreadable baskets are
                skipped and reported as entry gaps instead of aborting;
                always rides the parallel pipeline)
  rootio scrub --in FILE
               (walk the container, verify record frames and basket
                payloads, print a damage map; exit 0 = clean, 1 = damaged
                records found, 2 = container unreadable)
  rootio inspect --in FILE [--replan analysis|production|balanced|profile
               [--workers N] [--profile reads.profile]]
               (--replan profile replans from a recorded access profile:
                hot branches get decode-speed settings, cold ones ratio;
                it also prints the exact `rootio repack` invocation that
                applies the plan)
  rootio repack IN OUT [--profile reads.profile]
               [--use-case analysis|production|balanced]
               [--target-basket-kb N] [--dict-budget BYTES] [--salvage]
               [--workers N]
               (profile-driven rewrite — the act step of the adaptive loop:
                per-branch codec/preconditioner/entropy settings from the
                recorded profile (or a static --use-case without one),
                baskets re-chunked toward observed read windows, one shared
                dictionary trained for small-basket branches. Strict by
                default: a damaged input fails the rewrite; --salvage keeps
                the intact rows and reports the dropped entry spans. The
                output is event-for-event identical to the source — see
                docs/REPACK.md for the operations book)
  rootio serve --corpus DIR [--workers N] [--max-scans N] [--queue-depth N]
               [--cache-mb N] [--io BACKEND] [--io-latency-ms N]
               (long-running scan server over every .rfil in DIR: queries
                share one worker pool and a decoded-basket cache. Line
                protocol on stdin:
                  QUERY file=NAME [branches=A,B] [entries=A..B] [salvage]
                  STATS | WAIT | QUIT
                QUERY lines run concurrently; WAIT drains them)
  rootio bench-concurrent [--corpus DIR] [--queries N] [--events N]
               [--workers N] [--cache-mb N]
               (drive N concurrent all-branch queries twice — cold cache,
                then warm — and report aggregate MB/s, p99 latency, and
                cache counters; without --corpus a temporary 2-file
                NanoAOD corpus is generated)
  rootio fig2|fig3|fig4|fig5|fig6|dict|scaling [--quick]
  rootio all-figures [--quick]

FIGURES (paper mapping — see DESIGN.md §4):
  fig2     compression speed vs ratio, all {algorithm x level}
  fig3     decompression speed by algorithm and input level
  fig4     CF-ZLIB patch-set speedup vs reference ZLIB
  fig5     hardware-class vs software checksum kernels
  fig6     NanoAOD: LZ4 vs LZ4+BitShuffle vs ZLIB
  dict     ZSTD dictionary study on small baskets
  scaling  parallel pipeline scaling (L3)
"
}

pub fn run(argv: Vec<String>) -> Result<i32> {
    let Some(cmd) = argv.first().cloned() else {
        println!("{}", usage());
        return Ok(2);
    };
    let args = parse_args(&argv[1..]);
    match cmd.as_str() {
        "write" => cmd_write(&args),
        "read" => cmd_read(&args),
        "inspect" => cmd_inspect(&args),
        "repack" => cmd_repack(&args),
        "scrub" => cmd_scrub(&args),
        "serve" => cmd_serve(&args),
        "bench-concurrent" => cmd_bench_concurrent(&args),
        "fig2" | "fig3" | "fig4" | "fig5" | "fig6" | "dict" | "scaling" => {
            let cfg = bench_cfg(&args);
            let (out, _) = run_figure(&cmd, &cfg)?;
            println!("== {cmd} ==\n{out}");
            Ok(0)
        }
        "all-figures" => {
            let cfg = bench_cfg(&args);
            for name in ["fig2", "fig3", "fig4", "fig5", "fig6", "dict", "scaling"] {
                let (out, _) = run_figure(name, &cfg)?;
                println!("== {name} ==\n{out}\n");
            }
            Ok(0)
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            Ok(2)
        }
    }
}

fn bench_cfg(args: &Args) -> BenchConfig {
    if args.flags.contains_key("quick") {
        BenchConfig::quick()
    } else {
        BenchConfig::from_env()
    }
}

fn cmd_write(args: &Args) -> Result<i32> {
    let out = PathBuf::from(args.flags.get("out").context("--out required")?);
    let workload = args.flags.get("workload").map(|s| s.as_str()).unwrap_or("synthetic");
    let n: usize = args
        .flags
        .get("events")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(synthetic::PAPER_EVENTS);
    let basket: usize = args
        .flags
        .get("basket")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(crate::rfile::DEFAULT_BASKET_SIZE);
    let workers: usize = args
        .flags
        .get("workers")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(|| PipelineConfig::default().workers);
    let mut settings = args
        .flags
        .get("setting")
        .map(|s| parse_setting(s))
        .transpose()?
        .unwrap_or(Settings::new(Algorithm::Zstd, 5));
    if let Some(p) = args.flags.get("precond") {
        settings.precond = parse_precond(p)?;
    }

    let (schema, events) = match workload {
        "synthetic" => (synthetic::schema(), synthetic::events(n, 0x2019_C4E9)),
        "nanoaod" => (nanoaod::schema(), nanoaod::events(n, 0x2019_C4E9)),
        other => bail!("unknown workload '{other}'"),
    };

    // Adaptive mode: plan per-branch settings from the first basket-sized
    // chunk of each branch (the planner also runs inside examples per
    // basket; the CLI applies per-branch choices for simplicity).
    let mut schema = schema;
    if let Some(mode) = args.flags.get("adaptive") {
        let use_case = match mode.as_str() {
            "analysis" => UseCase::Analysis,
            "production" => UseCase::Production,
            "balanced" => UseCase::Balanced,
            other => bail!("unknown use case '{other}'"),
        };
        let source = load_feature_source(args)?;
        let mut planner = Planner::new(use_case, source);
        let baskets = crate::bench::figures::collect_baskets(schema.clone(), &events, basket);
        let mut per_branch: HashMap<u32, Settings> = HashMap::new();
        for b in &baskets {
            per_branch
                .entry(b.branch_id)
                .or_insert_with(|| planner.plan(&b.logical_payload()));
        }
        for (i, def) in schema.iter_mut().enumerate() {
            if let Some(s) = per_branch.get(&(i as u32)) {
                def.settings = Some(*s);
            }
        }
        println!(
            "adaptive({mode}, {}): per-branch settings chosen for {} branches",
            planner.source.label(),
            per_branch.len()
        );
    }

    let t0 = std::time::Instant::now();
    let (meta, snap) = write_tree_parallel(
        &out,
        "Events",
        schema,
        settings,
        basket,
        PipelineConfig { workers, queue_depth: workers * 4, dictionary: Vec::new() },
        events.into_iter(),
    )?;
    let wall = t0.elapsed();
    let file_len = std::fs::metadata(&out)?.len();
    println!(
        "wrote {}: {} events, {} baskets, {} bytes ({:.3} ratio) in {:.2}s [{:.1} MB/s wall]",
        out.display(),
        meta.n_entries,
        meta.baskets.len(),
        file_len,
        snap.ratio(),
        wall.as_secs_f64(),
        snap.bytes_in as f64 / 1e6 / wall.as_secs_f64(),
    );
    println!("{}", snap.report("pipeline"));
    Ok(0)
}

fn load_feature_source(args: &Args) -> Result<FeatureSource> {
    let dir = args
        .flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    if dir.join("analyzer_4096.hlo.txt").exists() {
        let client = crate::runtime::cpu_client()?;
        let analyzer = crate::runtime::Analyzer::load(&client, &dir)?;
        Ok(FeatureSource::Xla(analyzer))
    } else {
        eprintln!(
            "note: {} missing XLA artifacts, using native analyzer mirror",
            dir.display()
        );
        Ok(FeatureSource::Native)
    }
}

fn cmd_read(args: &Args) -> Result<i32> {
    let path = PathBuf::from(args.flags.get("in").context("--in required")?);
    // --workers N engages the parallel read pipeline (0 or absent = the
    // serial oracle path).
    let workers: usize = args
        .flags
        .get("workers")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    let mut reader = TreeReader::open(&path)?;
    let entries = args
        .flags
        .get("entries")
        .map(|s| parse_entry_range(s))
        .transpose()?;
    // --salvage: degraded scan of a damaged file — unreadable baskets are
    // skipped and reported as entry gaps instead of aborting the read.
    let salvage = args.flags.contains_key("salvage");
    // --io: physical read backend for the parallel pipeline's prefetcher.
    let io = parse_io_config(args)?;
    // --branches: the columnar projection path (multi-branch single-pass
    // scan with per-branch metrics). --entries without a branch selection
    // projects every branch over the range.
    if let Some(list) = args.flags.get("branches") {
        let names: Vec<String> =
            list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
        if names.is_empty() {
            bail!("--branches needs a comma-separated list of branch names");
        }
        return cmd_read_projection(args, &reader, &names, workers, entries, salvage);
    }
    if let Some(branch) = args.flags.get("branch") {
        if salvage {
            return cmd_read_branch_salvage(&reader, branch, workers, entries, io);
        }
    } else if entries.is_some() || salvage {
        let names: Vec<String> = reader.meta.branches.iter().map(|b| b.name.clone()).collect();
        return cmd_read_projection(args, &reader, &names, workers, entries, salvage);
    }
    // Both paths answer directory queries from the same TreeMeta; only the
    // value reads dispatch to the serial oracle or the pipeline.
    if io.is_some() && workers == 0 {
        bail!("--io selects the parallel pipeline's read backend; add --workers N");
    }
    let par = (workers > 0).then(|| {
        let p = reader.read_ahead(ReadAhead::with_workers(workers));
        match io {
            Some(cfg) => p.with_io(cfg),
            None => p,
        }
    });
    let t0 = std::time::Instant::now();
    let bytes: usize;
    if let Some(branch) = args.flags.get("branch") {
        let id = reader
            .branch_id(branch)
            .with_context(|| format!("no branch '{branch}'"))?;
        if let Some((a, b)) = entries {
            // Entry-range read of one branch: only the overlapping baskets
            // are decoded, boundary baskets trimmed.
            let (a, b) = reader.meta.clamp_entry_range(a, b);
            let values = match &par {
                Some(p) => p.read_range(id, a..b)?,
                None => reader.read_range(id, a..b)?,
            };
            println!("branch '{branch}' entries [{a}, {b}): {} values", values.len());
            bytes = reader
                .meta
                .baskets_for_range(id, a, b)
                .iter()
                .map(|l| l.uncompressed_len as usize)
                .sum();
        } else {
            let values = match &par {
                Some(p) => p.read_branch(id)?,
                None => reader.read_branch(id)?,
            };
            println!("branch '{branch}': {} entries", values.len());
            bytes = reader
                .baskets_for(id)
                .iter()
                .map(|l| l.uncompressed_len as usize)
                .sum();
        }
    } else {
        let events = match &par {
            Some(p) => p.read_all_events()?,
            None => reader.read_all_events()?,
        };
        println!("read {} events x {} branches", events.len(), reader.meta.branches.len());
        bytes = reader.meta.baskets.iter().map(|l| l.uncompressed_len as usize).sum();
    }
    if let Some(p) = &par {
        println!("{}", p.metrics_snapshot().report_decode(&format!("read-pipeline[{workers}w]")));
    }
    let wall = t0.elapsed();
    println!(
        "decompressed {:.2} MB in {:.3}s ({:.1} MB/s)",
        bytes as f64 / 1e6,
        wall.as_secs_f64(),
        bytes as f64 / 1e6 / wall.as_secs_f64()
    );
    Ok(0)
}

/// `rootio scrub --in FILE`: walk the container record by record, verify
/// every frame and basket payload, and print a damage map. Exit code is
/// the CI contract: 0 = clean, 1 = damaged records found, 2 = container
/// unreadable (header/trailer gone).
fn cmd_scrub(args: &Args) -> Result<i32> {
    let path = args
        .flags
        .get("in")
        .cloned()
        .or_else(|| args.bare.first().cloned())
        .context("scrub needs --in FILE (or a bare path)")?;
    let report = crate::rfile::scrub_file(&PathBuf::from(path))?;
    println!("{}", report.render());
    Ok(report.exit_code())
}

/// `rootio read --branch NAME --salvage [--entries A..B]`: salvage-mode
/// single-branch read. Damaged baskets are skipped; the recovered values
/// come back with explicit entry gaps and per-basket damage records.
fn cmd_read_branch_salvage(
    reader: &TreeReader,
    branch: &str,
    workers: usize,
    entries: Option<(u64, u64)>,
    io: Option<IoConfig>,
) -> Result<i32> {
    // Salvage always rides the pipeline; 0/absent means default workers.
    let workers = if workers == 0 { ReadAhead::default().workers } else { workers };
    let mut par = reader.read_ahead(ReadAhead::with_workers(workers));
    if let Some(cfg) = io {
        par = par.with_io(cfg);
    }
    let id = reader
        .branch_id(branch)
        .with_context(|| format!("no branch '{branch}'"))?;
    let (a, b) = match entries {
        Some((a, b)) => reader.meta.clamp_entry_range(a, b),
        None => (0, reader.meta.n_entries),
    };
    let t0 = std::time::Instant::now();
    let col = par.read_range_salvage(id, a..b)?;
    let wall = t0.elapsed();
    println!(
        "branch '{branch}' entries [{a}, {b}): {} values recovered, {} entries lost across {} gaps",
        col.values.len(),
        col.entries_skipped(),
        col.gaps.len(),
    );
    for g in &col.gaps {
        println!("  gap: entries [{}, {})", g.first_entry, g.end_entry());
    }
    for d in &col.damage {
        println!("  damaged: {d}");
    }
    println!("{}", par.metrics_snapshot().report_decode(&format!("salvage[{workers}w]")));
    println!("salvaged in {:.3}s", wall.as_secs_f64());
    Ok(0)
}

/// `rootio read --branches A,B,C [--entries A..B]`: project a branch
/// subset through one pipelined pass (offset-sorted prefetch unless
/// `--prefetch submission` asks for the branch-major baseline), optionally
/// sliced to an entry range, and report per-branch read metrics.
/// `--feedback FILE` folds the scan's stats into a read profile for
/// `inspect --replan profile`.
fn cmd_read_projection(
    args: &Args,
    reader: &TreeReader,
    names: &[String],
    workers: usize,
    entries: Option<(u64, u64)>,
    salvage: bool,
) -> Result<i32> {
    use crate::coordinator::{PrefetchOrder, ProjectionPlan};
    use crate::runtime::ReadFeedback;
    let names: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    // Projection always rides the pipeline; --workers 0/absent means the
    // default worker count, not the serial path.
    let workers = if workers == 0 { ReadAhead::default().workers } else { workers };
    let order = match args.flags.get("prefetch").map(|s| s.as_str()) {
        None | Some("offset") => PrefetchOrder::FileOffset,
        Some("submission") => PrefetchOrder::Submission,
        Some(other) => bail!("unknown prefetch order '{other}' (want offset|submission)"),
    };
    let mut par = reader.read_ahead(ReadAhead::with_workers(workers));
    if let Some(cfg) = parse_io_config(args)? {
        par = par.with_io(cfg);
    }
    let ids = ProjectionPlan::resolve_names(&par.meta, &names)?;
    let mut plan = ProjectionPlan::new(&par.meta, &ids, order)?;
    let (range_start, range_end) = match entries {
        Some((a, b)) => {
            plan = plan.slice(a, b);
            par.meta.clamp_entry_range(a, b)
        }
        None => (0, par.meta.n_entries),
    };
    println!(
        "projection: {} of {} branches, entries [{range_start}, {range_end}) of {}, \
         {} baskets, {} backward seeks ({})",
        names.len(),
        par.meta.branches.len(),
        par.meta.n_entries,
        plan.locs().len(),
        plan.backward_seeks(),
        match order {
            PrefetchOrder::FileOffset => "offset-sorted sweep",
            PrefetchOrder::Submission => "submission-order baseline",
        },
    );
    let mode = if salvage { ScanMode::Salvage } else { ScanMode::Strict };
    let t0 = std::time::Instant::now();
    let mut proj = par.project_plan_with_mode(&plan, mode)?;
    let columns = proj.read_columns()?;
    let wall = t0.elapsed();
    if salvage {
        let lost: u64 = proj.branch_stats().iter().map(|s| s.damaged_entries).sum();
        println!(
            "salvaged {} projected branches over entries [{range_start}, {range_end}) \
             ({lost} branch-entries lost to damage)",
            columns.len(),
        );
    } else {
        println!("read {} entries x {} projected branches", range_end - range_start, columns.len());
    }
    println!(
        "{:<28} {:>8} {:>10} {:>12} {:>12} {:>7} {:>8} {:>8}",
        "branch", "baskets", "entries", "raw", "compressed", "ratio", "damaged", "lost"
    );
    for st in proj.branch_stats() {
        println!(
            "{:<28} {:>8} {:>10} {:>12} {:>12} {:>7.3} {:>8} {:>8}",
            st.name,
            st.baskets,
            st.entries,
            st.logical_bytes,
            st.compressed_bytes,
            st.logical_bytes as f64 / st.compressed_bytes.max(1) as f64,
            st.damaged_baskets,
            st.damaged_entries,
        );
    }
    if salvage {
        for (slot, name) in names.iter().enumerate() {
            for g in proj.branch_gaps(slot) {
                println!("  gap in '{name}': entries [{}, {})", g.first_entry, g.end_entry());
            }
        }
        for d in proj.damage() {
            println!("  damaged: {d}");
        }
    }
    println!("{}", par.metrics_snapshot().report_decode(&format!("projection[{workers}w]")));
    let bytes = plan.logical_bytes() as f64;
    println!(
        "decompressed {:.2} MB in {:.3}s ({:.1} MB/s)",
        bytes / 1e6,
        wall.as_secs_f64(),
        bytes / 1e6 / wall.as_secs_f64()
    );
    // --feedback FILE: fold this scan's per-branch stats into a persistent
    // access profile (created on first use, accumulated across runs). Each
    // recording run closes one decay generation first, so the profile is
    // an exponentially-weighted history rather than an unbounded sum.
    if let Some(fp) = args.flags.get("feedback") {
        let fp = PathBuf::from(fp);
        let mut fb = if fp.exists() { ReadFeedback::load(&fp)? } else { ReadFeedback::new() };
        fb.advance_generation();
        fb.record_scan(proj.branch_stats());
        fb.save(&fp)?;
        println!(
            "recorded scan into read profile {} ({:.2} weighted scans, gen {}, {} branches)",
            fp.display(),
            fb.scans,
            fb.generation,
            fb.branches().len()
        );
    }
    Ok(0)
}

/// `rootio inspect --replan profile --profile FILE`: replan per-branch
/// settings from a recorded access profile. Each branch's analyzer
/// features are weighted by its observed read intensity (profile bytes
/// read per scan / stored bytes), so branches analyses hammer get
/// decode-speed settings and branches nobody reads get ratio settings —
/// the stats-fed closing of the paper's §3 adaptive loop.
fn cmd_inspect_replan_profile(
    path: &std::path::Path,
    reader: &TreeReader,
    profile_path: &std::path::Path,
    workers: usize,
) -> Result<i32> {
    use crate::runtime::ReadFeedback;
    let fb = ReadFeedback::load(profile_path)?;
    if fb.scans <= 0.0 {
        bail!("read profile {} records no scans", profile_path.display());
    }
    let planner = Planner::new(UseCase::Balanced, FeatureSource::Native);
    let profiles = crate::runtime::analyze_tree(path, workers)?;
    println!(
        "replan(profile {}: {:.2} weighted scans) of {} — {} branches, analyzed via {}w read pipeline",
        profile_path.display(),
        fb.scans,
        path.display(),
        profiles.len(),
        workers
    );
    println!(
        "{:<28} {:>12} {:>12} {:>10} {:<11} {:<24} {}",
        "branch", "stored", "read", "intensity", "effective", "current", "suggested"
    );
    for p in &profiles {
        let intensity = fb.intensity(&p.name, p.logical_bytes);
        let (effective, suggested) = match &p.features {
            Some(f) => {
                let (uc, s) = planner.plan_from_feedback(f, intensity);
                (uc, s.label())
            }
            None => {
                let uc = Planner::use_case_for_intensity(intensity);
                (uc, format!("{} (basket below analyzer bucket)", Planner::default_settings_for(uc).label()))
            }
        };
        let current = reader.meta.branches[p.branch_id as usize]
            .settings
            .map(|s| s.label())
            .unwrap_or_else(|| format!("(default {})", reader.meta.default_settings.label()));
        println!(
            "{:<28} {:>12} {:>12.0} {:>10.3} {:<11} {:<24} {}",
            p.name,
            p.logical_bytes,
            fb.logical_bytes_read(&p.name),
            intensity,
            format!("{effective:?}").to_lowercase(),
            current,
            suggested
        );
    }
    // The advise → act handoff: print the exact repack invocation that
    // applies this plan (docs/REPACK.md walks the full loop).
    let out = path.with_extension("repacked.rfil");
    println!("\nto apply this plan, rewrite the file with:");
    println!(
        "  rootio repack {} {} --profile {}",
        path.display(),
        out.display(),
        profile_path.display()
    );
    Ok(0)
}

/// `rootio repack IN OUT`: apply a recorded access profile (or a static
/// use case) to an existing file — per-branch settings, re-chunked
/// baskets, trained dictionary — via
/// [`repack_file`](crate::coordinator::repack::repack_file).
fn cmd_repack(args: &Args) -> Result<i32> {
    use crate::coordinator::repack::{repack_file, RepackOptions};
    use crate::runtime::ReadFeedback;
    let mut bare = args.bare.iter();
    let src = args
        .flags
        .get("in")
        .cloned()
        .or_else(|| bare.next().cloned())
        .context("repack needs IN OUT paths (bare args, or --in/--out)")?;
    let dst = args
        .flags
        .get("out")
        .cloned()
        .or_else(|| bare.next().cloned())
        .context("repack needs an output path (second bare arg, or --out)")?;
    let src = PathBuf::from(src);
    let dst = PathBuf::from(dst);
    if src == dst {
        bail!("repack output must differ from the input");
    }
    let mut opts = RepackOptions::default();
    if let Some(uc) = args.flags.get("use-case") {
        opts.use_case = match uc.as_str() {
            "analysis" => UseCase::Analysis,
            "production" => UseCase::Production,
            "balanced" => UseCase::Balanced,
            other => bail!("unknown use case '{other}' (want analysis|production|balanced)"),
        };
    }
    if let Some(fp) = args.flags.get("profile") {
        opts.profile = Some(ReadFeedback::load(&PathBuf::from(fp))?);
    }
    if let Some(kb) = args.flags.get("target-basket-kb") {
        let kb: usize = kb.parse().context("bad --target-basket-kb")?;
        if kb == 0 {
            bail!("--target-basket-kb must be at least 1");
        }
        opts.target_basket_bytes = Some(kb * 1024);
    }
    if let Some(b) = args.flags.get("dict-budget") {
        opts.dict_budget = b.parse().context("bad --dict-budget")?;
    }
    if let Some(w) = args.flags.get("workers") {
        opts.workers = w.parse().context("bad --workers")?;
    }
    opts.salvage = args.flags.contains_key("salvage");
    let report = repack_file(&src, &dst, &opts)?;
    print!("{}", report.render());
    println!("verify with: rootio read --in {} --workers 2", dst.display());
    Ok(0)
}

fn cmd_inspect(args: &Args) -> Result<i32> {
    let path = PathBuf::from(args.flags.get("in").context("--in required")?);
    let reader = TreeReader::open(&path)?;
    // --replan USE_CASE: profile each branch's first basket through the
    // parallel read pipeline and print the settings the adaptive planner
    // would pick for a rewrite.
    if let Some(mode) = args.flags.get("replan") {
        let workers: usize = args
            .flags
            .get("workers")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or_else(|| ReadAhead::default().workers);
        // --replan profile: weight the replan by a recorded access profile
        // (what analyses actually read) instead of a static use-case label.
        if mode == "profile" {
            let fp = args
                .flags
                .get("profile")
                .context("--replan profile needs --profile FILE (record one with `rootio read --branches ... --feedback FILE`)")?;
            return cmd_inspect_replan_profile(&path, &reader, &PathBuf::from(fp), workers);
        }
        let use_case = match mode.as_str() {
            "analysis" => UseCase::Analysis,
            "production" => UseCase::Production,
            "balanced" => UseCase::Balanced,
            other => bail!("unknown use case '{other}' (want analysis|production|balanced|profile)"),
        };
        let planner = Planner::new(use_case, FeatureSource::Native);
        let profiles = crate::runtime::analyze_tree(&path, workers)?;
        println!(
            "replan({mode}) of {} — {} branches, analyzed via {}w read pipeline",
            path.display(),
            profiles.len(),
            workers
        );
        println!("{:<28} {:>8} {:>12} {:<24} {}", "branch", "baskets", "raw", "current", "suggested");
        for p in &profiles {
            let current = reader.meta.branches[p.branch_id as usize]
                .settings
                .map(|s| s.label())
                .unwrap_or_else(|| format!("(default {})", reader.meta.default_settings.label()));
            let suggested = match &p.features {
                Some(f) => planner.plan_from_features(f).label(),
                None => format!("{} (basket below analyzer bucket)", planner.default_settings().label()),
            };
            println!("{:<28} {:>8} {:>12} {:<24} {}", p.name, p.baskets, p.logical_bytes, current, suggested);
        }
        return Ok(0);
    }
    let m = &reader.meta;
    println!("tree '{}': {} entries, {} branches, {} baskets", m.name, m.n_entries, m.branches.len(), m.baskets.len());
    println!("default setting: {}", m.default_settings.label());
    if let Some(d) = m.dictionary_offset {
        println!("dictionary record at offset {d}");
    }
    let mut per_branch: HashMap<u32, (u64, u64, u32)> = HashMap::new();
    for l in &m.baskets {
        let e = per_branch.entry(l.branch_id).or_default();
        e.0 += l.uncompressed_len as u64;
        e.1 += l.compressed_len as u64;
        e.2 += 1;
    }
    let mut ids: Vec<u32> = per_branch.keys().copied().collect();
    ids.sort();
    println!("{:<28} {:>8} {:>12} {:>12} {:>7} {}", "branch", "baskets", "raw", "compressed", "ratio", "setting");
    for id in ids {
        let (raw, comp, n) = per_branch[&id];
        let def = &m.branches[id as usize];
        println!(
            "{:<28} {:>8} {:>12} {:>12} {:>7.3} {}",
            def.name,
            n,
            raw,
            comp,
            raw as f64 / comp.max(1) as f64,
            def.settings.map(|s| s.label()).unwrap_or_else(|| "(default)".into()),
        );
    }
    Ok(0)
}

/// Build a [`ServeConfig`](crate::coordinator::ServeConfig) from the
/// shared serve/bench-concurrent flags.
fn serve_cfg(args: &Args) -> Result<crate::coordinator::ServeConfig> {
    let mut cfg = crate::coordinator::ServeConfig::default();
    if let Some(w) = args.flags.get("workers") {
        cfg.workers = w.parse::<usize>().context("bad --workers")?.max(1);
        cfg.queue_depth = 2 * cfg.workers;
    }
    if let Some(m) = args.flags.get("max-scans") {
        cfg.max_scans = m.parse::<usize>().context("bad --max-scans")?.max(1);
    }
    if let Some(q) = args.flags.get("queue-depth") {
        cfg.queue_depth = q.parse::<usize>().context("bad --queue-depth")?.max(1);
    }
    if let Some(c) = args.flags.get("cache-mb") {
        cfg.cache_bytes = c.parse::<u64>().context("bad --cache-mb")? << 20;
    }
    if let Some(io) = parse_io_config(args)? {
        cfg.io = io;
    }
    Ok(cfg)
}

/// Parse one `QUERY file=NAME [branches=A,B] [entries=A..B] [salvage]`
/// line of the serve protocol.
fn parse_serve_query(line: &str) -> Result<crate::coordinator::Query> {
    use crate::coordinator::Query;
    let mut q = Query { file: String::new(), branches: Vec::new(), entries: None, mode: ScanMode::Strict };
    for tok in line.split_whitespace().skip(1) {
        if let Some(f) = tok.strip_prefix("file=") {
            q.file = f.to_string();
        } else if let Some(b) = tok.strip_prefix("branches=") {
            q.branches = b.split(',').filter(|s| !s.is_empty()).map(|s| s.to_string()).collect();
        } else if let Some(e) = tok.strip_prefix("entries=") {
            q.entries = Some(parse_entry_range(e)?);
        } else if tok == "salvage" {
            q.mode = ScanMode::Salvage;
        } else {
            bail!("unknown QUERY token '{tok}'");
        }
    }
    if q.file.is_empty() {
        bail!("QUERY needs file=NAME");
    }
    Ok(q)
}

/// `rootio serve --corpus DIR`: a long-running scan server speaking a
/// line protocol on stdin (no network dependencies in the offline crate
/// set — a socket front-end would wrap this same loop). QUERY lines run
/// concurrently on the shared worker pool; results print as they finish.
fn cmd_serve(args: &Args) -> Result<i32> {
    use std::io::BufRead;
    let corpus = PathBuf::from(args.flags.get("corpus").context("--corpus DIR required")?);
    let server = crate::coordinator::ScanServer::open_corpus(&corpus, serve_cfg(args)?)?;
    let files: Vec<String> = server.files().iter().map(|f| f.name.clone()).collect();
    println!("serving {} file(s) from {}: {}", files.len(), corpus.display(), files.join(", "));
    let stdin = std::io::stdin();
    let mut next_id = 0u64;
    std::thread::scope(|scope| -> Result<()> {
        let server = &server;
        for line in stdin.lock().lines() {
            let line = line?;
            let trimmed = line.trim();
            let upper = trimmed.split_whitespace().next().unwrap_or("").to_uppercase();
            match upper.as_str() {
                "" => {}
                "QUERY" => {
                    let q = match parse_serve_query(trimmed) {
                        Ok(q) => q,
                        Err(e) => {
                            println!("ERR {e:#}");
                            continue;
                        }
                    };
                    let id = next_id;
                    next_id += 1;
                    match server.query(&q) {
                        Ok(mut sq) => {
                            // Queries drain on their own threads so many can
                            // be in flight; scope joins them all on QUIT/EOF.
                            scope.spawn(move || {
                                let t0 = std::time::Instant::now();
                                match sq.read_columns() {
                                    Ok(cols) => {
                                        let st = sq.stats();
                                        let rows = cols.first().map(|c| c.len()).unwrap_or(0);
                                        println!(
                                            "OK #{id} file={} rows={rows} cols={} gaps={} {:.3}s wait={:.3}s decoded={} cached={} coalesced={}",
                                            q.file,
                                            cols.len(),
                                            sq.gaps().len(),
                                            t0.elapsed().as_secs_f64(),
                                            st.queue_wait.as_secs_f64(),
                                            st.baskets_decoded,
                                            st.baskets_from_cache,
                                            st.baskets_coalesced,
                                        );
                                    }
                                    Err(e) => println!("ERR #{id} {e:#}"),
                                }
                            });
                        }
                        Err(e) => println!("ERR #{id} {e:#}"),
                    }
                }
                "STATS" => {
                    let cs = server.cache_stats();
                    println!(
                        "STATS lookups={} hits={} misses={} evictions={} resident={}B/{} entries peak_active={}",
                        cs.lookups, cs.hits, cs.misses, cs.evictions, cs.resident_bytes,
                        cs.resident_entries, server.peak_active()
                    );
                    println!("{}", server.metrics_snapshot().report_decode("serve"));
                }
                // WAIT is only meaningful interactively: the scope already
                // joins every query thread before QUIT returns.
                "WAIT" => {}
                "QUIT" | "EXIT" => break,
                other => println!("ERR unknown command '{other}' (QUERY/STATS/WAIT/QUIT)"),
            }
        }
        Ok(())
    })?;
    println!("{}", server.metrics_snapshot().report_decode("serve"));
    Ok(0)
}

/// `rootio bench-concurrent`: drive N concurrent all-branch queries over
/// a corpus twice — cold cache, then warm — and report aggregate
/// throughput, p99 latency, and cache counters. The real lanes live in
/// the bench harness (BENCH_codecs.json §concurrent); this is the
/// interactive spot-check.
fn cmd_bench_concurrent(args: &Args) -> Result<i32> {
    use crate::coordinator::{Query, ScanServer};
    let queries: usize =
        args.flags.get("queries").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let queries = queries.max(1);
    let events: usize = args.flags.get("events").map(|s| s.parse()).transpose()?.unwrap_or(20_000);

    // Use --corpus if given, else generate a temporary two-file NanoAOD
    // corpus (LZ4-1 + BitShuffle, the paper's Run-3 default lane).
    let (corpus, temp): (PathBuf, bool) = match args.flags.get("corpus") {
        Some(dir) => (PathBuf::from(dir), false),
        None => {
            let mut dir = std::env::temp_dir();
            dir.push(format!("rootio_bench_concurrent_{}", std::process::id()));
            std::fs::create_dir_all(&dir)?;
            let mut settings = Settings::new(Algorithm::Lz4, 1);
            settings.precond = Precond::BitShuffle(4);
            for (i, name) in ["nanoaod_a", "nanoaod_b"].iter().enumerate() {
                crate::rfile::write_tree_serial(
                    &dir.join(format!("{name}.rfil")),
                    "Events",
                    nanoaod::schema(),
                    settings,
                    crate::rfile::DEFAULT_BASKET_SIZE,
                    nanoaod::events(events, 0x5EED + i as u64).into_iter(),
                )?;
            }
            (dir, true)
        }
    };

    let server = ScanServer::open_corpus(&corpus, serve_cfg(args)?)?;
    let names: Vec<String> = server.files().iter().map(|f| f.name.clone()).collect();
    println!(
        "bench-concurrent: {} queries over {} file(s), {} workers, cache {} MB",
        queries,
        names.len(),
        serve_cfg(args)?.workers,
        serve_cfg(args)?.cache_bytes >> 20
    );

    let wave = |label: &str| -> Result<()> {
        let t0 = std::time::Instant::now();
        let mut bytes = 0u64;
        let mut lats: Vec<f64> = Vec::with_capacity(queries);
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(queries);
            for i in 0..queries {
                let file = names[i % names.len()].clone();
                let server = &server;
                handles.push(scope.spawn(move || -> Result<(u64, f64)> {
                    let q0 = std::time::Instant::now();
                    let mut sq = server.query(&Query::all(&file))?;
                    let logical = sq.plan().logical_bytes();
                    sq.read_columns()?;
                    Ok((logical, q0.elapsed().as_secs_f64()))
                }));
            }
            for h in handles {
                let (b, lat) = h.join().expect("query thread panicked")?;
                bytes += b;
                lats.push(lat);
            }
            Ok(())
        })?;
        let wall = t0.elapsed().as_secs_f64();
        lats.sort_by(|a, b| a.total_cmp(b));
        let p99 = lats[((lats.len() as f64 * 0.99).ceil() as usize).clamp(1, lats.len()) - 1];
        println!(
            "{label}: {:.2} MB in {:.3}s = {:.1} MB/s aggregate, p99 latency {:.3}s",
            bytes as f64 / 1e6,
            wall,
            bytes as f64 / 1e6 / wall,
            p99
        );
        Ok(())
    };

    wave("cold")?;
    wave("warm")?;
    let cs = server.cache_stats();
    println!(
        "cache: lookups={} hits={} misses={} evictions={} resident={:.2}MB peak_active={}",
        cs.lookups,
        cs.hits,
        cs.misses,
        cs.evictions,
        cs.resident_bytes as f64 / 1e6,
        server.peak_active()
    );
    println!("{}", server.metrics_snapshot().report_decode("bench-concurrent"));
    if temp {
        drop(server);
        std::fs::remove_dir_all(&corpus).ok();
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn setting_parse() {
        assert_eq!(parse_setting("ZSTD-5").unwrap(), Settings::new(Algorithm::Zstd, 5));
        assert_eq!(parse_setting("CF-ZLIB-6").unwrap(), Settings::new(Algorithm::CfZlib, 6));
        assert_eq!(parse_setting("lz4-1").unwrap(), Settings::new(Algorithm::Lz4, 1));
        assert!(parse_setting("nope").is_err());
    }

    #[test]
    fn precond_parse() {
        assert_eq!(parse_precond("bitshuffle4").unwrap(), Precond::BitShuffle(4));
        assert_eq!(parse_precond("shuffle8").unwrap(), Precond::Shuffle(8));
        assert_eq!(parse_precond("delta").unwrap(), Precond::Delta(4));
        assert_eq!(parse_precond("none").unwrap(), Precond::None);
        assert!(parse_precond("xor4").is_err());
    }

    #[test]
    fn entry_range_parse() {
        assert_eq!(parse_entry_range("100..200").unwrap(), (100, 200));
        assert_eq!(parse_entry_range("..200").unwrap(), (0, 200));
        assert_eq!(parse_entry_range("100..").unwrap(), (100, u64::MAX));
        assert_eq!(parse_entry_range("..").unwrap(), (0, u64::MAX));
        assert_eq!(parse_entry_range("7..7").unwrap(), (7, 7)); // empty window ok
        assert_eq!(parse_entry_range(" 1 .. 2 ").unwrap(), (1, 2));
        assert!(parse_entry_range("200..100").is_err(), "backwards rejected");
        assert!(parse_entry_range("100").is_err());
        assert!(parse_entry_range("a..b").is_err());
        assert!(parse_entry_range("1..2..3").is_err());
    }

    #[test]
    fn io_config_parse() {
        let parse = |argv: &[&str]| {
            parse_io_config(&parse_args(
                &argv.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            ))
        };
        assert!(parse(&[]).unwrap().is_none(), "no flags → caller default");
        let io = parse(&["--io", "coalesced"]).unwrap().unwrap();
        assert_eq!(io.backend, IoBackend::Coalesced);
        let io = parse(&["--io", "remote-sim", "--io-latency-ms", "10"]).unwrap().unwrap();
        assert_eq!(io.backend, IoBackend::RemoteSim);
        assert_eq!(io.latency, Duration::from_millis(10));
        assert_eq!(parse(&["--io", "mmap"]).unwrap().unwrap().backend, IoBackend::Mmap);
        assert_eq!(parse(&["--io", "pread"]).unwrap().unwrap().backend, IoBackend::Pread);
        assert!(parse(&["--io", "sata"]).is_err(), "unknown backend rejected");
        assert!(
            parse(&["--io-latency-ms", "5"]).is_err(),
            "latency without remote-sim rejected"
        );
        assert!(
            parse(&["--io", "mmap", "--io-latency-ms", "5"]).is_err(),
            "latency on a local backend rejected"
        );
    }

    #[test]
    fn args_parse() {
        let argv: Vec<String> = ["--out", "f.rfil", "--quick", "--events", "100"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = parse_args(&argv);
        assert_eq!(a.flags.get("out").unwrap(), "f.rfil");
        assert_eq!(a.flags.get("quick").unwrap(), "true");
        assert_eq!(a.flags.get("events").unwrap(), "100");
    }
}
