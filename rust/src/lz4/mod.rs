//! From-scratch LZ4 (block format + HC variant + ROOT-style frame).
//!
//! Paper §2.2: LZ4's byte-aligned, entropy-free design gives it the fastest
//! decompression at every level (Fig 3) but a poor ratio on ROOT offset
//! arrays (fixed by the preconditioners in `crate::precond`, Fig 6).

pub mod block;
pub mod decode;
pub mod frame;
pub mod hc;

pub use block::Lz4Fast;
pub use decode::{decompress_block, Lz4Error};
pub use decode::decompress_block_dict_into;
pub use frame::{lz4_compress, lz4_decompress, lz4_decompress_dict, lz4_decompress_into, method_for_level, Lz4Encoder, Lz4Method};
pub use hc::Lz4Hc;
