//! From-scratch LZ4 (block format + HC variant + ROOT-style frame).
//!
//! Paper §2.2: LZ4's byte-aligned, entropy-free design gives it the fastest
//! decompression at every level (Fig 3) but a poor ratio on ROOT offset
//! arrays (fixed by the preconditioners in `crate::precond`, Fig 6).
//!
//! # §Perf fast paths (LZ4/ZSTD hot-lane overhaul)
//!
//! * **Wild-copy block decode** (`decode`): sequences execute against a
//!   pre-sized output buffer with a 16-byte pad — unconditional 16-byte
//!   literal moves, 8-byte-stride match copies for `offset >= 8`, a
//!   doubling `copy_within` stepper for self-overlapping `offset < 8`, and
//!   a `memset` lane for `offset == 1`. Every format check of the original
//!   Vec-growth decoder is preserved, so malformed input is rejected
//!   identically. Oracle: `decode::reference::decompress_block_naive`,
//!   property-tested byte-identical (and accept/reject-identical) in
//!   `rust/tests/prop_codecs.rs` across roundtrip, dictionary, overlap and
//!   fuzzed-garbage cases.
//! * **Shared match finder** (`hc` over
//!   `crate::util::match_finder::ChainTable`): the HC chain walk (SWAR
//!   `common_prefix`, quick-reject, `nice_len` early exit, `good_length`
//!   lookahead shortening) is the same substrate as the ZSTD matcher; the
//!   fast path's `hash5` also lives there. Compressor output is validated
//!   by decode roundtrips (parse policy may evolve; decoded bytes must
//!   not).
//!
//! Equivalence guarantee: for every stream either decoder accepts, fast
//! and naive decodes return the same bytes; streams one rejects, both
//! reject.

pub mod block;
pub mod decode;
pub mod frame;
pub mod hc;

pub use block::Lz4Fast;
pub use decode::{decompress_block, Lz4Error};
pub use decode::decompress_block_dict_into;
pub use frame::{lz4_compress, lz4_decompress, lz4_decompress_dict, lz4_decompress_into, method_for_level, Lz4Encoder, Lz4Method};
pub use hc::Lz4Hc;
