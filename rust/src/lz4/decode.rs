//! LZ4 block decoder. Decompression speed is the whole point of LZ4 in the
//! paper (Fig 3: "extremely fast decompressor at all compression levels"),
//! so this is one of the repository's hot paths: wide wild copies inside a
//! bounds-checked envelope, scalar fallback near the edges.

/// Decode error (untrusted input — never panic).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lz4Error(pub &'static str);

impl std::fmt::Display for Lz4Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lz4: {}", self.0)
    }
}
impl std::error::Error for Lz4Error {}

const E: fn(&'static str) -> Lz4Error = Lz4Error;

/// Decompress a block with known uncompressed size (ROOT's record header
/// always stores it; the LZ4 block format itself is not self-terminating).
pub fn decompress_block(src: &[u8], expected_len: usize) -> Result<Vec<u8>, Lz4Error> {
    let mut out = Vec::with_capacity(expected_len);
    decompress_block_into(src, expected_len, &mut out)?;
    Ok(out)
}

/// Decompress into a reusable buffer (cleared first).
pub fn decompress_block_into(src: &[u8], expected_len: usize, out: &mut Vec<u8>) -> Result<(), Lz4Error> {
    decompress_block_dict_into(src, &[], expected_len, out)
}

/// Decompress a block produced with a dictionary prefix: `out` is primed
/// with `dict` so matches can reach into it; the dictionary is stripped
/// from the returned content.
pub fn decompress_block_dict_into(
    src: &[u8],
    dict: &[u8],
    expected_len: usize,
    out: &mut Vec<u8>,
) -> Result<(), Lz4Error> {
    out.clear();
    out.reserve(dict.len() + expected_len);
    out.extend_from_slice(dict);
    let expected_len = dict.len() + expected_len;
    let dict_len = dict.len();
    let mut i = 0usize;
    loop {
        let token = *src.get(i).ok_or(E("truncated token"))?;
        i += 1;
        // Literal length.
        let mut lit_len = (token >> 4) as usize;
        if lit_len == 15 {
            lit_len += read_len(src, &mut i)?;
        }
        if i + lit_len > src.len() {
            return Err(E("literal overrun"));
        }
        if out.len() + lit_len > expected_len {
            return Err(E("output overflow (literals)"));
        }
        out.extend_from_slice(&src[i..i + lit_len]);
        i += lit_len;

        if i == src.len() {
            // Final literals-only sequence.
            if out.len() != expected_len {
                return Err(E("size mismatch"));
            }
            out.drain(..dict_len);
            return Ok(());
        }

        // Match.
        if i + 2 > src.len() {
            return Err(E("truncated offset"));
        }
        let offset = u16::from_le_bytes(src[i..i + 2].try_into().unwrap()) as usize;
        i += 2;
        if offset == 0 {
            return Err(E("zero offset"));
        }
        if offset > out.len() {
            return Err(E("offset beyond output"));
        }
        let mut match_len = (token & 0x0F) as usize;
        if match_len == 15 {
            match_len += read_len(src, &mut i)?;
        }
        match_len += 4;
        if out.len() + match_len > expected_len {
            return Err(E("output overflow (match)"));
        }
        copy_match(out, offset, match_len);
    }
}

#[inline]
fn read_len(src: &[u8], i: &mut usize) -> Result<usize, Lz4Error> {
    let mut total = 0usize;
    loop {
        let b = *src.get(*i).ok_or(E("truncated length"))?;
        *i += 1;
        total += b as usize;
        if b != 255 {
            return Ok(total);
        }
        if total > (1 << 30) {
            return Err(E("length overflow"));
        }
    }
}

/// Backwards copy supporting overlap; see deflate::inflate::copy_match for
/// the same pattern.
#[inline]
fn copy_match(out: &mut Vec<u8>, dist: usize, len: usize) {
    let start = out.len() - dist;
    if dist >= len {
        out.extend_from_within(start..start + len);
        return;
    }
    if dist == 1 {
        let b = out[out.len() - 1];
        let new_len = out.len() + len;
        out.resize(new_len, b);
        return;
    }
    out.reserve(len);
    let mut remaining = len;
    let mut src = start;
    while remaining > 0 {
        let chunk = remaining.min(out.len() - src);
        out.extend_from_within(src..src + chunk);
        src += chunk;
        remaining -= chunk;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_block() {
        // Single zero token = empty literals, end.
        assert_eq!(decompress_block(&[0], 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn rejects_bad_inputs() {
        // Truncated.
        assert!(decompress_block(&[], 5).is_err());
        // Literal length runs past end.
        assert!(decompress_block(&[0xF0, 200], 300).is_err());
        // Match offset beyond output.
        // token: 1 literal, match len 4; lit 'a'; offset 9 (too far).
        assert!(decompress_block(&[0x10, b'a', 9, 0], 10).is_err());
        // Zero offset.
        assert!(decompress_block(&[0x10, b'a', 0, 0], 10).is_err());
    }

    #[test]
    fn size_mismatch_detected() {
        // 3 literals but caller expects 4.
        assert!(decompress_block(&[0x30, b'a', b'b', b'c'], 4).is_err());
        assert_eq!(decompress_block(&[0x30, b'a', b'b', b'c'], 3).unwrap(), b"abc");
    }

    #[test]
    fn fuzz_garbage_never_panics() {
        let mut rng = Rng::new(0x44);
        for _ in 0..500 {
            let n = rng.range(0, 300);
            let garbage = rng.bytes(n);
            let expected = rng.range(0, 1000);
            let _ = decompress_block(&garbage, expected); // must not panic
        }
    }

    #[test]
    fn overlap_copy_periods() {
        // Hand-built stream: 4 literals "abab" then match offset 2 len 10.
        // -> "abab" + "ababababab"
        let stream = [0x46u8, b'a', b'b', b'a', b'b', 2, 0, 0x00];
        // token 0x46: lit_len 4, match_len 6+4=10; trailing empty-literal token.
        let out = decompress_block(&stream, 14).unwrap();
        assert_eq!(&out, b"ababababababab");
    }
}
