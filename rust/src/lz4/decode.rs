//! LZ4 block decoder. Decompression speed is the whole point of LZ4 in the
//! paper (Fig 3: "extremely fast decompressor at all compression levels"),
//! so this is one of the repository's hottest paths.
//!
//! # §Perf: wild-copy fast decode
//!
//! The decoder writes through a **pre-sized** output buffer (`+16` bytes
//! of pad; a reused buffer is only zero-extended on capacity shortfall, so
//! steady state pays no memset) instead of growing a `Vec` push-by-push:
//!
//! * literals of ≤ 16 bytes are copied with one unconditional 16-byte move
//!   whenever 16 bytes of input and pad-envelope headroom exist (the copy
//!   may scribble past the literal run into bytes the next sequence
//!   overwrites — never past the padded buffer);
//! * matches with `offset >= 8` copy 8 bytes per step, over-copying into
//!   the pad at the tail of the match;
//! * matches with `offset < 8` (self-overlapping) replicate the period via
//!   a doubling `copy_within` stepper, with a `memset` special case for
//!   `offset == 1`;
//! * every format check of the naive decoder (truncation, zero/too-far
//!   offsets, output overflow, size mismatch) is preserved verbatim, so
//!   the accept/reject set is unchanged.
//!
//! [`reference::decompress_block_naive`] keeps the original Vec-growth
//! decoder as the oracle; `rust/tests/prop_codecs.rs` asserts both return
//! identical bytes on every valid stream and agree on rejection for
//! malformed/truncated/fuzzed ones.

/// Decode error (untrusted input — never panic).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lz4Error(pub &'static str);

impl std::fmt::Display for Lz4Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lz4: {}", self.0)
    }
}
impl std::error::Error for Lz4Error {}

const E: fn(&'static str) -> Lz4Error = Lz4Error;

/// Pad appended to the output buffer so wild copies can overshoot safely.
const WILD_PAD: usize = 16;

/// Decompress a block with known uncompressed size (ROOT's record header
/// always stores it; the LZ4 block format itself is not self-terminating).
pub fn decompress_block(src: &[u8], expected_len: usize) -> Result<Vec<u8>, Lz4Error> {
    let mut out = Vec::with_capacity(expected_len + WILD_PAD);
    decompress_block_into(src, expected_len, &mut out)?;
    Ok(out)
}

/// Decompress into a reusable buffer (cleared first).
pub fn decompress_block_into(src: &[u8], expected_len: usize, out: &mut Vec<u8>) -> Result<(), Lz4Error> {
    decompress_block_dict_into(src, &[], expected_len, out)
}

/// Decompress a block produced with a dictionary prefix: the output is
/// primed with `dict` so matches can reach into it; the dictionary is
/// stripped from the returned content. On error `out` is left cleared.
pub fn decompress_block_dict_into(
    src: &[u8],
    dict: &[u8],
    expected_len: usize,
    out: &mut Vec<u8>,
) -> Result<(), Lz4Error> {
    let total = dict.len() + expected_len;
    let need = total + WILD_PAD;
    // Reuse whatever the caller's buffer already holds: every output byte
    // in [dict.len(), total) is written by the sequence loop before it can
    // be read (match sources always sit below the write cursor), so only a
    // capacity shortfall needs zero-extending — steady-state reuse of a
    // pooled buffer pays no memset.
    if out.len() < need {
        out.resize(need, 0);
    } else {
        out.truncate(need);
    }
    out[..dict.len()].copy_from_slice(dict);
    match decode_into(src, out.as_mut_slice(), dict.len(), total) {
        Ok(()) => {
            out.truncate(total);
            out.drain(..dict.len());
            Ok(())
        }
        Err(e) => {
            out.clear();
            Err(e)
        }
    }
}

/// Core sequence loop over the pre-sized buffer. `out.len() == total +
/// WILD_PAD`; `o` starts after the dictionary prefix and must land exactly
/// on `total`.
fn decode_into(src: &[u8], out: &mut [u8], start: usize, total: usize) -> Result<(), Lz4Error> {
    let n = src.len();
    let mut i = 0usize;
    let mut o = start;
    loop {
        let token = *src.get(i).ok_or(E("truncated token"))?;
        i += 1;
        // Literal run.
        let mut lit_len = (token >> 4) as usize;
        if lit_len == 15 {
            lit_len += read_len(src, &mut i)?;
        }
        if i + lit_len > n {
            return Err(E("literal overrun"));
        }
        if o + lit_len > total {
            return Err(E("output overflow (literals)"));
        }
        if lit_len <= 16 && i + 16 <= n {
            // Wild copy: 16 bytes unconditionally (o + 16 <= total + 16 =
            // padded length always holds since o <= total here).
            out[o..o + 16].copy_from_slice(&src[i..i + 16]);
        } else {
            out[o..o + lit_len].copy_from_slice(&src[i..i + lit_len]);
        }
        i += lit_len;
        o += lit_len;

        if i == n {
            // Final literals-only sequence.
            if o != total {
                return Err(E("size mismatch"));
            }
            return Ok(());
        }

        // Match.
        if i + 2 > n {
            return Err(E("truncated offset"));
        }
        let offset = u16::from_le_bytes([src[i], src[i + 1]]) as usize;
        i += 2;
        if offset == 0 {
            return Err(E("zero offset"));
        }
        if offset > o {
            return Err(E("offset beyond output"));
        }
        let mut match_len = (token & 0x0F) as usize;
        if match_len == 15 {
            match_len += read_len(src, &mut i)?;
        }
        match_len += 4;
        if o + match_len > total {
            return Err(E("output overflow (match)"));
        }
        copy_match(out, o, offset, match_len);
        o += match_len;
    }
}

#[inline]
fn read_len(src: &[u8], i: &mut usize) -> Result<usize, Lz4Error> {
    let mut total = 0usize;
    loop {
        let b = *src.get(*i).ok_or(E("truncated length"))?;
        *i += 1;
        total += b as usize;
        if b != 255 {
            return Ok(total);
        }
        if total > (1 << 30) {
            return Err(E("length overflow"));
        }
    }
}

/// Backwards copy of `len` bytes from `d - offset` to `d` inside the padded
/// buffer. Caller guarantees `offset <= d` and `d + len + WILD_PAD <=
/// out.len()` (pad absorbs the 8-byte overshoot).
#[inline]
fn copy_match(out: &mut [u8], d: usize, offset: usize, len: usize) {
    let end = d + len;
    if offset >= 8 && end + 8 <= out.len() {
        // Wild copy: 8 bytes per step; chunks never overlap (offset >= 8)
        // and the tail overshoot lands in the pad.
        let (mut s, mut d) = (d - offset, d);
        while d < end {
            let v = u64::from_le_bytes(out[s..s + 8].try_into().unwrap());
            out[d..d + 8].copy_from_slice(&v.to_le_bytes());
            s += 8;
            d += 8;
        }
        return;
    }
    if offset == 1 {
        let b = out[d - 1];
        out[d..end].fill(b);
        return;
    }
    if offset >= len {
        // Disjoint ranges: one exact move.
        out.copy_within(d - offset..d - offset + len, d);
        return;
    }
    // Self-overlapping period (and the pad-less defensive tail for any
    // offset): replicate it, doubling the span of final bytes available to
    // copy from on each step — never a raw memmove over overlapping
    // ranges, which would duplicate stale bytes instead of the period.
    let s = d - offset;
    let mut have = offset;
    let mut copied = 0usize;
    while copied < len {
        let chunk = have.min(len - copied);
        out.copy_within(s..s + chunk, d + copied);
        copied += chunk;
        have += chunk;
    }
}

/// Pre-optimization Vec-growth decoder, kept as the oracle for the wild-copy
/// fast path (`rust/tests/prop_codecs.rs` pits them against each other on
/// valid, malformed, truncated and fuzzed streams).
#[doc(hidden)]
pub mod reference {
    use super::{read_len, Lz4Error, E};

    pub fn decompress_block_naive(
        src: &[u8],
        dict: &[u8],
        expected_len: usize,
    ) -> Result<Vec<u8>, Lz4Error> {
        let mut out: Vec<u8> = Vec::with_capacity(dict.len() + expected_len);
        out.extend_from_slice(dict);
        let expected_len = dict.len() + expected_len;
        let dict_len = dict.len();
        let mut i = 0usize;
        loop {
            let token = *src.get(i).ok_or(E("truncated token"))?;
            i += 1;
            let mut lit_len = (token >> 4) as usize;
            if lit_len == 15 {
                lit_len += read_len(src, &mut i)?;
            }
            if i + lit_len > src.len() {
                return Err(E("literal overrun"));
            }
            if out.len() + lit_len > expected_len {
                return Err(E("output overflow (literals)"));
            }
            out.extend_from_slice(&src[i..i + lit_len]);
            i += lit_len;

            if i == src.len() {
                if out.len() != expected_len {
                    return Err(E("size mismatch"));
                }
                out.drain(..dict_len);
                return Ok(out);
            }

            if i + 2 > src.len() {
                return Err(E("truncated offset"));
            }
            let offset = u16::from_le_bytes(src[i..i + 2].try_into().unwrap()) as usize;
            i += 2;
            if offset == 0 {
                return Err(E("zero offset"));
            }
            if offset > out.len() {
                return Err(E("offset beyond output"));
            }
            let mut match_len = (token & 0x0F) as usize;
            if match_len == 15 {
                match_len += read_len(src, &mut i)?;
            }
            match_len += 4;
            if out.len() + match_len > expected_len {
                return Err(E("output overflow (match)"));
            }
            copy_match_vec(&mut out, offset, match_len);
        }
    }

    fn copy_match_vec(out: &mut Vec<u8>, dist: usize, len: usize) {
        let start = out.len() - dist;
        if dist >= len {
            out.extend_from_within(start..start + len);
            return;
        }
        if dist == 1 {
            let b = out[out.len() - 1];
            let new_len = out.len() + len;
            out.resize(new_len, b);
            return;
        }
        out.reserve(len);
        let mut remaining = len;
        let mut src = start;
        while remaining > 0 {
            let chunk = remaining.min(out.len() - src);
            out.extend_from_within(src..src + chunk);
            src += chunk;
            remaining -= chunk;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_block() {
        // Single zero token = empty literals, end.
        assert_eq!(decompress_block(&[0], 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn rejects_bad_inputs() {
        // Truncated.
        assert!(decompress_block(&[], 5).is_err());
        // Literal length runs past end.
        assert!(decompress_block(&[0xF0, 200], 300).is_err());
        // Match offset beyond output.
        // token: 1 literal, match len 4; lit 'a'; offset 9 (too far).
        assert!(decompress_block(&[0x10, b'a', 9, 0], 10).is_err());
        // Zero offset.
        assert!(decompress_block(&[0x10, b'a', 0, 0], 10).is_err());
    }

    #[test]
    fn size_mismatch_detected() {
        // 3 literals but caller expects 4.
        assert!(decompress_block(&[0x30, b'a', b'b', b'c'], 4).is_err());
        assert_eq!(decompress_block(&[0x30, b'a', b'b', b'c'], 3).unwrap(), b"abc");
    }

    #[test]
    fn fuzz_garbage_never_panics_and_agrees_with_naive() {
        let mut rng = Rng::new(0x44);
        for _ in 0..500 {
            let n = rng.range(0, 300);
            let garbage = rng.bytes(n);
            let expected = rng.range(0, 1000);
            let fast = decompress_block(&garbage, expected); // must not panic
            let naive = reference::decompress_block_naive(&garbage, &[], expected);
            match (&fast, &naive) {
                (Ok(a), Ok(b)) => assert_eq!(a, b),
                (Err(_), Err(_)) => {}
                _ => panic!("fast {fast:?} vs naive accept/reject mismatch"),
            }
        }
    }

    #[test]
    fn overlap_copy_periods() {
        // Hand-built stream: 4 literals "abab" then match offset 2 len 10.
        // -> "abab" + "ababababab"
        let stream = [0x46u8, b'a', b'b', b'a', b'b', 2, 0, 0x00];
        // token 0x46: lit_len 4, match_len 6+4=10; trailing empty-literal token.
        let out = decompress_block(&stream, 14).unwrap();
        assert_eq!(&out, b"ababababababab");
    }

    #[test]
    fn all_short_offsets_replicate_correctly() {
        // For each offset < 8 build a stream: `offset` literals then a long
        // overlapping match; the decode must equal the periodic expansion.
        for offset in 1usize..8 {
            for match_len in [4usize, 5, 7, 8, 9, 15, 31, 64, 200] {
                let lits: Vec<u8> = (0..offset as u8).map(|k| b'A' + k).collect();
                let mut stream = Vec::new();
                let ml = match_len - 4;
                stream.push(((lits.len() as u8) << 4) | (ml.min(15) as u8));
                stream.extend_from_slice(&lits);
                stream.extend_from_slice(&(offset as u16).to_le_bytes());
                if ml >= 15 {
                    let mut v = ml - 15;
                    while v >= 255 {
                        stream.push(255);
                        v -= 255;
                    }
                    stream.push(v as u8);
                }
                stream.push(0x00); // trailing empty-literal token
                let total = offset + match_len;
                let expect: Vec<u8> = (0..total).map(|k| lits[k % offset]).collect();
                let fast = decompress_block(&stream, total).unwrap();
                assert_eq!(fast, expect, "offset {offset} len {match_len}");
                let naive = reference::decompress_block_naive(&stream, &[], total).unwrap();
                assert_eq!(naive, expect);
            }
        }
    }

    #[test]
    fn error_leaves_buffer_cleared() {
        let mut out = vec![1u8, 2, 3];
        assert!(decompress_block_into(&[0xF0, 200], 300, &mut out).is_err());
        assert!(out.is_empty());
    }
}
