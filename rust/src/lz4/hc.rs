//! LZ4-HC: the high-compression LZ4 variant (paper §2.2 — "a slower
//! compressor which achieves higher compression ratios", typically ~20%
//! better ratio). Same block format as the fast compressor, but match
//! finding uses hash chains with a per-level search depth and greedy-with-
//! lookahead parsing instead of a single-probe hash table.
//!
//! §Perf: the chain walk is the shared
//! [`crate::util::match_finder::ChainTable`] (SWAR `common_prefix`
//! extension, quick-reject, `nice_len` early exit, `good_length` chain
//! shortening on the lazy lookahead) — the same substrate the ZSTD-style
//! matcher uses; this module keeps only the HC parse policy.

use super::block::{compress_bound, MAX_DISTANCE, MIN_MATCH};
use crate::util::match_finder::{ChainTable, SearchCfg};

const HASH_LOG: u32 = 15;
const LAST_LITERALS: usize = 5;
const MFLIMIT: usize = 12;

/// Search depth per HC level (mirrors lz4hc's 2^(level-1) clamping).
pub fn depth_for_level(level: u8) -> u32 {
    match level {
        0..=2 => 16,
        3 => 32,
        4 => 64,
        5 => 128,
        6 => 256,
        7 => 512,
        8 => 1024,
        _ => 4096,
    }
}

/// Per-level search knobs: depth from [`depth_for_level`]; `nice_len`
/// grows with level (an already-long match is good enough to stop), and
/// matches of `good_len`+ quarter the lazy-lookahead budget.
fn cfg_for_level(level: u8) -> SearchCfg {
    let depth = depth_for_level(level);
    let nice_len = match level {
        0..=4 => 128,
        5..=6 => 256,
        7..=8 => 512,
        _ => 1 << 16,
    };
    SearchCfg { depth, nice_len, good_len: 32, min_match: MIN_MATCH }
}

/// Reusable HC compressor state.
pub struct Lz4Hc {
    chains: ChainTable,
}

impl Default for Lz4Hc {
    fn default() -> Self {
        Self::new()
    }
}

impl Lz4Hc {
    pub fn new() -> Self {
        Self { chains: ChainTable::new(HASH_LOG) }
    }

    /// Compress one block at the given HC level (3..=12 in lz4 terms).
    pub fn compress(&mut self, src: &[u8], level: u8, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(compress_bound(src.len()));
        let n = src.len();
        if n == 0 {
            out.push(0);
            return;
        }
        if n < MFLIMIT + 1 {
            emit_last_literals(src, 0, out);
            return;
        }
        self.chains.reset(n);
        let cfg = cfg_for_level(level);
        let match_limit = n - LAST_LITERALS;
        let mf_limit = n - MFLIMIT;

        let mut anchor = 0usize;
        let mut i = 0usize;
        let mut inserted = 0usize; // positions [0, inserted) are in the chains

        macro_rules! insert_up_to {
            ($end:expr) => {
                while inserted < $end && inserted + 4 <= n {
                    self.chains.insert(src, inserted);
                    inserted += 1;
                }
            };
        }

        while i <= mf_limit {
            insert_up_to!(i + 1);
            let (len, dist) = self.find_best(src, i, match_limit, &cfg, None);
            if len < MIN_MATCH {
                i += 1;
                continue;
            }
            // Lookahead: try i+1; if strictly better, emit literal and move on
            // (single-step lazy matching — a good chunk of HC's gain).
            let mut best_len = len;
            let mut best_dist = dist;
            let mut start = i;
            if i + 1 <= mf_limit {
                insert_up_to!(i + 2);
                // good_length discipline: already holding a good match, probe
                // the lookahead position on a quartered chain budget.
                let lookahead_depth = if len >= cfg.good_len {
                    Some((cfg.depth / 4).max(1))
                } else {
                    None
                };
                let (len2, dist2) = self.find_best(src, i + 1, match_limit, &cfg, lookahead_depth);
                if len2 > best_len + 1 {
                    best_len = len2;
                    best_dist = dist2;
                    start = i + 1;
                }
            }
            // Extend backwards.
            let mut ref_start = start - best_dist;
            while start > anchor && ref_start > 0 && src[start - 1] == src[ref_start - 1] {
                start -= 1;
                ref_start -= 1;
                best_len += 1;
            }
            emit_sequence(src, anchor, start, best_dist as u16, best_len, out);
            i = start + best_len;
            anchor = i;
            insert_up_to!(i.min(mf_limit + 1));
        }
        emit_last_literals(src, anchor, out);
    }

    /// Longest match at position i (shared chain walk, capped so the match
    /// never reaches into the spec's end-of-block literal region).
    fn find_best(
        &self,
        src: &[u8],
        i: usize,
        match_limit: usize,
        cfg: &SearchCfg,
        depth_override: Option<u32>,
    ) -> (usize, usize) {
        if i + MIN_MATCH > match_limit {
            return (0, 0);
        }
        let cap = match_limit - i;
        self.chains.find(src, i, cap, MAX_DISTANCE, cfg, depth_override)
    }
}

fn emit_sequence(src: &[u8], lit_start: usize, lit_end: usize, offset: u16, match_len: usize, out: &mut Vec<u8>) {
    let lit_len = lit_end - lit_start;
    let ml = match_len - MIN_MATCH;
    out.push(((lit_len.min(15) as u8) << 4) | ml.min(15) as u8);
    if lit_len >= 15 {
        emit_len(lit_len - 15, out);
    }
    out.extend_from_slice(&src[lit_start..lit_end]);
    out.extend_from_slice(&offset.to_le_bytes());
    if ml >= 15 {
        emit_len(ml - 15, out);
    }
}

fn emit_last_literals(src: &[u8], anchor: usize, out: &mut Vec<u8>) {
    let lit_len = src.len() - anchor;
    out.push((lit_len.min(15) as u8) << 4);
    if lit_len >= 15 {
        emit_len(lit_len - 15, out);
    }
    out.extend_from_slice(&src[anchor..]);
}

#[inline]
fn emit_len(mut v: usize, out: &mut Vec<u8>) {
    while v >= 255 {
        out.push(255);
        v -= 255;
    }
    out.push(v as u8);
}

#[cfg(test)]
mod tests {
    use super::super::block::Lz4Fast;
    use super::super::decode::decompress_block;
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8], level: u8) {
        let mut c = Lz4Hc::new();
        let mut out = Vec::new();
        c.compress(data, level, &mut out);
        let d = decompress_block(&out, data.len()).expect("decode");
        assert_eq!(d, data, "level={level} n={}", data.len());
    }

    #[test]
    fn empty_and_tiny() {
        for n in 0..20usize {
            let data: Vec<u8> = (0..n as u8).collect();
            roundtrip(&data, 9);
        }
    }

    #[test]
    fn fuzz_roundtrip() {
        let mut rng = Rng::new(0x4C48);
        for round in 0..80 {
            let n = rng.range(0, 40_000);
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                match rng.range(0, 2) {
                    0 => {
                        let b = (rng.next_u64() & 0xFF) as u8;
                        let run = rng.range(1, 400);
                        data.extend(std::iter::repeat(b).take(run));
                    }
                    1 => data.extend_from_slice(b"Electron_eta::"),
                    _ => {
                        let k = rng.range(1, 80);
                        let b = rng.bytes(k);
                        data.extend_from_slice(&b);
                    }
                }
            }
            data.truncate(n);
            roundtrip(&data, [3u8, 6, 9, 12][round % 4]);
        }
    }

    #[test]
    fn hc_beats_fast_on_text() {
        // Paper: "LZ4-HC typically results in a 20% improvement of
        // compression ratio" — require HC to be meaningfully smaller.
        // A pool of random chunks re-sampled with repetition: the fast
        // compressor's single-probe hash table constantly loses candidates
        // to collisions, while HC's chains recover them.
        let mut rng = Rng::new(0x4C49);
        let pool: Vec<Vec<u8>> = (0..256).map(|_| rng.bytes(24)).collect();
        let mut data = Vec::new();
        while data.len() < 200_000 {
            data.extend_from_slice(&pool[rng.range(0, 255)]);
        }
        let mut fast = Lz4Fast::new();
        let mut hc = Lz4Hc::new();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        fast.compress(&data, 1, &mut a);
        hc.compress(&data, 9, &mut b);
        assert!(
            (b.len() as f64) < 0.97 * a.len() as f64,
            "HC {} vs fast {}",
            b.len(),
            a.len()
        );
        assert_eq!(decompress_block(&b, data.len()).unwrap(), data);
    }

    #[test]
    fn deeper_levels_never_larger_much() {
        let mut rng = Rng::new(0x4C4A);
        let mut data = Vec::new();
        while data.len() < 60_000 {
            data.extend_from_slice(b"Jet_btag=");
            data.extend_from_slice(&rng.bytes(4));
        }
        let mut hc = Lz4Hc::new();
        let mut prev = usize::MAX / 2;
        for level in [3u8, 6, 9, 12] {
            let mut out = Vec::new();
            hc.compress(&data, level, &mut out);
            assert!(out.len() <= prev + prev / 50, "level {level}: {} vs {prev}", out.len());
            prev = out.len();
            assert_eq!(decompress_block(&out, data.len()).unwrap(), data);
        }
    }
}
