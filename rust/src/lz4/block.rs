//! LZ4 block format (lz4/lz4 `lz4_Block_format.md`) — fast compressor.
//!
//! The format the paper's §2.2 analyzes: byte-aligned tokens, 4-byte minimum
//! matches, no entropy stage. That design is why LZ4 decodes so fast (Fig 3)
//! and why ROOT offset arrays compress so poorly without a preconditioner
//! (Fig 6): the monotone offset sequence never produces byte-aligned repeats.
//!
//! Sequence layout: token byte (hi nibble = literal length, lo nibble =
//! match length - 4, 15 = extended by 255-run bytes), literals, 2-byte LE
//! offset, extended match length. The final sequence is literals-only; the
//! last 5 bytes must be literals and the last match must start ≥ 12 bytes
//! from the end (format end-conditions).

pub const MIN_MATCH: usize = 4;
/// End-of-block conditions from the spec.
const LAST_LITERALS: usize = 5;
const MFLIMIT: usize = 12;
/// Max offset.
pub const MAX_DISTANCE: usize = 65_535;

const HASH_LOG: u32 = 16;

#[inline]
fn hash5(v: u64) -> usize {
    // lz4-style hash of 5 bytes for the fast path at default accel
    // (shared SWAR helper from the match-finder substrate).
    crate::util::match_finder::hash5(v, HASH_LOG)
}

#[inline]
fn read_u32(data: &[u8], i: usize) -> u32 {
    u32::from_le_bytes(data[i..i + 4].try_into().unwrap())
}

#[inline]
fn read_u64(data: &[u8], i: usize) -> u64 {
    u64::from_le_bytes(data[i..i + 8].try_into().unwrap())
}

/// Reusable compressor state.
pub struct Lz4Fast {
    table: Vec<u32>,
}

impl Default for Lz4Fast {
    fn default() -> Self {
        Self::new()
    }
}

impl Lz4Fast {
    pub fn new() -> Self {
        Self { table: vec![0u32; 1 << HASH_LOG] }
    }

    /// Compress one block. `accel` ≥ 1: larger = faster/looser search (maps
    /// from ROOT's negative LZ4 levels; 1 = default LZ4).
    pub fn compress(&mut self, src: &[u8], accel: u32, out: &mut Vec<u8>) {
        self.compress_dict(src, 0, accel, out)
    }

    /// Compress `src[start..]` with `src[..start]` as a dictionary prefix
    /// (matchable within the 64 KiB offset range, never emitted) — the
    /// LZ4 half of the paper's §3 note that trained dictionaries "are
    /// useable for ... LZ4 as well".
    pub fn compress_dict(&mut self, src: &[u8], start: usize, accel: u32, out: &mut Vec<u8>) {
        out.clear();
        let n = src.len();
        if n == start {
            out.push(0); // single empty-literal token
            return;
        }
        if n < start + MFLIMIT + 1 {
            emit_last_literals(src, start, out);
            return;
        }
        self.table.fill(0);
        let accel = accel.max(1) as usize;

        let match_limit = n - LAST_LITERALS;
        let mf_limit = n - MFLIMIT;
        // Prime the table with dictionary positions (position 0 is the
        // hash-table sentinel and is skipped; one lost byte).
        let mut pos = 1usize;
        while pos + 8 <= start.min(mf_limit + 1) {
            let h = hash5(read_u64(src, pos));
            self.table[h] = pos as u32;
            pos += 1;
        }
        let mut anchor = start;
        let mut i = start.max(1); // position 0 can't match backwards

        'outer: loop {
            // Find a match: step grows with misses (acceleration).
            let mut step = 1usize;
            let mut search_count = accel << 6; // 64 attempts per accel unit before growing
            let mut candidate;
            loop {
                if i > mf_limit {
                    break 'outer;
                }
                let h = hash5(read_u64(src, i));
                candidate = self.table[h] as usize;
                self.table[h] = i as u32;
                if candidate != 0
                    && candidate < i
                    && i - candidate <= MAX_DISTANCE
                    && read_u32(src, candidate) == read_u32(src, i)
                {
                    break;
                }
                search_count -= 1;
                if search_count == 0 {
                    search_count = accel << 6;
                    step += 1 + (step >> 6);
                }
                i += step;
            }

            // Extend backwards.
            let mut match_start = i;
            let mut ref_start = candidate;
            while match_start > anchor && ref_start > 0 && src[match_start - 1] == src[ref_start - 1] {
                match_start -= 1;
                ref_start -= 1;
            }

            // Extend forwards (shared SWAR prefix extension; the first
            // MIN_MATCH bytes are already known equal).
            let cap = match_limit - match_start;
            let len = (MIN_MATCH
                + crate::util::match_finder::common_prefix(
                    src,
                    ref_start + MIN_MATCH,
                    match_start + MIN_MATCH,
                    cap - MIN_MATCH,
                ))
            .min(cap);

            emit_sequence(src, anchor, match_start, (match_start - ref_start) as u16, len, out);
            i = match_start + len;
            anchor = i;
            if i > mf_limit {
                break;
            }
            // Prime the table with the position before the next search.
            let h = hash5(read_u64(src, i - 2));
            self.table[h] = (i - 2) as u32;
        }
        emit_last_literals(src, anchor, out);
    }
}

/// Emit token + literals + offset + extended match length.
fn emit_sequence(src: &[u8], lit_start: usize, lit_end: usize, offset: u16, match_len: usize, out: &mut Vec<u8>) {
    debug_assert!(match_len >= MIN_MATCH);
    debug_assert!(offset >= 1);
    let lit_len = lit_end - lit_start;
    let ml = match_len - MIN_MATCH;
    let tok_lit = lit_len.min(15) as u8;
    let tok_ml = ml.min(15) as u8;
    out.push((tok_lit << 4) | tok_ml);
    if lit_len >= 15 {
        emit_len(lit_len - 15, out);
    }
    out.extend_from_slice(&src[lit_start..lit_end]);
    out.extend_from_slice(&offset.to_le_bytes());
    if ml >= 15 {
        emit_len(ml - 15, out);
    }
}

fn emit_last_literals(src: &[u8], anchor: usize, out: &mut Vec<u8>) {
    let lit_len = src.len() - anchor;
    let tok = lit_len.min(15) as u8;
    out.push(tok << 4);
    if lit_len >= 15 {
        emit_len(lit_len - 15, out);
    }
    out.extend_from_slice(&src[anchor..]);
}

#[inline]
fn emit_len(mut v: usize, out: &mut Vec<u8>) {
    while v >= 255 {
        out.push(255);
        v -= 255;
    }
    out.push(v as u8);
}

/// Worst-case compressed size (spec's LZ4_compressBound).
pub fn compress_bound(n: usize) -> usize {
    n + n / 255 + 16
}

#[cfg(test)]
mod tests {
    use super::super::decode::decompress_block;
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8], accel: u32) {
        let mut c = Lz4Fast::new();
        let mut out = Vec::new();
        c.compress(data, accel, &mut out);
        let d = decompress_block(&out, data.len()).expect("decode");
        assert_eq!(d, data, "accel={accel} n={}", data.len());
    }

    #[test]
    fn empty_and_tiny() {
        for n in 0..20usize {
            let data: Vec<u8> = (0..n as u8).collect();
            roundtrip(&data, 1);
        }
    }

    #[test]
    fn runs_compress_well() {
        let data = vec![9u8; 100_000];
        let mut c = Lz4Fast::new();
        let mut out = Vec::new();
        c.compress(&data, 1, &mut out);
        assert!(out.len() < 500, "{} bytes for 100k run", out.len());
        assert_eq!(decompress_block(&out, data.len()).unwrap(), data);
    }

    #[test]
    fn offset_arrays_barely_compress() {
        // The paper's Fig-6 pathology: monotone BE u32 offsets.
        let data: Vec<u8> = (1u32..=25_000).flat_map(|i| i.to_be_bytes()).collect();
        let mut c = Lz4Fast::new();
        let mut out = Vec::new();
        c.compress(&data, 1, &mut out);
        let ratio = data.len() as f64 / out.len() as f64;
        assert!(ratio < 1.7, "LZ4 should do poorly on offsets, got ratio {ratio:.2}");
        assert_eq!(decompress_block(&out, data.len()).unwrap(), data);
        // With BitShuffle preconditioning the same data compresses far better.
        let pre = crate::precond::bitshuffle(&data, 4);
        let mut out2 = Vec::new();
        c.compress(&pre, 1, &mut out2);
        let ratio2 = data.len() as f64 / out2.len() as f64;
        assert!(ratio2 > 2.0 * ratio, "bitshuffle ratio {ratio2:.2} vs plain {ratio:.2}");
    }

    #[test]
    fn fuzz_roundtrip() {
        let mut rng = Rng::new(0x124);
        for round in 0..120 {
            let n = rng.range(0, 50_000);
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                match rng.range(0, 3) {
                    0 => {
                        let b = (rng.next_u64() & 0xFF) as u8;
                        let run = rng.range(1, 800);
                        data.extend(std::iter::repeat(b).take(run));
                    }
                    1 => data.extend_from_slice(b"basket_payload/"),
                    2 => {
                        let k = rng.range(1, 128);
                        let b = rng.bytes(k);
                        data.extend_from_slice(&b);
                    }
                    _ => data.extend_from_slice(&rng.next_u32().to_le_bytes()),
                }
            }
            data.truncate(n);
            roundtrip(&data, 1 + (round % 8) as u32);
        }
    }

    #[test]
    fn incompressible_bounded() {
        let mut rng = Rng::new(0x125);
        let data = rng.bytes(65_536);
        let mut c = Lz4Fast::new();
        let mut out = Vec::new();
        c.compress(&data, 1, &mut out);
        assert!(out.len() <= compress_bound(data.len()));
        assert_eq!(decompress_block(&out, data.len()).unwrap(), data);
    }

    #[test]
    fn higher_accel_still_correct() {
        let mut rng = Rng::new(0x126);
        let mut data = Vec::new();
        while data.len() < 30_000 {
            data.extend_from_slice(b"xyzzy-");
            data.extend_from_slice(&rng.bytes(2));
        }
        for accel in [1u32, 4, 16, 64] {
            roundtrip(&data, accel);
        }
    }
}
