//! Minimal LZ4 frame wrapper as ROOT uses it: ROOT's LZ4 baskets carry a
//! content checksum ahead of the block (ROOT uses xxhash64; per DESIGN.md we
//! carry CRC-32 from our `checksum` module — same role, same failure
//! detection, one fewer substrate). Layout:
//!
//! ```text
//! [u32 crc32 of UNCOMPRESSED payload, LE][LZ4 block bytes]
//! ```
//!
//! The block itself is the standard LZ4 block format, so the compression
//! behaviour under study is untouched; the frame only adds integrity.

use super::block::Lz4Fast;
use super::decode::{decompress_block_into, Lz4Error};
use super::hc::Lz4Hc;
use crate::checksum::crc32;

/// LZ4 "method": fast with acceleration, or HC with level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lz4Method {
    Fast { accel: u32 },
    Hc { level: u8 },
}

/// Map ROOT compression level 1..=9 to an LZ4 method, mirroring ROOT's
/// `R__zipLZ4`: low levels use the fast path, >=4 uses HC at that level.
pub fn method_for_level(level: u8) -> Lz4Method {
    match level {
        0 | 1 => Lz4Method::Fast { accel: 1 },
        2 => Lz4Method::Fast { accel: 1 },
        3 => Lz4Method::Fast { accel: 1 },
        l => Lz4Method::Hc { level: l },
    }
}

/// Reusable encoder holding both engines' state.
#[derive(Default)]
pub struct Lz4Encoder {
    fast: Lz4Fast,
    hc: Lz4Hc,
    scratch: Vec<u8>,
}

impl Lz4Encoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Compress `src` into a framed LZ4 payload.
    pub fn compress(&mut self, src: &[u8], method: Lz4Method) -> Vec<u8> {
        match method {
            Lz4Method::Fast { accel } => self.fast.compress(src, accel, &mut self.scratch),
            Lz4Method::Hc { level } => self.hc.compress(src, level, &mut self.scratch),
        }
        let mut out = Vec::with_capacity(self.scratch.len() + 4);
        out.extend_from_slice(&crc32(src).to_le_bytes());
        out.extend_from_slice(&self.scratch);
        out
    }

    /// Compress with a dictionary prefix (fast path only — HC falls back to
    /// dictionary-less compression; documented limitation).
    pub fn compress_dict(&mut self, src: &[u8], dict: &[u8], method: Lz4Method) -> Vec<u8> {
        if dict.is_empty() {
            return self.compress(src, method);
        }
        let accel = match method {
            Lz4Method::Fast { accel } => accel,
            Lz4Method::Hc { .. } => 1, // HC+dict falls back to fast+dict
        };
        let mut buf = Vec::with_capacity(dict.len() + src.len());
        buf.extend_from_slice(dict);
        buf.extend_from_slice(src);
        self.fast.compress_dict(&buf, dict.len(), accel, &mut self.scratch);
        let mut out = Vec::with_capacity(self.scratch.len() + 4);
        out.extend_from_slice(&crc32(src).to_le_bytes());
        out.extend_from_slice(&self.scratch);
        out
    }
}

/// Dictionary-aware framed decompression.
pub fn lz4_decompress_dict(src: &[u8], dict: &[u8], expected_len: usize) -> Result<Vec<u8>, Lz4Error> {
    if src.len() < 4 {
        return Err(Lz4Error("frame too short"));
    }
    let expect_crc = u32::from_le_bytes(src[..4].try_into().unwrap());
    let mut out = Vec::new();
    super::decode::decompress_block_dict_into(&src[4..], dict, expected_len, &mut out)?;
    if crc32(&out) != expect_crc {
        return Err(Lz4Error("content checksum mismatch"));
    }
    Ok(out)
}

/// One-shot compression.
pub fn lz4_compress(src: &[u8], method: Lz4Method) -> Vec<u8> {
    Lz4Encoder::new().compress(src, method)
}

/// Decompress a framed LZ4 payload, verifying the content checksum.
pub fn lz4_decompress(src: &[u8], expected_len: usize) -> Result<Vec<u8>, Lz4Error> {
    let mut out = Vec::new();
    lz4_decompress_into(src, expected_len, &mut out)?;
    Ok(out)
}

/// Reusable-buffer variant.
pub fn lz4_decompress_into(src: &[u8], expected_len: usize, out: &mut Vec<u8>) -> Result<(), Lz4Error> {
    if src.len() < 4 {
        return Err(Lz4Error("frame too short"));
    }
    let expect_crc = u32::from_le_bytes(src[..4].try_into().unwrap());
    decompress_block_into(&src[4..], expected_len, out)?;
    if crc32(out) != expect_crc {
        return Err(Lz4Error("content checksum mismatch"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_methods() {
        let mut rng = Rng::new(0xF7A);
        let mut data = Vec::new();
        while data.len() < 50_000 {
            data.extend_from_slice(b"nTau=");
            data.extend_from_slice(&rng.bytes(7));
        }
        for level in 1..=9u8 {
            let m = method_for_level(level);
            let c = lz4_compress(&data, m);
            assert_eq!(lz4_decompress(&c, data.len()).unwrap(), data, "level {level}");
        }
    }

    #[test]
    fn checksum_detects_corruption() {
        let data = b"some basket payload some basket payload".to_vec();
        let mut c = lz4_compress(&data, Lz4Method::Fast { accel: 1 });
        // Corrupt a literal byte inside the block (not the stored crc).
        let n = c.len();
        c[n - 3] ^= 0x01;
        match lz4_decompress(&c, data.len()) {
            Err(_) => {}
            Ok(d) => assert_ne!(d, data, "corruption silently accepted"),
        }
    }

    #[test]
    fn empty_payload() {
        let c = lz4_compress(b"", Lz4Method::Fast { accel: 1 });
        assert_eq!(lz4_decompress(&c, 0).unwrap(), b"");
    }
}
