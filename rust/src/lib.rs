//! # rootio
//!
//! A from-scratch reproduction of the system studied in *"ROOT I/O
//! compression algorithms and their performance impact within Run 3"*
//! (Shadura & Bockelman, CHEP 2019): a ROOT-like columnar I/O framework with
//! pluggable lossless compression — ZLIB (reference and Cloudflare-tuned),
//! LZ4/LZ4-HC, a ZSTD-style tANS codec with dictionaries, an LZMA-style
//! range coder, and the legacy ROOT codec — plus Shuffle/BitShuffle/Delta
//! preconditioners, parallel basket pipelines on both the write and read
//! sides, and an XLA-served adaptive compression planner.
//!
//! The layer map lives in `docs/ARCHITECTURE.md`; the byte-level on-disk
//! format (RFIL v3 container, RZS1 sections) is specified in
//! `docs/FORMAT.md`; the bench artifact schema in `docs/BENCHMARKS.md`.
//!
//! ## Entry points
//!
//! * Write: [`rfile::write_tree_serial`] (inline) or
//!   [`coordinator::write_tree_parallel`] (multi-worker pipeline).
//! * Read: [`rfile::TreeReader`] (serial oracle) or
//!   [`coordinator::ParallelTreeReader`] / [`rfile::reader::TreeReader::read_ahead`]
//!   (prefetch + parallel decompression, in-order delivery).
//! * Columnar reads: [`coordinator::ProjectionReader`] via
//!   [`coordinator::ParallelTreeReader::project`] — multi-branch
//!   single-pass scans with offset-sorted prefetch.
//! * Entry-range reads: [`coordinator::ParallelTreeReader::project_range`]
//!   / [`rfile::TreeReader::read_range`] — decode only the baskets
//!   overlapping an entry window, boundary rows trimmed.
//! * Stats-fed replanning: [`runtime::ReadFeedback`] +
//!   [`coordinator::Planner::plan_from_feedback`] — replan compression
//!   from a recorded access profile.
//! * Profile-driven repack: [`coordinator::repack_file`] +
//!   [`coordinator::Planner::plan_repack`] — rewrite a file under a
//!   recorded profile (per-branch codecs, re-chunked baskets, trained
//!   dictionary), closing the adaptive loop; event-for-event identical
//!   output.
//! * Concurrent serving: [`coordinator::ScanServer`] — many projection /
//!   entry-range queries over a corpus through one shared worker pool,
//!   with a sharded LRU cache of decoded baskets
//!   ([`coordinator::BasketCache`]) and per-query metrics.
//! * Buffer-level compression: [`compression::Engine`].
//!
//! ## End-to-end roundtrip
//!
//! ```
//! use rootio::compression::{Algorithm, Settings};
//! use rootio::coordinator::{ParallelTreeReader, ReadAhead};
//! use rootio::gen::synthetic;
//! use rootio::rfile::{write_tree_serial, TreeReader};
//!
//! let path = std::env::temp_dir().join(format!("rootio_doc_crate_{}.rfil", std::process::id()));
//! let events = synthetic::events(150, 11);
//! write_tree_serial(
//!     &path,
//!     "Events",
//!     synthetic::schema(),
//!     Settings::new(Algorithm::Zstd, 5),
//!     4096,
//!     events.iter().cloned(),
//! )
//! .unwrap();
//!
//! // Serial read (the oracle) ...
//! let mut serial = TreeReader::open(&path).unwrap();
//! assert_eq!(serial.read_all_events().unwrap(), events);
//!
//! // ... and the parallel basket read pipeline, byte-identical.
//! let parallel = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
//! assert_eq!(parallel.read_all_events().unwrap(), events);
//! std::fs::remove_file(&path).ok();
//! ```

// Lint policy (CI runs `cargo clippy --all-targets -- -D warnings`):
// correctness, suspicious, perf, and complexity lints are load-bearing and
// stay denied. The `style` group is allowed wholesale — the codec lanes
// intentionally mirror their in-tree naive reference implementations
// line-for-line (index-explicit loops, explicit big-endian byte plumbing),
// and style rewrites would diverge a fast path from the oracle it is
// property-tested bit-identical against. The named complexity/perf allows
// below exist for the same reason; `unknown_lints` keeps the list stable
// across clippy versions (newer lints are named here before older
// toolchains know them).
#![allow(unknown_lints)]
#![allow(clippy::style)]
#![allow(
    clippy::manual_div_ceil,
    clippy::manual_is_multiple_of,
    clippy::manual_memcpy,
    clippy::needless_lifetimes,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod bench;
pub mod checksum;
pub mod cli;
pub mod compression;
pub mod coordinator;
pub mod deflate;
pub mod gen;
pub mod legacy;
pub mod lz4;
pub mod lzma;
pub mod precond;
pub mod rfile;
pub mod zstd;
pub mod runtime;
pub mod util;
