//! # rootio
//!
//! A from-scratch reproduction of the system studied in *"ROOT I/O
//! compression algorithms and their performance impact within Run 3"*
//! (Shadura & Bockelman, CHEP 2019): a ROOT-like columnar I/O framework with
//! pluggable lossless compression — ZLIB (reference and Cloudflare-tuned),
//! LZ4/LZ4-HC, a ZSTD-style tANS codec with dictionaries, an LZMA-style
//! range coder, and the legacy ROOT codec — plus Shuffle/BitShuffle/Delta
//! preconditioners, a parallel compression pipeline, and an XLA-served
//! adaptive compression planner.
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for measured results.

pub mod bench;
pub mod checksum;
pub mod cli;
pub mod compression;
pub mod coordinator;
pub mod deflate;
pub mod gen;
pub mod legacy;
pub mod lz4;
pub mod lzma;
pub mod precond;
pub mod rfile;
pub mod zstd;
pub mod runtime;
pub mod util;
