//! The "custom ROOT compression algorithm ... dating back to the 1990's,
//! used only for ROOT backward compatibility" (paper §2, item iii).
//!
//! The historical R__zip is a PKZIP-era LZSS variant; we implement a
//! behaviour-matched stand-in: flag-byte LZSS with a 8 KiB window and
//! 3..=34-byte matches at fixed 16-bit encodings — no entropy stage, so it
//! is dominated by every modern codec in the survey, which is exactly the
//! role it plays in Fig 2.

const WINDOW: usize = 8192;
const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 34; // 5-bit length field

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LegacyError(pub &'static str);

impl std::fmt::Display for LegacyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "legacy: {}", self.0)
    }
}
impl std::error::Error for LegacyError {}

/// Compress with the legacy scheme. `level` only modulates search effort.
pub fn legacy_compress(src: &[u8], level: u8) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() / 2 + 16);
    let max_chain = 1usize << (level.clamp(1, 9) / 2 + 2);

    // Tiny hash-head/prev chain over 3-byte prefixes.
    let mut head = vec![-1i32; 1 << 12];
    let mut prev = vec![-1i32; src.len()];
    let hash = |d: &[u8], i: usize| -> usize {
        let v = (d[i] as u32) | (d[i + 1] as u32) << 8 | (d[i + 2] as u32) << 16;
        (v.wrapping_mul(0x9E37_79B1) >> 20) as usize
    };

    let n = src.len();
    let mut i = 0usize;
    let mut flags_pos = usize::MAX;
    let mut flag_bit = 8u8;
    macro_rules! push_flag {
        ($bit:expr) => {
            if flag_bit == 8 {
                flags_pos = out.len();
                out.push(0);
                flag_bit = 0;
            }
            if $bit != 0 {
                out[flags_pos] |= 1 << flag_bit;
            }
            flag_bit += 1;
        };
    }

    while i < n {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= n && i + 3 <= n {
            let h = hash(src, i);
            let mut cand = head[h];
            let lower = i.saturating_sub(WINDOW);
            let mut chain = max_chain;
            while cand >= 0 && chain > 0 {
                let c = cand as usize;
                if c < lower {
                    break;
                }
                let cap = (n - i).min(MAX_MATCH);
                let mut l = 0usize;
                while l < cap && src[c + l] == src[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = i - c;
                    if l == cap {
                        break;
                    }
                }
                cand = prev[c];
                chain -= 1;
            }
        }
        if best_len >= MIN_MATCH {
            push_flag!(1);
            // 16-bit: 13-bit distance-1, 5-bit... need 18 bits; use 13+5=18?
            // Classic LZSS packs (dist-1: 13 bits, len-3: 5 bits) in 18 bits;
            // we byte-align: u16 dist-1 (13 bits used) | (len-3) << 13 needs
            // 18 bits -> 3 bytes? Keep it simple: [u8 len-3][u16 dist-1].
            out.push((best_len - MIN_MATCH) as u8);
            out.extend_from_slice(&((best_dist - 1) as u16).to_le_bytes());
            // Insert hash entries over the matched span, then skip it.
            let end = i + best_len;
            let insert_end = end.min(n.saturating_sub(2));
            let mut j = i;
            while j < insert_end {
                let h = hash(src, j);
                prev[j] = head[h];
                head[h] = j as i32;
                j += 1;
            }
            i = end;
        } else {
            push_flag!(0);
            out.push(src[i]);
            if i + 3 <= n {
                let h = hash(src, i);
                prev[i] = head[h];
                head[h] = i as i32;
            }
            i += 1;
        }
    }
    out
}

/// Decompress; `expected_len` comes from the record header.
pub fn legacy_decompress(src: &[u8], expected_len: usize) -> Result<Vec<u8>, LegacyError> {
    let mut out = Vec::with_capacity(expected_len);
    let mut i = 0usize;
    let mut flags = 0u8;
    let mut flag_bit = 8u8;
    while out.len() < expected_len {
        if flag_bit == 8 {
            flags = *src.get(i).ok_or(LegacyError("truncated flags"))?;
            i += 1;
            flag_bit = 0;
        }
        let is_match = (flags >> flag_bit) & 1 == 1;
        flag_bit += 1;
        if is_match {
            if i + 3 > src.len() {
                return Err(LegacyError("truncated match"));
            }
            let len = src[i] as usize + MIN_MATCH;
            let dist = u16::from_le_bytes(src[i + 1..i + 3].try_into().unwrap()) as usize + 1;
            i += 3;
            if dist > out.len() {
                return Err(LegacyError("offset beyond output"));
            }
            if out.len() + len > expected_len {
                return Err(LegacyError("overrun"));
            }
            let start = out.len() - dist;
            if dist >= len {
                out.extend_from_within(start..start + len);
            } else {
                let mut rem = len;
                let mut s = start;
                while rem > 0 {
                    let chunk = rem.min(out.len() - s);
                    out.extend_from_within(s..s + chunk);
                    s += chunk;
                    rem -= chunk;
                }
            }
        } else {
            let b = *src.get(i).ok_or(LegacyError("truncated literal"))?;
            i += 1;
            out.push(b);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8], level: u8) {
        let c = legacy_compress(data, level);
        let d = legacy_decompress(&c, data.len()).expect("decode");
        assert_eq!(d, data, "level {level} n={}", data.len());
    }

    #[test]
    fn basic_roundtrips() {
        let mut rng = Rng::new(0x1990);
        roundtrip(b"", 6);
        roundtrip(b"a", 6);
        roundtrip(b"abcabcabcabcabc", 6);
        roundtrip(&vec![5u8; 50_000], 6);
        let noise = rng.bytes(20_000);
        roundtrip(&noise, 6);
    }

    #[test]
    fn fuzz_roundtrip() {
        let mut rng = Rng::new(0x1991);
        for round in 0..50 {
            let n = rng.range(0, 15_000);
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                if rng.chance(0.5) {
                    let b = (rng.next_u64() & 0xFF) as u8;
                    let r = rng.range(1, 100);
                    data.extend(std::iter::repeat(b).take(r));
                } else {
                    let k = rng.range(1, 40);
                    let b = rng.bytes(k);
                    data.extend_from_slice(&b);
                }
            }
            data.truncate(n);
            roundtrip(&data, [1u8, 5, 9][round % 3]);
        }
    }

    #[test]
    fn dominated_by_zlib() {
        // Its role in Fig 2: worse ratio than ZLIB at comparable settings.
        let mut data = Vec::new();
        while data.len() < 100_000 {
            data.extend_from_slice(b"The legacy codec exists for backward compatibility only. ");
        }
        let l = legacy_compress(&data, 6).len();
        let z = crate::deflate::zlib_compress(&data, crate::deflate::Flavor::Reference, 6).len();
        assert!(z < l, "zlib {z} should beat legacy {l}");
    }

    #[test]
    fn garbage_never_panics() {
        let mut rng = Rng::new(0x1992);
        for _ in 0..300 {
            let n = rng.range(0, 200);
            let g = rng.bytes(n);
            let _ = legacy_decompress(&g, 500);
        }
    }
}
