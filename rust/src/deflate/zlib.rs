//! zlib stream framing (RFC 1950): 2-byte header, DEFLATE body, Adler-32
//! trailer. This is the exact byte format ROOT writes for its ZLIB baskets,
//! so our output is readable by any zlib and vice versa (see
//! `rust/tests/interop_flate2.rs`).

use super::compress::{deflate, deflate_stored, deflate_with};
use super::inflate::{inflate, InflateError};
use super::matcher::{Matcher, Token};
use super::tuning::{Flavor, Tuning};
use crate::checksum::adler32::{adler32_with, Backend as AdlerBackend};

/// Compress into a zlib stream at (flavor, level). Level 0 emits stored
/// blocks (ROOT's "compression disabled" still frames data when asked to).
pub fn zlib_compress(data: &[u8], flavor: Flavor, level: u8) -> Vec<u8> {
    let tuning = Tuning::new(flavor, level);
    let body = if level == 0 { deflate_stored(data) } else { deflate(data, &tuning) };
    frame(body, data, level, tuning.adler_backend)
}

/// Hot-path variant with caller-owned scratch buffers.
pub fn zlib_compress_with(
    data: &[u8],
    flavor: Flavor,
    level: u8,
    matcher: &mut Matcher,
    tokens: &mut Vec<Token>,
) -> Vec<u8> {
    let tuning = Tuning::new(flavor, level);
    let body = if level == 0 {
        deflate_stored(data)
    } else {
        deflate_with(data, &tuning, matcher, tokens)
    };
    frame(body, data, level, tuning.adler_backend)
}

/// Compress into a zlib stream with a preset dictionary (RFC 1950 FDICT):
/// header carries FDICT=1 + DICTID (adler32 of the dictionary); matches
/// may reach into the dictionary. This is the paper's §3 observation that
/// ZSTD-trained dictionaries "are useable for ZLIB ... as well".
pub fn zlib_compress_dict(data: &[u8], dict: &[u8], flavor: Flavor, level: u8) -> Vec<u8> {
    if dict.is_empty() {
        return zlib_compress(data, flavor, level);
    }
    let tuning = Tuning::new(flavor, level);
    let mut buf = Vec::with_capacity(dict.len() + data.len());
    buf.extend_from_slice(dict);
    buf.extend_from_slice(data);
    let body = if level == 0 {
        deflate_stored(data)
    } else {
        super::compress::deflate_dict(&buf, dict.len(), &tuning)
    };
    // Frame with FDICT: CMF, FLG(FDICT=1), DICTID, body, adler32(data).
    let mut out = Vec::with_capacity(body.len() + 10);
    let cmf: u8 = 0x78;
    let flevel: u8 = match level {
        0..=1 => 0,
        2..=5 => 1,
        6 => 2,
        _ => 3,
    };
    let mut flg = (flevel << 6) | 0x20; // FDICT
    let rem = ((cmf as u16) << 8 | flg as u16) % 31;
    if rem != 0 {
        flg += (31 - rem) as u8;
    }
    out.push(cmf);
    out.push(flg);
    out.extend_from_slice(&adler32_with(dict, tuning.adler_backend).to_be_bytes());
    out.extend_from_slice(&body);
    out.extend_from_slice(&adler32_with(data, tuning.adler_backend).to_be_bytes());
    out
}

/// Decompress a zlib stream that may carry an FDICT header; `dict` must be
/// the same dictionary used at compression (verified via DICTID).
pub fn zlib_decompress_dict(
    data: &[u8],
    dict: &[u8],
    size_hint: usize,
    max_out: usize,
) -> Result<Vec<u8>, InflateError> {
    if data.len() < 6 {
        return Err(InflateError("zlib stream too short"));
    }
    if data[1] & 0x20 == 0 {
        return zlib_decompress(data, size_hint, max_out);
    }
    if data.len() < 10 {
        return Err(InflateError("zlib FDICT stream too short"));
    }
    let cmf = data[0];
    if cmf & 0x0F != 8 || ((cmf as u16) << 8 | data[1] as u16) % 31 != 0 {
        return Err(InflateError("zlib header check failed"));
    }
    let dictid = u32::from_be_bytes(data[2..6].try_into().unwrap());
    if dictid != adler32_with(dict, AdlerBackend::Swar) {
        return Err(InflateError("dictionary id mismatch"));
    }
    let body = &data[6..data.len() - 4];
    let out = super::inflate::inflate_dict(body, dict, size_hint, max_out)?;
    let expect = u32::from_be_bytes(data[data.len() - 4..].try_into().unwrap());
    if adler32_with(&out, AdlerBackend::Swar) != expect {
        return Err(InflateError("adler32 mismatch"));
    }
    Ok(out)
}

/// Compress with a fully custom [`Tuning`] (bench harness: lets Fig 4/5
/// isolate single axes like the checksum kernel or hash width).
pub fn zlib_compress_custom(data: &[u8], tuning: &Tuning) -> Vec<u8> {
    let body = deflate(data, tuning);
    frame(body, data, tuning.level, tuning.adler_backend)
}

fn frame(body: Vec<u8>, data: &[u8], level: u8, adler: AdlerBackend) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 6);
    // CMF: CM=8 (deflate), CINFO=7 (32K window).
    let cmf: u8 = 0x78;
    // FLG: FLEVEL from level, FDICT=0, FCHECK makes (CMF<<8|FLG) % 31 == 0.
    let flevel: u8 = match level {
        0..=1 => 0,
        2..=5 => 1,
        6 => 2,
        _ => 3,
    };
    let mut flg = flevel << 6;
    let rem = ((cmf as u16) << 8 | flg as u16) % 31;
    if rem != 0 {
        flg += (31 - rem) as u8;
    }
    out.push(cmf);
    out.push(flg);
    out.extend_from_slice(&body);
    out.extend_from_slice(&adler32_with(data, adler).to_be_bytes());
    out
}

/// Decompress a zlib stream, verifying header and Adler-32 trailer.
pub fn zlib_decompress(data: &[u8], size_hint: usize, max_out: usize) -> Result<Vec<u8>, InflateError> {
    if data.len() < 6 {
        return Err(InflateError("zlib stream too short"));
    }
    let cmf = data[0];
    let flg = data[1];
    if cmf & 0x0F != 8 {
        return Err(InflateError("unsupported compression method"));
    }
    if (cmf >> 4) > 7 {
        return Err(InflateError("window size too large"));
    }
    if ((cmf as u16) << 8 | flg as u16) % 31 != 0 {
        return Err(InflateError("zlib header check failed"));
    }
    if flg & 0x20 != 0 {
        return Err(InflateError("preset dictionary not supported"));
    }
    let body = &data[2..data.len() - 4];
    let out = inflate(body, size_hint, max_out)?;
    let expect = u32::from_be_bytes(data[data.len() - 4..].try_into().unwrap());
    let got = adler32_with(&out, AdlerBackend::Swar);
    if got != expect {
        return Err(InflateError("adler32 mismatch"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const MAX: usize = 64 << 20;

    #[test]
    fn roundtrip_all_levels_and_flavors() {
        let mut rng = Rng::new(0x21B);
        let mut data = Vec::new();
        for i in 0..4000u32 {
            data.extend_from_slice(&(i * 3).to_be_bytes());
            if i % 5 == 0 {
                data.extend_from_slice(&rng.bytes(3));
            }
        }
        for flavor in [Flavor::Reference, Flavor::Cloudflare] {
            for level in 0..=9u8 {
                let c = zlib_compress(&data, flavor, level);
                let d = zlib_decompress(&c, data.len(), MAX).unwrap();
                assert_eq!(d, data, "{flavor:?} level {level}");
                if level > 0 {
                    assert!(c.len() < data.len(), "{flavor:?} level {level} didn't compress");
                }
            }
        }
    }

    #[test]
    fn header_is_valid_zlib() {
        for level in 0..=9u8 {
            let c = zlib_compress(b"test data", Flavor::Cloudflare, level);
            assert_eq!(c[0], 0x78);
            assert_eq!(((c[0] as u16) << 8 | c[1] as u16) % 31, 0, "level {level}");
        }
    }

    #[test]
    fn corrupted_checksum_detected() {
        let mut c = zlib_compress(b"payload payload payload", Flavor::Reference, 6);
        let n = c.len();
        c[n - 1] ^= 0xFF;
        assert_eq!(
            zlib_decompress(&c, 32, MAX).unwrap_err().0,
            "adler32 mismatch"
        );
    }

    #[test]
    fn corrupted_header_detected() {
        let mut c = zlib_compress(b"payload", Flavor::Reference, 6);
        c[0] = 0x79; // CM != 8
        assert!(zlib_decompress(&c, 16, MAX).is_err());
    }

    #[test]
    fn ratios_differ_slightly_between_flavors() {
        // Paper §2.1: "compression ratios for CF-ZLIB and ZLIB vary slightly
        // even at equivalent compression levels" (different hash widths).
        // At level 1-5 CF uses quadruplets; sizes may differ but both must
        // round-trip. We just assert both compress comparably (within 20%).
        let mut rng = Rng::new(0x21C);
        let mut data = Vec::new();
        while data.len() < 100_000 {
            data.extend_from_slice(b"Run3_event_");
            data.extend_from_slice(&rng.bytes(6));
        }
        let a = zlib_compress(&data, Flavor::Reference, 1).len() as f64;
        let b = zlib_compress(&data, Flavor::Cloudflare, 1).len() as f64;
        assert!((a / b - 1.0).abs() < 0.2, "ref {a} vs cf {b}");
    }
}
