//! Shared RFC 1951 constant tables: length/distance code bases and extra
//! bits, code-length-alphabet permutation order.

/// Length codes 257..=285: (base length, extra bits).
pub const LENGTH_TABLE: [(u16, u8); 29] = [
    (3, 0), (4, 0), (5, 0), (6, 0), (7, 0), (8, 0), (9, 0), (10, 0),
    (11, 1), (13, 1), (15, 1), (17, 1),
    (19, 2), (23, 2), (27, 2), (31, 2),
    (35, 3), (43, 3), (51, 3), (59, 3),
    (67, 4), (83, 4), (99, 4), (115, 4),
    (131, 5), (163, 5), (195, 5), (227, 5),
    (258, 0),
];

/// Distance codes 0..=29: (base distance, extra bits).
pub const DIST_TABLE: [(u16, u8); 30] = [
    (1, 0), (2, 0), (3, 0), (4, 0),
    (5, 1), (7, 1),
    (9, 2), (13, 2),
    (17, 3), (25, 3),
    (33, 4), (49, 4),
    (65, 5), (97, 5),
    (129, 6), (193, 6),
    (257, 7), (385, 7),
    (513, 8), (769, 8),
    (1025, 9), (1537, 9),
    (2049, 10), (3073, 10),
    (4097, 11), (6145, 11),
    (8193, 12), (12289, 12),
    (16385, 13), (24577, 13),
];

/// Order in which code-length-code lengths are transmitted (RFC 1951 §3.2.7).
pub const CLC_ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

/// Number of literal/length symbols (0..=285, 286 entries).
pub const NUM_LITLEN: usize = 286;
/// Number of distance symbols.
pub const NUM_DIST: usize = 30;

/// Map a match length (3..=258) to (code index 0..=28 within 257..285).
#[inline]
pub fn length_code(len: u16) -> usize {
    debug_assert!((3..=258).contains(&len));
    // Binary-search-free: a 256-entry LUT would be faster; built on first use.
    LENGTH_LUT[(len - 3) as usize] as usize
}

/// Map a distance (1..=32768) to code index 0..=29.
#[inline]
pub fn dist_code(dist: u16) -> usize {
    debug_assert!(dist >= 1);
    let d = (dist - 1) as usize;
    if d < 256 {
        DIST_LUT_LO[d] as usize
    } else {
        DIST_LUT_HI[d >> 7] as usize
    }
}

/// Length LUT: len-3 -> length code index (0..=28).
pub static LENGTH_LUT: [u8; 256] = build_length_lut();
static DIST_LUT_LO: [u8; 256] = build_dist_lut_lo();
static DIST_LUT_HI: [u8; 256] = build_dist_lut_hi();

const fn build_length_lut() -> [u8; 256] {
    let mut lut = [0u8; 256];
    let mut code = 0usize;
    let mut len = 3usize;
    while len <= 258 {
        // Advance code while len exceeds the next base.
        while code + 1 < 29 && len >= LENGTH_TABLE[code + 1].0 as usize {
            code += 1;
        }
        lut[len - 3] = code as u8;
        len += 1;
    }
    // Special case: 258 has its own code 28 (base 258, 0 extra).
    lut[258 - 3] = 28;
    lut
}

const fn build_dist_lut_lo() -> [u8; 256] {
    let mut lut = [0u8; 256];
    let mut d = 0usize; // dist-1
    while d < 256 {
        let dist = d + 1;
        let mut code = 0usize;
        while code + 1 < 30 && dist >= DIST_TABLE[code + 1].0 as usize {
            code += 1;
        }
        lut[d] = code as u8;
        d += 1;
    }
    lut
}

const fn build_dist_lut_hi() -> [u8; 256] {
    // Index: (dist-1) >> 7 for dist > 256.
    let mut lut = [0u8; 256];
    let mut i = 0usize;
    while i < 256 {
        let dist = (i << 7) + 1 + 127; // representative distance in bucket
        let dist = if dist > 32768 { 32768 } else { dist };
        let mut code = 0usize;
        while code + 1 < 30 && dist >= DIST_TABLE[code + 1].0 as usize {
            code += 1;
        }
        lut[i] = code as u8;
        i += 1;
    }
    lut
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn length_code_matches_table() {
        for len in 3u16..=258 {
            let c = length_code(len);
            let (base, extra) = LENGTH_TABLE[c];
            assert!(len >= base, "len {len} code {c}");
            assert!(
                (len as u32) < base as u32 + (1u32 << extra) || len == 258,
                "len {len} code {c} base {base} extra {extra}"
            );
        }
        assert_eq!(length_code(3), 0);
        assert_eq!(length_code(258), 28);
        assert_eq!(length_code(10), 7);
        assert_eq!(length_code(11), 8);
    }

    #[test]
    fn dist_code_matches_table() {
        for dist in 1u32..=32768 {
            let c = dist_code(dist as u16);
            let (base, extra) = DIST_TABLE[c];
            assert!(dist >= base as u32, "dist {dist} code {c}");
            assert!(
                dist < base as u32 + (1u32 << extra),
                "dist {dist} code {c} base {base} extra {extra}"
            );
        }
    }
}
