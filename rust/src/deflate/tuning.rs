//! Engine tuning profiles: reference zlib vs the Cloudflare fork.
//!
//! The paper's §2.1 enumerates the CF-ZLIB differences we model:
//!
//! * **Hash width** — reference zlib hashes 3-byte prefixes (“triplets”);
//!   CF hashes 4-byte prefixes (“quadruplets”) at fast levels (1–5),
//!   shrinking the hash map and skipping unproductive 3-byte matches.
//! * **Checksum kernel** — reference: scalar/16×-unrolled adler32;
//!   CF: SWAR (`_mm_sad_epu8`-style) adler32 with 8× unrolling.
//! * **Unroll factors** — CF reduced hand-unrolling (adler32 16→8,
//!   crc32 8→4) because modern OoO cores prefer tighter loops.
//!
//! Both profiles emit bit-identical *formats* (RFC 1950/1951); only match
//! finding and checksum kernels differ, so compressed sizes differ slightly
//! — exactly the paper's observation ("compression ratios for CF-ZLIB and
//! ZLIB vary slightly even at equivalent compression levels").

use crate::checksum::adler32::Backend as AdlerBackend;

/// Which implementation family to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Flavor {
    /// Mark Adler's reference zlib.
    Reference,
    /// Cloudflare fork as patched into ROOT 6.18.00.
    #[default]
    Cloudflare,
}

/// Per-level match-finding parameters (zlib's `configuration_table`).
#[derive(Debug, Clone, Copy)]
pub struct LevelParams {
    /// Reduce lazy search above this match length.
    pub good_length: u16,
    /// Do not perform lazy search above this length (levels ≤3: insert cap).
    pub max_lazy: u16,
    /// Quit search above this length.
    pub nice_length: u16,
    /// Maximum hash-chain links to walk.
    pub max_chain: u16,
    /// Use the lazy-matching strategy (levels ≥ 4).
    pub lazy: bool,
}

/// zlib's deflate_slow/fast configuration table, levels 1..=9.
const ZLIB_LEVELS: [LevelParams; 9] = [
    // 1..=3: deflate_fast
    LevelParams { good_length: 4, max_lazy: 4, nice_length: 8, max_chain: 4, lazy: false },
    LevelParams { good_length: 4, max_lazy: 5, nice_length: 16, max_chain: 8, lazy: false },
    LevelParams { good_length: 4, max_lazy: 6, nice_length: 32, max_chain: 32, lazy: false },
    // 4..=9: deflate_slow
    LevelParams { good_length: 4, max_lazy: 4, nice_length: 16, max_chain: 16, lazy: true },
    LevelParams { good_length: 8, max_lazy: 16, nice_length: 32, max_chain: 32, lazy: true },
    LevelParams { good_length: 8, max_lazy: 16, nice_length: 128, max_chain: 128, lazy: true },
    LevelParams { good_length: 8, max_lazy: 32, nice_length: 128, max_chain: 256, lazy: true },
    LevelParams { good_length: 32, max_lazy: 128, nice_length: 258, max_chain: 1024, lazy: true },
    LevelParams { good_length: 32, max_lazy: 258, nice_length: 258, max_chain: 4096, lazy: true },
];

/// A fully-resolved tuning for one (flavor, level) pair.
#[derive(Debug, Clone, Copy)]
pub struct Tuning {
    pub flavor: Flavor,
    pub level: u8,
    pub params: LevelParams,
    /// Bytes hashed per table entry: 3 (triplet) or 4 (quadruplet).
    pub hash_width: u8,
    /// Checksum kernel for the zlib wrapper.
    pub adler_backend: AdlerBackend,
}

impl Tuning {
    /// Resolve a tuning. `level` is clamped to 1..=9 (0 is handled by the
    /// stored-block path in `compress`).
    pub fn new(flavor: Flavor, level: u8) -> Self {
        let level = level.clamp(1, 9);
        let params = ZLIB_LEVELS[(level - 1) as usize];
        let (hash_width, adler_backend) = match flavor {
            Flavor::Reference => (3, AdlerBackend::Unrolled),
            // CF: quadruplet hashing for the fast levels (1–5), SWAR adler.
            Flavor::Cloudflare => (if level <= 5 { 4 } else { 3 }, AdlerBackend::Swar),
        };
        Self { flavor, level, params, hash_width, adler_backend }
    }

    /// Label used in figure output, e.g. "ZLIB-6" / "CF-ZLIB-6".
    pub fn label(&self) -> String {
        match self.flavor {
            Flavor::Reference => format!("ZLIB-{}", self.level),
            Flavor::Cloudflare => format!("CF-ZLIB-{}", self.level),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_clamping() {
        assert_eq!(Tuning::new(Flavor::Reference, 0).level, 1);
        assert_eq!(Tuning::new(Flavor::Reference, 99).level, 9);
    }

    #[test]
    fn cf_quadruplet_fast_levels_only() {
        for l in 1..=5u8 {
            assert_eq!(Tuning::new(Flavor::Cloudflare, l).hash_width, 4);
        }
        for l in 6..=9u8 {
            assert_eq!(Tuning::new(Flavor::Cloudflare, l).hash_width, 3);
        }
        for l in 1..=9u8 {
            assert_eq!(Tuning::new(Flavor::Reference, l).hash_width, 3);
        }
    }

    #[test]
    fn params_monotone_effort() {
        // Chain caps never decrease with level within a strategy.
        for l in 1..9usize {
            assert!(ZLIB_LEVELS[l].max_chain >= ZLIB_LEVELS[l - 1].max_chain || l == 3);
        }
    }
}
