//! DEFLATE decompression (RFC 1951) — table-driven, branch-light bit reader.

use super::consts::*;
use super::huffman::Decoder;
use crate::util::bitio::BitReader;

/// Inflate errors carry a static reason; inputs are untrusted (files on
/// disk), so every malformed case must land here rather than panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InflateError(pub &'static str);

impl std::fmt::Display for InflateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "inflate: {}", self.0)
    }
}
impl std::error::Error for InflateError {}

const E: fn(&'static str) -> InflateError = InflateError;

/// Decompress a raw DEFLATE stream. `size_hint` pre-sizes the output (the
/// ROOT record header stores the exact uncompressed size, so the hot path
/// always has it). `max_out` bounds memory for untrusted input.
pub fn inflate(data: &[u8], size_hint: usize, max_out: usize) -> Result<Vec<u8>, InflateError> {
    inflate_dict(data, &[], size_hint, max_out)
}

/// Inflate with a preset dictionary (RFC 1950 FDICT): the window starts
/// primed with `dict`, so back-references may reach into it.
pub fn inflate_dict(
    data: &[u8],
    dict: &[u8],
    size_hint: usize,
    max_out: usize,
) -> Result<Vec<u8>, InflateError> {
    inflate_impl(data, dict, size_hint, max_out, true)
}

/// Careful-loop-only oracle (§Perf): identical tables and per-symbol logic
/// with the multi-symbol fast loop disabled. The property suite asserts
/// [`inflate`] matches it byte-for-byte on every corpus stream and agrees
/// on rejection for malformed/truncated ones.
#[doc(hidden)]
pub fn inflate_reference(data: &[u8], size_hint: usize, max_out: usize) -> Result<Vec<u8>, InflateError> {
    inflate_impl(data, &[], size_hint, max_out, false)
}

fn inflate_impl(
    data: &[u8],
    dict: &[u8],
    size_hint: usize,
    max_out: usize,
    use_fast: bool,
) -> Result<Vec<u8>, InflateError> {
    let mut out: Vec<u8> = Vec::with_capacity(dict.len() + size_hint.min(max_out));
    out.extend_from_slice(dict);
    let max_out = max_out.saturating_add(dict.len());
    let mut r = BitReader::new(data);
    loop {
        let bfinal = r.read_bits(1) != 0;
        let btype = r.read_bits(2);
        match btype {
            0b00 => inflate_stored(&mut r, &mut out, max_out)?,
            0b01 => {
                let (lit, dist) = fixed_decoders();
                inflate_block(&mut r, lit, dist, &mut out, max_out, use_fast)?;
            }
            0b10 => {
                let (lit, dist) = read_dynamic_trees(&mut r)?;
                inflate_block(&mut r, &lit, dist.as_ref(), &mut out, max_out, use_fast)?;
            }
            _ => return Err(E("reserved block type")),
        }
        if r.overflowed() {
            return Err(E("truncated stream"));
        }
        if bfinal {
            out.drain(..dict.len());
            return Ok(out);
        }
    }
}

fn inflate_stored(r: &mut BitReader, out: &mut Vec<u8>, max_out: usize) -> Result<(), InflateError> {
    r.align_byte();
    let len = r.read_bits(16) as u16;
    let nlen = r.read_bits(16) as u16;
    if r.overflowed() {
        return Err(E("truncated stored header"));
    }
    if len != !nlen {
        return Err(E("stored LEN/NLEN mismatch"));
    }
    if out.len() + len as usize > max_out {
        return Err(E("output limit exceeded"));
    }
    let start = out.len();
    out.resize(start + len as usize, 0);
    r.read_bytes(&mut out[start..])
        .map_err(|_| E("truncated stored block"))
}

fn fixed_decoders() -> (&'static Decoder, Option<&'static Decoder>) {
    use std::sync::OnceLock;
    static FIXED: OnceLock<(Decoder, Decoder)> = OnceLock::new();
    let (lit, dist) = FIXED.get_or_init(|| {
        let mut l = vec![0u8; 288];
        for (i, v) in l.iter_mut().enumerate() {
            *v = match i {
                0..=143 => 8,
                144..=255 => 9,
                256..=279 => 7,
                _ => 8,
            };
        }
        // 32 codes: 30/31 are defined by the RFC but invalid if used.
        let d = vec![5u8; 32];
        (
            Decoder::from_lengths(&l).expect("fixed lit tree"),
            Decoder::from_lengths(&d).expect("fixed dist tree"),
        )
    });
    (lit, Some(dist))
}

fn read_dynamic_trees(r: &mut BitReader) -> Result<(Decoder, Option<Decoder>), InflateError> {
    let hlit = r.read_bits(5) as usize + 257;
    let hdist = r.read_bits(5) as usize + 1;
    let hclen = r.read_bits(4) as usize + 4;
    if hlit > NUM_LITLEN {
        return Err(E("HLIT too large"));
    }
    if hdist > NUM_DIST {
        return Err(E("HDIST too large"));
    }
    let mut clc_lengths = [0u8; 19];
    for k in 0..hclen {
        clc_lengths[CLC_ORDER[k]] = r.read_bits(3) as u8;
    }
    if r.overflowed() {
        return Err(E("truncated tree header"));
    }
    let clc = Decoder::from_lengths(&clc_lengths).map_err(|_| E("bad code-length code"))?;

    let mut lengths = vec![0u8; hlit + hdist];
    let mut i = 0usize;
    while i < lengths.len() {
        let sym = clc.decode(r).map_err(|_| E("bad CLC symbol"))?;
        match sym {
            0..=15 => {
                lengths[i] = sym as u8;
                i += 1;
            }
            16 => {
                if i == 0 {
                    return Err(E("repeat with no previous length"));
                }
                let run = 3 + r.read_bits(2) as usize;
                if i + run > lengths.len() {
                    return Err(E("length repeat overflow"));
                }
                let v = lengths[i - 1];
                lengths[i..i + run].fill(v);
                i += run;
            }
            17 => {
                let run = 3 + r.read_bits(3) as usize;
                if i + run > lengths.len() {
                    return Err(E("zero repeat overflow"));
                }
                i += run;
            }
            18 => {
                let run = 11 + r.read_bits(7) as usize;
                if i + run > lengths.len() {
                    return Err(E("zero repeat overflow"));
                }
                i += run;
            }
            _ => return Err(E("invalid CLC symbol")),
        }
        if r.overflowed() {
            return Err(E("truncated tree payload"));
        }
    }
    let (lit_lengths, dist_lengths) = lengths.split_at(hlit);
    if lit_lengths[256] == 0 {
        return Err(E("no end-of-block code"));
    }
    let lit = Decoder::from_lengths(lit_lengths).map_err(|_| E("bad literal tree"))?;
    let dist = if dist_lengths.iter().all(|&l| l == 0) {
        None
    } else {
        Some(Decoder::from_lengths(dist_lengths).map_err(|_| E("bad distance tree"))?)
    };
    Ok((lit, dist))
}

fn inflate_block(
    r: &mut BitReader,
    lit: &Decoder,
    dist: Option<&Decoder>,
    out: &mut Vec<u8>,
    max_out: usize,
    use_fast: bool,
) -> Result<(), InflateError> {
    // §Perf multi-symbol fast loop (zlib-ng's `inflate_fast` shape): while
    // at least 64 real input bits remain and the output has a full
    // MAX_MATCH of headroom, a complete token — literal (<=15 bits) or
    // match (<=15+5+15+13 = 48 bits) — can be decoded with NO per-symbol
    // truncation or output-limit checks: the reader's 57-bit refill means
    // every peek sees real bits, and consuming <=48 of >=64 real bits can
    // never touch synthetic padding. Literal *runs* batch inside one outer
    // iteration: after each pushed literal only the two cheap window checks
    // re-run (each literal consumes <=15 bits, so re-validating >=64 keeps
    // the match-token budget intact), not the full loop re-entry. The
    // careful loop below finishes the tail; both loops share the same
    // tables, so behavior is identical (oracle: `inflate_reference`).
    'fast: while use_fast && r.bits_remaining() >= 64 && out.len() + 258 <= max_out {
        let mut sym = lit.decode_fast(r);
        while sym < 256 {
            out.push(sym as u8);
            if r.bits_remaining() < 64 || out.len() + 258 > max_out {
                continue 'fast;
            }
            sym = lit.decode_fast(r);
        }
        if sym == 256 {
            return Ok(());
        }
        if sym > 285 {
            return Err(if sym == crate::deflate::huffman::INVALID_SYM {
                E("bad literal/length code")
            } else {
                E("invalid literal/length symbol")
            });
        }
        let (lbase, lextra) = LENGTH_TABLE[(sym - 257) as usize];
        let len = lbase as usize + r.read_bits(lextra as u32) as usize;
        let dist_dec = dist.ok_or(E("match with empty distance tree"))?;
        let dsym = dist_dec.decode_fast(r);
        if dsym as usize >= DIST_TABLE.len() {
            return Err(if dsym == crate::deflate::huffman::INVALID_SYM {
                E("bad distance code")
            } else {
                E("invalid distance symbol")
            });
        }
        let (dbase, dextra) = DIST_TABLE[dsym as usize];
        let d = dbase as usize + r.read_bits(dextra as u32) as usize;
        if d > out.len() {
            return Err(E("distance beyond output start"));
        }
        copy_match(out, d, len);
    }
    // Careful tail loop: per-symbol truncation and output-limit checks.
    loop {
        let sym = lit.decode(r).map_err(|_| E("bad literal/length code"))?;
        if r.overflowed() {
            return Err(E("truncated block"));
        }
        match sym {
            0..=255 => {
                if out.len() >= max_out {
                    return Err(E("output limit exceeded"));
                }
                out.push(sym as u8);
            }
            256 => return Ok(()),
            257..=285 => {
                let (lbase, lextra) = LENGTH_TABLE[(sym - 257) as usize];
                let len = lbase as usize + r.read_bits(lextra as u32) as usize;
                let dist_dec = dist.ok_or(E("match with empty distance tree"))?;
                let dsym = dist_dec.decode(r).map_err(|_| E("bad distance code"))?;
                if dsym as usize >= DIST_TABLE.len() {
                    return Err(E("invalid distance symbol"));
                }
                let (dbase, dextra) = DIST_TABLE[dsym as usize];
                let d = dbase as usize + r.read_bits(dextra as u32) as usize;
                if r.overflowed() {
                    return Err(E("truncated match"));
                }
                if d > out.len() {
                    return Err(E("distance beyond output start"));
                }
                if out.len() + len > max_out {
                    return Err(E("output limit exceeded"));
                }
                copy_match(out, d, len);
            }
            _ => return Err(E("invalid literal/length symbol")),
        }
    }
}

/// Overlapping backwards copy. For dist >= 8 use wide chunk copies (safe
/// because source and destination don't overlap within a chunk).
#[inline]
fn copy_match(out: &mut Vec<u8>, dist: usize, len: usize) {
    let start = out.len() - dist;
    if dist >= len {
        // No overlap at all.
        out.extend_from_within(start..start + len);
        return;
    }
    if dist == 1 {
        // Run of a single byte.
        let b = out[out.len() - 1];
        let new_len = out.len() + len;
        out.resize(new_len, b);
        return;
    }
    // Overlapping: replicate the dist-sized period.
    out.reserve(len);
    let mut remaining = len;
    let mut src = start;
    while remaining > 0 {
        let chunk = remaining.min(out.len() - src);
        out.extend_from_within(src..src + chunk);
        src += chunk;
        remaining -= chunk;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deflate::compress::{deflate, deflate_stored};
    use crate::deflate::tuning::{Flavor, Tuning};
    use crate::util::rng::Rng;

    const MAX: usize = 64 << 20;

    fn roundtrip(data: &[u8], tuning: &Tuning) {
        let c = deflate(data, tuning);
        let d = inflate(&c, data.len(), MAX).expect("inflate");
        assert_eq!(d, data, "{} on {} bytes", tuning.label(), data.len());
    }

    #[test]
    fn roundtrip_corpus() {
        let mut rng = Rng::new(0x1F1F);
        let mut corpus: Vec<Vec<u8>> = vec![
            vec![],
            b"a".to_vec(),
            b"hello hello hello hello".to_vec(),
            vec![0u8; 100_000],
            (0u32..20_000).flat_map(|i| i.to_be_bytes()).collect(),
        ];
        corpus.push(rng.bytes(70_000));
        // Text-like.
        let mut text = Vec::new();
        while text.len() < 50_000 {
            text.extend_from_slice(b"The LHC will increase both energy and luminosity. ");
        }
        corpus.push(text);
        for data in &corpus {
            for flavor in [Flavor::Reference, Flavor::Cloudflare] {
                for level in [1u8, 4, 6, 9] {
                    roundtrip(data, &Tuning::new(flavor, level));
                }
            }
        }
    }

    #[test]
    fn roundtrip_stored() {
        let mut rng = Rng::new(0x1F20);
        for n in [0usize, 1, 100, 65_535, 65_536, 200_000] {
            let data = rng.bytes(n);
            let c = deflate_stored(&data);
            assert_eq!(inflate(&c, n, MAX).unwrap(), data);
        }
    }

    #[test]
    fn roundtrip_fuzz() {
        let mut rng = Rng::new(0x1F21);
        for round in 0..60 {
            let n = rng.range(0, 30_000);
            // Structured randomness: random spans of runs, text, noise.
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                match rng.range(0, 3) {
                    0 => {
                        let b = (rng.next_u64() & 0xFF) as u8;
                        let run = rng.range(1, 300);
                        data.extend(std::iter::repeat(b).take(run));
                    }
                    1 => data.extend_from_slice(b"branch_entry_offset_"),
                    2 => {
                        let k = rng.range(1, 64);
                        let bytes = rng.bytes(k);
                        data.extend_from_slice(&bytes);
                    }
                    _ => {
                        let v = rng.next_u32();
                        data.extend_from_slice(&v.to_be_bytes());
                    }
                }
            }
            data.truncate(n);
            let level = [1u8, 3, 6, 9][round % 4];
            let flavor = if round % 2 == 0 { Flavor::Reference } else { Flavor::Cloudflare };
            roundtrip(&data, &Tuning::new(flavor, level));
        }
    }

    #[test]
    fn rejects_garbage() {
        let mut rng = Rng::new(0x1F22);
        let mut rejected = 0;
        for _ in 0..200 {
            let n = rng.range(1, 200);
            let garbage = rng.bytes(n);
            if inflate(&garbage, 1000, 1 << 16).is_err() {
                rejected += 1;
            }
        }
        // Random bytes are overwhelmingly invalid deflate streams.
        assert!(rejected > 150, "only {rejected}/200 rejected");
    }

    #[test]
    fn rejects_truncation() {
        let data = vec![7u8; 10_000];
        let c = deflate(&data, &Tuning::new(Flavor::Reference, 6));
        for cut in [1, c.len() / 2, c.len() - 1] {
            assert!(
                inflate(&c[..cut], data.len(), MAX).is_err(),
                "cut at {cut} accepted"
            );
        }
    }

    #[test]
    fn respects_output_limit() {
        let data = vec![0u8; 1 << 20];
        let c = deflate(&data, &Tuning::new(Flavor::Reference, 6));
        let err = inflate(&c, 1024, 1024).unwrap_err();
        assert_eq!(err.0, "output limit exceeded");
    }

    #[test]
    fn overlapping_copy_cases() {
        // dist < len exercises the periodic copy.
        let mut data = Vec::new();
        for period in [1usize, 2, 3, 5, 7] {
            for _ in 0..100 {
                for k in 0..period {
                    data.push((k * 37) as u8);
                }
            }
        }
        roundtrip(&data, &Tuning::new(Flavor::Reference, 6));
    }
}
