//! Canonical Huffman code construction and fast table-driven decoding for
//! DEFLATE (RFC 1951 §3.2).
//!
//! Encoding side: package-merge-free length-limited Huffman via the classic
//! heap build + overflow rebalancing (zlib's approach), emitting canonical
//! codes. Decoding side: a single-level lookup table of `1 << MAX_BITS`
//! entries per tree (15 bits → 32K entries; we build the table at the
//! code's actual max length to keep it small for typical trees).

/// Maximum DEFLATE code length.
pub const MAX_BITS: usize = 15;

/// Build optimal code lengths (≤ `max_bits`) for the given symbol
/// frequencies. Returns a length per symbol (0 = unused). Deterministic.
pub fn build_code_lengths(freqs: &[u64], max_bits: usize) -> Vec<u8> {
    let n = freqs.len();
    let mut lengths = vec![0u8; n];
    let used: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    match used.len() {
        0 => return lengths,
        1 => {
            lengths[used[0]] = 1;
            return lengths;
        }
        _ => {}
    }

    // Huffman tree via two-queue method on sorted leaves (deterministic,
    // O(n log n) from the sort only).
    #[derive(Clone, Copy)]
    struct Node {
        freq: u64,
        // leaf: symbol index; internal: children indices into `nodes`
        left: i32,
        right: i32,
        symbol: i32,
    }
    let mut leaves: Vec<(u64, usize)> = used.iter().map(|&i| (freqs[i], i)).collect();
    // Sort by (freq, symbol) for determinism.
    leaves.sort_unstable();
    let mut nodes: Vec<Node> = leaves
        .iter()
        .map(|&(f, s)| Node { freq: f, left: -1, right: -1, symbol: s as i32 })
        .collect();

    let mut q1: std::collections::VecDeque<usize> = (0..nodes.len()).collect();
    let mut q2: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    let take_min = |q1: &mut std::collections::VecDeque<usize>,
                    q2: &mut std::collections::VecDeque<usize>,
                    nodes: &Vec<Node>|
     -> usize {
        match (q1.front(), q2.front()) {
            (Some(&a), Some(&b)) => {
                if nodes[a].freq <= nodes[b].freq {
                    q1.pop_front().unwrap()
                } else {
                    q2.pop_front().unwrap()
                }
            }
            (Some(_), None) => q1.pop_front().unwrap(),
            (None, Some(_)) => q2.pop_front().unwrap(),
            (None, None) => unreachable!(),
        }
    };
    while q1.len() + q2.len() > 1 {
        let a = take_min(&mut q1, &mut q2, &nodes);
        let b = take_min(&mut q1, &mut q2, &nodes);
        let parent = Node {
            freq: nodes[a].freq + nodes[b].freq,
            left: a as i32,
            right: b as i32,
            symbol: -1,
        };
        nodes.push(parent);
        q2.push_back(nodes.len() - 1);
    }
    let root = take_min(&mut q1, &mut q2, &nodes);

    // Depth-first assign depths.
    let mut stack = vec![(root, 0u8)];
    let mut bl_count = [0u32; MAX_BITS + 1 + 32];
    while let Some((idx, depth)) = stack.pop() {
        let node = nodes[idx];
        if node.symbol >= 0 {
            let d = depth.max(1);
            lengths[node.symbol as usize] = d;
            bl_count[d as usize] += 1;
        } else {
            stack.push((node.left as usize, depth + 1));
            stack.push((node.right as usize, depth + 1));
        }
    }

    // Limit lengths to max_bits (zlib-style rebalancing): move overflowed
    // leaves up, compensating by demoting the deepest ≤max_bits leaf.
    let mut overflow: i64 = 0;
    for d in (max_bits + 1)..bl_count.len() {
        overflow += bl_count[d] as i64;
        bl_count[max_bits] += bl_count[d];
        bl_count[d] = 0;
    }
    if overflow > 0 {
        // Clamp all the overflowed lengths to max_bits first.
        for l in lengths.iter_mut() {
            if *l as usize > max_bits {
                *l = max_bits as u8;
            }
        }
        // Restore Kraft equality: sum(2^-len) must equal 1.
        loop {
            let kraft: i64 = lengths
                .iter()
                .filter(|&&l| l > 0)
                .map(|&l| 1i64 << (max_bits - l as usize))
                .sum();
            let full = 1i64 << max_bits;
            if kraft <= full {
                break;
            }
            // Find deepest symbol with len < max_bits? No — to reduce kraft
            // we must *lengthen* some code. Pick the symbol with the
            // smallest frequency among those with len < max_bits.
            let mut best: Option<(u64, usize)> = None;
            for &s in used.iter() {
                let l = lengths[s] as usize;
                if l > 0 && l < max_bits {
                    let key = (freqs[s], s);
                    if best.map_or(true, |b| key < b) {
                        best = Some(key);
                    }
                }
            }
            let (_, s) = best.expect("kraft repair impossible");
            lengths[s] += 1;
        }
        // Kraft may now be < 1 (wasted space); shorten codes greedily to
        // tighten (optional for correctness, improves ratio slightly).
        loop {
            let kraft: i64 = lengths
                .iter()
                .filter(|&&l| l > 0)
                .map(|&l| 1i64 << (max_bits - l as usize))
                .sum();
            let full = 1i64 << max_bits;
            if kraft == full {
                break;
            }
            debug_assert!(kraft < full);
            // Shorten the most frequent symbol whose shortening keeps
            // kraft <= full.
            let slack = full - kraft;
            let mut best: Option<(std::cmp::Reverse<u64>, usize)> = None;
            for &s in used.iter() {
                let l = lengths[s] as usize;
                if l > 1 {
                    let gain = 1i64 << (max_bits - l as usize); // doubling its share
                    if gain <= slack {
                        let key = (std::cmp::Reverse(freqs[s]), s);
                        if best.map_or(true, |b| key < b) {
                            best = Some(key);
                        }
                    }
                }
            }
            match best {
                Some((_, s)) => lengths[s] -= 1,
                None => break, // cannot tighten further; prefix property holds
            }
        }
    }
    lengths
}

/// Assign canonical codes from lengths (RFC 1951 §3.2.2). Returns
/// `codes[sym]` with bits in *LSB-first transmit order* (i.e. already
/// bit-reversed for the deflate bit writer).
pub fn canonical_codes(lengths: &[u8]) -> Vec<u16> {
    let mut bl_count = [0u16; MAX_BITS + 1];
    for &l in lengths {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = [0u16; MAX_BITS + 2];
    let mut code = 0u16;
    for bits in 1..=MAX_BITS {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    let mut codes = vec![0u16; lengths.len()];
    for (sym, &len) in lengths.iter().enumerate() {
        if len > 0 {
            let c = next_code[len as usize];
            next_code[len as usize] += 1;
            codes[sym] = reverse_bits(c, len as u32);
        }
    }
    codes
}

#[inline]
fn reverse_bits(v: u16, n: u32) -> u16 {
    v.reverse_bits() >> (16 - n)
}

/// Fast Huffman decoder: two-level table (zlib-style). A root table of
/// `ROOT_BITS` bits resolves all short codes in one lookup; longer codes
/// indirect into per-prefix subtables. Keeps the hot table L1-resident
/// (root: 2^10 × 4 B = 4 KiB) instead of up to 128 KiB for a flat 15-bit
/// table — a §Perf win on both build time and lookup locality.
pub struct Decoder {
    root: Vec<Entry>,
    sub: Vec<Entry>,
    /// (start offset in `sub`, extra bits) per subtable id.
    subs: Vec<(u32, u8)>,
    pub max_len: u32,
}

const ROOT_BITS: u32 = 10;
const SUB_MARKER: u8 = 0xFF;

#[derive(Clone, Copy, Default)]
struct Entry {
    val: u16,
    len: u8, // 0 = invalid, SUB_MARKER = subtable (val = subtable id)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HuffError(pub &'static str);

impl std::fmt::Display for HuffError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "huffman: {}", self.0)
    }
}
impl std::error::Error for HuffError {}

impl Decoder {
    /// Build from code lengths. Enforces that the code is complete (Kraft
    /// equality) unless exactly one symbol is used (DEFLATE permits a
    /// 1-symbol distance tree encoded with one 1-bit code).
    pub fn from_lengths(lengths: &[u8]) -> Result<Self, HuffError> {
        let max_len = lengths.iter().copied().max().unwrap_or(0) as u32;
        if max_len == 0 {
            return Err(HuffError("empty code"));
        }
        if max_len as usize > MAX_BITS {
            return Err(HuffError("code length > 15"));
        }
        let used = lengths.iter().filter(|&&l| l > 0).count();
        let kraft: u32 = lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 1u32 << (max_len - l as u32))
            .sum();
        let full = 1u32 << max_len;
        if used > 1 && kraft != full {
            return Err(HuffError("incomplete or oversubscribed code"));
        }
        if used == 1 && kraft > full {
            return Err(HuffError("oversubscribed code"));
        }

        let codes = canonical_codes(lengths);
        let root_bits = max_len.min(ROOT_BITS);
        let mut root = vec![Entry::default(); 1 << root_bits];
        let mut sub: Vec<Entry> = Vec::new();
        let mut subs: Vec<(u32, u8)> = Vec::new();

        // Short codes fill the root directly.
        for (sym, &len) in lengths.iter().enumerate() {
            if len == 0 || len as u32 > root_bits {
                continue;
            }
            let step = 1usize << len;
            let mut idx = codes[sym] as usize;
            while idx < root.len() {
                root[idx] = Entry { val: sym as u16, len };
                idx += step;
            }
        }
        // Long codes: group by their low root_bits (LSB-first prefix).
        if max_len > root_bits {
            use std::collections::HashMap;
            let mut groups: HashMap<usize, Vec<usize>> = HashMap::new();
            for (sym, &len) in lengths.iter().enumerate() {
                if (len as u32) > root_bits {
                    groups
                        .entry(codes[sym] as usize & ((1 << root_bits) - 1))
                        .or_default()
                        .push(sym);
                }
            }
            let mut prefixes: Vec<_> = groups.into_iter().collect();
            prefixes.sort_unstable_by_key(|(p, _)| *p);
            for (prefix, symbols) in prefixes {
                let group_max = symbols
                    .iter()
                    .map(|&s| lengths[s] as u32)
                    .max()
                    .unwrap();
                let extra = group_max - root_bits;
                let start = sub.len() as u32;
                sub.resize(sub.len() + (1usize << extra), Entry::default());
                for &sym in &symbols {
                    let len = lengths[sym] as u32;
                    let high = (codes[sym] as usize) >> root_bits; // (len-root) bits
                    let step = 1usize << (len - root_bits);
                    let mut idx = high;
                    while idx < (1usize << extra) {
                        sub[start as usize + idx] = Entry { val: sym as u16, len: len as u8 };
                        idx += step;
                    }
                }
                let id = subs.len() as u16;
                subs.push((start, extra as u8));
                root[prefix] = Entry { val: id, len: SUB_MARKER };
            }
        }
        Ok(Self { root, sub, subs, max_len })
    }

    /// Decode one symbol from the bit reader.
    #[inline]
    pub fn decode(&self, r: &mut crate::util::bitio::BitReader) -> Result<u16, HuffError> {
        match self.decode_fast(r) {
            INVALID_SYM => Err(HuffError("invalid code")),
            s => Ok(s as u16),
        }
    }

    /// §Perf hot-loop variant: decode one symbol with no `Result` wrapping,
    /// returning [`INVALID_SYM`] for an invalid code. Identical table walk
    /// to [`Decoder::decode`] (which is implemented on top of this). The
    /// caller guarantees enough buffered bits — the inflate fast loop checks
    /// `bits_remaining() >= 64` before each token, which covers the
    /// decoder's 15-bit worst case several times over thanks to the bit
    /// reader's 57-bit refill.
    #[inline(always)]
    pub fn decode_fast(&self, r: &mut crate::util::bitio::BitReader) -> u32 {
        let root_bits = self.max_len.min(ROOT_BITS);
        let e = self.root[r.peek(root_bits) as usize];
        if e.len as u32 <= root_bits && e.len != 0 {
            r.consume(e.len as u32);
            return e.val as u32;
        }
        if e.len == SUB_MARKER {
            let (start, extra) = self.subs[e.val as usize];
            let idx = (r.peek(root_bits + extra as u32) >> root_bits) as usize;
            let e2 = self.sub[start as usize + idx];
            if e2.len == 0 {
                return INVALID_SYM;
            }
            r.consume(e2.len as u32);
            return e2.val as u32;
        }
        INVALID_SYM
    }
}

/// Sentinel returned by [`Decoder::decode_fast`] for invalid codes.
pub const INVALID_SYM: u32 = u32::MAX;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bitio::{BitReader, BitWriter};
    use crate::util::rng::Rng;

    fn roundtrip_symbols(freqs: &[u64], max_bits: usize, seed: u64) {
        let lengths = build_code_lengths(freqs, max_bits);
        // Kraft inequality must hold.
        let kraft: f64 = lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| (0.5f64).powi(l as i32))
            .sum();
        assert!(kraft <= 1.0 + 1e-12, "kraft={kraft}");
        for (i, &l) in lengths.iter().enumerate() {
            assert_eq!(l > 0, freqs[i] > 0, "sym {i}");
            assert!(l as usize <= max_bits);
        }
        let codes = canonical_codes(&lengths);
        let dec = Decoder::from_lengths(&lengths);
        if lengths.iter().filter(|&&l| l > 0).count() < 1 {
            return;
        }
        let dec = dec.expect("decoder build");
        // Encode a random symbol stream weighted by freq, decode it back.
        let mut rng = Rng::new(seed);
        let alive: Vec<usize> = (0..freqs.len()).filter(|&i| freqs[i] > 0).collect();
        let mut syms = Vec::new();
        let mut w = BitWriter::new();
        for _ in 0..2000 {
            let s = alive[rng.range(0, alive.len() - 1)];
            syms.push(s as u16);
            w.write_bits(codes[s] as u64, lengths[s] as u32);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &expect in &syms {
            assert_eq!(dec.decode(&mut r).unwrap(), expect);
        }
        assert!(!r.overflowed());
    }

    #[test]
    fn uniform_freqs() {
        roundtrip_symbols(&[10u64; 16], 15, 1);
    }

    #[test]
    fn skewed_freqs() {
        let mut freqs = vec![0u64; 288];
        for i in 0..288 {
            freqs[i] = if i < 10 { 100_000 >> i } else { (i % 7 == 0) as u64 };
        }
        roundtrip_symbols(&freqs, 15, 2);
    }

    #[test]
    fn two_symbols() {
        let mut freqs = vec![0u64; 30];
        freqs[3] = 5;
        freqs[17] = 1_000_000;
        roundtrip_symbols(&freqs, 15, 3);
    }

    #[test]
    fn single_symbol_gets_len1() {
        let mut freqs = vec![0u64; 10];
        freqs[4] = 99;
        let lengths = build_code_lengths(&freqs, 15);
        assert_eq!(lengths[4], 1);
        assert!(Decoder::from_lengths(&lengths).is_ok());
    }

    #[test]
    fn length_limit_enforced() {
        // Fibonacci-ish frequencies force deep trees; limit must clamp.
        let mut freqs = vec![0u64; 40];
        let (mut a, mut b) = (1u64, 1u64);
        for f in freqs.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        for limit in [7usize, 9, 15] {
            let lengths = build_code_lengths(&freqs, limit);
            assert!(lengths.iter().all(|&l| (l as usize) <= limit));
            roundtrip_symbols(&freqs, limit, 4);
        }
    }

    #[test]
    fn random_freq_fuzz() {
        let mut rng = Rng::new(0xF00D);
        for round in 0..50 {
            let n = rng.range(2, 300);
            let mut freqs = vec![0u64; n];
            for f in freqs.iter_mut() {
                if rng.chance(0.7) {
                    let shift = rng.range(1, 30);
                    *f = rng.below(1 << shift) + 1;
                }
            }
            if freqs.iter().filter(|&&f| f > 0).count() < 2 {
                freqs[0] = 1;
                freqs[n - 1] = 2;
            }
            roundtrip_symbols(&freqs, 15, 100 + round);
        }
    }

    #[test]
    fn decoder_rejects_bad_codes() {
        // Oversubscribed: three 1-bit codes.
        assert!(Decoder::from_lengths(&[1, 1, 1]).is_err());
        // Incomplete: single 2-bit code with 2 symbols used.
        assert!(Decoder::from_lengths(&[2, 2]).is_err());
        // Empty.
        assert!(Decoder::from_lengths(&[0, 0]).is_err());
    }

    #[test]
    fn canonical_code_order() {
        // RFC 1951 §3.2.2 example: lengths (3,3,3,3,3,2,4,4) for A..H.
        let lengths = [3u8, 3, 3, 3, 3, 2, 4, 4];
        let codes = canonical_codes(&lengths);
        // Expected canonical codes (MSB-first): F=00, A=010 ... H=1111.
        let expect_msb: [(usize, u16); 8] = [
            (5, 0b00),
            (0, 0b010),
            (1, 0b011),
            (2, 0b100),
            (3, 0b101),
            (4, 0b110),
            (6, 0b1110),
            (7, 0b1111),
        ];
        for (sym, msb) in expect_msb {
            let len = lengths[sym] as u32;
            assert_eq!(codes[sym], super::reverse_bits(msb, len), "sym {sym}");
        }
    }
}
