//! LZ77 match finding for DEFLATE: hash-head + prev-chain exactly like
//! zlib's `deflate.c`, parameterized by the [`Tuning`] profile so the
//! reference (triplet-hash) and Cloudflare (quadruplet-hash) behaviours are
//! both available.

use super::tuning::Tuning;

/// DEFLATE window size (RFC 1951: distances up to 32768).
pub const WINDOW_SIZE: usize = 32 * 1024;
/// Minimum/maximum match lengths in DEFLATE.
pub const MIN_MATCH: usize = 3;
pub const MAX_MATCH: usize = 258;

/// One LZ77 token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Token {
    Literal(u8),
    /// length in 3..=258, distance in 1..=32768
    Match { len: u16, dist: u16 },
}

const HASH_BITS: u32 = 15;
const HASH_SIZE: usize = 1 << HASH_BITS;

#[inline]
fn hash3(data: &[u8], i: usize) -> usize {
    // Multiplicative hash over 3 bytes (reference zlib uses shift-xor; a
    // multiplicative mix has the same role and better distribution).
    let v = (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes(data[i..i + 4].try_into().unwrap());
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Reusable match-finder state (hash head + chain links). Reusing it across
/// baskets avoids the dominant allocation in the per-basket hot loop.
pub struct Matcher {
    head: Vec<i32>,
    prev: Vec<i32>,
}

impl Default for Matcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Matcher {
    pub fn new() -> Self {
        Self { head: vec![-1; HASH_SIZE], prev: Vec::new() }
    }

    /// Tokenize `data` according to `tuning`. Appends to `out` (cleared
    /// first) to allow buffer reuse.
    pub fn tokenize(&mut self, data: &[u8], tuning: &Tuning, out: &mut Vec<Token>) {
        self.tokenize_from(data, 0, tuning, out)
    }

    /// Tokenize `data[start..]` with `data[..start]` as a preset dictionary
    /// (RFC 1950 FDICT semantics): dictionary bytes are matchable within
    /// the 32 KiB window but never emitted as tokens.
    pub fn tokenize_from(&mut self, data: &[u8], start: usize, tuning: &Tuning, out: &mut Vec<Token>) {
        out.clear();
        let n = data.len();
        self.head.fill(-1);
        self.prev.clear();
        self.prev.resize(n, -1);

        let hash_width = tuning.hash_width as usize;
        if n < start + hash_width.max(MIN_MATCH) + 1 {
            out.extend(data[start..].iter().map(|&b| Token::Literal(b)));
            return;
        }
        let p = tuning.params;

        let hash_at = |data: &[u8], i: usize| -> usize {
            if hash_width == 4 {
                hash4(data, i)
            } else {
                hash3(data, i)
            }
        };
        // Last position where a full hash fits.
        let hash_end = n - hash_width;

        // Preload the dictionary region into the hash chains.
        for pos in 0..start.min(hash_end + 1) {
            let h = hash_at(data, pos);
            self.prev[pos] = self.head[h];
            self.head[h] = pos as i32;
        }

        let mut i = start;
        // Lazy-matching state.
        let mut prev_len: usize = 0;
        let mut prev_dist: usize = 0;
        let mut have_prev = false;

        macro_rules! insert {
            ($pos:expr) => {
                if $pos <= hash_end {
                    let h = hash_at(data, $pos);
                    self.prev[$pos] = self.head[h];
                    self.head[h] = $pos as i32;
                }
            };
        }

        while i < n {
            // Find the longest match at i.
            let (mut len, mut dist) = (0usize, 0usize);
            if i <= hash_end && i + MIN_MATCH <= n {
                let h = hash_at(data, i);
                let mut cand = self.head[h];
                let limit = i.saturating_sub(WINDOW_SIZE);
                let mut chain = if have_prev && prev_len >= p.good_length as usize {
                    (p.max_chain / 4).max(1)
                } else {
                    p.max_chain
                };
                let max_len = MAX_MATCH.min(n - i);
                let nice = (p.nice_length as usize).min(max_len);
                while cand >= 0 && chain > 0 {
                    let c = cand as usize;
                    if c < limit {
                        break;
                    }
                    // Quick reject: compare the byte that would extend the
                    // current best match.
                    if len == 0 || data[c + len] == data[i + len] {
                        let m = match_len(data, c, i, max_len);
                        if m > len {
                            len = m;
                            dist = i - c;
                            if m >= nice {
                                break;
                            }
                        }
                    }
                    cand = self.prev[c];
                    chain -= 1;
                }
                if len < MIN_MATCH {
                    len = 0;
                }
                // zlib drops distant 3-byte matches: too far to be worth it.
                if len == MIN_MATCH && dist > 4096 {
                    len = 0;
                }
            }

            if p.lazy {
                if have_prev {
                    // Previous match exists; emit it unless current is better.
                    if len > prev_len && prev_len < p.max_lazy as usize {
                        // Defer: previous position becomes a literal.
                        out.push(Token::Literal(data[i - 1]));
                        prev_len = len;
                        prev_dist = dist;
                        insert!(i);
                        i += 1;
                        continue;
                    } else {
                        // Emit previous match (started at i-1).
                        out.push(Token::Match { len: prev_len as u16, dist: prev_dist as u16 });
                        // Insert hashes for the matched span (from i+1 on;
                        // i-1 and i already inserted).
                        let end = i - 1 + prev_len;
                        let mut j = i + 1;
                        while j < end {
                            insert!(j);
                            j += 1;
                        }
                        have_prev = false;
                        i = end;
                        continue;
                    }
                }
                if len >= MIN_MATCH && len <= p.max_lazy as usize {
                    // Hold as candidate for lazy evaluation.
                    prev_len = len;
                    prev_dist = dist;
                    have_prev = true;
                    insert!(i);
                    i += 1;
                    continue;
                }
                if len >= MIN_MATCH {
                    // Long match: take immediately (no lazy above max_lazy).
                    out.push(Token::Match { len: len as u16, dist: dist as u16 });
                    let end = i + len;
                    insert!(i);
                    let mut j = i + 1;
                    while j < end {
                        insert!(j);
                        j += 1;
                    }
                    i = end;
                    continue;
                }
                out.push(Token::Literal(data[i]));
                insert!(i);
                i += 1;
            } else {
                // deflate_fast: greedy; max_lazy caps *insertion* length.
                if len >= MIN_MATCH {
                    out.push(Token::Match { len: len as u16, dist: dist as u16 });
                    let end = i + len;
                    insert!(i);
                    if len <= p.max_lazy as usize {
                        let mut j = i + 1;
                        while j < end {
                            insert!(j);
                            j += 1;
                        }
                    }
                    i = end;
                } else {
                    out.push(Token::Literal(data[i]));
                    insert!(i);
                    i += 1;
                }
            }
        }
        if have_prev {
            out.push(Token::Match { len: prev_len as u16, dist: prev_dist as u16 });
            // Trailing bytes of the match are already past; tokenize() only
            // reaches here when the match ran to the end of input.
            let covered: usize = (n - 1 + prev_len).min(n); // defensive
            debug_assert!(covered <= n);
        }
    }
}

/// Length of the common prefix of `data[a..]` and `data[b..]`, capped.
///
/// §Perf: extends the match 8 bytes per iteration — one `u64` load pair, an
/// XOR, and `trailing_zeros` to locate the first differing byte — instead of
/// a byte-at-a-time walk; the scalar loop only finishes the sub-8-byte tail.
/// `pub` (doc-hidden) so the property suite can pit it against
/// [`reference::match_len_naive`].
#[doc(hidden)]
#[inline]
pub fn match_len(data: &[u8], a: usize, b: usize, cap: usize) -> usize {
    debug_assert!(a < b);
    // One shared SWAR implementation for every codec (PR 2); semantics and
    // the [`reference::match_len_naive`] oracle are unchanged.
    crate::util::match_finder::common_prefix(data, a, b, cap)
}

/// Byte-at-a-time oracle for [`match_len`] (property-tested equal).
#[doc(hidden)]
pub mod reference {
    pub fn match_len_naive(data: &[u8], a: usize, b: usize, cap: usize) -> usize {
        debug_assert!(a < b);
        let x = &data[a..];
        let y = &data[b..];
        let cap = cap.min(x.len()).min(y.len());
        let mut i = 0usize;
        while i < cap && x[i] == y[i] {
            i += 1;
        }
        i
    }
}

/// Expand tokens back to bytes (used by tests and as a matcher oracle).
pub fn expand_tokens(tokens: &[Token]) -> Vec<u8> {
    let mut out = Vec::new();
    for t in tokens {
        match *t {
            Token::Literal(b) => out.push(b),
            Token::Match { len, dist } => {
                let start = out.len() - dist as usize;
                for k in 0..len as usize {
                    out.push(out[start + k]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deflate::tuning::{Flavor, Tuning};
    use crate::util::rng::Rng;

    fn check_tokens_valid(data: &[u8], tokens: &[Token]) {
        let mut pos = 0usize;
        for t in tokens {
            match *t {
                Token::Literal(b) => {
                    assert_eq!(data[pos], b);
                    pos += 1;
                }
                Token::Match { len, dist } => {
                    let (len, dist) = (len as usize, dist as usize);
                    assert!((MIN_MATCH..=MAX_MATCH).contains(&len), "len {len}");
                    assert!(dist >= 1 && dist <= WINDOW_SIZE && dist <= pos, "dist {dist} pos {pos}");
                    for k in 0..len {
                        assert_eq!(data[pos + k], data[pos - dist + k], "match body");
                    }
                    pos += len;
                }
            }
        }
        assert_eq!(pos, data.len(), "tokens must cover input exactly");
    }

    fn all_tunings() -> Vec<Tuning> {
        let mut v = Vec::new();
        for flavor in [Flavor::Reference, Flavor::Cloudflare] {
            for level in [1u8, 3, 4, 6, 9] {
                v.push(Tuning::new(flavor, level));
            }
        }
        v
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut m = Matcher::new();
        let mut out = Vec::new();
        for t in all_tunings() {
            for data in [&b""[..], b"a", b"ab", b"abc", b"aaaa"] {
                m.tokenize(data, &t, &mut out);
                check_tokens_valid(data, &out);
                assert_eq!(expand_tokens(&out), data);
            }
        }
    }

    #[test]
    fn repetitive_input_finds_matches() {
        let data: Vec<u8> = b"abcabcabcabcabcabcabcabcabcabc".to_vec();
        let mut m = Matcher::new();
        let mut out = Vec::new();
        for t in all_tunings() {
            m.tokenize(&data, &t, &mut out);
            check_tokens_valid(&data, &out);
            let matches = out.iter().filter(|t| matches!(t, Token::Match { .. })).count();
            assert!(matches >= 1, "{}: no matches found", t.label());
        }
    }

    #[test]
    fn long_runs_capped_at_max_match() {
        let data = vec![0u8; 10_000];
        let mut m = Matcher::new();
        let mut out = Vec::new();
        for t in all_tunings() {
            m.tokenize(&data, &t, &mut out);
            check_tokens_valid(&data, &out);
            // A 10_000-byte zero run should be mostly MAX_MATCH matches.
            let toks = out.len();
            assert!(toks < 100, "{}: {toks} tokens for 10k zeros", t.label());
        }
    }

    #[test]
    fn random_data_roundtrips() {
        let mut rng = Rng::new(0x17A9);
        let mut m = Matcher::new();
        let mut out = Vec::new();
        for _ in 0..30 {
            let n = rng.range(0, 20_000);
            // Mix of random and structured data.
            let mut data = rng.bytes(n);
            if n > 100 {
                let span = rng.range(10, n / 2);
                let src = rng.range(0, n - span - 1);
                let dst = rng.range(0, n - span - 1);
                data.copy_within(src..src + span, dst);
            }
            for t in all_tunings() {
                m.tokenize(&data, &t, &mut out);
                check_tokens_valid(&data, &out);
            }
        }
    }

    #[test]
    fn higher_levels_do_not_regress_much() {
        // On compressible data, level 9 should produce <= tokens than level 1.
        let mut rng = Rng::new(0x17AA);
        let mut base = Vec::new();
        for _ in 0..200 {
            base.extend_from_slice(b"event_data:");
            base.extend_from_slice(&rng.bytes(8));
        }
        let mut m = Matcher::new();
        let mut t1 = Vec::new();
        let mut t9 = Vec::new();
        m.tokenize(&base, &Tuning::new(Flavor::Reference, 1), &mut t1);
        m.tokenize(&base, &Tuning::new(Flavor::Reference, 9), &mut t9);
        assert!(t9.len() <= t1.len() + t1.len() / 10, "l9 {} vs l1 {}", t9.len(), t1.len());
    }

    #[test]
    fn window_limit_respected() {
        // A repeat at distance > 32768 must NOT be found as a match.
        let mut data = vec![0xAAu8; 40_000];
        // Make the middle unique noise so the only long match is far away.
        let mut rng = Rng::new(5);
        for i in 200..39_800 {
            data[i] = (rng.next_u64() & 0xFF) as u8;
        }
        let mut m = Matcher::new();
        let mut out = Vec::new();
        m.tokenize(&data, &Tuning::new(Flavor::Reference, 9), &mut out);
        check_tokens_valid(&data, &out); // check_tokens_valid enforces dist<=pos & window
    }
}
