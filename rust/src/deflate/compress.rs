//! Raw DEFLATE (RFC 1951) compression: token stream → bit stream with
//! per-block choice of stored / fixed-Huffman / dynamic-Huffman encoding,
//! like zlib's `_tr_flush_block`.

use super::consts::*;
use super::huffman::{build_code_lengths, canonical_codes};
use super::matcher::{Matcher, Token};
use super::tuning::Tuning;
use crate::util::bitio::BitWriter;

/// Tokens per block before we flush (zlib uses a 16K-symbol buffer; bigger
/// blocks amortize tree headers better on our basket-sized inputs).
const BLOCK_TOKENS: usize = 48 * 1024;
/// Stored blocks cap at 65535 bytes.
const MAX_STORED: usize = 65_535;

/// Compress `data` as a raw DEFLATE stream at the given tuning.
pub fn deflate(data: &[u8], tuning: &Tuning) -> Vec<u8> {
    let mut matcher = Matcher::new();
    let mut tokens = Vec::new();
    deflate_with(data, tuning, &mut matcher, &mut tokens)
}

/// Compress `buf[start..]` with `buf[..start]` as a preset dictionary
/// (matchable, not emitted) — the RFC 1950 FDICT mechanism the paper's §3
/// points at ("the generated dictionaries are useable for ZLIB ... as
/// well").
pub fn deflate_dict(buf: &[u8], start: usize, tuning: &Tuning) -> Vec<u8> {
    let mut matcher = Matcher::new();
    let mut tokens = Vec::new();
    let mut w = BitWriter::with_capacity((buf.len() - start) / 2 + 64);
    if buf.len() == start {
        write_stored_blocks(&mut w, &[], true);
        return w.finish();
    }
    matcher.tokenize_from(buf, start, tuning, &mut tokens);
    let mut start_tok = 0usize;
    let mut start_byte = start;
    while start_tok < tokens.len() {
        let end_tok = (start_tok + BLOCK_TOKENS).min(tokens.len());
        let span: usize = tokens[start_tok..end_tok]
            .iter()
            .map(|t| match t {
                Token::Literal(_) => 1usize,
                Token::Match { len, .. } => *len as usize,
            })
            .sum();
        let is_final = end_tok == tokens.len();
        write_block_with(&mut w, &tokens[start_tok..end_tok], &buf[start_byte..start_byte + span], is_final, true);
        start_tok = end_tok;
        start_byte += span;
    }
    w.finish()
}

/// Compress with caller-provided scratch (hot-path variant: no per-call
/// allocations beyond the output).
pub fn deflate_with(
    data: &[u8],
    tuning: &Tuning,
    matcher: &mut Matcher,
    tokens: &mut Vec<Token>,
) -> Vec<u8> {
    deflate_with_emitter(data, tuning, matcher, tokens, true)
}

/// Reference encoder: identical match finding and tree construction, but
/// per-field token emission (one `write_bits` per Huffman code / extra-bits
/// field). The fused fast path must stay byte-identical to this — property
/// tested in `rust/tests/prop_codecs.rs`.
#[doc(hidden)]
pub fn deflate_reference(data: &[u8], tuning: &Tuning) -> Vec<u8> {
    let mut matcher = Matcher::new();
    let mut tokens = Vec::new();
    deflate_with_emitter(data, tuning, &mut matcher, &mut tokens, false)
}

fn deflate_with_emitter(
    data: &[u8],
    tuning: &Tuning,
    matcher: &mut Matcher,
    tokens: &mut Vec<Token>,
    fused: bool,
) -> Vec<u8> {
    let mut w = BitWriter::with_capacity(data.len() / 2 + 64);
    if data.is_empty() {
        // A single final stored block of length 0.
        write_stored_blocks(&mut w, data, true);
        return w.finish();
    }
    matcher.tokenize(data, tuning, tokens);

    // Split the token stream into blocks, tracking the input span covered by
    // each so stored-block fallback knows which bytes to copy.
    let mut start_tok = 0usize;
    let mut start_byte = 0usize;
    while start_tok < tokens.len() {
        let end_tok = (start_tok + BLOCK_TOKENS).min(tokens.len());
        let span: usize = tokens[start_tok..end_tok]
            .iter()
            .map(|t| match t {
                Token::Literal(_) => 1usize,
                Token::Match { len, .. } => *len as usize,
            })
            .sum();
        let is_final = end_tok == tokens.len();
        write_block_with(
            &mut w,
            &tokens[start_tok..end_tok],
            &data[start_byte..start_byte + span],
            is_final,
            fused,
        );
        start_tok = end_tok;
        start_byte += span;
    }
    w.finish()
}

/// "Level 0": no compression — stored blocks only (ROOT compression level 0
/// disables compression entirely, but the zlib wrapper still frames it).
pub fn deflate_stored(data: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::with_capacity(data.len() + data.len() / MAX_STORED * 5 + 16);
    write_stored_blocks(&mut w, data, true);
    w.finish()
}

fn write_stored_blocks(w: &mut BitWriter, data: &[u8], finish: bool) {
    let mut chunks = data.chunks(MAX_STORED).peekable();
    if data.is_empty() {
        w.write_bits(finish as u64, 1);
        w.write_bits(0b00, 2); // BTYPE=00
        w.align_byte();
        w.write_bytes(&0u16.to_le_bytes());
        w.write_bytes(&0xFFFFu16.to_le_bytes());
        return;
    }
    while let Some(chunk) = chunks.next() {
        let last = chunks.peek().is_none() && finish;
        w.write_bits(last as u64, 1);
        w.write_bits(0b00, 2);
        w.align_byte();
        let len = chunk.len() as u16;
        w.write_bytes(&len.to_le_bytes());
        w.write_bytes(&(!len).to_le_bytes());
        w.write_bytes(chunk);
    }
}

/// Fixed literal/length code lengths (RFC 1951 §3.2.6).
fn fixed_litlen_lengths() -> Vec<u8> {
    let mut l = vec![0u8; 288];
    for (i, v) in l.iter_mut().enumerate() {
        *v = match i {
            0..=143 => 8,
            144..=255 => 9,
            256..=279 => 7,
            _ => 8,
        };
    }
    l
}

fn fixed_dist_lengths() -> Vec<u8> {
    vec![5u8; 30]
}

struct Trees {
    lit_lengths: Vec<u8>,
    lit_codes: Vec<u16>,
    dist_lengths: Vec<u8>,
    dist_codes: Vec<u16>,
}

fn histogram(tokens: &[Token]) -> ([u64; NUM_LITLEN], [u64; NUM_DIST]) {
    let mut lit = [0u64; NUM_LITLEN];
    let mut dist = [0u64; NUM_DIST];
    for t in tokens {
        match *t {
            Token::Literal(b) => lit[b as usize] += 1,
            Token::Match { len, dist: d } => {
                lit[257 + length_code(len)] += 1;
                dist[dist_code(d)] += 1;
            }
        }
    }
    lit[256] += 1; // end-of-block
    (lit, dist)
}

/// Cost in bits of encoding `tokens` with the given code lengths.
fn body_cost(tokens_hist: &([u64; NUM_LITLEN], [u64; NUM_DIST]), lit_len: &[u8], dist_len: &[u8]) -> u64 {
    let (lit, dist) = tokens_hist;
    let mut bits = 0u64;
    for (sym, &count) in lit.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let extra = if sym > 256 { LENGTH_TABLE[sym - 257].1 as u64 } else { 0 };
        bits += count * (lit_len[sym] as u64 + extra);
    }
    for (sym, &count) in dist.iter().enumerate() {
        if count == 0 {
            continue;
        }
        bits += count * (dist_len[sym] as u64 + DIST_TABLE[sym].1 as u64);
    }
    bits
}

fn write_block_with(w: &mut BitWriter, tokens: &[Token], raw: &[u8], is_final: bool, fused: bool) {
    let hist = histogram(tokens);
    let (lit_hist, dist_hist) = &hist;

    // Dynamic trees.
    let mut dyn_lit = build_code_lengths(lit_hist, 15);
    dyn_lit.resize(NUM_LITLEN, 0);
    let mut dyn_dist = build_code_lengths(dist_hist, 15);
    dyn_dist.resize(NUM_DIST, 0);
    // DEFLATE requires at least one distance code length transmitted; if no
    // matches, send a single zero-length slot (handled by HDIST below). Also
    // if exactly one distance code is used it gets length 1 — legal.
    let (clc_payload, clc_lengths, clc_codes, header_bits) = encode_tree_header(&dyn_lit, &dyn_dist);

    let fixed_lit = fixed_litlen_lengths();
    let fixed_dist = fixed_dist_lengths();

    let dyn_cost = 3 + header_bits + body_cost(&hist, &dyn_lit, &dyn_dist);
    let fix_cost = 3 + body_cost(&hist, &fixed_lit, &fixed_dist);
    let stored_cost = 3 + 32 + (raw.len() as u64) * 8 + 7 /* alignment upper bound */
        + (raw.len() / MAX_STORED) as u64 * 40;

    if stored_cost < dyn_cost && stored_cost < fix_cost {
        write_stored_blocks(w, raw, is_final);
        return;
    }

    if fix_cost <= dyn_cost {
        w.write_bits(is_final as u64, 1);
        w.write_bits(0b01, 2);
        let lit_codes = canonical_codes(&fixed_lit);
        let dist_codes = canonical_codes(&fixed_dist);
        let trees = Trees {
            lit_lengths: fixed_lit,
            lit_codes,
            dist_lengths: fixed_dist,
            dist_codes,
        };
        write_body(w, tokens, &trees, fused);
    } else {
        w.write_bits(is_final as u64, 1);
        w.write_bits(0b10, 2);
        write_tree_header(w, &clc_payload, &clc_lengths, &clc_codes, &dyn_lit, &dyn_dist);
        let lit_codes = canonical_codes(&dyn_lit);
        let dist_codes = canonical_codes(&dyn_dist);
        let trees = Trees {
            lit_lengths: dyn_lit,
            lit_codes,
            dist_lengths: dyn_dist,
            dist_codes,
        };
        write_body(w, tokens, &trees, fused);
    }
}

/// Code-length-code symbol: (symbol, extra bits value, extra bit count).
type ClcSym = (u8, u8, u8);

/// RLE-encode the two trees' lengths into the code-length alphabet
/// (symbols 0..15 literal, 16 repeat prev 3–6, 17 zeros 3–10, 18 zeros
/// 11–138) and build the CLC huffman code. Returns payload, clc lengths,
/// clc codes, and total header bit cost.
fn encode_tree_header(lit: &[u8], dist: &[u8]) -> (Vec<ClcSym>, [u8; 19], Vec<u16>, u64) {
    let hlit = trailing_trim(lit, 257);
    let hdist = trailing_trim(dist, 1);
    let mut seq: Vec<u8> = Vec::with_capacity(hlit + hdist);
    seq.extend_from_slice(&lit[..hlit]);
    seq.extend_from_slice(&dist[..hdist]);

    let mut payload: Vec<ClcSym> = Vec::new();
    let mut i = 0usize;
    while i < seq.len() {
        let v = seq[i];
        let mut run = 1usize;
        while i + run < seq.len() && seq[i + run] == v {
            run += 1;
        }
        if v == 0 {
            let mut r = run;
            while r >= 11 {
                let take = r.min(138);
                payload.push((18, (take - 11) as u8, 7));
                r -= take;
            }
            if r >= 3 {
                payload.push((17, (r - 3) as u8, 3));
                r = 0;
            }
            for _ in 0..r {
                payload.push((0, 0, 0));
            }
        } else {
            payload.push((v, 0, 0));
            let mut r = run - 1;
            while r >= 3 {
                let take = r.min(6);
                payload.push((16, (take - 3) as u8, 2));
                r -= take;
            }
            for _ in 0..r {
                payload.push((v, 0, 0));
            }
        }
        i += run;
    }

    let mut clc_freq = [0u64; 19];
    for &(s, _, _) in &payload {
        clc_freq[s as usize] += 1;
    }
    let clc_lengths_v = build_code_lengths(&clc_freq, 7);
    let mut clc_lengths = [0u8; 19];
    clc_lengths[..clc_lengths_v.len()].copy_from_slice(&clc_lengths_v);
    let clc_codes = canonical_codes(&clc_lengths);

    // HCLEN: number of CLC lengths transmitted, in CLC_ORDER, min 4.
    let mut hclen = 19;
    while hclen > 4 && clc_lengths[CLC_ORDER[hclen - 1]] == 0 {
        hclen -= 1;
    }
    let mut bits = 5 + 5 + 4 + 3 * hclen as u64;
    for &(s, _, extra) in &payload {
        bits += clc_lengths[s as usize] as u64 + extra as u64;
    }
    (payload, clc_lengths, clc_codes, bits)
}

fn trailing_trim(lengths: &[u8], min: usize) -> usize {
    let mut n = lengths.len();
    while n > min && lengths[n - 1] == 0 {
        n -= 1;
    }
    n
}

fn write_tree_header(
    w: &mut BitWriter,
    payload: &[ClcSym],
    clc_lengths: &[u8; 19],
    clc_codes: &[u16],
    lit: &[u8],
    dist: &[u8],
) {
    let hlit = trailing_trim(lit, 257);
    let hdist = trailing_trim(dist, 1);
    let mut hclen = 19;
    while hclen > 4 && clc_lengths[CLC_ORDER[hclen - 1]] == 0 {
        hclen -= 1;
    }
    w.write_bits((hlit - 257) as u64, 5);
    w.write_bits((hdist - 1) as u64, 5);
    w.write_bits((hclen - 4) as u64, 4);
    for k in 0..hclen {
        w.write_bits(clc_lengths[CLC_ORDER[k]] as u64, 3);
    }
    for &(s, extra_val, extra_bits) in payload {
        w.write_bits(clc_codes[s as usize] as u64, clc_lengths[s as usize] as u32);
        if extra_bits > 0 {
            w.write_bits(extra_val as u64, extra_bits as u32);
        }
    }
}

fn write_body(w: &mut BitWriter, tokens: &[Token], trees: &Trees, fused: bool) {
    if fused {
        write_body_fused(w, tokens, trees);
    } else {
        write_body_reference(w, tokens, trees);
    }
}

/// §Perf fast path: every match token costs exactly ONE `write_bits` call.
///
/// DEFLATE transmits a match as four LSB-first fields — length code, length
/// extra bits, distance code, distance extra bits. Because the bit writer is
/// LSB-first, writing fields A then B is identical to writing
/// `A | (B << bits(A))` in one call; the whole token is at most
/// 15+5+15+13 = 48 bits, under the writer's 57-bit limit. The per-length
/// (code ‖ extra) halves are precomputed into a 256-entry fused table per
/// block; the distance half is fused inline from the (much smaller) distance
/// code tables. Byte-identical to [`write_body_reference`] by construction
/// and by property test.
fn write_body_fused(w: &mut BitWriter, tokens: &[Token], trees: &Trees) {
    // len-3 -> (huffman code | extra value << code_len, total bit count).
    let mut len_fused = [(0u32, 0u8); 256];
    for len in 3u16..=258 {
        let lc = length_code(len);
        let s = 257 + lc;
        let (lbase, lextra) = LENGTH_TABLE[lc];
        let nbits = trees.lit_lengths[s];
        let bits = trees.lit_codes[s] as u32 | (((len - lbase) as u32) << nbits);
        len_fused[(len - 3) as usize] = (bits, nbits + lextra);
    }
    for t in tokens {
        match *t {
            Token::Literal(b) => {
                let s = b as usize;
                w.write_bits(trees.lit_codes[s] as u64, trees.lit_lengths[s] as u32);
            }
            Token::Match { len, dist } => {
                let (lbits, ln) = len_fused[(len - 3) as usize];
                let dc = dist_code(dist);
                let (dbase, dextra) = DIST_TABLE[dc];
                let dn = trees.dist_lengths[dc] as u32;
                let dbits = trees.dist_codes[dc] as u64 | (((dist - dbase) as u64) << dn);
                w.write_bits(
                    lbits as u64 | (dbits << ln),
                    ln as u32 + dn + dextra as u32,
                );
            }
        }
    }
    // End of block.
    w.write_bits(trees.lit_codes[256] as u64, trees.lit_lengths[256] as u32);
}

/// Reference per-field emission (one `write_bits` per Huffman/extra field);
/// oracle for the fused fast path.
fn write_body_reference(w: &mut BitWriter, tokens: &[Token], trees: &Trees) {
    for t in tokens {
        match *t {
            Token::Literal(b) => {
                let s = b as usize;
                w.write_bits(trees.lit_codes[s] as u64, trees.lit_lengths[s] as u32);
            }
            Token::Match { len, dist } => {
                let lc = length_code(len);
                let s = 257 + lc;
                let (lbase, lextra) = LENGTH_TABLE[lc];
                w.write_bits(trees.lit_codes[s] as u64, trees.lit_lengths[s] as u32);
                if lextra > 0 {
                    w.write_bits((len - lbase) as u64, lextra as u32);
                }
                let dc = dist_code(dist);
                let (dbase, dextra) = DIST_TABLE[dc];
                w.write_bits(trees.dist_codes[dc] as u64, trees.dist_lengths[dc] as u32);
                if dextra > 0 {
                    w.write_bits((dist - dbase) as u64, dextra as u32);
                }
            }
        }
    }
    // End of block.
    w.write_bits(trees.lit_codes[256] as u64, trees.lit_lengths[256] as u32);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deflate::tuning::{Flavor, Tuning};

    // Round-trip tests live in inflate.rs / interop tests; here we check
    // structural properties only.

    #[test]
    fn stored_empty() {
        let out = deflate_stored(b"");
        // 1 bit BFINAL + 2 bits BTYPE + pad + LEN/NLEN = 5 bytes.
        assert_eq!(out.len(), 5);
        assert_eq!(out[0] & 0b111, 0b001); // final, stored
    }

    #[test]
    fn stored_roundtrip_framing() {
        let data = vec![7u8; 100_000]; // forces 2 stored blocks
        let out = deflate_stored(&data);
        assert!(out.len() > data.len()); // stored adds framing
        assert!(out.len() < data.len() + 64);
    }

    #[test]
    fn compressible_data_shrinks() {
        let data = vec![42u8; 65_536];
        for level in [1u8, 6, 9] {
            let out = deflate(&data, &Tuning::new(Flavor::Reference, level));
            assert!(out.len() < 1024, "level {level}: {} bytes", out.len());
        }
    }

    #[test]
    fn incompressible_data_bounded_expansion() {
        let mut rng = crate::util::rng::Rng::new(1);
        let data = rng.bytes(65_536);
        let out = deflate(&data, &Tuning::new(Flavor::Cloudflare, 6));
        // Stored fallback keeps expansion tiny.
        assert!(out.len() <= data.len() + 5 * (data.len() / MAX_STORED + 1) + 16);
    }

    #[test]
    fn fused_emission_is_byte_identical_to_reference() {
        let mut rng = crate::util::rng::Rng::new(0xF0_5ED);
        let mut corpus: Vec<Vec<u8>> = vec![
            vec![],
            b"abcabcabcabcabcabc".to_vec(),
            vec![0u8; 70_000],
            (0u32..10_000).flat_map(|i| (i * 7).to_be_bytes()).collect(),
        ];
        corpus.push(rng.bytes(40_000));
        for data in &corpus {
            for flavor in [Flavor::Reference, Flavor::Cloudflare] {
                for level in [1u8, 6, 9] {
                    let t = Tuning::new(flavor, level);
                    assert_eq!(
                        deflate(data, &t),
                        deflate_reference(data, &t),
                        "{} on {} bytes",
                        t.label(),
                        data.len()
                    );
                }
            }
        }
    }

    #[test]
    fn trailing_trim_bounds() {
        let mut l = vec![0u8; 286];
        assert_eq!(trailing_trim(&l, 257), 257);
        l[260] = 5;
        assert_eq!(trailing_trim(&l, 257), 261);
        let d = vec![0u8; 30];
        assert_eq!(trailing_trim(&d, 1), 1);
    }
}
