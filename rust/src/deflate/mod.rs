//! From-scratch DEFLATE / zlib (RFC 1950/1951) implementation with two
//! tuning profiles: reference zlib and the Cloudflare fork whose patch set
//! the paper contributed to ROOT 6.18.00 (§2.1, Figs 4-5).
//!
//! Format-compatible with any zlib: see `rust/tests/interop_flate2.rs`.

pub mod compress;
pub mod consts;
pub mod huffman;
pub mod inflate;
pub mod matcher;
pub mod tuning;
pub mod zlib;

pub use inflate::{inflate, InflateError};
pub use tuning::{Flavor, Tuning};
pub use zlib::{zlib_compress, zlib_decompress};
