//! From-scratch DEFLATE / zlib (RFC 1950/1951) implementation with two
//! tuning profiles: reference zlib and the Cloudflare fork whose patch set
//! the paper contributed to ROOT 6.18.00 (§2.1, Figs 4-5).
//!
//! Format-compatible with any zlib: see `rust/tests/interop_flate2.rs`
//! (run with `--features interop-flate2`).
//!
//! # §Perf fast paths (hot-path throughput overhaul)
//!
//! Four classic scalar fast paths, each with an in-tree naive reference it
//! must stay **bit-identical** to (asserted by `rust/tests/prop_codecs.rs`
//! across the fuzz corpus):
//!
//! * **Match extension** (`matcher::match_len`): extends candidate matches
//!   8 bytes per step via `u64` XOR + `trailing_zeros`; oracle:
//!   `matcher::reference::match_len_naive`. Chain walking is shortened by
//!   zlib's `good_length`/`nice_length`/`max_chain` knobs from
//!   [`tuning::LevelParams`].
//! * **Fused token emission** (`compress`): a 256-entry per-block table
//!   fuses each length's Huffman code with its extra bits, and the distance
//!   half fuses inline, so one LSB-first `write_bits` call emits an entire
//!   match token (≤48 bits); oracle: `compress::deflate_reference`
//!   (per-field emission).
//! * **Word-flush bit writer** (`crate::util::bitio::BitWriter`): flushes
//!   whole 64-bit words instead of byte-at-a-time; oracle:
//!   `bitio::reference::NaiveBitWriter`.
//! * **Multi-symbol inflate loop** (`inflate` + `huffman::Decoder::
//!   decode_fast`): while ≥64 real bits and ≥258 output bytes of headroom
//!   remain, whole tokens decode with no per-symbol truncation/limit
//!   checks, exploiting the reader's 57-bit refill; literal runs batch
//!   several symbols per window with only the two cheap checks re-run
//!   between them. The careful per-symbol loop finishes the tail, so error
//!   behavior on malformed input is unchanged; oracle:
//!   `inflate::inflate_reference` (fast loop disabled), property-tested
//!   byte-identical across the fuzz corpus.
//!
//! Equivalence guarantee: fast and reference paths produce byte-identical
//! streams (same tokens, same trees, same bits); on decode the fast loop is
//! a check-hoisted restriction of the careful loop. Compressed output is
//! therefore byte-for-byte reproducible across this PR.

pub mod compress;
pub mod consts;
pub mod huffman;
pub mod inflate;
pub mod matcher;
pub mod tuning;
pub mod zlib;

pub use inflate::{inflate, InflateError};
pub use tuning::{Flavor, Tuning};
pub use zlib::{zlib_compress, zlib_decompress};
