//! LZMA-style compressor: large-window LZ77 parse + adaptive range coding
//! with contextual models — literals conditioned on the previous byte
//! (lc=3), match flags on position alignment (pb=2), lengths and distance
//! slots on binary trees. A faithful simplification of the LZMA scheme (no
//! rep-distance slots); see DESIGN.md's honesty box.
//!
//! This codec holds LZMA's position in the paper's survey: best compression
//! ratio, slowest compression/decompression (Figs 2-3).

use super::rangecoder::{BitModel, RangeDecoder, RangeEncoder};
use crate::zstd::compress::{value_code, value_decode};
use crate::zstd::matcher::{ChainMatcher, SearchParams, MIN_MATCH};
use crate::util::varint::{get_uvarint, put_uvarint};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LzmaError(pub &'static str);

impl std::fmt::Display for LzmaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lzma: {}", self.0)
    }
}
impl std::error::Error for LzmaError {}

const E: fn(&'static str) -> LzmaError = LzmaError;

/// lc = 3 literal context bits, pb = 2 position bits (LZMA defaults).
const LC: u32 = 3;
const PB: u32 = 2;
const POS_STATES: usize = 1 << PB;
/// Value codes go up to 32 (see zstd::compress::value_code); tree of 6 bits.
const CODE_TREE_BITS: u32 = 6;

struct Models {
    is_match: Vec<BitModel>,
    /// 8-bit literal trees, one per lc context.
    literal: Vec<BitModel>,
    len_code: Vec<BitModel>,
    dist_code: Vec<BitModel>,
    /// Adaptive models for the low 4 "align" bits of large distances.
    align: Vec<BitModel>,
}

impl Models {
    fn new() -> Self {
        Self {
            is_match: vec![BitModel::default(); POS_STATES],
            literal: vec![BitModel::default(); (1 << LC) * 0x100],
            len_code: vec![BitModel::default(); 1 << CODE_TREE_BITS],
            dist_code: vec![BitModel::default(); 1 << CODE_TREE_BITS],
            align: vec![BitModel::default(); 16],
        }
    }

    #[inline]
    fn lit_ctx(prev_byte: u8) -> usize {
        ((prev_byte >> (8 - LC)) as usize) * 0x100
    }
}

/// Search effort per ROOT level: LZMA always searches deeper than the
/// zstd-style codec at the same nominal level.
fn params_for_level(level: u8) -> SearchParams {
    let base = SearchParams::for_level(level.clamp(1, 9));
    SearchParams { depth: base.depth * 4, lazy: true, nice_len: base.nice_len * 2, ..base }
}

/// Compress `src`; output is self-framed (uvarint raw length + rc payload).
pub fn lzma_compress(src: &[u8], level: u8) -> Vec<u8> {
    let mut matcher = ChainMatcher::new();
    let mut seqs = Vec::new();
    let mut literals = Vec::new();
    matcher.parse(src, 0, &params_for_level(level), &mut seqs, &mut literals);

    let mut out = Vec::with_capacity(src.len() / 3 + 16);
    put_uvarint(&mut out, src.len() as u64);

    let mut enc = RangeEncoder::new();
    let mut m = Models::new();
    let mut lit_pos = 0usize;
    let mut pos = 0usize; // uncompressed position (for pos_state)
    let mut prev_byte = 0u8;

    let mut encode_literal = |enc: &mut RangeEncoder, m: &mut Models, b: u8, prev: u8, pos: usize| {
        let ps = pos & (POS_STATES - 1);
        enc.encode_bit(&mut m.is_match[ps], 0);
        let ctx = Models::lit_ctx(prev);
        // 8-bit bit-tree over the context slice.
        let probs = &mut m.literal[ctx..ctx + 0x100];
        enc.encode_tree(probs, 8, b as u32);
    };

    for s in &seqs {
        for _ in 0..s.lit_len {
            let b = literals[lit_pos];
            lit_pos += 1;
            encode_literal(&mut enc, &mut m, b, prev_byte, pos);
            prev_byte = b;
            pos += 1;
        }
        // Match: flag 1, then len code + dist code trees + direct extras.
        let ps = pos & (POS_STATES - 1);
        enc.encode_bit(&mut m.is_match[ps], 1);
        let (lc, le, ln) = value_code(s.match_len - MIN_MATCH as u32);
        enc.encode_tree(&mut m.len_code, CODE_TREE_BITS, lc as u32);
        if ln > 0 {
            enc.encode_direct(le, ln);
        }
        let (dc, de, dn) = value_code(s.offset - 1);
        enc.encode_tree(&mut m.dist_code, CODE_TREE_BITS, dc as u32);
        if dn > 4 {
            // High bits direct, low 4 bits through the adaptive align tree.
            enc.encode_direct(de >> 4, dn - 4);
            enc.encode_tree(&mut m.align, 4, de & 0xF);
        } else if dn > 0 {
            enc.encode_direct(de, dn);
        }
        pos += s.match_len as usize;
        // prev_byte after a match = last byte of the match; recover it from
        // literals? Not available — use src directly.
        prev_byte = src[pos - 1];
    }
    // Trailing literals.
    while lit_pos < literals.len() {
        let b = literals[lit_pos];
        lit_pos += 1;
        encode_literal(&mut enc, &mut m, b, prev_byte, pos);
        prev_byte = b;
        pos += 1;
    }
    debug_assert_eq!(pos, src.len());
    out.extend_from_slice(&enc.finish());
    out
}

/// Decompress. `max_out` bounds memory on untrusted input.
pub fn lzma_decompress(src: &[u8], max_out: usize) -> Result<Vec<u8>, LzmaError> {
    let (raw_len, hdr) = get_uvarint(src).ok_or(E("truncated header"))?;
    let raw_len = raw_len as usize;
    if raw_len > max_out {
        return Err(E("output limit exceeded"));
    }
    let payload = &src[hdr..];
    if raw_len == 0 {
        return Ok(Vec::new());
    }
    if payload.len() < 5 {
        return Err(E("payload too short"));
    }
    let mut dec = RangeDecoder::new(payload);
    let mut m = Models::new();
    let mut out: Vec<u8> = Vec::with_capacity(raw_len);
    let mut prev_byte = 0u8;

    while out.len() < raw_len {
        let ps = out.len() & (POS_STATES - 1);
        if dec.decode_bit(&mut m.is_match[ps]) == 0 {
            let ctx = Models::lit_ctx(prev_byte);
            let probs = &mut m.literal[ctx..ctx + 0x100];
            let b = dec.decode_tree(probs, 8) as u8;
            out.push(b);
            prev_byte = b;
        } else {
            let lc = dec.decode_tree(&mut m.len_code, CODE_TREE_BITS) as u16;
            if lc > 32 {
                return Err(E("bad length code"));
            }
            let le = if lc > 1 { dec.decode_direct(lc as u32 - 1) } else { 0 };
            let match_len = value_decode(lc, le) as usize + MIN_MATCH;
            let dc = dec.decode_tree(&mut m.dist_code, CODE_TREE_BITS) as u16;
            if dc > 32 {
                return Err(E("bad distance code"));
            }
            let dn = if dc > 0 { dc as u32 - 1 } else { 0 };
            let de = if dn > 4 {
                let hi = dec.decode_direct(dn - 4);
                let lo = dec.decode_tree(&mut m.align, 4);
                (hi << 4) | lo
            } else if dn > 0 {
                dec.decode_direct(dn)
            } else {
                0
            };
            let offset = value_decode(dc, de) as usize + 1;
            if offset > out.len() {
                return Err(E("offset beyond output"));
            }
            if out.len() + match_len > raw_len {
                return Err(E("match overruns declared size"));
            }
            copy_match(&mut out, offset, match_len);
            prev_byte = out[out.len() - 1];
        }
        if dec.overrun() {
            return Err(E("range coder payload exhausted"));
        }
    }
    Ok(out)
}

#[inline]
fn copy_match(out: &mut Vec<u8>, dist: usize, len: usize) {
    let start = out.len() - dist;
    if dist >= len {
        out.extend_from_within(start..start + len);
    } else {
        let mut rem = len;
        let mut src = start;
        while rem > 0 {
            let chunk = rem.min(out.len() - src);
            out.extend_from_within(src..src + chunk);
            src += chunk;
            rem -= chunk;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const MAX: usize = 64 << 20;

    fn roundtrip(data: &[u8], level: u8) {
        let c = lzma_compress(data, level);
        let d = lzma_decompress(&c, MAX).expect("decompress");
        assert_eq!(d, data, "level {level} n={}", data.len());
    }

    #[test]
    fn roundtrip_corpus() {
        let mut rng = Rng::new(0x12A);
        let mut corpus: Vec<Vec<u8>> = vec![
            vec![],
            b"q".to_vec(),
            b"lzma lzma lzma lzma".to_vec(),
            vec![0u8; 80_000],
        ];
        corpus.push((0u32..20_000).flat_map(|i| i.to_be_bytes()).collect());
        corpus.push(rng.bytes(40_000));
        for data in &corpus {
            for level in [1u8, 6, 9] {
                roundtrip(data, level);
            }
        }
    }

    #[test]
    fn best_ratio_of_all_codecs_on_structured_data() {
        // LZMA's survey position (Fig 2): highest ratio. Compare on
        // basket-like serialized structures.
        let mut rng = Rng::new(0x12B);
        let mut data = Vec::new();
        for i in 0..30_000u32 {
            data.extend_from_slice(&(i as f32 * 0.1).to_be_bytes());
            if i % 8 == 0 {
                data.extend_from_slice(&i.to_be_bytes());
            }
            if i % 50 == 0 {
                data.extend_from_slice(&rng.bytes(2));
            }
        }
        let l = lzma_compress(&data, 6).len();
        let z = crate::deflate::zlib_compress(&data, crate::deflate::Flavor::Cloudflare, 6).len();
        let s = crate::zstd::zstd_compress(&data, 6).len();
        assert!(l < z, "lzma {l} vs zlib {z}");
        assert!(l <= s + s / 20, "lzma {l} vs zstd {s}");
    }

    #[test]
    fn fuzz_roundtrip() {
        let mut rng = Rng::new(0x12C);
        for round in 0..40 {
            let n = rng.range(0, 20_000);
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                match rng.range(0, 2) {
                    0 => {
                        let b = (rng.next_u64() & 0xFF) as u8;
                        let r = rng.range(1, 200);
                        data.extend(std::iter::repeat(b).take(r));
                    }
                    1 => data.extend_from_slice(b"GenPart_pdgId"),
                    _ => {
                        let k = rng.range(1, 50);
                        let b = rng.bytes(k);
                        data.extend_from_slice(&b);
                    }
                }
            }
            data.truncate(n);
            roundtrip(&data, [1u8, 6, 9][round % 3]);
        }
    }

    #[test]
    fn garbage_never_panics() {
        let mut rng = Rng::new(0x12D);
        for _ in 0..300 {
            let n = rng.range(0, 300);
            let garbage = rng.bytes(n);
            let _ = lzma_decompress(&garbage, 1 << 20);
        }
    }

    #[test]
    fn truncation_rejected() {
        let data: Vec<u8> = (0u32..10_000).flat_map(|i| i.to_be_bytes()).collect();
        let c = lzma_compress(&data, 6);
        for cut in [3, c.len() / 2] {
            match lzma_decompress(&c[..cut], MAX) {
                Err(_) => {}
                Ok(d) => assert_ne!(d, data),
            }
        }
    }
}
