//! Binary range coder with adaptive probabilities — the LZMA entropy engine
//! (paper §2: LZMA "has more complex encoding techniques, such as use of a
//! range encoder (using a complex model for probability-based prediction)").
//!
//! Standard LZMA construction: 11-bit probabilities, adaptation shift 5,
//! 32-bit range with byte-wise normalization and carry propagation through
//! a cache byte.

/// Number of probability bits.
pub const PROB_BITS: u32 = 11;
pub const PROB_INIT: u16 = (1 << PROB_BITS) / 2;
const MOVE_BITS: u32 = 5;
const TOP: u32 = 1 << 24;

/// One adaptive binary probability.
#[derive(Debug, Clone, Copy)]
pub struct BitModel(pub u16);

impl Default for BitModel {
    fn default() -> Self {
        Self(PROB_INIT)
    }
}

impl BitModel {
    #[inline]
    fn update(&mut self, bit: u32) {
        if bit == 0 {
            self.0 += ((1 << PROB_BITS) - self.0) >> MOVE_BITS;
        } else {
            self.0 -= self.0 >> MOVE_BITS;
        }
    }
}

/// Range encoder.
pub struct RangeEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl Default for RangeEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl RangeEncoder {
    pub fn new() -> Self {
        Self { low: 0, range: u32::MAX, cache: 0, cache_size: 1, out: Vec::new() }
    }

    #[inline]
    pub fn encode_bit(&mut self, model: &mut BitModel, bit: u32) {
        let bound = (self.range >> PROB_BITS) * model.0 as u32;
        if bit == 0 {
            self.range = bound;
        } else {
            self.low += bound as u64;
            self.range -= bound;
        }
        model.update(bit);
        while self.range < TOP {
            self.shift_low();
            self.range <<= 8;
        }
    }

    /// Encode `n` bits without modelling (equiprobable).
    #[inline]
    pub fn encode_direct(&mut self, value: u32, n: u32) {
        for i in (0..n).rev() {
            self.range >>= 1;
            let bit = (value >> i) & 1;
            if bit != 0 {
                self.low += self.range as u64;
            }
            while self.range < TOP {
                self.shift_low();
                self.range <<= 8;
            }
        }
    }

    /// Bit-tree encode `value` with `n` bits, MSB-first, over `probs`
    /// (length `1 << n`).
    pub fn encode_tree(&mut self, probs: &mut [BitModel], n: u32, value: u32) {
        let mut m = 1usize;
        for i in (0..n).rev() {
            let bit = (value >> i) & 1;
            self.encode_bit(&mut probs[m], bit);
            m = (m << 1) | bit as usize;
        }
    }

    fn shift_low(&mut self) {
        if self.low < 0xFF00_0000 || self.low >= (1 << 32) {
            let carry = (self.low >> 32) as u8;
            let mut c = self.cache;
            loop {
                self.out.push(c.wrapping_add(carry));
                c = 0xFF;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & 0xFFFF_FFFF;
    }

    /// Flush and return the encoded bytes.
    pub fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

/// Range decoder.
pub struct RangeDecoder<'a> {
    range: u32,
    code: u32,
    data: &'a [u8],
    pos: usize,
    overrun: bool,
}

impl<'a> RangeDecoder<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        let mut d = Self { range: u32::MAX, code: 0, data, pos: 1, overrun: false };
        for _ in 0..4 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        if self.pos < self.data.len() {
            let b = self.data[self.pos];
            self.pos += 1;
            b
        } else {
            self.overrun = true;
            0
        }
    }

    /// True if the decoder consumed synthetic bytes past the end.
    pub fn overrun(&self) -> bool {
        self.overrun
    }

    #[inline]
    pub fn decode_bit(&mut self, model: &mut BitModel) -> u32 {
        let bound = (self.range >> PROB_BITS) * model.0 as u32;
        let bit = if self.code < bound {
            self.range = bound;
            0
        } else {
            self.code -= bound;
            self.range -= bound;
            1
        };
        model.update(bit);
        while self.range < TOP {
            self.code = (self.code << 8) | self.next_byte() as u32;
            self.range <<= 8;
        }
        bit
    }

    #[inline]
    pub fn decode_direct(&mut self, n: u32) -> u32 {
        let mut v = 0u32;
        for _ in 0..n {
            self.range >>= 1;
            let bit = if self.code >= self.range {
                self.code -= self.range;
                1
            } else {
                0
            };
            v = (v << 1) | bit;
            while self.range < TOP {
                self.code = (self.code << 8) | self.next_byte() as u32;
                self.range <<= 8;
            }
        }
        v
    }

    pub fn decode_tree(&mut self, probs: &mut [BitModel], n: u32) -> u32 {
        let mut m = 1usize;
        for _ in 0..n {
            let bit = self.decode_bit(&mut probs[m]);
            m = (m << 1) | bit as usize;
        }
        (m as u32) - (1 << n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bit_roundtrip_skewed() {
        let mut rng = Rng::new(0x7A);
        let bits: Vec<u32> = (0..50_000).map(|_| rng.chance(0.03) as u32).collect();
        let mut enc = RangeEncoder::new();
        let mut m = BitModel::default();
        for &b in &bits {
            enc.encode_bit(&mut m, b);
        }
        let payload = enc.finish();
        // Skewed bits should compress far below 1 bit each.
        assert!(payload.len() < bits.len() / 30, "{} bytes", payload.len());
        let mut dec = RangeDecoder::new(&payload);
        let mut m = BitModel::default();
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(dec.decode_bit(&mut m), b, "bit {i}");
        }
        assert!(!dec.overrun());
    }

    #[test]
    fn direct_roundtrip() {
        let mut rng = Rng::new(0x7B);
        let values: Vec<(u32, u32)> = (0..5000)
            .map(|_| {
                let n = rng.range(1, 30) as u32;
                (rng.next_u32() & ((1u32 << n) - 1).max(1), n)
            })
            .collect();
        let mut enc = RangeEncoder::new();
        for &(v, n) in &values {
            enc.encode_direct(v, n);
        }
        let payload = enc.finish();
        let mut dec = RangeDecoder::new(&payload);
        for &(v, n) in &values {
            assert_eq!(dec.decode_direct(n), v);
        }
    }

    #[test]
    fn tree_roundtrip() {
        let mut rng = Rng::new(0x7C);
        let n = 6u32;
        let values: Vec<u32> = (0..20_000).map(|_| (rng.below(1 << n)) as u32).collect();
        let mut enc = RangeEncoder::new();
        let mut probs = vec![BitModel::default(); 1 << n];
        for &v in &values {
            enc.encode_tree(&mut probs, n, v);
        }
        let payload = enc.finish();
        let mut dec = RangeDecoder::new(&payload);
        let mut probs = vec![BitModel::default(); 1 << n];
        for &v in &values {
            assert_eq!(dec.decode_tree(&mut probs, n), v);
        }
    }

    #[test]
    fn mixed_sequences_roundtrip() {
        // Interleave modelled bits, trees and direct bits like the codec does.
        let mut rng = Rng::new(0x7D);
        let mut enc = RangeEncoder::new();
        let mut flag = BitModel::default();
        let mut tree = vec![BitModel::default(); 64];
        let mut script = Vec::new();
        for _ in 0..10_000 {
            let choice = rng.range(0, 2);
            script.push(choice);
            match choice {
                0 => {
                    let b = rng.chance(0.2) as u32;
                    script.push(b as usize);
                    enc.encode_bit(&mut flag, b);
                }
                1 => {
                    let v = rng.below(64) as u32;
                    script.push(v as usize);
                    enc.encode_tree(&mut tree, 6, v);
                }
                _ => {
                    let v = rng.below(1 << 13) as u32;
                    script.push(v as usize);
                    enc.encode_direct(v, 13);
                }
            }
        }
        let payload = enc.finish();
        let mut dec = RangeDecoder::new(&payload);
        let mut flag = BitModel::default();
        let mut tree = vec![BitModel::default(); 64];
        let mut i = 0;
        while i < script.len() {
            let choice = script[i];
            let v = script[i + 1] as u32;
            i += 2;
            match choice {
                0 => assert_eq!(dec.decode_bit(&mut flag), v),
                1 => assert_eq!(dec.decode_tree(&mut tree, 6), v),
                _ => assert_eq!(dec.decode_direct(13), v),
            }
        }
        assert!(!dec.overrun());
    }
}
