//! LZMA-style codec: adaptive binary range coder + contextual models +
//! large dictionary (paper §2, item ii). Holds LZMA's survey position:
//! best ratio, slowest speed (Figs 2-3).

pub mod codec;
pub mod rangecoder;

pub use codec::{lzma_compress, lzma_decompress, LzmaError};
