//! Read-feedback accumulator: the loop-closer between what analyses
//! *actually read* and what the adaptive planner chooses.
//!
//! "ROOT I/O compression improvements for HEP analysis" (arXiv:2004.10531)
//! argues compression choices should track the observed workload, not a
//! static label. Projection scans already measure per-branch reads
//! ([`BranchReadStats`]); a [`ReadFeedback`] accumulates those stats
//! across scans into a persistent **access profile**, and
//! [`Planner::plan_from_feedback`](crate::coordinator::Planner::plan_from_feedback)
//! weights its per-branch decision by the profile's observed read
//! intensity instead of a use-case label:
//!
//! ```text
//!  rootio read --branches a,b --feedback reads.profile   (repeat per scan)
//!        │   ProjectionReader::branch_stats → ReadFeedback::record_scan
//!        ▼
//!  reads.profile (text, one line per branch, accumulates across runs)
//!        │
//!  rootio inspect --replan profile --profile reads.profile
//!        │   runtime::analyze_tree features × ReadFeedback::intensity
//!        ▼
//!  per-branch settings: hot branches → decode-speed plan,
//!                       untouched branches → ratio plan
//! ```
//!
//! The profile format is a versioned plain-text table (no serde in the
//! offline crate set), stable across files with the same schema because
//! branches are keyed by **name**.

use crate::coordinator::projection::BranchReadStats;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Header line of the on-disk profile format.
const PROFILE_MAGIC: &str = "rootio-read-profile v1";

/// Escape a branch name for the tab-separated profile line (names are
/// arbitrary strings; a literal tab or newline would corrupt the framing
/// and brick the profile for the strict parser).
fn escape_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for c in name.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\t' => out.push_str("\\t"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            c => out.push(c),
        }
    }
    out
}

/// Inverse of [`escape_name`]; rejects truncated or unknown escapes.
fn unescape_name(escaped: &str) -> Option<String> {
    let mut out = String::with_capacity(escaped.len());
    let mut chars = escaped.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('\\') => out.push('\\'),
            Some('t') => out.push('\t'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            _ => return None,
        }
    }
    Some(out)
}

/// Accumulated read statistics for one branch across every recorded scan.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BranchFeedback {
    /// Branch id at last record time (informative — lookups key on name).
    pub branch_id: u32,
    pub name: String,
    /// Scans in which this branch was projected.
    pub scans: u64,
    /// Baskets decoded for this branch, summed over scans.
    pub baskets: u64,
    /// Entries decoded (boundary baskets of range reads decode whole).
    pub entries: u64,
    /// Uncompressed bytes decoded, summed over scans.
    pub logical_bytes: u64,
    /// Compressed bytes read off the file, summed over scans.
    pub compressed_bytes: u64,
}

/// A recorded access profile: per-branch read totals plus the number of
/// scans that produced them. Create empty ([`ReadFeedback::new`]), feed it
/// [`BranchReadStats`] after each projection drain
/// ([`ReadFeedback::record_scan`]), and persist it as a small text file
/// ([`ReadFeedback::save`] / [`ReadFeedback::load`]) so the profile
/// accumulates across processes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReadFeedback {
    /// Scans recorded into this profile.
    pub scans: u64,
    branches: Vec<BranchFeedback>,
}

impl ReadFeedback {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one finished scan's per-branch stats into the profile.
    /// Branches are matched by name, so profiles survive schema reorder
    /// and apply across files with the same branch names.
    pub fn record_scan(&mut self, stats: &[BranchReadStats]) {
        self.scans += 1;
        for st in stats {
            let b = self.entry_mut(&st.name, st.branch_id);
            b.scans += 1;
            b.baskets += st.baskets;
            b.entries += st.entries;
            b.logical_bytes += st.logical_bytes;
            b.compressed_bytes += st.compressed_bytes;
        }
    }

    /// Fold another profile into this one (distributed workers each record
    /// locally, then merge).
    pub fn merge(&mut self, other: &ReadFeedback) {
        self.scans += other.scans;
        for ob in &other.branches {
            let b = self.entry_mut(&ob.name, ob.branch_id);
            b.scans += ob.scans;
            b.baskets += ob.baskets;
            b.entries += ob.entries;
            b.logical_bytes += ob.logical_bytes;
            b.compressed_bytes += ob.compressed_bytes;
        }
    }

    fn entry_mut(&mut self, name: &str, branch_id: u32) -> &mut BranchFeedback {
        if let Some(i) = self.branches.iter().position(|b| b.name == name) {
            return &mut self.branches[i];
        }
        self.branches.push(BranchFeedback {
            branch_id,
            name: name.to_string(),
            ..BranchFeedback::default()
        });
        self.branches.last_mut().expect("just pushed")
    }

    /// Per-branch totals, in first-recorded order.
    pub fn branches(&self) -> &[BranchFeedback] {
        &self.branches
    }

    pub fn get(&self, name: &str) -> Option<&BranchFeedback> {
        self.branches.iter().find(|b| b.name == name)
    }

    /// Uncompressed bytes the profile saw decoded for `name` (0 if the
    /// branch was never read).
    pub fn logical_bytes_read(&self, name: &str) -> u64 {
        self.get(name).map(|b| b.logical_bytes).unwrap_or(0)
    }

    /// Total uncompressed bytes across every branch in the profile.
    pub fn total_logical_bytes(&self) -> u64 {
        self.branches.iter().map(|b| b.logical_bytes).sum()
    }

    /// Observed read intensity for `name`: the fraction of the branch's
    /// stored (uncompressed) bytes decoded *per recorded scan*. ~1.0 means
    /// the whole branch is read every scan (decode-speed-bound); ~0 means
    /// the branch is effectively write-only (ratio-bound). Can exceed 1.0
    /// when boundary baskets of overlapping range reads decode repeatedly.
    /// This is the weight [`crate::coordinator::Planner::plan_from_feedback`]
    /// consumes.
    pub fn intensity(&self, name: &str, stored_logical_bytes: u64) -> f64 {
        if self.scans == 0 || stored_logical_bytes == 0 {
            return 0.0;
        }
        self.logical_bytes_read(name) as f64 / (stored_logical_bytes as f64 * self.scans as f64)
    }

    /// Render the profile in its on-disk text format.
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        out.push_str(PROFILE_MAGIC);
        out.push('\n');
        out.push_str(&format!("scans\t{}\n", self.scans));
        for b in &self.branches {
            out.push_str(&format!(
                "branch\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                b.branch_id, b.scans, b.baskets, b.entries, b.logical_bytes, b.compressed_bytes,
                escape_name(&b.name)
            ));
        }
        out
    }

    /// Parse the on-disk text format (rejects unknown versions and
    /// malformed lines — a profile is planner input, not a best-effort
    /// log).
    pub fn deserialize(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        match lines.next() {
            Some(PROFILE_MAGIC) => {}
            other => bail!("not a rootio read profile (header {:?})", other.unwrap_or("")),
        }
        let mut fb = ReadFeedback::new();
        let mut saw_scans = false;
        for (lineno, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let mut fields = line.split('\t');
            let fail = || anyhow::anyhow!("read profile line {}: malformed '{line}'", lineno + 2);
            match fields.next() {
                Some("scans") => {
                    fb.scans = fields.next().ok_or_else(fail)?.parse().map_err(|_| fail())?;
                    saw_scans = true;
                }
                Some("branch") => {
                    let mut num = || -> Result<u64> {
                        fields.next().ok_or_else(fail)?.parse().map_err(|_| fail())
                    };
                    let branch_id = num()? as u32;
                    let scans = num()?;
                    let baskets = num()?;
                    let entries = num()?;
                    let logical_bytes = num()?;
                    let compressed_bytes = num()?;
                    // Name is the final field (tabs/newlines escaped by
                    // `escape_name`), so a trailing tab means a malformed
                    // line.
                    let name =
                        unescape_name(fields.next().ok_or_else(fail)?).ok_or_else(fail)?;
                    if fields.next().is_some() || name.is_empty() {
                        bail!("read profile line {}: malformed '{line}'", lineno + 2);
                    }
                    fb.branches.push(BranchFeedback {
                        branch_id,
                        name,
                        scans,
                        baskets,
                        entries,
                        logical_bytes,
                        compressed_bytes,
                    });
                }
                _ => bail!("read profile line {}: unknown record '{line}'", lineno + 2),
            }
        }
        if !saw_scans {
            bail!("read profile has no 'scans' line");
        }
        Ok(fb)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading read profile {}", path.display()))?;
        Self::deserialize(&text)
    }

    /// Persist the profile crash-safely: a partially written profile would
    /// fail `deserialize` on the next run and silently discard the history,
    /// so the bytes go to a temp file that is atomically renamed over the
    /// destination.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::fsio::atomic_write(path, self.serialize().as_bytes())
            .with_context(|| format!("writing read profile {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(name: &str, id: u32, logical: u64) -> BranchReadStats {
        BranchReadStats {
            branch_id: id,
            name: name.into(),
            baskets: 3,
            entries: 100,
            compressed_bytes: logical / 2,
            logical_bytes: logical,
            ..BranchReadStats::default()
        }
    }

    #[test]
    fn record_accumulates_and_roundtrips() {
        let mut fb = ReadFeedback::new();
        fb.record_scan(&[stats("pt", 3, 1000), stats("eta", 4, 500)]);
        fb.record_scan(&[stats("pt", 3, 1000)]);
        assert_eq!(fb.scans, 2);
        assert_eq!(fb.logical_bytes_read("pt"), 2000);
        assert_eq!(fb.logical_bytes_read("eta"), 500);
        assert_eq!(fb.logical_bytes_read("phi"), 0);
        assert_eq!(fb.get("pt").unwrap().scans, 2);
        assert_eq!(fb.get("eta").unwrap().scans, 1);
        assert_eq!(fb.total_logical_bytes(), 2500);
        let back = ReadFeedback::deserialize(&fb.serialize()).unwrap();
        assert_eq!(back, fb);
    }

    #[test]
    fn intensity_is_per_scan_fraction_of_stored_bytes() {
        let mut fb = ReadFeedback::new();
        fb.record_scan(&[stats("hot", 0, 1000), stats("warm", 1, 100)]);
        fb.record_scan(&[stats("hot", 0, 1000)]);
        // hot: 2000 bytes over 2 scans of a 1000-byte branch → 1.0.
        assert!((fb.intensity("hot", 1000) - 1.0).abs() < 1e-9);
        // warm: 100 bytes over 2 scans of a 1000-byte branch → 0.05.
        assert!((fb.intensity("warm", 1000) - 0.05).abs() < 1e-9);
        // Never read, zero-size, or empty profile → 0.
        assert_eq!(fb.intensity("cold", 1000), 0.0);
        assert_eq!(fb.intensity("hot", 0), 0.0);
        assert_eq!(ReadFeedback::new().intensity("hot", 1000), 0.0);
    }

    #[test]
    fn hostile_branch_names_roundtrip() {
        // Names are arbitrary strings: tabs/newlines/backslashes must
        // survive the tab-separated format instead of bricking the file.
        let mut fb = ReadFeedback::new();
        for name in ["a\tb", "line\nbreak", "back\\slash", "cr\rlf", "\\t literal"] {
            fb.record_scan(&[stats(name, 0, 10)]);
        }
        let text = fb.serialize();
        let back = ReadFeedback::deserialize(&text).unwrap();
        assert_eq!(back, fb);
        assert_eq!(back.logical_bytes_read("a\tb"), 10);
        // Truncated / unknown escapes are rejected, not misread.
        assert!(ReadFeedback::deserialize(
            "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\tbad\\\n"
        )
        .is_err());
        assert!(ReadFeedback::deserialize(
            "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\tbad\\x\n"
        )
        .is_err());
    }

    #[test]
    fn merge_folds_profiles() {
        let mut a = ReadFeedback::new();
        a.record_scan(&[stats("pt", 3, 1000)]);
        let mut b = ReadFeedback::new();
        b.record_scan(&[stats("pt", 3, 1000), stats("eta", 4, 500)]);
        a.merge(&b);
        assert_eq!(a.scans, 2);
        assert_eq!(a.logical_bytes_read("pt"), 2000);
        assert_eq!(a.logical_bytes_read("eta"), 500);
    }

    #[test]
    fn malformed_profiles_rejected() {
        assert!(ReadFeedback::deserialize("").is_err());
        assert!(ReadFeedback::deserialize("some other file\n").is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v2\nscans\t1\n").is_err());
        let ok = "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\tpt\n";
        assert!(ReadFeedback::deserialize(ok).is_ok());
        // Missing scans line, truncated branch line, junk record, extra
        // field, empty name.
        assert!(ReadFeedback::deserialize("rootio-read-profile v1\n").is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v1\nscans\t1\nbranch\t0\t1\n")
            .is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v1\nscans\t1\nwhat\t0\n").is_err());
        assert!(ReadFeedback::deserialize(
            "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\tpt\textra\n"
        )
        .is_err());
        assert!(ReadFeedback::deserialize(
            "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\t\n"
        )
        .is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v1\nscans\tx\n").is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let mut fb = ReadFeedback::new();
        fb.record_scan(&[stats("pt", 3, 1000)]);
        let mut path = std::env::temp_dir();
        path.push(format!("rootio_feedback_{}.profile", std::process::id()));
        fb.save(&path).unwrap();
        assert_eq!(ReadFeedback::load(&path).unwrap(), fb);
        std::fs::remove_file(&path).ok();
    }
}
