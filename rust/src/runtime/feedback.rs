//! Read-feedback accumulator: the loop-closer between what analyses
//! *actually read* and what the adaptive planner chooses.
//!
//! "ROOT I/O compression improvements for HEP analysis" (arXiv:2004.10531)
//! argues compression choices should track the observed workload, not a
//! static label. Projection scans already measure per-branch reads
//! ([`BranchReadStats`]); a [`ReadFeedback`] accumulates those stats
//! across scans into a persistent **access profile**, and
//! [`Planner::plan_from_feedback`](crate::coordinator::Planner::plan_from_feedback)
//! weights its per-branch decision by the profile's observed read
//! intensity instead of a use-case label:
//!
//! ```text
//!  rootio read --branches a,b --feedback reads.profile   (repeat per scan)
//!        │   ProjectionReader::branch_stats → ReadFeedback::record_scan
//!        ▼
//!  reads.profile (text, one line per branch, accumulates across runs)
//!        │
//!  rootio inspect --replan profile --profile reads.profile
//!        │   runtime::analyze_tree features × ReadFeedback::intensity
//!        ▼
//!  per-branch settings: hot branches → decode-speed plan,
//!                       untouched branches → ratio plan
//! ```
//!
//! The profile format is a versioned plain-text table (no serde in the
//! offline crate set), stable across files with the same schema because
//! branches are keyed by **name**.
//!
//! Profiles **decay**: each [`ReadFeedback::advance_generation`] call
//! multiplies every counter by [`ReadFeedback::DECAY_PER_GENERATION`], so
//! the profile is an exponentially-weighted history — a branch that was
//! hot last month but is cold now drifts back toward ratio-bound
//! settings instead of pinning its old plan forever. Counters are f64
//! for exactly this reason. [`ReadFeedback::merge`] aligns both sides to
//! the newer generation before summing.

use crate::coordinator::projection::BranchReadStats;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Header line of the current on-disk profile format (v2 adds the
/// `generation` record and fractional counters).
const PROFILE_MAGIC: &str = "rootio-read-profile v2";

/// v1 header: integer counters, no generation record. Still readable
/// (parsed as generation 0); saves always write v2.
const PROFILE_MAGIC_V1: &str = "rootio-read-profile v1";

/// Escape a branch name for the tab-separated profile line (names are
/// arbitrary strings; a literal tab or newline would corrupt the framing
/// and brick the profile for the strict parser).
fn escape_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for c in name.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\t' => out.push_str("\\t"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            c => out.push(c),
        }
    }
    out
}

/// Inverse of [`escape_name`]; rejects truncated or unknown escapes.
fn unescape_name(escaped: &str) -> Option<String> {
    let mut out = String::with_capacity(escaped.len());
    let mut chars = escaped.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('\\') => out.push('\\'),
            Some('t') => out.push('\t'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            _ => return None,
        }
    }
    Some(out)
}

/// Accumulated read statistics for one branch across every recorded scan.
/// Counters are f64 because generation decay scales them fractionally
/// (see [`ReadFeedback::advance_generation`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BranchFeedback {
    /// Branch id at last record time (informative — lookups key on name).
    pub branch_id: u32,
    pub name: String,
    /// Scans in which this branch was projected (decay-weighted).
    pub scans: f64,
    /// Baskets decoded for this branch, summed over scans.
    pub baskets: f64,
    /// Entries decoded (boundary baskets of range reads decode whole).
    pub entries: f64,
    /// Uncompressed bytes decoded, summed over scans.
    pub logical_bytes: f64,
    /// Compressed bytes read off the file, summed over scans.
    pub compressed_bytes: f64,
}

impl BranchFeedback {
    fn scale(&mut self, factor: f64) {
        self.scans *= factor;
        self.baskets *= factor;
        self.entries *= factor;
        self.logical_bytes *= factor;
        self.compressed_bytes *= factor;
    }
}

/// A recorded access profile: per-branch read totals plus the number of
/// scans that produced them. Create empty ([`ReadFeedback::new`]), feed it
/// [`BranchReadStats`] after each projection drain
/// ([`ReadFeedback::record_scan`]), and persist it as a small text file
/// ([`ReadFeedback::save`] / [`ReadFeedback::load`]) so the profile
/// accumulates across processes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReadFeedback {
    /// Scans recorded into this profile (decay-weighted).
    pub scans: f64,
    /// Decay epochs this profile has lived through
    /// ([`ReadFeedback::advance_generation`]).
    pub generation: u64,
    branches: Vec<BranchFeedback>,
}

impl ReadFeedback {
    /// Weight multiplier applied to every counter per generation: after
    /// `g` generations an observation contributes `0.8^g` of its original
    /// weight (half-life ≈ 3.1 generations).
    pub const DECAY_PER_GENERATION: f64 = 0.8;

    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one finished scan's per-branch stats into the profile.
    /// Branches are matched by name, so profiles survive schema reorder
    /// and apply across files with the same branch names.
    pub fn record_scan(&mut self, stats: &[BranchReadStats]) {
        self.scans += 1.0;
        for st in stats {
            let b = self.entry_mut(&st.name, st.branch_id);
            b.scans += 1.0;
            b.baskets += st.baskets as f64;
            b.entries += st.entries as f64;
            b.logical_bytes += st.logical_bytes as f64;
            b.compressed_bytes += st.compressed_bytes as f64;
        }
    }

    /// Close one decay epoch: every counter shrinks by
    /// [`Self::DECAY_PER_GENERATION`], so scans recorded *after* this call
    /// outweigh ones recorded before it. Callers advance once per natural
    /// aging unit (the CLI: once per process that records into a profile).
    pub fn advance_generation(&mut self) {
        self.generation += 1;
        self.scans *= Self::DECAY_PER_GENERATION;
        for b in &mut self.branches {
            b.scale(Self::DECAY_PER_GENERATION);
        }
    }

    /// Fold another profile into this one (distributed workers each record
    /// locally, then merge). Both sides are first aligned to the **newer**
    /// generation — the older profile's counters are scaled by
    /// `DECAY^(generation gap)` — so merging never lets stale history
    /// outvote fresh observations.
    pub fn merge(&mut self, other: &ReadFeedback) {
        let target = self.generation.max(other.generation);
        let self_factor = Self::DECAY_PER_GENERATION.powi((target - self.generation) as i32);
        let other_factor = Self::DECAY_PER_GENERATION.powi((target - other.generation) as i32);
        if self_factor != 1.0 {
            self.scans *= self_factor;
            for b in &mut self.branches {
                b.scale(self_factor);
            }
        }
        self.generation = target;
        self.scans += other.scans * other_factor;
        for ob in &other.branches {
            let b = self.entry_mut(&ob.name, ob.branch_id);
            b.scans += ob.scans * other_factor;
            b.baskets += ob.baskets * other_factor;
            b.entries += ob.entries * other_factor;
            b.logical_bytes += ob.logical_bytes * other_factor;
            b.compressed_bytes += ob.compressed_bytes * other_factor;
        }
    }

    fn entry_mut(&mut self, name: &str, branch_id: u32) -> &mut BranchFeedback {
        if let Some(i) = self.branches.iter().position(|b| b.name == name) {
            return &mut self.branches[i];
        }
        self.branches.push(BranchFeedback {
            branch_id,
            name: name.to_string(),
            ..BranchFeedback::default()
        });
        self.branches.last_mut().expect("just pushed")
    }

    /// Per-branch totals, in first-recorded order.
    pub fn branches(&self) -> &[BranchFeedback] {
        &self.branches
    }

    pub fn get(&self, name: &str) -> Option<&BranchFeedback> {
        self.branches.iter().find(|b| b.name == name)
    }

    /// Uncompressed bytes the profile saw decoded for `name`
    /// (decay-weighted; 0 if the branch was never read).
    pub fn logical_bytes_read(&self, name: &str) -> f64 {
        self.get(name).map(|b| b.logical_bytes).unwrap_or(0.0)
    }

    /// Total uncompressed bytes across every branch in the profile.
    pub fn total_logical_bytes(&self) -> f64 {
        self.branches.iter().map(|b| b.logical_bytes).sum()
    }

    /// Observed read intensity for `name`: the fraction of the branch's
    /// stored (uncompressed) bytes decoded *per recorded scan*. ~1.0 means
    /// the whole branch is read every scan (decode-speed-bound); ~0 means
    /// the branch is effectively write-only (ratio-bound). Can exceed 1.0
    /// when boundary baskets of overlapping range reads decode repeatedly.
    /// This is the weight [`crate::coordinator::Planner::plan_from_feedback`]
    /// consumes.
    pub fn intensity(&self, name: &str, stored_logical_bytes: u64) -> f64 {
        if self.scans <= 0.0 || stored_logical_bytes == 0 {
            return 0.0;
        }
        // Bytes and scan count decay by the same factor, so intensity is
        // a decay-weighted average of per-scan intensities: recent scans
        // dominate, but the ratio's scale is unchanged.
        self.logical_bytes_read(name) / (stored_logical_bytes as f64 * self.scans)
    }

    /// Render the profile in its on-disk text format (always the current
    /// v2). Rust's shortest-round-trip float formatting keeps save→load
    /// lossless.
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        out.push_str(PROFILE_MAGIC);
        out.push('\n');
        out.push_str(&format!("scans\t{}\n", self.scans));
        out.push_str(&format!("generation\t{}\n", self.generation));
        for b in &self.branches {
            out.push_str(&format!(
                "branch\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                b.branch_id, b.scans, b.baskets, b.entries, b.logical_bytes, b.compressed_bytes,
                escape_name(&b.name)
            ));
        }
        out
    }

    /// Parse the on-disk text format (rejects unknown versions and
    /// malformed lines — a profile is planner input, not a best-effort
    /// log). v1 profiles (integer counters, no `generation` record) load
    /// as generation 0.
    pub fn deserialize(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        match lines.next() {
            Some(PROFILE_MAGIC) | Some(PROFILE_MAGIC_V1) => {}
            other => bail!("not a rootio read profile (header {:?})", other.unwrap_or("")),
        }
        let mut fb = ReadFeedback::new();
        let mut saw_scans = false;
        for (lineno, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let mut fields = line.split('\t');
            let fail = || anyhow::anyhow!("read profile line {}: malformed '{line}'", lineno + 2);
            // Counters must be finite and non-negative: "inf"/"NaN"/"-3"
            // parse as f64 but would poison every downstream ratio.
            let counter = |s: &str| -> Option<f64> {
                let v: f64 = s.parse().ok()?;
                (v.is_finite() && v >= 0.0).then_some(v)
            };
            match fields.next() {
                Some("scans") => {
                    fb.scans =
                        counter(fields.next().ok_or_else(fail)?).ok_or_else(fail)?;
                    saw_scans = true;
                }
                Some("generation") => {
                    fb.generation =
                        fields.next().ok_or_else(fail)?.parse().map_err(|_| fail())?;
                }
                Some("branch") => {
                    let branch_id: u32 =
                        fields.next().ok_or_else(fail)?.parse().map_err(|_| fail())?;
                    let mut num = || -> Result<f64> {
                        counter(fields.next().ok_or_else(fail)?).ok_or_else(fail)
                    };
                    let scans = num()?;
                    let baskets = num()?;
                    let entries = num()?;
                    let logical_bytes = num()?;
                    let compressed_bytes = num()?;
                    // Name is the final field (tabs/newlines escaped by
                    // `escape_name`), so a trailing tab means a malformed
                    // line.
                    let name =
                        unescape_name(fields.next().ok_or_else(fail)?).ok_or_else(fail)?;
                    if fields.next().is_some() || name.is_empty() {
                        bail!("read profile line {}: malformed '{line}'", lineno + 2);
                    }
                    fb.branches.push(BranchFeedback {
                        branch_id,
                        name,
                        scans,
                        baskets,
                        entries,
                        logical_bytes,
                        compressed_bytes,
                    });
                }
                _ => bail!("read profile line {}: unknown record '{line}'", lineno + 2),
            }
        }
        if !saw_scans {
            bail!("read profile has no 'scans' line");
        }
        Ok(fb)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading read profile {}", path.display()))?;
        Self::deserialize(&text)
    }

    /// Persist the profile crash-safely: a partially written profile would
    /// fail `deserialize` on the next run and silently discard the history,
    /// so the bytes go to a temp file that is atomically renamed over the
    /// destination.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::fsio::atomic_write(path, self.serialize().as_bytes())
            .with_context(|| format!("writing read profile {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(name: &str, id: u32, logical: u64) -> BranchReadStats {
        BranchReadStats {
            branch_id: id,
            name: name.into(),
            baskets: 3,
            entries: 100,
            compressed_bytes: logical / 2,
            logical_bytes: logical,
            ..BranchReadStats::default()
        }
    }

    #[test]
    fn record_accumulates_and_roundtrips() {
        let mut fb = ReadFeedback::new();
        fb.record_scan(&[stats("pt", 3, 1000), stats("eta", 4, 500)]);
        fb.record_scan(&[stats("pt", 3, 1000)]);
        assert_eq!(fb.scans, 2.0);
        assert_eq!(fb.logical_bytes_read("pt"), 2000.0);
        assert_eq!(fb.logical_bytes_read("eta"), 500.0);
        assert_eq!(fb.logical_bytes_read("phi"), 0.0);
        assert_eq!(fb.get("pt").unwrap().scans, 2.0);
        assert_eq!(fb.get("eta").unwrap().scans, 1.0);
        assert_eq!(fb.total_logical_bytes(), 2500.0);
        let back = ReadFeedback::deserialize(&fb.serialize()).unwrap();
        assert_eq!(back, fb);
    }

    #[test]
    fn generation_decay_fades_history() {
        let d = ReadFeedback::DECAY_PER_GENERATION;
        let mut fb = ReadFeedback::new();
        fb.record_scan(&[stats("pt", 3, 1000)]);
        fb.advance_generation();
        fb.advance_generation();
        assert_eq!(fb.generation, 2);
        assert!((fb.scans - d * d).abs() < 1e-12);
        assert!((fb.logical_bytes_read("pt") - 1000.0 * d * d).abs() < 1e-9);
        let b = fb.get("pt").unwrap();
        assert!((b.baskets - 3.0 * d * d).abs() < 1e-12);
        assert!((b.entries - 100.0 * d * d).abs() < 1e-9);
        assert!((b.compressed_bytes - 500.0 * d * d).abs() < 1e-9);
        // Decay cancels in the intensity ratio: bytes and scan count
        // shrink together, so a steadily-hot branch keeps intensity 1.0.
        assert!((fb.intensity("pt", 1000) - 1.0).abs() < 1e-9);
        // A fresh scan lands at full weight on top of faded history.
        fb.record_scan(&[stats("pt", 3, 1000)]);
        assert!((fb.scans - (d * d + 1.0)).abs() < 1e-12);
        assert!((fb.logical_bytes_read("pt") - (1000.0 * d * d + 1000.0)).abs() < 1e-9);
        // Decayed values survive save→load exactly.
        let back = ReadFeedback::deserialize(&fb.serialize()).unwrap();
        assert_eq!(back, fb);
    }

    #[test]
    fn merge_aligns_generations_before_summing() {
        let d = ReadFeedback::DECAY_PER_GENERATION;
        // Old profile: one scan, then two epochs pass.
        let mut old = ReadFeedback::new();
        old.record_scan(&[stats("pt", 3, 1000)]);
        old.advance_generation();
        old.advance_generation();
        // Fresh profile at generation 2 already.
        let mut fresh = ReadFeedback::new();
        fresh.record_scan(&[stats("pt", 3, 1000)]);
        fresh.generation = 2;

        // Merging fresh INTO old (same generation): plain sum.
        let mut a = old.clone();
        a.merge(&fresh);
        assert_eq!(a.generation, 2);
        assert!((a.logical_bytes_read("pt") - (1000.0 * d * d + 1000.0)).abs() < 1e-9);

        // Merging a generation-0 profile into a generation-2 one decays
        // the OTHER side's counters to align.
        let mut lagging = ReadFeedback::new();
        lagging.record_scan(&[stats("pt", 3, 1000)]);
        let mut b = fresh.clone();
        b.merge(&lagging);
        assert_eq!(b.generation, 2);
        assert!((b.logical_bytes_read("pt") - (1000.0 + 1000.0 * d * d)).abs() < 1e-9);

        // Merging a newer profile into an older one decays SELF first.
        let mut c = lagging.clone();
        c.merge(&fresh);
        assert_eq!(c.generation, 2);
        assert!((c.scans - (d * d + 1.0)).abs() < 1e-12);
        assert!((c.logical_bytes_read("pt") - (1000.0 * d * d + 1000.0)).abs() < 1e-9);
    }

    #[test]
    fn v1_profiles_load_as_generation_zero() {
        let v1 = "rootio-read-profile v1\nscans\t2\nbranch\t3\t2\t6\t200\t2000\t1000\tpt\n";
        let fb = ReadFeedback::deserialize(v1).unwrap();
        assert_eq!(fb.generation, 0);
        assert_eq!(fb.scans, 2.0);
        assert_eq!(fb.logical_bytes_read("pt"), 2000.0);
        // Re-serializing upgrades to v2 with an explicit generation line,
        // and the upgraded text round-trips to the same profile.
        let text = fb.serialize();
        assert!(text.starts_with("rootio-read-profile v2\n"));
        assert!(text.contains("generation\t0\n"));
        assert_eq!(ReadFeedback::deserialize(&text).unwrap(), fb);
    }

    #[test]
    fn intensity_is_per_scan_fraction_of_stored_bytes() {
        let mut fb = ReadFeedback::new();
        fb.record_scan(&[stats("hot", 0, 1000), stats("warm", 1, 100)]);
        fb.record_scan(&[stats("hot", 0, 1000)]);
        // hot: 2000 bytes over 2 scans of a 1000-byte branch → 1.0.
        assert!((fb.intensity("hot", 1000) - 1.0).abs() < 1e-9);
        // warm: 100 bytes over 2 scans of a 1000-byte branch → 0.05.
        assert!((fb.intensity("warm", 1000) - 0.05).abs() < 1e-9);
        // Never read, zero-size, or empty profile → 0.
        assert_eq!(fb.intensity("cold", 1000), 0.0);
        assert_eq!(fb.intensity("hot", 0), 0.0);
        assert_eq!(ReadFeedback::new().intensity("hot", 1000), 0.0);
    }

    #[test]
    fn hostile_branch_names_roundtrip() {
        // Names are arbitrary strings: tabs/newlines/backslashes must
        // survive the tab-separated format instead of bricking the file.
        let mut fb = ReadFeedback::new();
        for name in ["a\tb", "line\nbreak", "back\\slash", "cr\rlf", "\\t literal"] {
            fb.record_scan(&[stats(name, 0, 10)]);
        }
        let text = fb.serialize();
        let back = ReadFeedback::deserialize(&text).unwrap();
        assert_eq!(back, fb);
        assert_eq!(back.logical_bytes_read("a\tb"), 10.0);
        // Truncated / unknown escapes are rejected, not misread.
        assert!(ReadFeedback::deserialize(
            "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\tbad\\\n"
        )
        .is_err());
        assert!(ReadFeedback::deserialize(
            "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\tbad\\x\n"
        )
        .is_err());
    }

    #[test]
    fn merge_folds_profiles() {
        let mut a = ReadFeedback::new();
        a.record_scan(&[stats("pt", 3, 1000)]);
        let mut b = ReadFeedback::new();
        b.record_scan(&[stats("pt", 3, 1000), stats("eta", 4, 500)]);
        a.merge(&b);
        assert_eq!(a.scans, 2.0);
        assert_eq!(a.logical_bytes_read("pt"), 2000.0);
        assert_eq!(a.logical_bytes_read("eta"), 500.0);
    }

    #[test]
    fn malformed_profiles_rejected() {
        assert!(ReadFeedback::deserialize("").is_err());
        assert!(ReadFeedback::deserialize("some other file\n").is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v3\nscans\t1\n").is_err());
        // Both live versions parse.
        let ok = "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\tpt\n";
        assert!(ReadFeedback::deserialize(ok).is_ok());
        let ok2 = "rootio-read-profile v2\nscans\t1.5\ngeneration\t2\nbranch\t0\t1\t2\t3\t4\t5\tpt\n";
        assert!(ReadFeedback::deserialize(ok2).is_ok());
        // Non-finite or negative counters are rejected, not ingested.
        assert!(ReadFeedback::deserialize("rootio-read-profile v2\nscans\tNaN\n").is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v2\nscans\tinf\n").is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v2\nscans\t-1\n").is_err());
        assert!(ReadFeedback::deserialize(
            "rootio-read-profile v2\nscans\t1\nbranch\t0\t1\t2\t-3\t4\t5\tpt\n"
        )
        .is_err());
        // generation must be a non-negative integer.
        assert!(
            ReadFeedback::deserialize("rootio-read-profile v2\nscans\t1\ngeneration\t1.5\n")
                .is_err()
        );
        // Missing scans line, truncated branch line, junk record, extra
        // field, empty name.
        assert!(ReadFeedback::deserialize("rootio-read-profile v1\n").is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v1\nscans\t1\nbranch\t0\t1\n")
            .is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v1\nscans\t1\nwhat\t0\n").is_err());
        assert!(ReadFeedback::deserialize(
            "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\tpt\textra\n"
        )
        .is_err());
        assert!(ReadFeedback::deserialize(
            "rootio-read-profile v1\nscans\t1\nbranch\t0\t1\t2\t3\t4\t5\t\n"
        )
        .is_err());
        assert!(ReadFeedback::deserialize("rootio-read-profile v1\nscans\tx\n").is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let mut fb = ReadFeedback::new();
        fb.record_scan(&[stats("pt", 3, 1000)]);
        let mut path = std::env::temp_dir();
        path.push(format!("rootio_feedback_{}.profile", std::process::id()));
        fb.save(&path).unwrap();
        assert_eq!(ReadFeedback::load(&path).unwrap(), fb);
        std::fs::remove_file(&path).ok();
    }
}
