//! The XLA-served basket analyzer: wraps compiled `analyzer_<n>.hlo.txt`
//! executables (one per basket-size bucket) behind a byte-slice API.
//!
//! Load path per artifact: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! Text interchange is mandatory — see aot.py's module docstring.

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Basket-prefix buckets, must mirror python/compile/aot.py BUCKETS.
pub const BUCKETS: [usize; 3] = [4096, 32768, 262144];
/// Feature vector length, must mirror python/compile/model.py.
pub const NUM_FEATURES: usize = 8;

/// Analyzer features (named view over the raw vector).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Features {
    pub h_raw: f32,
    pub h_shuffle: f32,
    pub h_bitshuffle: f32,
    pub h_delta: f32,
    pub rep_raw: f32,
    pub rep_bitshuffle: f32,
    pub zero_bitshuffle: f32,
    pub rep_shuffle: f32,
}

impl Features {
    pub fn from_vec(v: &[f32]) -> Result<Self> {
        if v.len() != NUM_FEATURES {
            bail!("feature vector has {} entries, expected {NUM_FEATURES}", v.len());
        }
        Ok(Self {
            h_raw: v[0],
            h_shuffle: v[1],
            h_bitshuffle: v[2],
            h_delta: v[3],
            rep_raw: v[4],
            rep_bitshuffle: v[5],
            zero_bitshuffle: v[6],
            rep_shuffle: v[7],
        })
    }
}

struct BucketExe {
    size: usize,
    exe: xla::PjRtLoadedExecutable,
    /// Reused input staging buffer (basket bytes widened to i32).
    staging: Vec<i32>,
}

/// Compiled analyzer over all buckets.
pub struct Analyzer {
    buckets: Vec<BucketExe>,
}

impl Analyzer {
    /// Load every `analyzer_<n>.hlo.txt` from `artifacts_dir` and compile.
    pub fn load(client: &xla::PjRtClient, artifacts_dir: &Path) -> Result<Self> {
        let mut buckets = Vec::new();
        for &size in BUCKETS.iter() {
            let path = artifacts_dir.join(format!("analyzer_{size}.hlo.txt"));
            if !path.exists() {
                bail!(
                    "missing artifact {} — run `make artifacts` first",
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            buckets.push(BucketExe { size, exe, staging: vec![0i32; size] });
        }
        Ok(Self { buckets })
    }

    /// Smallest bucket size (baskets below this are not analyzed).
    pub fn min_bucket(&self) -> usize {
        self.buckets.first().map(|b| b.size).unwrap_or(usize::MAX)
    }

    /// Analyze the basket prefix: picks the largest bucket that fits,
    /// widens bytes to i32, executes the XLA computation, returns features.
    /// Returns None for baskets smaller than the smallest bucket.
    pub fn analyze(&mut self, basket: &[u8]) -> Result<Option<Features>> {
        let Some(idx) = self
            .buckets
            .iter()
            .rposition(|b| b.size <= basket.len())
        else {
            return Ok(None);
        };
        let b = &mut self.buckets[idx];
        for (dst, src) in b.staging.iter_mut().zip(basket.iter()) {
            *dst = *src as i32;
        }
        let input = xla::Literal::vec1(&b.staging[..]);
        let result = b.exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        Ok(Some(Features::from_vec(&values)?))
    }
}

/// Pure-rust mirror of the analyzer's math, used (a) to validate the XLA
/// path in tests and (b) as a fallback when artifacts are absent.
pub fn analyze_native(basket: &[u8], bucket: usize) -> Option<Features> {
    use crate::precond;
    use crate::util::stats::{repeat_fraction, shannon_entropy};
    if basket.len() < bucket {
        return None;
    }
    let buf = &basket[..bucket];
    const STRIDE: usize = 4;
    let shuf = precond::shuffle(buf, STRIDE);
    let bits = precond::bitshuffle(buf, STRIDE);
    let delta = precond::delta(buf, STRIDE);
    let zero = bits.iter().filter(|&&b| b == 0 || b == 255).count() as f32 / bits.len() as f32;
    Some(Features {
        h_raw: shannon_entropy(buf) as f32,
        h_shuffle: shannon_entropy(&shuf) as f32,
        h_bitshuffle: shannon_entropy(&bits) as f32,
        h_delta: shannon_entropy(&delta) as f32,
        rep_raw: repeat_fraction(buf) as f32,
        rep_bitshuffle: repeat_fraction(&bits) as f32,
        zero_bitshuffle: zero,
        rep_shuffle: repeat_fraction(&shuf) as f32,
    })
}

/// Pick the largest bucket <= len (shared by native and XLA paths).
pub fn bucket_for(len: usize) -> Option<usize> {
    BUCKETS.iter().rev().find(|&&b| b <= len).copied()
}

/// One branch's profile from scanning an existing RFIL file: what the
/// adaptive planner needs to re-plan compression settings after the fact.
#[derive(Debug, Clone)]
pub struct BranchProfile {
    pub branch_id: u32,
    pub name: String,
    /// Basket count for this branch (from the directory).
    pub baskets: u32,
    /// Total uncompressed bytes across the branch's baskets.
    pub logical_bytes: u64,
    /// Analyzer features of the branch's first basket (`None` when every
    /// basket is below the smallest analyzer bucket).
    pub features: Option<Features>,
}

/// Profile every branch of an existing RFIL file: stream one basket per
/// branch through the parallel read pipeline
/// ([`crate::coordinator::ParallelTreeReader`]) and run the native analyzer
/// over its logical payload. Feed the resulting features into
/// [`crate::coordinator::Planner::plan_from_features`] to propose new
/// per-branch settings for a rewrite (the paper's §3 "switch between
/// compression algorithms and settings" workflow, applied retroactively) —
/// or into [`crate::coordinator::Planner::plan_from_feedback`] together
/// with a recorded access profile ([`crate::runtime::ReadFeedback`],
/// intensity = profile bytes read / `BranchProfile::logical_bytes`) so the
/// replan weights each branch by what analyses actually read
/// (`rootio inspect --replan profile --profile reads.profile`).
///
/// The basket sweep rides a
/// [`ProjectionPlan::first_baskets`](crate::coordinator::ProjectionPlan::first_baskets)
/// prefetch plan: the first baskets of all branches, sorted by file offset,
/// so profiling is **one monotonically-increasing pass** over the head of
/// the file instead of a branch-major walk that seeks back per branch.
pub fn analyze_tree(path: &Path, workers: usize) -> Result<Vec<BranchProfile>> {
    use crate::coordinator::{ParallelTreeReader, ProjectionPlan, ReadAhead};
    let reader = ParallelTreeReader::open(path, ReadAhead::with_workers(workers.max(1)))?;
    let plan = ProjectionPlan::first_baskets(&reader.meta);
    debug_assert!(plan.is_monotonic_sweep());
    let mut profiles: Vec<BranchProfile> = reader
        .meta
        .branches
        .iter()
        .enumerate()
        .map(|(b, def)| BranchProfile {
            branch_id: b as u32,
            name: def.name.clone(),
            baskets: 0,
            logical_bytes: 0,
            features: None,
        })
        .collect();
    for loc in &reader.meta.baskets {
        if let Some(p) = profiles.get_mut(loc.branch_id as usize) {
            p.baskets += 1;
            p.logical_bytes += loc.uncompressed_len as u64;
        }
    }
    let mut scan = reader.scan(plan.locs().to_vec())?;
    let mut logical = Vec::new();
    while let Some(item) = scan.next_basket() {
        let (loc, content) = item?;
        if let Some(p) = profiles.get_mut(loc.branch_id as usize) {
            // Rebuild the logical payload (data then big-endian offsets) —
            // the same bytes the write-side planner analyzes.
            logical.clear();
            logical.extend_from_slice(&content.data);
            for &o in &content.offsets {
                logical.extend_from_slice(&o.to_be_bytes());
            }
            p.features = bucket_for(logical.len()).and_then(|b| analyze_native(&logical, b));
        }
        scan.recycle(content);
    }
    Ok(profiles)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(100), None);
        assert_eq!(bucket_for(4096), Some(4096));
        assert_eq!(bucket_for(40_000), Some(32_768));
        assert_eq!(bucket_for(1 << 20), Some(262_144));
    }

    #[test]
    fn native_features_separate_offsets_from_noise() {
        let offsets: Vec<u8> = (1u32..=2048).flat_map(|i| i.to_be_bytes()).collect();
        let f = analyze_native(&offsets, 4096).unwrap();
        assert!(f.h_bitshuffle < 0.5 * f.h_raw, "{f:?}");

        let mut rng = crate::util::rng::Rng::new(1);
        let noise = rng.bytes(8192);
        let f = analyze_native(&noise, 4096).unwrap();
        assert!(f.h_bitshuffle > 0.95 * f.h_raw, "{f:?}");
    }

    // XLA-path tests live in rust/tests/integration_runtime.rs (they need
    // artifacts/ built).
}
