//! PJRT runtime: loads the AOT-compiled basket-analyzer HLO artifacts and
//! executes them from the request path. Python is never involved here —
//! `make artifacts` ran once at build time (see python/compile/aot.py and
//! DESIGN.md §2).

pub mod analyzer;
pub mod feedback;

pub use analyzer::{analyze_tree, Analyzer, BranchProfile, Features, BUCKETS, NUM_FEATURES};
pub use feedback::{BranchFeedback, ReadFeedback};

use anyhow::Result;

/// Create the CPU PJRT client (one per process; cheap to share by ref).
pub fn cpu_client() -> Result<xla::PjRtClient> {
    Ok(xla::PjRtClient::cpu()?)
}
