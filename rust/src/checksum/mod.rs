//! Checksums used by the codec wrappers (paper §2.1 identifies these as
//! ZLIB hotspots): Adler-32 for the zlib stream format, CRC-32 for the
//! basket record payloads and the Fig-5 hardware-vs-software study.

pub mod adler32;
pub mod crc32;

pub use adler32::{adler32, adler32_with, Adler32};
pub use crc32::{crc32, crc32_with, Crc32};
