//! CRC-32 (IEEE 802.3, polynomial 0xEDB88320) with three backends.
//!
//! The paper's Fig 5 compares CF-ZLIB with and without *hardware* CRC32
//! instructions (SSE 4.2 `crc32`, ARMv8 `CRC32B/W/X`). We have no portable
//! intrinsics in this environment, so per DESIGN.md's substitution table the
//! "hardware" configuration is modeled by the strongest software kernel
//! (slice-by-8, ~8 bytes/iteration, limited by ALU not table lookups) and the
//! "no hardware" configuration by the classic 1-byte table loop; the bitwise
//! loop exists as a correctness oracle and worst-case reference.

/// Reflected IEEE polynomial.
const POLY: u32 = 0xEDB8_8320;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Bit-at-a-time (oracle; never used on the hot path).
    Bitwise,
    /// Classic single-table byte loop (models "no hardware crc32").
    Table,
    /// Slice-by-8 (models the "hardware crc32" configuration of Fig 5).
    #[default]
    Slice8,
}

/// 8 tables × 256 entries, built at first use.
struct Tables {
    t: [[u32; 256]; 8],
}

fn build_tables() -> Tables {
    let mut t = [[0u32; 256]; 8];
    for i in 0..256usize {
        let mut crc = i as u32;
        for _ in 0..8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
        }
        t[0][i] = crc;
    }
    for k in 1..8 {
        for i in 0..256usize {
            let prev = t[k - 1][i];
            t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
        }
    }
    Tables { t }
}

fn tables() -> &'static Tables {
    use std::sync::OnceLock;
    static TABLES: OnceLock<Tables> = OnceLock::new();
    TABLES.get_or_init(build_tables)
}

/// Streaming CRC-32 state.
#[derive(Debug, Clone, Copy)]
pub struct Crc32 {
    state: u32, // pre-inverted
    backend: Backend,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new(Backend::default())
    }
}

impl Crc32 {
    pub fn new(backend: Backend) -> Self {
        Self { state: 0xFFFF_FFFF, backend }
    }

    pub fn from_value(value: u32, backend: Backend) -> Self {
        Self { state: !value, backend }
    }

    #[inline]
    pub fn update(&mut self, data: &[u8]) {
        self.state = match self.backend {
            Backend::Bitwise => update_bitwise(self.state, data),
            Backend::Table => update_table(self.state, data),
            Backend::Slice8 => update_slice8(self.state, data),
        };
    }

    pub fn value(&self) -> u32 {
        !self.state
    }
}

fn update_bitwise(mut crc: u32, data: &[u8]) -> u32 {
    for &byte in data {
        crc ^= byte as u32;
        for _ in 0..8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
        }
    }
    crc
}

fn update_table(mut crc: u32, data: &[u8]) -> u32 {
    let t = &tables().t[0];
    for &byte in data {
        crc = (crc >> 8) ^ t[((crc ^ byte as u32) & 0xFF) as usize];
    }
    crc
}

fn update_slice8(mut crc: u32, data: &[u8]) -> u32 {
    let tb = tables();
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let lo = u32::from_le_bytes(c[0..4].try_into().unwrap()) ^ crc;
        let hi = u32::from_le_bytes(c[4..8].try_into().unwrap());
        crc = tb.t[7][(lo & 0xFF) as usize]
            ^ tb.t[6][((lo >> 8) & 0xFF) as usize]
            ^ tb.t[5][((lo >> 16) & 0xFF) as usize]
            ^ tb.t[4][(lo >> 24) as usize]
            ^ tb.t[3][(hi & 0xFF) as usize]
            ^ tb.t[2][((hi >> 8) & 0xFF) as usize]
            ^ tb.t[1][((hi >> 16) & 0xFF) as usize]
            ^ tb.t[0][(hi >> 24) as usize];
    }
    update_table(crc, chunks.remainder())
}

/// One-shot convenience with the default backend.
pub fn crc32(data: &[u8]) -> u32 {
    crc32_with(data, Backend::default())
}

pub fn crc32_with(data: &[u8], backend: Backend) -> u32 {
    let mut c = Crc32::new(backend);
    c.update(data);
    c.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn known_vectors() {
        // Standard check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn backends_agree() {
        let mut rng = Rng::new(0xC3C3);
        for _ in 0..40 {
            let n = rng.range(0, 30_000);
            let data = rng.bytes(n);
            let b = crc32_with(&data, Backend::Bitwise);
            let t = crc32_with(&data, Backend::Table);
            let s = crc32_with(&data, Backend::Slice8);
            assert_eq!(b, t, "bitwise vs table, n={n}");
            assert_eq!(b, s, "bitwise vs slice8, n={n}");
        }
    }

    #[test]
    fn streaming_equals_oneshot() {
        let mut rng = Rng::new(0xC3C4);
        let data = rng.bytes(65_536 + 3);
        for backend in [Backend::Bitwise, Backend::Table, Backend::Slice8] {
            let mut c = Crc32::new(backend);
            let mut pos = 0;
            while pos < data.len() {
                let step = rng.range(1, 777).min(data.len() - pos);
                c.update(&data[pos..pos + step]);
                pos += step;
            }
            assert_eq!(c.value(), crc32_with(&data, backend));
        }
    }

    #[test]
    fn resume_from_value() {
        let data = b"crc32 resume test vector 0123456789";
        let full = crc32(data);
        let mut c = Crc32::new(Backend::Slice8);
        c.update(&data[..7]);
        let mut c2 = Crc32::from_value(c.value(), Backend::Slice8);
        c2.update(&data[7..]);
        assert_eq!(c2.value(), full);
    }
}
