//! Adler-32 (RFC 1950 §8) — the zlib stream checksum.
//!
//! The paper (§2.1) identifies adler32 as a ZLIB hotspot and describes the
//! Cloudflare fix: vectorized byte summation via `_mm_sad_epu8` plus reduced
//! loop unrolling (16 → 8). We provide three backends so Fig 5's
//! "hardware vs software checksum" axis can be reproduced on one host:
//!
//! * [`Backend::Scalar`]   — the classic byte-at-a-time reference loop
//!   (models stock zlib on a CPU without SSE4.2).
//! * [`Backend::Unrolled`] — zlib's 16×-unrolled `DO16` loop.
//! * [`Backend::Swar`]     — the CF-style kernel: 8-byte-wide accumulation
//!   using SWAR (SIMD-within-a-register) byte sums, the portable analogue of
//!   `_mm_sad_epu8`, with 8× unrolling per CF's tuning.

const MOD: u32 = 65_521;
/// Largest n such that 255*n*(n+1)/2 + (n+1)*(MOD-1) fits in u32 (zlib NMAX).
const NMAX: usize = 5552;

/// Which adler32 kernel to use. Mirrors zlib-reference vs Cloudflare builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Byte-at-a-time (pre-SIMD reference).
    Scalar,
    /// Reference zlib 16×-unrolled loop.
    Unrolled,
    /// Cloudflare-style SWAR kernel (portable `_mm_sad_epu8` analogue).
    #[default]
    Swar,
}

/// Streaming Adler-32 state.
#[derive(Debug, Clone, Copy)]
pub struct Adler32 {
    a: u32,
    b: u32,
    backend: Backend,
}

impl Default for Adler32 {
    fn default() -> Self {
        Self::new(Backend::default())
    }
}

impl Adler32 {
    pub fn new(backend: Backend) -> Self {
        Self { a: 1, b: 0, backend }
    }

    pub fn from_value(value: u32, backend: Backend) -> Self {
        Self { a: value & 0xFFFF, b: value >> 16, backend }
    }

    #[inline]
    pub fn update(&mut self, data: &[u8]) {
        match self.backend {
            Backend::Scalar => self.update_scalar(data),
            Backend::Unrolled => self.update_unrolled(data),
            Backend::Swar => self.update_swar(data),
        }
    }

    pub fn value(&self) -> u32 {
        (self.b << 16) | self.a
    }

    fn update_scalar(&mut self, data: &[u8]) {
        let (mut a, mut b) = (self.a, self.b);
        for chunk in data.chunks(NMAX) {
            for &byte in chunk {
                a += byte as u32;
                b += a;
            }
            a %= MOD;
            b %= MOD;
        }
        self.a = a;
        self.b = b;
    }

    fn update_unrolled(&mut self, data: &[u8]) {
        let (mut a, mut b) = (self.a, self.b);
        for chunk in data.chunks(NMAX) {
            let mut iter = chunk.chunks_exact(16);
            for group in &mut iter {
                // zlib's DO16 macro.
                for &byte in group {
                    a += byte as u32;
                    b += a;
                }
            }
            for &byte in iter.remainder() {
                a += byte as u32;
                b += a;
            }
            a %= MOD;
            b %= MOD;
        }
        self.a = a;
        self.b = b;
    }

    /// SWAR kernel: process 8 bytes per step with u64 lane arithmetic.
    ///
    /// For a block of k bytes starting from state (a, b):
    ///   a' = a + sum(x_i)
    ///   b' = b + k*a + sum((k - i) * x_i)            (i = 0-based)
    /// We compute sum(x_i) with a SWAR horizontal add (the `_mm_sad_epu8`
    /// role) and the weighted sum with per-lane multipliers.
    fn update_swar(&mut self, data: &[u8]) {
        let (mut a, mut b) = (self.a as u64, self.b as u64);
        for chunk in data.chunks(NMAX) {
            let mut iter = chunk.chunks_exact(8);
            for g in &mut iter {
                let v = u64::from_le_bytes(g.try_into().unwrap());
                // Horizontal byte sum via SWAR: mask alternate bytes, add.
                let even = v & 0x00FF_00FF_00FF_00FF;
                let odd = (v >> 8) & 0x00FF_00FF_00FF_00FF;
                let pairs = even + odd; // four 16-bit partial sums
                let quads = (pairs & 0x0000_FFFF_0000_FFFF) + (pairs >> 16 & 0x0000_FFFF_0000_FFFF);
                let total = (quads & 0xFFFF_FFFF) + (quads >> 32);
                // Weighted sum: weight of byte i (0..8) is (8 - i).
                let w = (g[0] as u64) * 8
                    + (g[1] as u64) * 7
                    + (g[2] as u64) * 6
                    + (g[3] as u64) * 5
                    + (g[4] as u64) * 4
                    + (g[5] as u64) * 3
                    + (g[6] as u64) * 2
                    + (g[7] as u64);
                b += 8 * a + w;
                a += total;
            }
            for &byte in iter.remainder() {
                a += byte as u64;
                b += a;
            }
            a %= MOD as u64;
            b %= MOD as u64;
        }
        self.a = a as u32;
        self.b = b as u32;
    }
}

/// One-shot convenience.
pub fn adler32(data: &[u8]) -> u32 {
    adler32_with(data, Backend::default())
}

/// One-shot with an explicit backend.
pub fn adler32_with(data: &[u8], backend: Backend) -> u32 {
    let mut s = Adler32::new(backend);
    s.update(data);
    s.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // RFC 1950 / zlib-documented vectors.
    #[test]
    fn known_vectors() {
        assert_eq!(adler32(b""), 1);
        assert_eq!(adler32(b"a"), 0x00620062);
        assert_eq!(adler32(b"abc"), 0x024d0127);
        assert_eq!(adler32(b"Wikipedia"), 0x11E60398);
    }

    #[test]
    fn backends_agree_on_random_data() {
        let mut rng = Rng::new(0xADE1);
        for _ in 0..50 {
            let n = rng.range(0, 40_000);
            let data = rng.bytes(n);
            let s = adler32_with(&data, Backend::Scalar);
            let u = adler32_with(&data, Backend::Unrolled);
            let w = adler32_with(&data, Backend::Swar);
            assert_eq!(s, u, "scalar vs unrolled, n={n}");
            assert_eq!(s, w, "scalar vs swar, n={n}");
        }
    }

    #[test]
    fn streaming_equals_oneshot() {
        let mut rng = Rng::new(0xADE2);
        let data = rng.bytes(100_000);
        for backend in [Backend::Scalar, Backend::Unrolled, Backend::Swar] {
            let mut s = Adler32::new(backend);
            let mut pos = 0;
            while pos < data.len() {
                let step = rng.range(1, 9999).min(data.len() - pos);
                s.update(&data[pos..pos + step]);
                pos += step;
            }
            assert_eq!(s.value(), adler32_with(&data, backend));
        }
    }

    #[test]
    fn worst_case_all_0xff_no_overflow() {
        // NMAX is chosen so this cannot overflow u32 in the scalar path.
        let data = vec![0xFFu8; NMAX * 3 + 5];
        let s = adler32_with(&data, Backend::Scalar);
        let w = adler32_with(&data, Backend::Swar);
        assert_eq!(s, w);
    }

    #[test]
    fn from_value_resumes() {
        let data = b"hello world, adler32 resume test";
        let full = adler32(data);
        let mut s1 = Adler32::new(Backend::Swar);
        s1.update(&data[..10]);
        let mut s2 = Adler32::from_value(s1.value(), Backend::Swar);
        s2.update(&data[10..]);
        assert_eq!(s2.value(), full);
    }
}
