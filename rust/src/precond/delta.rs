//! Delta preconditioner: byte-wise delta with configurable stride.
//!
//! Not in the paper's headline figures but part of the same Blosc-inspired
//! family (§2.2) and used by the adaptive planner as a third candidate view:
//! ROOT offset arrays are *monotone*, so deltas of the serialized integers
//! are tiny and compress extremely well even without an entropy stage.

/// Forward delta: `out[i] = data[i] - data[i - stride]` (wrapping), first
/// `stride` bytes verbatim.
pub fn delta(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = data.to_vec();
    delta_in_place(&mut out, stride);
    out
}

/// In-place forward delta.
pub fn delta_in_place(data: &mut [u8], stride: usize) {
    if stride == 0 || data.len() <= stride {
        return;
    }
    // Walk backwards so each source byte is still the original value.
    for i in (stride..data.len()).rev() {
        data[i] = data[i].wrapping_sub(data[i - stride]);
    }
}

/// Inverse delta (prefix sum with stride).
pub fn undelta(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = data.to_vec();
    undelta_in_place(&mut out, stride);
    out
}

/// In-place inverse delta.
pub fn undelta_in_place(data: &mut [u8], stride: usize) {
    if stride == 0 || data.len() <= stride {
        return;
    }
    for i in stride..data.len() {
        data[i] = data[i].wrapping_add(data[i - stride]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(0xDE17A);
        for _ in 0..200 {
            let n = rng.range(0, 3000);
            let stride = rng.range(1, 9);
            let data = rng.bytes(n);
            assert_eq!(undelta(&delta(&data, stride), stride), data);
        }
    }

    #[test]
    fn monotone_u32_offsets_become_sparse() {
        // Offsets 4, 8, 12, ... (BE u32) -> stride-4 delta is the constant 4
        // in the low byte and zeros elsewhere.
        let mut data = Vec::new();
        for i in 1u32..=64 {
            data.extend_from_slice(&(i * 4).to_be_bytes());
        }
        let d = delta(&data, 4);
        // After the first element, bytes are 0,0,0,4 repeating (with
        // borrows at 256-boundaries; 64*4=256 exactly hits one boundary).
        let fours = d.iter().filter(|&&b| b == 4).count();
        let zeros = d.iter().filter(|&&b| b == 0).count();
        assert!(fours >= 62, "fours={fours}");
        assert!(zeros >= 3 * 62, "zeros={zeros}");
    }

    #[test]
    fn short_input_untouched() {
        let data = [9u8, 8, 7];
        assert_eq!(delta(&data, 4), data.to_vec());
        assert_eq!(delta(&data, 3), data.to_vec());
    }
}
