//! Preconditioners (paper §2.2): deterministic, invertible byte transforms
//! applied before compression to expose structure to byte-aligned matchers.
//!
//! The paper investigates Blosc-inspired Shuffle and BitShuffle to rescue
//! LZ4's compression ratio on ROOT offset arrays (Fig 6); we additionally
//! ship a Delta transform used by the adaptive planner.
//!
//! # §Perf fast paths
//!
//! * **BitShuffle** runs as a SWAR loop: each 8-element × 8-bit tile is
//!   gathered into a `u64` and transposed with the Hacker's-Delight 8×8
//!   bit-matrix trick (~18 ALU ops) instead of bit-at-a-time shifts. The
//!   scalar loop survives as `bitshuffle::reference::{bitshuffle_naive,
//!   unbitshuffle_naive}` — also the executable statement of the layout
//!   contract shared with the Pallas kernel
//!   (`python/compile/kernels/bitshuffle.py`).
//! * **Shuffle** has single-pass specializations for the common strides
//!   2/4/8 (one `chunks_exact` read pass, `stride` sequential write
//!   streams via `split_at_mut`); the any-stride per-plane loop survives as
//!   `shuffle::reference::{shuffle_naive, unshuffle_naive}`.
//!
//! Equivalence guarantee: every fast path is byte-identical to its naive
//! reference for all (input, stride) — property-tested in
//! `rust/tests/prop_codecs.rs` across the fuzz corpus, so on-disk bytes are
//! unchanged by the optimization PR.

pub mod bitshuffle;
pub mod delta;
pub mod shuffle;

pub use bitshuffle::{bitshuffle, bitshuffle_into, unbitshuffle, unbitshuffle_into};
pub use delta::{delta, delta_in_place, undelta, undelta_in_place};
pub use shuffle::{shuffle, shuffle_into, unshuffle, unshuffle_into};

/// Preconditioner selector, stored in the basket record header so readers
/// can invert the transform without out-of-band metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precond {
    /// No transform.
    #[default]
    None,
    /// Byte shuffle with element size in bytes.
    Shuffle(u8),
    /// Bit shuffle with element size in bytes.
    BitShuffle(u8),
    /// Byte-wise delta with stride in bytes.
    Delta(u8),
}

impl Precond {
    /// Apply the forward transform.
    pub fn apply(&self, data: &[u8]) -> Vec<u8> {
        match *self {
            Precond::None => data.to_vec(),
            Precond::Shuffle(s) => shuffle(data, s as usize),
            Precond::BitShuffle(s) => bitshuffle(data, s as usize),
            Precond::Delta(s) => delta(data, s as usize),
        }
    }

    /// Apply the inverse transform.
    pub fn invert(&self, data: &[u8]) -> Vec<u8> {
        match *self {
            Precond::None => data.to_vec(),
            Precond::Shuffle(s) => unshuffle(data, s as usize),
            Precond::BitShuffle(s) => unbitshuffle(data, s as usize),
            Precond::Delta(s) => undelta(data, s as usize),
        }
    }

    /// Encode as (tag, stride) for the record header.
    pub fn encode(&self) -> (u8, u8) {
        match *self {
            Precond::None => (0, 0),
            Precond::Shuffle(s) => (1, s),
            Precond::BitShuffle(s) => (2, s),
            Precond::Delta(s) => (3, s),
        }
    }

    /// Decode from (tag, stride); unknown tags are an error.
    pub fn decode(tag: u8, stride: u8) -> Option<Self> {
        Some(match tag {
            0 => Precond::None,
            1 => Precond::Shuffle(stride),
            2 => Precond::BitShuffle(stride),
            3 => Precond::Delta(stride),
            _ => return None,
        })
    }

    /// Human-readable label used in figure output.
    pub fn label(&self) -> String {
        match *self {
            Precond::None => "none".into(),
            Precond::Shuffle(s) => format!("shuffle{s}"),
            Precond::BitShuffle(s) => format!("bitshuffle{s}"),
            Precond::Delta(s) => format!("delta{s}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn all_variants_roundtrip() {
        let mut rng = Rng::new(0x9999);
        let variants = [
            Precond::None,
            Precond::Shuffle(4),
            Precond::Shuffle(8),
            Precond::BitShuffle(2),
            Precond::BitShuffle(4),
            Precond::Delta(1),
            Precond::Delta(4),
        ];
        for _ in 0..50 {
            let n = rng.range(0, 4000);
            let data = rng.bytes(n);
            for p in variants {
                assert_eq!(p.invert(&p.apply(&data)), data, "{p:?}");
            }
        }
    }

    #[test]
    fn encode_decode() {
        for p in [
            Precond::None,
            Precond::Shuffle(4),
            Precond::BitShuffle(8),
            Precond::Delta(2),
        ] {
            let (t, s) = p.encode();
            assert_eq!(Precond::decode(t, s), Some(p));
        }
        assert_eq!(Precond::decode(77, 4), None);
    }
}
