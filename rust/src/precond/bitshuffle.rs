//! BitShuffle preconditioner (Blosc/bitshuffle-style), paper §2.2 & Fig 6.
//!
//! Like byte Shuffle but at bit granularity: viewing the buffer as a matrix
//! of `nelem` elements × `elem_bits` bits, BitShuffle transposes it so bit k
//! of every element is contiguous. For ROOT offset arrays (monotone
//! integers) almost all high bits are constant, so the transposed buffer is
//! dominated by all-zero / all-one bytes — ideal for LZ4.
//!
//! Layout contract (shared with the Pallas kernel in
//! `python/compile/kernels/bitshuffle.py`, property-tested against it):
//! within each `stride`-byte element, bits are indexed `byte*8 + bit` with
//! bit 0 the LSB of byte 0; output plane k (one of `stride*8`) holds bit k
//! of elements `0..nelem`, packed LSB-first, plane-major. The non-multiple
//! tail is copied verbatim.
//!
//! This transform is the repository's L1 kernel: the rust implementation
//! here is the production (request-path) version; the Pallas kernel is the
//! TPU mapping of the same math.

/// Bit-transpose `data` with element size `stride` bytes.
pub fn bitshuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = vec![0u8; data.len()];
    bitshuffle_into(data, stride, &mut out);
    out
}

/// Bit-transpose into a caller-provided buffer.
///
/// `nelem = data.len() / stride` elements participate; requires the body
/// bit-count per plane (`nelem`) to pack into `ceil(nelem/8)` bytes. To keep
/// the transform length-preserving and self-inverting we require
/// `nelem % 8 == 0` for the bit stage; when it is not, we fall back to byte
/// shuffle semantics for the ragged group (last `nelem % 8` elements join
/// the verbatim tail).
pub fn bitshuffle_into(data: &[u8], stride: usize, out: &mut [u8]) {
    assert_eq!(data.len(), out.len());
    if stride == 0 || data.len() < stride * 8 {
        out.copy_from_slice(data);
        return;
    }
    let nelem_total = data.len() / stride;
    let nelem = nelem_total & !7; // multiple of 8 elements in the bit stage
    let body = nelem * stride;
    let planes = stride * 8; // total bit planes
    let plane_bytes = nelem / 8;

    // SWAR hot loop (§Perf): for each 8-element group and each byte slot,
    // gather the 8 bytes into a u64 (byte lane = element), transpose the
    // 8x8 bit matrix in ~18 ALU ops, and scatter the 8 resulting bytes to
    // their bit planes. ~8x fewer operations than the bit-at-a-time loop.
    // Loop order: byte slot outer, group inner — the 8 plane-write streams
    // advance sequentially with g instead of scattering across all
    // stride*8 planes per group (§Perf iteration 2).
    let groups = nelem / 8;
    for b in 0..stride {
        for g in 0..groups {
            let base = g * 8 * stride;
            let p = base + b;
            let x = (data[p] as u64)
                | (data[p + stride] as u64) << 8
                | (data[p + 2 * stride] as u64) << 16
                | (data[p + 3 * stride] as u64) << 24
                | (data[p + 4 * stride] as u64) << 32
                | (data[p + 5 * stride] as u64) << 40
                | (data[p + 6 * stride] as u64) << 48
                | (data[p + 7 * stride] as u64) << 56;
            let y = transpose8x8(x);
            // Byte lane `bit` of y is the plane byte for plane b*8+bit.
            let plane0 = b * 8;
            let yb = y.to_le_bytes();
            out[plane0 * plane_bytes + g] = yb[0];
            out[(plane0 + 1) * plane_bytes + g] = yb[1];
            out[(plane0 + 2) * plane_bytes + g] = yb[2];
            out[(plane0 + 3) * plane_bytes + g] = yb[3];
            out[(plane0 + 4) * plane_bytes + g] = yb[4];
            out[(plane0 + 5) * plane_bytes + g] = yb[5];
            out[(plane0 + 6) * plane_bytes + g] = yb[6];
            out[(plane0 + 7) * plane_bytes + g] = yb[7];
        }
    }
    let _ = planes;
    out[body..].copy_from_slice(&data[body..]);
}

/// Inverse of [`bitshuffle`].
pub fn unbitshuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = vec![0u8; data.len()];
    unbitshuffle_into(data, stride, &mut out);
    out
}

/// Inverse bit-transpose into a caller-provided buffer.
pub fn unbitshuffle_into(data: &[u8], stride: usize, out: &mut [u8]) {
    assert_eq!(data.len(), out.len());
    if stride == 0 || data.len() < stride * 8 {
        out.copy_from_slice(data);
        return;
    }
    let nelem_total = data.len() / stride;
    let nelem = nelem_total & !7;
    let body = nelem * stride;
    let planes = stride * 8;
    let plane_bytes = nelem / 8;

    // Inverse SWAR loop: gather the 8 plane bytes of one byte slot into a
    // u64 (byte lane = bit), transpose back, scatter to the 8 elements.
    let groups = nelem / 8;
    let _ = planes;
    for g in 0..groups {
        let base = g * 8 * stride;
        for b in 0..stride {
            let plane0 = b * 8;
            let x = (data[plane0 * plane_bytes + g] as u64)
                | (data[(plane0 + 1) * plane_bytes + g] as u64) << 8
                | (data[(plane0 + 2) * plane_bytes + g] as u64) << 16
                | (data[(plane0 + 3) * plane_bytes + g] as u64) << 24
                | (data[(plane0 + 4) * plane_bytes + g] as u64) << 32
                | (data[(plane0 + 5) * plane_bytes + g] as u64) << 40
                | (data[(plane0 + 6) * plane_bytes + g] as u64) << 48
                | (data[(plane0 + 7) * plane_bytes + g] as u64) << 56;
            let y = transpose8x8(x);
            let yb = y.to_le_bytes();
            let p = base + b;
            out[p] = yb[0];
            out[p + stride] = yb[1];
            out[p + 2 * stride] = yb[2];
            out[p + 3 * stride] = yb[3];
            out[p + 4 * stride] = yb[4];
            out[p + 5 * stride] = yb[5];
            out[p + 6 * stride] = yb[6];
            out[p + 7 * stride] = yb[7];
        }
    }
    out[body..].copy_from_slice(&data[body..]);
}

/// Bit-at-a-time reference implementations (pre-optimization), kept as the
/// oracle for the SWAR fast path: `rust/tests/prop_codecs.rs` asserts the
/// u64 8×8-transpose loops above are byte-identical to these for every
/// (input, stride). Also the executable statement of the layout contract
/// shared with the Pallas kernel.
#[doc(hidden)]
pub mod reference {
    /// Scalar bit-by-bit forward transform; same layout contract as
    /// [`super::bitshuffle`].
    pub fn bitshuffle_naive(data: &[u8], stride: usize) -> Vec<u8> {
        let mut out = vec![0u8; data.len()];
        if stride == 0 || data.len() < stride * 8 {
            out.copy_from_slice(data);
            return out;
        }
        let nelem = (data.len() / stride) & !7;
        let body = nelem * stride;
        let plane_bytes = nelem / 8;
        for e in 0..nelem {
            for b in 0..stride {
                let byte = data[e * stride + b];
                for bit in 0..8 {
                    let v = (byte >> bit) & 1;
                    let plane = b * 8 + bit;
                    out[plane * plane_bytes + e / 8] |= v << (e % 8);
                }
            }
        }
        out[body..].copy_from_slice(&data[body..]);
        out
    }

    /// Scalar bit-by-bit inverse transform.
    pub fn unbitshuffle_naive(data: &[u8], stride: usize) -> Vec<u8> {
        let mut out = vec![0u8; data.len()];
        if stride == 0 || data.len() < stride * 8 {
            out.copy_from_slice(data);
            return out;
        }
        let nelem = (data.len() / stride) & !7;
        let body = nelem * stride;
        let plane_bytes = nelem / 8;
        for e in 0..nelem {
            for b in 0..stride {
                let mut acc = 0u8;
                for bit in 0..8 {
                    let plane = b * 8 + bit;
                    let v = (data[plane * plane_bytes + e / 8] >> (e % 8)) & 1;
                    acc |= v << bit;
                }
                out[e * stride + b] = acc;
            }
        }
        out[body..].copy_from_slice(&data[body..]);
        out
    }
}

/// 8x8 bit-matrix transpose in a u64 (Hacker's Delight §7-3): byte lane i,
/// bit j maps to byte lane j, bit i. Self-inverse.
#[inline]
fn transpose8x8(mut x: u64) -> u64 {
    let t = (x ^ (x >> 7)) & 0x00AA_00AA_00AA_00AA;
    x ^= t ^ (t << 7);
    let t = (x ^ (x >> 14)) & 0x0000_CCCC_0000_CCCC;
    x ^= t ^ (t << 14);
    let t = (x ^ (x >> 28)) & 0x0000_0000_F0F0_F0F0;
    x ^= t ^ (t << 28);
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(0xB175);
        for _ in 0..300 {
            let n = rng.range(0, 4096);
            let stride = rng.range(1, 12);
            let data = rng.bytes(n);
            assert_eq!(
                unbitshuffle(&bitshuffle(&data, stride), stride),
                data,
                "n={n} stride={stride}"
            );
        }
    }

    #[test]
    fn small_input_identity() {
        // Fewer than 8 elements: verbatim copy.
        let data = [1u8, 2, 3, 4, 5, 6, 7];
        assert_eq!(bitshuffle(&data, 4), data.to_vec());
    }

    #[test]
    fn constant_elements_become_constant_planes() {
        // 64 identical u32 elements -> every plane byte is 0x00 or 0xFF.
        let mut data = Vec::new();
        for _ in 0..64 {
            data.extend_from_slice(&0xA5C3_0F01u32.to_be_bytes());
        }
        let b = bitshuffle(&data, 4);
        assert!(b.iter().all(|&x| x == 0 || x == 0xFF));
    }

    #[test]
    fn monotone_offsets_mostly_zero() {
        // Fig 6 mechanism at bit granularity: offsets 1..512 (BE u32) leave
        // only the low ~9 bit planes non-constant.
        let mut data = Vec::new();
        for i in 1u32..=512 {
            data.extend_from_slice(&i.to_be_bytes());
        }
        let b = bitshuffle(&data, 4);
        let zeros = b.iter().filter(|&&x| x == 0).count();
        assert!(
            zeros as f64 > 0.6 * b.len() as f64,
            "zeros={zeros}/{}",
            b.len()
        );
    }

    #[test]
    fn single_bit_lands_in_right_plane() {
        // 8 elements of 2 bytes; element 3 has bit 5 of byte 1 set.
        let mut data = vec![0u8; 16];
        data[3 * 2 + 1] = 1 << 5;
        let b = bitshuffle(&data, 2);
        // plane index = byte_in_elem*8 + bit = 8 + 5 = 13; plane_bytes = 1.
        for (i, &x) in b.iter().enumerate() {
            if i == 13 {
                assert_eq!(x, 1 << 3); // element 3 -> bit 3 of the plane byte
            } else {
                assert_eq!(x, 0, "plane byte {i}");
            }
        }
    }

    #[test]
    fn swar_matches_naive_reference() {
        let mut rng = Rng::new(0xB177);
        for _ in 0..200 {
            let n = rng.range(0, 2000);
            let stride = rng.range(1, 10);
            let data = rng.bytes(n);
            let fast = bitshuffle(&data, stride);
            assert_eq!(fast, reference::bitshuffle_naive(&data, stride), "fwd n={n} stride={stride}");
            assert_eq!(
                unbitshuffle(&fast, stride),
                reference::unbitshuffle_naive(&fast, stride),
                "inv n={n} stride={stride}"
            );
        }
    }

    #[test]
    fn ragged_element_count_roundtrips() {
        // 13 elements of 4 bytes: 8 in the bit stage, 5 in the tail.
        let mut rng = Rng::new(0xB176);
        let data = rng.bytes(13 * 4);
        let b = bitshuffle(&data, 4);
        assert_eq!(&b[32..], &data[32..], "tail verbatim");
        assert_eq!(unbitshuffle(&b, 4), data);
    }
}
