//! Byte-Shuffle preconditioner (Blosc-style), paper §2.2.
//!
//! Rearranges an array of fixed-size elements so that byte k of every
//! element is stored contiguously: for stride 4 over bytes
//! `1,2,3,4,5,6,7,8` the output order is `1,5,2,6,3,7,4,8`. Serialized
//! integers that differ only in their low byte (ROOT offset arrays!) then
//! produce long runs of identical bytes, which LZ4's byte-aligned matcher
//! can finally exploit.
//!
//! The transform is applied to the largest prefix that is a multiple of
//! `stride`; the tail is copied verbatim (Blosc does the same), so any
//! buffer round-trips for any stride.

/// Shuffle `data` with element size `stride` into a new buffer.
pub fn shuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = vec![0u8; data.len()];
    shuffle_into(data, stride, &mut out);
    out
}

/// Shuffle into a caller-provided buffer (`out.len() == data.len()`).
///
/// §Perf: the common power-of-two strides (2/4/8 — i16/f32/f64 and the
/// offset arrays) take a single-pass specialization that reads each input
/// byte exactly once (`chunks_exact`, no bounds checks) and writes `stride`
/// sequential plane streams obtained via `split_at_mut`. The generic path
/// makes `stride` passes over the input instead. Outputs are identical;
/// property-tested against each other.
pub fn shuffle_into(data: &[u8], stride: usize, out: &mut [u8]) {
    assert_eq!(data.len(), out.len());
    if stride <= 1 || data.len() < stride {
        out.copy_from_slice(data);
        return;
    }
    let nelem = data.len() / stride;
    let body = nelem * stride;
    match stride {
        2 => shuffle2(&data[..body], &mut out[..body]),
        4 => shuffle4(&data[..body], &mut out[..body]),
        8 => shuffle8(&data[..body], &mut out[..body]),
        _ => {
            // out[k*nelem + i] = data[i*stride + k]
            for k in 0..stride {
                let dst = &mut out[k * nelem..(k + 1) * nelem];
                let mut src = k;
                for d in dst.iter_mut() {
                    *d = data[src];
                    src += stride;
                }
            }
        }
    }
    out[body..].copy_from_slice(&data[body..]);
}

fn shuffle2(body: &[u8], out: &mut [u8]) {
    let n = body.len() / 2;
    let (p0, p1) = out.split_at_mut(n);
    for (i, ch) in body.chunks_exact(2).enumerate() {
        p0[i] = ch[0];
        p1[i] = ch[1];
    }
}

fn shuffle4(body: &[u8], out: &mut [u8]) {
    let n = body.len() / 4;
    let (p0, rest) = out.split_at_mut(n);
    let (p1, rest) = rest.split_at_mut(n);
    let (p2, p3) = rest.split_at_mut(n);
    for (i, ch) in body.chunks_exact(4).enumerate() {
        p0[i] = ch[0];
        p1[i] = ch[1];
        p2[i] = ch[2];
        p3[i] = ch[3];
    }
}

fn shuffle8(body: &[u8], out: &mut [u8]) {
    let n = body.len() / 8;
    let (p0, rest) = out.split_at_mut(n);
    let (p1, rest) = rest.split_at_mut(n);
    let (p2, rest) = rest.split_at_mut(n);
    let (p3, rest) = rest.split_at_mut(n);
    let (p4, rest) = rest.split_at_mut(n);
    let (p5, rest) = rest.split_at_mut(n);
    let (p6, p7) = rest.split_at_mut(n);
    for (i, ch) in body.chunks_exact(8).enumerate() {
        p0[i] = ch[0];
        p1[i] = ch[1];
        p2[i] = ch[2];
        p3[i] = ch[3];
        p4[i] = ch[4];
        p5[i] = ch[5];
        p6[i] = ch[6];
        p7[i] = ch[7];
    }
}

/// Inverse of [`shuffle`].
pub fn unshuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = vec![0u8; data.len()];
    unshuffle_into(data, stride, &mut out);
    out
}

/// Inverse shuffle into a caller-provided buffer (same specializations as
/// the forward direction, mirrored: sequential plane reads, one output pass).
pub fn unshuffle_into(data: &[u8], stride: usize, out: &mut [u8]) {
    assert_eq!(data.len(), out.len());
    if stride <= 1 || data.len() < stride {
        out.copy_from_slice(data);
        return;
    }
    let nelem = data.len() / stride;
    let body = nelem * stride;
    match stride {
        2 => unshuffle2(&data[..body], &mut out[..body]),
        4 => unshuffle4(&data[..body], &mut out[..body]),
        8 => unshuffle8(&data[..body], &mut out[..body]),
        _ => {
            for k in 0..stride {
                let src = &data[k * nelem..(k + 1) * nelem];
                let mut dst = k;
                for &s in src.iter() {
                    out[dst] = s;
                    dst += stride;
                }
            }
        }
    }
    out[body..].copy_from_slice(&data[body..]);
}

fn unshuffle2(body: &[u8], out: &mut [u8]) {
    let n = body.len() / 2;
    let (p0, p1) = body.split_at(n);
    for (i, ch) in out.chunks_exact_mut(2).enumerate() {
        ch[0] = p0[i];
        ch[1] = p1[i];
    }
}

fn unshuffle4(body: &[u8], out: &mut [u8]) {
    let n = body.len() / 4;
    let (p0, rest) = body.split_at(n);
    let (p1, rest) = rest.split_at(n);
    let (p2, p3) = rest.split_at(n);
    for (i, ch) in out.chunks_exact_mut(4).enumerate() {
        ch[0] = p0[i];
        ch[1] = p1[i];
        ch[2] = p2[i];
        ch[3] = p3[i];
    }
}

fn unshuffle8(body: &[u8], out: &mut [u8]) {
    let n = body.len() / 8;
    let (p0, rest) = body.split_at(n);
    let (p1, rest) = rest.split_at(n);
    let (p2, rest) = rest.split_at(n);
    let (p3, rest) = rest.split_at(n);
    let (p4, rest) = rest.split_at(n);
    let (p5, rest) = rest.split_at(n);
    let (p6, p7) = rest.split_at(n);
    for (i, ch) in out.chunks_exact_mut(8).enumerate() {
        ch[0] = p0[i];
        ch[1] = p1[i];
        ch[2] = p2[i];
        ch[3] = p3[i];
        ch[4] = p4[i];
        ch[5] = p5[i];
        ch[6] = p6[i];
        ch[7] = p7[i];
    }
}

/// Generic per-plane reference implementations (the pre-specialization
/// code), kept as the oracle for the stride-specialized fast paths.
#[doc(hidden)]
pub mod reference {
    /// Plane-at-a-time forward shuffle for any stride.
    pub fn shuffle_naive(data: &[u8], stride: usize) -> Vec<u8> {
        let mut out = vec![0u8; data.len()];
        if stride <= 1 || data.len() < stride {
            out.copy_from_slice(data);
            return out;
        }
        let nelem = data.len() / stride;
        let body = nelem * stride;
        for k in 0..stride {
            let dst = &mut out[k * nelem..(k + 1) * nelem];
            let mut src = k;
            for d in dst.iter_mut() {
                *d = data[src];
                src += stride;
            }
        }
        out[body..].copy_from_slice(&data[body..]);
        out
    }

    /// Plane-at-a-time inverse shuffle for any stride.
    pub fn unshuffle_naive(data: &[u8], stride: usize) -> Vec<u8> {
        let mut out = vec![0u8; data.len()];
        if stride <= 1 || data.len() < stride {
            out.copy_from_slice(data);
            return out;
        }
        let nelem = data.len() / stride;
        let body = nelem * stride;
        for k in 0..stride {
            let src = &data[k * nelem..(k + 1) * nelem];
            let mut dst = k;
            for &s in src.iter() {
                out[dst] = s;
                dst += stride;
            }
        }
        out[body..].copy_from_slice(&data[body..]);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn specialized_strides_match_generic() {
        let mut rng = Rng::new(0x5F60);
        for _ in 0..200 {
            let n = rng.range(0, 4000);
            let data = rng.bytes(n);
            for stride in [2usize, 4, 8] {
                let fast = shuffle(&data, stride);
                assert_eq!(fast, reference::shuffle_naive(&data, stride), "fwd stride={stride} n={n}");
                assert_eq!(
                    unshuffle(&fast, stride),
                    reference::unshuffle_naive(&fast, stride),
                    "inv stride={stride} n={n}"
                );
            }
        }
    }

    #[test]
    fn paper_example() {
        // Paper §2.2: stride 4 over bytes 1..8 -> 1,5,2,6,3,7,4,8.
        let input = [1u8, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(shuffle(&input, 4), vec![1, 5, 2, 6, 3, 7, 4, 8]);
    }

    #[test]
    fn paper_offset_array_example() {
        // Big-endian 32-bit ints 1 and 2: 0,0,0,1,0,0,0,2 -> 0,0,0,0,0,0,1,2.
        let input = [0u8, 0, 0, 1, 0, 0, 0, 2];
        assert_eq!(shuffle(&input, 4), vec![0, 0, 0, 0, 0, 0, 1, 2]);
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(0x5F5F);
        for _ in 0..300 {
            let n = rng.range(0, 5000);
            let stride = rng.range(1, 16);
            let data = rng.bytes(n);
            assert_eq!(unshuffle(&shuffle(&data, stride), stride), data, "n={n} stride={stride}");
        }
    }

    #[test]
    fn tail_preserved() {
        let data = [1u8, 2, 3, 4, 5, 6, 7, 8, 9, 10];
        let s = shuffle(&data, 4);
        // Tail (bytes 9, 10) copied verbatim at the end.
        assert_eq!(&s[8..], &[9, 10]);
        assert_eq!(unshuffle(&s, 4), data);
    }

    #[test]
    fn stride_one_is_identity() {
        let data: Vec<u8> = (0..100).collect();
        assert_eq!(shuffle(&data, 1), data);
    }

    #[test]
    fn monotone_offsets_become_runs() {
        // The Fig-6 mechanism: a ROOT offset array (big-endian monotone ints)
        // shuffles into long zero runs.
        let mut data = Vec::new();
        for i in 1u32..=256 {
            data.extend_from_slice(&i.to_be_bytes());
        }
        let s = shuffle(&data, 4);
        // First 3*256 bytes are the three high bytes, almost all zero.
        let zeros = s[..768].iter().filter(|&&b| b == 0).count();
        assert!(zeros >= 767, "zeros={zeros}");
    }
}
