//! Byte-Shuffle preconditioner (Blosc-style), paper §2.2.
//!
//! Rearranges an array of fixed-size elements so that byte k of every
//! element is stored contiguously: for stride 4 over bytes
//! `1,2,3,4,5,6,7,8` the output order is `1,5,2,6,3,7,4,8`. Serialized
//! integers that differ only in their low byte (ROOT offset arrays!) then
//! produce long runs of identical bytes, which LZ4's byte-aligned matcher
//! can finally exploit.
//!
//! The transform is applied to the largest prefix that is a multiple of
//! `stride`; the tail is copied verbatim (Blosc does the same), so any
//! buffer round-trips for any stride.

/// Shuffle `data` with element size `stride` into a new buffer.
pub fn shuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = vec![0u8; data.len()];
    shuffle_into(data, stride, &mut out);
    out
}

/// Shuffle into a caller-provided buffer (`out.len() == data.len()`).
pub fn shuffle_into(data: &[u8], stride: usize, out: &mut [u8]) {
    assert_eq!(data.len(), out.len());
    if stride <= 1 || data.len() < stride {
        out.copy_from_slice(data);
        return;
    }
    let nelem = data.len() / stride;
    let body = nelem * stride;
    // out[k*nelem + i] = data[i*stride + k]
    for k in 0..stride {
        let dst = &mut out[k * nelem..(k + 1) * nelem];
        let mut src = k;
        for d in dst.iter_mut() {
            *d = data[src];
            src += stride;
        }
    }
    out[body..].copy_from_slice(&data[body..]);
}

/// Inverse of [`shuffle`].
pub fn unshuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = vec![0u8; data.len()];
    unshuffle_into(data, stride, &mut out);
    out
}

/// Inverse shuffle into a caller-provided buffer.
pub fn unshuffle_into(data: &[u8], stride: usize, out: &mut [u8]) {
    assert_eq!(data.len(), out.len());
    if stride <= 1 || data.len() < stride {
        out.copy_from_slice(data);
        return;
    }
    let nelem = data.len() / stride;
    let body = nelem * stride;
    for k in 0..stride {
        let src = &data[k * nelem..(k + 1) * nelem];
        let mut dst = k;
        for &s in src.iter() {
            out[dst] = s;
            dst += stride;
        }
    }
    out[body..].copy_from_slice(&data[body..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn paper_example() {
        // Paper §2.2: stride 4 over bytes 1..8 -> 1,5,2,6,3,7,4,8.
        let input = [1u8, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(shuffle(&input, 4), vec![1, 5, 2, 6, 3, 7, 4, 8]);
    }

    #[test]
    fn paper_offset_array_example() {
        // Big-endian 32-bit ints 1 and 2: 0,0,0,1,0,0,0,2 -> 0,0,0,0,0,0,1,2.
        let input = [0u8, 0, 0, 1, 0, 0, 0, 2];
        assert_eq!(shuffle(&input, 4), vec![0, 0, 0, 0, 0, 0, 1, 2]);
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(0x5F5F);
        for _ in 0..300 {
            let n = rng.range(0, 5000);
            let stride = rng.range(1, 16);
            let data = rng.bytes(n);
            assert_eq!(unshuffle(&shuffle(&data, stride), stride), data, "n={n} stride={stride}");
        }
    }

    #[test]
    fn tail_preserved() {
        let data = [1u8, 2, 3, 4, 5, 6, 7, 8, 9, 10];
        let s = shuffle(&data, 4);
        // Tail (bytes 9, 10) copied verbatim at the end.
        assert_eq!(&s[8..], &[9, 10]);
        assert_eq!(unshuffle(&s, 4), data);
    }

    #[test]
    fn stride_one_is_identity() {
        let data: Vec<u8> = (0..100).collect();
        assert_eq!(shuffle(&data, 1), data);
    }

    #[test]
    fn monotone_offsets_become_runs() {
        // The Fig-6 mechanism: a ROOT offset array (big-endian monotone ints)
        // shuffles into long zero runs.
        let mut data = Vec::new();
        for i in 1u32..=256 {
            data.extend_from_slice(&i.to_be_bytes());
        }
        let s = shuffle(&data, 4);
        // First 3*256 bytes are the three high bytes, almost all zero.
        let zeros = s[..768].iter().filter(|&&b| b == 0).count();
        assert!(zeros >= 767, "zeros={zeros}");
    }
}
