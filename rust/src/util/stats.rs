//! Small statistics helpers shared by the bench harness, the adaptive
//! planner's pure-rust fallback heuristics, and tests.

/// Shannon entropy (bits/byte) of a byte buffer.
pub fn shannon_entropy(data: &[u8]) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    let mut hist = [0u64; 256];
    for &b in data {
        hist[b as usize] += 1;
    }
    entropy_from_hist(&hist, data.len() as u64)
}

/// Shannon entropy (bits/symbol) from a histogram with `total` counts.
pub fn entropy_from_hist(hist: &[u64; 256], total: u64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let inv = 1.0 / total as f64;
    let mut h = 0.0;
    for &c in hist.iter() {
        if c > 0 {
            let p = c as f64 * inv;
            h -= p * p.log2();
        }
    }
    h
}

/// Byte histogram.
pub fn byte_histogram(data: &[u8]) -> [u64; 256] {
    let mut hist = [0u64; 256];
    for &b in data {
        hist[b as usize] += 1;
    }
    hist
}

/// Fraction of positions where `data[i] == data[i-1]` — a cheap run proxy.
pub fn repeat_fraction(data: &[u8]) -> f64 {
    if data.len() < 2 {
        return 0.0;
    }
    let reps = data.windows(2).filter(|w| w[0] == w[1]).count();
    reps as f64 / (data.len() - 1) as f64
}

/// Summary statistics over a set of f64 samples (bench harness).
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
    pub stddev: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let median = percentile_sorted(&sorted, 50.0);
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&devs, 50.0);
        Self {
            n,
            mean,
            median,
            min: sorted[0],
            max: sorted[n - 1],
            mad,
            stddev: var.sqrt(),
        }
    }
}

/// Percentile (0..=100) of an ascending-sorted slice, linear interpolation.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_extremes() {
        assert_eq!(shannon_entropy(&[]), 0.0);
        assert_eq!(shannon_entropy(&[7u8; 4096]), 0.0);
        // All 256 values equally often -> 8 bits.
        let all: Vec<u8> = (0..=255u8).cycle().take(256 * 16).collect();
        assert!((shannon_entropy(&all) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn entropy_two_symbols() {
        let half: Vec<u8> = std::iter::repeat(0u8)
            .take(512)
            .chain(std::iter::repeat(1u8).take(512))
            .collect();
        assert!((shannon_entropy(&half) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn repeat_fraction_bounds() {
        assert_eq!(repeat_fraction(&[1, 1, 1, 1]), 1.0);
        assert_eq!(repeat_fraction(&[1, 2, 3, 4]), 0.0);
        assert_eq!(repeat_fraction(&[5]), 0.0);
    }

    #[test]
    fn summary_basics() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!(s.mean > 3.0); // pulled by outlier
        assert!(s.mad <= 2.0); // robust to outlier
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
    }
}
