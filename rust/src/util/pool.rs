//! Small thread-safe buffer pools (§Perf): the parallel pipeline's workers
//! compress thousands of baskets per second, and before pooling every
//! basket paid fresh allocations on the worker plus drops on the committer.
//! Renting buffers from a shared free list makes the steady-state hot path
//! allocation-free: the committer returns each payload buffer after writing
//! it, the workers return consumed basket data/offset buffers, and the next
//! basket reuses the (already-grown) capacity.
//!
//! One generic [`Pool<T>`] implementation backs both concrete pools —
//! [`BufferPool`] (`Vec<u8>`: payload + basket data buffers) and
//! [`OffsetPool`] (`Vec<u32>`: per-entry offset arrays of jagged branches)
//! — so the bounding discipline lives in exactly one place: at most
//! `max_buffers` parked buffers, and any buffer whose capacity (in
//! elements) exceeded `max_capacity` (e.g. one pathological jumbo basket)
//! is dropped instead of parked.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared pool of reusable `Vec<T>` buffers. `Clone` is cheap (`Arc`).
pub struct Pool<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Pool<T> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

struct Inner<T> {
    free: Mutex<Vec<Vec<T>>>,
    max_buffers: usize,
    max_capacity: usize,
    reuses: AtomicU64,
    allocs: AtomicU64,
}

/// Pool of `Vec<u8>` payload/data buffers.
pub type BufferPool = Pool<u8>;
/// Pool of `Vec<u32>` offset buffers (`PendingBasket::offsets`).
pub type OffsetPool = Pool<u32>;

impl Default for BufferPool {
    fn default() -> Self {
        // 64 parked buffers × 32 MiB cap comfortably covers a pipeline with
        // 2×workers in-flight baskets of the 16 MiB max record span.
        Self::new(64, 32 << 20)
    }
}

impl Default for OffsetPool {
    fn default() -> Self {
        // 64 parked × 1M entries (4 MiB) mirrors BufferPool's default scale.
        Self::new(64, 1 << 20)
    }
}

impl<T> Pool<T> {
    pub fn new(max_buffers: usize, max_capacity: usize) -> Self {
        Self {
            inner: Arc::new(Inner {
                free: Mutex::new(Vec::new()),
                max_buffers,
                max_capacity,
                reuses: AtomicU64::new(0),
                allocs: AtomicU64::new(0),
            }),
        }
    }

    /// Rent a cleared buffer (recycled if one is parked, fresh otherwise).
    pub fn get(&self) -> Vec<T> {
        let recycled = self.inner.free.lock().unwrap().pop();
        match recycled {
            Some(buf) => {
                self.inner.reuses.fetch_add(1, Ordering::Relaxed);
                debug_assert!(buf.is_empty());
                buf
            }
            None => {
                self.inner.allocs.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    /// Return a buffer to the pool. Contents are cleared; capacity is kept
    /// unless it exceeds the pool's cap or the free list is full.
    pub fn put(&self, mut buf: Vec<T>) {
        if buf.capacity() == 0 || buf.capacity() > self.inner.max_capacity {
            return;
        }
        buf.clear();
        let mut free = self.inner.free.lock().unwrap();
        if free.len() < self.inner.max_buffers {
            free.push(buf);
        }
    }

    /// (buffers reused, fresh allocations) since creation.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.inner.reuses.load(Ordering::Relaxed),
            self.inner.allocs.load(Ordering::Relaxed),
        )
    }

    /// Number of currently parked buffers.
    pub fn parked(&self) -> usize {
        self.inner.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_cycle() {
        let pool = BufferPool::new(4, 1 << 20);
        let mut b = pool.get();
        b.extend_from_slice(b"hello");
        let cap = b.capacity();
        pool.put(b);
        assert_eq!(pool.parked(), 1);
        let b2 = pool.get();
        assert!(b2.is_empty());
        assert_eq!(b2.capacity(), cap, "capacity must be recycled");
        let (reuses, allocs) = pool.stats();
        assert_eq!((reuses, allocs), (1, 1));
    }

    #[test]
    fn bounded_buffers_and_capacity() {
        let pool = BufferPool::new(2, 64);
        for _ in 0..5 {
            let mut b = Vec::new();
            b.push(1u8);
            pool.put(b);
        }
        assert!(pool.parked() <= 2);
        // Oversized buffers are dropped, not parked.
        let pool = BufferPool::new(8, 16);
        let b = Vec::with_capacity(1024);
        pool.put(b);
        assert_eq!(pool.parked(), 0);
        // Zero-capacity buffers are not worth parking.
        pool.put(Vec::new());
        assert_eq!(pool.parked(), 0);
    }

    #[test]
    fn offset_pool_reuse_and_bounds() {
        let pool = OffsetPool::new(2, 1 << 10);
        let mut b = pool.get();
        b.extend_from_slice(&[1, 2, 3]);
        let cap = b.capacity();
        pool.put(b);
        assert_eq!(pool.parked(), 1);
        let b2 = pool.get();
        assert!(b2.is_empty());
        assert_eq!(b2.capacity(), cap);
        assert_eq!(pool.stats(), (1, 1));
        // Oversized offset buffers are dropped, not parked.
        pool.put(Vec::with_capacity(1 << 12));
        assert_eq!(pool.parked(), 0);
    }

    #[test]
    fn cross_thread_recycling() {
        let pool = BufferPool::new(16, 1 << 20);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u32 {
                    let mut b = p.get();
                    b.extend_from_slice(&i.to_be_bytes());
                    p.put(b);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let (reuses, allocs) = pool.stats();
        assert_eq!(reuses + allocs, 400);
        assert!(allocs <= 16, "at most one fresh alloc per parked slot: {allocs}");
    }
}
