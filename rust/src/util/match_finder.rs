//! Shared LZ77-family match-finder substrate (§Perf).
//!
//! Before this module, the chain-based matchers in the tree each carried
//! their own copy of the hash-head + prev-chain walk, the SWAR
//! common-prefix extension, and the multiplicative hashes. The chain walk
//! here backs `lz4::hc` (64 KiB window) and `zstd::matcher` (256 KiB
//! window); `deflate::matcher` keeps its own walk — its `hash3`/`hash4`
//! flavor split emulates reference-vs-Cloudflare zlib and is part of the
//! PR-1 equivalence surface — but delegates its SWAR match extension to
//! [`common_prefix`]. This module owns:
//!
//! * [`ChainTable`] — reusable hash-head + prev-chain state with a
//!   `find` that walks at most `depth` links, quick-rejects candidates on
//!   the byte that would extend the current best, stops early at
//!   `nice_len` (zlib's `nice_length`) and *shortens the remaining chain
//!   budget* once a match of `good_len` is found (zlib's `good_length`
//!   discipline, ported from PR 1's deflate matcher).
//! * [`common_prefix`] — 8-bytes-per-step match extension via `u64` XOR +
//!   `trailing_zeros`, with a byte-wise oracle in [`reference`] that the
//!   property suite pits it against (`rust/tests/prop_codecs.rs`).
//! * [`hash4`] / [`hash5`] — the multiplicative hashes used by the
//!   min-match-4 codecs (LZ4 fast path uses `hash5` so one extra byte of
//!   context disambiguates; chain matchers use `hash4`).
//!
//! The callers keep their own parse loops (greedy vs lazy vs
//! one-step-lookahead are codec-level policies); only the *search* is
//! shared, so a chain-walk improvement lands in every codec at once.

/// Sentinel for "no position" in head/prev chains.
pub const NO_POS: i32 = -1;

#[inline]
fn read_u32(data: &[u8], i: usize) -> u32 {
    u32::from_le_bytes(data[i..i + 4].try_into().unwrap())
}

/// Multiplicative hash of 4 bytes into `hash_log` bits.
#[inline]
pub fn hash4(v: u32, hash_log: u32) -> usize {
    (v.wrapping_mul(0x9E37_79B1) >> (32 - hash_log)) as usize
}

/// lz4-style hash of 5 bytes (low 40 bits of `v`) into `hash_log` bits.
#[inline]
pub fn hash5(v: u64, hash_log: u32) -> usize {
    ((v << 24).wrapping_mul(889_523_592_379u64) >> (64 - hash_log)) as usize
}

/// Length of the common prefix of `data[a..]` and `data[b..]`, capped at
/// `cap` (§Perf: 8 bytes per step via `u64` XOR + `trailing_zeros`; the
/// scalar loop only finishes the sub-8-byte tail). Property-tested equal
/// to [`reference::common_prefix_naive`].
#[inline]
pub fn common_prefix(data: &[u8], a: usize, b: usize, cap: usize) -> usize {
    let x = &data[a..];
    let y = &data[b..];
    let cap = cap.min(x.len()).min(y.len());
    let mut l = 0usize;
    while l + 8 <= cap {
        let xa = u64::from_le_bytes(x[l..l + 8].try_into().unwrap());
        let yb = u64::from_le_bytes(y[l..l + 8].try_into().unwrap());
        let xor = xa ^ yb;
        if xor != 0 {
            return l + (xor.trailing_zeros() / 8) as usize;
        }
        l += 8;
    }
    while l < cap && x[l] == y[l] {
        l += 1;
    }
    l
}

/// Byte-at-a-time oracles for the SWAR fast paths.
#[doc(hidden)]
pub mod reference {
    /// Naive counterpart of [`super::common_prefix`].
    pub fn common_prefix_naive(data: &[u8], a: usize, b: usize, cap: usize) -> usize {
        let x = &data[a..];
        let y = &data[b..];
        let cap = cap.min(x.len()).min(y.len());
        let mut l = 0usize;
        while l < cap && x[l] == y[l] {
            l += 1;
        }
        l
    }
}

/// Per-search knobs (a codec maps its level to these).
#[derive(Debug, Clone, Copy)]
pub struct SearchCfg {
    /// Maximum chain links to walk.
    pub depth: u32,
    /// Stop searching once a match at least this long is found.
    pub nice_len: usize,
    /// Once a match at least this long is found, cut the remaining chain
    /// budget to a quarter (zlib `good_length` discipline).
    pub good_len: usize,
    /// Shortest match worth reporting.
    pub min_match: usize,
}

/// Reusable hash-head + prev-chain match finder over a single buffer.
pub struct ChainTable {
    hash_log: u32,
    head: Vec<i32>,
    prev: Vec<i32>,
}

impl ChainTable {
    pub fn new(hash_log: u32) -> Self {
        Self { hash_log, head: vec![NO_POS; 1usize << hash_log], prev: Vec::new() }
    }

    /// Reset for a buffer of `n` bytes (clears all chains).
    pub fn reset(&mut self, n: usize) {
        self.head.fill(NO_POS);
        self.prev.clear();
        self.prev.resize(n, NO_POS);
    }

    /// Insert position `pos` into its chain. Caller guarantees
    /// `pos + 4 <= data.len()`.
    #[inline]
    pub fn insert(&mut self, data: &[u8], pos: usize) {
        debug_assert!(pos + 4 <= data.len());
        let h = hash4(read_u32(data, pos), self.hash_log);
        self.prev[pos] = self.head[h];
        self.head[h] = pos as i32;
    }

    /// Longest match at `i` against positions within `max_dist`, capped at
    /// `cap` bytes. `depth_override` (if set) replaces `cfg.depth` — callers
    /// use it to search shallower when lazy evaluation already holds a good
    /// match. Returns `(len, dist)`, or `(0, 0)` if nothing reaches
    /// `cfg.min_match`.
    pub fn find(
        &self,
        data: &[u8],
        i: usize,
        cap: usize,
        max_dist: usize,
        cfg: &SearchCfg,
        depth_override: Option<u32>,
    ) -> (usize, usize) {
        if i + 4 > data.len() {
            return (0, 0);
        }
        let h = hash4(read_u32(data, i), self.hash_log);
        let mut cand = self.head[h];
        let lower = i.saturating_sub(max_dist);
        let nice = cfg.nice_len.min(cap);
        let (mut best_len, mut best_dist) = (0usize, 0usize);
        let mut steps = depth_override.unwrap_or(cfg.depth);
        while cand >= 0 && steps > 0 {
            let c = cand as usize;
            if c >= i {
                // Position i itself (or later) may already be chained by the
                // caller's insert discipline; skip without spending budget.
                cand = self.prev[c];
                continue;
            }
            if c < lower {
                break;
            }
            // Quick reject: compare the byte that would extend the best.
            if best_len == 0 || (i + best_len < data.len() && data[c + best_len] == data[i + best_len]) {
                let l = common_prefix(data, c, i, cap);
                if l > best_len {
                    best_len = l;
                    best_dist = i - c;
                    if l >= nice {
                        break;
                    }
                    if l >= cfg.good_len {
                        // Good enough: stop trying so hard (chain /4).
                        steps = (steps / 4).max(1);
                    }
                }
            }
            cand = self.prev[c];
            steps -= 1;
        }
        if best_len < cfg.min_match {
            (0, 0)
        } else {
            (best_len, best_dist)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn common_prefix_fast_equals_naive() {
        let mut rng = Rng::new(0x3F17);
        for _ in 0..300 {
            let n = rng.range(2, 4000);
            let data: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0x3) as u8).collect();
            let b = rng.range(1, n - 1);
            let a = rng.range(0, b - 1);
            let cap = rng.range(0, 400);
            assert_eq!(
                common_prefix(&data, a, b, cap),
                reference::common_prefix_naive(&data, a, b, cap),
                "a={a} b={b} cap={cap}"
            );
        }
        let data = vec![9u8; 500];
        for cap in [0usize, 1, 7, 8, 9, 15, 16, 17, 100, 500] {
            assert_eq!(
                common_prefix(&data, 0, 50, cap),
                reference::common_prefix_naive(&data, 0, 50, cap)
            );
        }
    }

    #[test]
    fn finds_obvious_matches() {
        let data = b"abcdefgh_abcdefgh_abcdefgh".to_vec();
        let mut t = ChainTable::new(12);
        t.reset(data.len());
        for p in 0..=data.len() - 4 {
            t.insert(&data, p);
        }
        let cfg = SearchCfg { depth: 64, nice_len: 1 << 16, good_len: 1 << 16, min_match: 4 };
        let (len, dist) = t.find(&data, 9, data.len() - 9, 1 << 16, &cfg, None);
        assert!(len >= 8, "len {len}");
        assert_eq!(dist % 9, 0, "dist {dist}");
    }

    #[test]
    fn window_and_min_match_respected() {
        let mut rng = Rng::new(0x3F18);
        let mut data = rng.bytes(1000);
        let tail: Vec<u8> = data[..100].to_vec();
        data.extend_from_slice(&tail); // repeat at distance 1000
        let mut t = ChainTable::new(12);
        t.reset(data.len());
        for p in 0..=data.len() - 4 {
            t.insert(&data, p);
        }
        let cfg = SearchCfg { depth: 4096, nice_len: 1 << 16, good_len: 1 << 16, min_match: 4 };
        // Window of 500 cannot reach the distance-1000 repeat.
        let (len, _) = t.find(&data, 1000, data.len() - 1000, 500, &cfg, None);
        assert!(len < 100, "window violated: len {len}");
        // Full window finds it.
        let (len, dist) = t.find(&data, 1000, data.len() - 1000, 1 << 16, &cfg, None);
        assert_eq!((len, dist), (100, 1000));
    }

    #[test]
    fn good_len_shortening_still_finds_a_match() {
        // Shortening the chain must never lose an already-found match.
        let mut data = Vec::new();
        for _ in 0..50 {
            data.extend_from_slice(b"periodic-block-32-bytes-long!!!!");
        }
        let mut t = ChainTable::new(10); // tiny table -> heavy collisions
        t.reset(data.len());
        for p in 0..=data.len() - 4 {
            t.insert(&data, p);
        }
        let cfg = SearchCfg { depth: 8, nice_len: 1 << 16, good_len: 8, min_match: 4 };
        let (len, dist) = t.find(&data, 64, data.len() - 64, 1 << 16, &cfg, None);
        assert!(len >= 32, "len {len} dist {dist}");
    }
}
