//! LEB128-style varints + fixed-width big-endian helpers.
//!
//! `rfile` serializes in big-endian (network order) to mirror ROOT's disk
//! layout; metadata blocks use varints where ROOT would use version-dependent
//! fixed widths.

/// Append an LEB128 varint.
pub fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read an LEB128 varint; returns (value, bytes consumed) or None on
/// truncation / overlong (>10 bytes) encodings.
pub fn get_uvarint(data: &[u8]) -> Option<(u64, usize)> {
    let mut v: u64 = 0;
    for (i, &byte) in data.iter().enumerate().take(10) {
        v |= ((byte & 0x7F) as u64) << (7 * i);
        if byte & 0x80 == 0 {
            return Some((v, i + 1));
        }
    }
    None
}

/// Big-endian fixed-width writes (ROOT disk convention).
pub fn put_u16_be(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_be_bytes());
}
pub fn put_u32_be(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_be_bytes());
}
pub fn put_u64_be(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_be_bytes());
}

pub fn get_u16_be(data: &[u8]) -> Option<u16> {
    Some(u16::from_be_bytes(data.get(..2)?.try_into().ok()?))
}
pub fn get_u32_be(data: &[u8]) -> Option<u32> {
    Some(u32::from_be_bytes(data.get(..4)?.try_into().ok()?))
}
pub fn get_u64_be(data: &[u8]) -> Option<u64> {
    Some(u64::from_be_bytes(data.get(..8)?.try_into().ok()?))
}

/// A cursor for sequential decoding of metadata blocks.
pub struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self { data, pos: 0 }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    pub fn uvarint(&mut self) -> Option<u64> {
        let (v, n) = get_uvarint(&self.data[self.pos..])?;
        self.pos += n;
        Some(v)
    }

    pub fn u8(&mut self) -> Option<u8> {
        let v = *self.data.get(self.pos)?;
        self.pos += 1;
        Some(v)
    }

    pub fn u16_be(&mut self) -> Option<u16> {
        let v = get_u16_be(&self.data[self.pos..])?;
        self.pos += 2;
        Some(v)
    }

    pub fn u32_be(&mut self) -> Option<u32> {
        let v = get_u32_be(&self.data[self.pos..])?;
        self.pos += 4;
        Some(v)
    }

    pub fn u64_be(&mut self) -> Option<u64> {
        let v = get_u64_be(&self.data[self.pos..])?;
        self.pos += 8;
        Some(v)
    }

    pub fn bytes(&mut self, n: usize) -> Option<&'a [u8]> {
        let v = self.data.get(self.pos..self.pos + n)?;
        self.pos += n;
        Some(v)
    }

    /// Length-prefixed (uvarint) byte string.
    pub fn lp_bytes(&mut self) -> Option<&'a [u8]> {
        let n = self.uvarint()? as usize;
        self.bytes(n)
    }

    /// Length-prefixed UTF-8 string.
    pub fn lp_str(&mut self) -> Option<&'a str> {
        std::str::from_utf8(self.lp_bytes()?).ok()
    }
}

/// Append a length-prefixed byte string.
pub fn put_lp_bytes(out: &mut Vec<u8>, data: &[u8]) {
    put_uvarint(out, data.len() as u64);
    out.extend_from_slice(data);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uvarint_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, 0xFFFF, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_uvarint(&mut buf, v);
            let (got, n) = get_uvarint(&buf).unwrap();
            assert_eq!(got, v);
            assert_eq!(n, buf.len());
        }
    }

    #[test]
    fn uvarint_truncated_rejected() {
        assert!(get_uvarint(&[0x80]).is_none());
        assert!(get_uvarint(&[]).is_none());
        assert!(get_uvarint(&[0x80; 11]).is_none());
    }

    #[test]
    fn cursor_sequence() {
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 300);
        put_u32_be(&mut buf, 0xDEADBEEF);
        put_lp_bytes(&mut buf, b"tree");
        let mut c = Cursor::new(&buf);
        assert_eq!(c.uvarint(), Some(300));
        assert_eq!(c.u32_be(), Some(0xDEADBEEF));
        assert_eq!(c.lp_str(), Some("tree"));
        assert_eq!(c.remaining(), 0);
        assert!(c.u8().is_none());
    }

    #[test]
    fn be_roundtrip() {
        let mut buf = Vec::new();
        put_u16_be(&mut buf, 0x1234);
        put_u64_be(&mut buf, 0x0102030405060708);
        assert_eq!(get_u16_be(&buf), Some(0x1234));
        assert_eq!(get_u64_be(&buf[2..]), Some(0x0102030405060708));
    }
}
