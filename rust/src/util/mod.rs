//! Shared low-level utilities: deterministic RNG, bit I/O, varints,
//! statistics, timing.

pub mod bitio;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod varint;
