//! Shared low-level utilities: deterministic RNG, bit I/O, varints,
//! statistics, timing, and the shared LZ77 match-finder substrate.

pub mod bitio;
pub mod fsio;
pub mod match_finder;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod varint;
