//! Bit-level I/O used by the DEFLATE and ZSTD-style codecs.
//!
//! DEFLATE packs bits LSB-first within bytes (RFC 1951 §3.1.1); our tANS
//! stage reuses the same convention. `BitWriter` accumulates into a `u64`
//! and flushes whole bytes; `BitReader` reads ahead up to 57 bits at a time
//! with a branch-light refill, which is the single most important structural
//! choice for inflate throughput.

/// LSB-first bit writer.
#[derive(Default)]
pub struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { out: Vec::with_capacity(cap), acc: 0, nbits: 0 }
    }

    /// Write the low `n` bits of `bits` (n <= 57).
    ///
    /// §Perf: flushes the accumulator *word-at-a-time* — one unconditional
    /// 8-byte little-endian store followed by a truncate to the number of
    /// whole bytes — instead of the byte-by-byte `push` loop. The invariant
    /// is `nbits < 8 && acc < (1 << nbits)` between calls, so up to 57 new
    /// bits always fit in the 64-bit accumulator.
    #[inline]
    pub fn write_bits(&mut self, bits: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || bits < (1u64 << n) || n == 0);
        debug_assert!(self.nbits < 8 && self.acc >> self.nbits == 0);
        self.acc |= bits << self.nbits;
        self.nbits += n;
        if self.nbits >= 8 {
            let nbytes = (self.nbits >> 3) as usize;
            let len = self.out.len();
            self.out.extend_from_slice(&self.acc.to_le_bytes());
            self.out.truncate(len + nbytes);
            // `nbits` can be exactly 64 here (7 pending + 57 new), making a
            // single `>> 64` UB; the two-step shift keeps every case defined
            // and leaves only the still-pending low bits in the accumulator,
            // so a later `align_byte` can never re-emit already-flushed
            // (stale) bytes.
            self.acc = (self.acc >> 1) >> (nbytes * 8 - 1);
            self.nbits &= 7;
        }
    }

    /// Pad with zero bits to the next byte boundary.
    ///
    /// With the word-flush discipline `nbits < 8` always holds on entry and
    /// `acc` holds exactly the pending bits (high bits zero), so at most one
    /// byte is emitted and the accumulator reset cannot discard real data.
    #[inline]
    pub fn align_byte(&mut self) {
        debug_assert!(self.nbits < 8);
        if self.nbits > 0 {
            self.out.push(self.acc as u8);
            self.acc = 0;
            self.nbits = 0;
        }
        debug_assert_eq!(self.acc, 0, "no stale bits may survive alignment");
    }

    /// Write raw bytes; the stream must be byte-aligned.
    pub fn write_bytes(&mut self, data: &[u8]) {
        debug_assert_eq!(self.nbits, 0, "write_bytes requires byte alignment");
        self.out.extend_from_slice(data);
    }

    /// Number of whole bytes emitted so far (excluding pending bits).
    pub fn byte_len(&self) -> usize {
        self.out.len()
    }

    /// Total bits written (incl. pending).
    pub fn bit_len(&self) -> usize {
        self.out.len() * 8 + self.nbits as usize
    }

    /// Flush pending bits (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.out
    }
}

/// Pre-optimization reference implementations, kept as oracles for the
/// property tests in `rust/tests/prop_codecs.rs`: the word-flush
/// [`BitWriter`] must stay byte-identical to this byte-at-a-time writer for
/// every (value, width) sequence, including `align_byte` interleavings.
#[doc(hidden)]
pub mod reference {
    /// Byte-at-a-time LSB-first bit writer (the original hot-path code).
    #[derive(Default)]
    pub struct NaiveBitWriter {
        out: Vec<u8>,
        acc: u64,
        nbits: u32,
    }

    impl NaiveBitWriter {
        pub fn new() -> Self {
            Self::default()
        }

        pub fn write_bits(&mut self, bits: u64, n: u32) {
            debug_assert!(n <= 57);
            self.acc |= bits << self.nbits;
            self.nbits += n;
            while self.nbits >= 8 {
                self.out.push(self.acc as u8);
                self.acc >>= 8;
                self.nbits -= 8;
            }
        }

        pub fn align_byte(&mut self) {
            if self.nbits > 0 {
                self.out.push(self.acc as u8);
                self.acc = 0;
                self.nbits = 0;
            }
        }

        pub fn finish(mut self) -> Vec<u8> {
            self.align_byte();
            self.out
        }
    }
}

/// Error for bit reads past end of input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitReadError;

impl std::fmt::Display for BitReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bit stream exhausted")
    }
}

impl std::error::Error for BitReadError {}

/// LSB-first bit reader over a byte slice.
///
/// Maintains a 64-bit accumulator; `refill` tops it up to >= 56 bits when
/// possible. Reads past the end of input yield zero bits but are tracked so
/// `overflowed()` can reject truncated streams after the fact — this is the
/// same trick zlib-ng and miniz use to keep the hot loop branch-light.
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,  // next byte index to load
    acc: u64,
    nbits: u32,
    /// bits consumed beyond the physical end of `data`
    over: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        let mut r = Self { data, pos: 0, acc: 0, nbits: 0, over: 0 };
        r.refill();
        r
    }

    #[inline]
    fn refill(&mut self) {
        // Fast path: 8-byte load.
        if self.pos + 8 <= self.data.len() && self.nbits <= 56 {
            let chunk = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into().unwrap());
            self.acc |= chunk << self.nbits;
            let take = (63 - self.nbits) / 8;
            self.pos += take as usize;
            self.nbits += take * 8;
            return;
        }
        while self.nbits <= 56 {
            let byte = if self.pos < self.data.len() {
                let b = self.data[self.pos];
                self.pos += 1;
                b
            } else {
                self.over += 8;
                0
            };
            self.acc |= (byte as u64) << self.nbits;
            self.nbits += 8;
        }
    }

    /// Peek at the next `n` bits without consuming (n <= 56).
    #[inline]
    pub fn peek(&self, n: u32) -> u64 {
        debug_assert!(n <= 56);
        self.acc & ((1u64 << n) - 1)
    }

    /// Consume `n` bits.
    #[inline]
    pub fn consume(&mut self, n: u32) {
        debug_assert!(n <= self.nbits);
        self.acc >>= n;
        self.nbits -= n;
        if self.nbits < 56 {
            self.refill();
        }
    }

    /// Read `n` bits (n <= 56).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> u64 {
        let v = self.peek(n);
        self.consume(n);
        v
    }

    /// Discard bits to the next byte boundary (relative to stream start).
    pub fn align_byte(&mut self) {
        let rem = (self.bit_pos() % 8) as u32;
        if rem != 0 {
            self.consume(8 - rem);
        }
    }

    /// Bits consumed from the start of the stream.
    pub fn bit_pos(&self) -> usize {
        (self.pos + (self.over / 8) as usize) * 8 - self.nbits as usize
    }

    /// Byte position if aligned.
    pub fn byte_pos(&self) -> usize {
        let bp = self.bit_pos();
        debug_assert_eq!(bp % 8, 0);
        bp / 8
    }

    /// Copy `n` raw bytes (requires byte alignment). Returns Err on overrun.
    pub fn read_bytes(&mut self, out: &mut [u8]) -> Result<(), BitReadError> {
        self.align_byte();
        let start = self.bit_pos() / 8;
        if start + out.len() > self.data.len() {
            return Err(BitReadError);
        }
        out.copy_from_slice(&self.data[start..start + out.len()]);
        // Reset the accumulator past the copied region.
        self.pos = start + out.len();
        self.acc = 0;
        self.nbits = 0;
        self.over = 0;
        self.refill();
        Ok(())
    }

    /// True if any read consumed synthetic (past-the-end) bits.
    #[inline]
    pub fn overflowed(&self) -> bool {
        // Some of the synthetic bits may still sit unconsumed in the
        // accumulator; only count them once consumed.
        let synthetic_in_acc = self.over.min(self.nbits);
        self.over > synthetic_in_acc
            || (self.over > 0 && self.bit_pos() > self.data.len() * 8)
    }

    /// Remaining whole input bits (not counting synthetic zeros).
    pub fn bits_remaining(&self) -> usize {
        (self.data.len() * 8).saturating_sub(self.bit_pos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFF, 8);
        w.write_bits(0, 1);
        w.write_bits(0b111111, 6);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_bits(8), 0xFF);
        assert_eq!(r.read_bits(1), 0);
        assert_eq!(r.read_bits(6), 0b111111);
        assert!(!r.overflowed());
    }

    #[test]
    fn roundtrip_random_widths() {
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..200 {
            let n = rng.range(1, 300);
            let mut widths = Vec::with_capacity(n);
            let mut values = Vec::with_capacity(n);
            let mut w = BitWriter::new();
            for _ in 0..n {
                let width = rng.range(1, 56) as u32;
                let val = rng.next_u64() & ((1u64 << width) - 1);
                widths.push(width);
                values.push(val);
                w.write_bits(val, width);
            }
            let buf = w.finish();
            let mut r = BitReader::new(&buf);
            for (width, val) in widths.iter().zip(&values) {
                assert_eq!(r.read_bits(*width), *val);
            }
        }
    }

    #[test]
    fn align_and_bytes() {
        let mut w = BitWriter::new();
        w.write_bits(0b1, 1);
        w.align_byte();
        w.write_bytes(b"abc");
        let buf = w.finish();
        assert_eq!(buf.len(), 4);
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(1), 1);
        let mut out = [0u8; 3];
        r.read_bytes(&mut out).unwrap();
        assert_eq!(&out, b"abc");
    }

    #[test]
    fn word_flush_matches_naive_writer() {
        // The word-flush writer must be byte-identical to the byte-at-a-time
        // reference for arbitrary width sequences with interleaved aligns.
        let mut rng = Rng::new(0xF1A5);
        for _ in 0..100 {
            let mut w = BitWriter::new();
            let mut nw = reference::NaiveBitWriter::new();
            for _ in 0..rng.range(1, 500) {
                if rng.chance(0.1) {
                    w.align_byte();
                    nw.align_byte();
                    continue;
                }
                let width = rng.range(1, 57) as u32;
                let val = rng.next_u64() & ((1u64 << width) - 1);
                w.write_bits(val, width);
                nw.write_bits(val, width);
            }
            assert_eq!(w.finish(), nw.finish());
        }
    }

    #[test]
    fn full_accumulator_boundary() {
        // 7 pending bits + 57 new bits = exactly 64: the flush must emit all
        // 8 bytes and leave a clean accumulator (the `>> 64` hazard).
        let mut w = BitWriter::new();
        w.write_bits(0x55, 7);
        w.write_bits((1u64 << 57) - 1, 57);
        assert_eq!(w.byte_len(), 8);
        assert_eq!(w.bit_len(), 64);
        // align_byte after an exact word flush must emit nothing.
        w.align_byte();
        assert_eq!(w.byte_len(), 8);
        w.write_bits(0b1010, 4);
        let buf = w.finish();
        assert_eq!(buf.len(), 9);
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(7), 0x55);
        assert_eq!(r.read_bits(57), (1u64 << 57) - 1);
        assert_eq!(r.read_bits(4), 0b1010);
    }

    #[test]
    fn align_byte_regression_no_stale_bytes() {
        // Regression: after a word flush lands exactly on a byte boundary,
        // align_byte + further writes must not re-emit flushed bytes.
        let mut w = BitWriter::new();
        w.write_bits(0xFF, 8); // flush leaves nbits == 0
        w.align_byte(); // must be a no-op
        w.write_bits(0x00, 8);
        w.write_bits(0b1, 1);
        w.align_byte(); // pads the single pending bit
        let buf = w.finish();
        assert_eq!(buf, vec![0xFF, 0x00, 0b1]);
    }

    #[test]
    fn truncation_detected() {
        let buf = vec![0xAAu8; 2];
        let mut r = BitReader::new(&buf);
        let _ = r.read_bits(16);
        assert!(!r.overflowed());
        let _ = r.read_bits(16);
        assert!(r.overflowed());
    }

    #[test]
    fn peek_does_not_consume() {
        let buf = vec![0b1010_1010u8];
        let r = BitReader::new(&buf);
        assert_eq!(r.peek(4), 0b1010);
        assert_eq!(r.peek(8), 0b1010_1010);
    }

    #[test]
    fn bit_pos_tracks() {
        let buf = vec![0u8; 16];
        let mut r = BitReader::new(&buf);
        assert_eq!(r.bit_pos(), 0);
        r.read_bits(5);
        assert_eq!(r.bit_pos(), 5);
        r.align_byte();
        assert_eq!(r.bit_pos(), 8);
        r.read_bits(16);
        assert_eq!(r.bit_pos(), 24);
    }
}
