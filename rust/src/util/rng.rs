//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, and determinism across runs is a
//! feature for us anyway (workload generators must be reproducible so that
//! figure harnesses measure the same bytes every run). We use SplitMix64 for
//! seeding and Xoshiro256** as the workhorse generator — both public-domain
//! algorithms with excellent statistical quality.

/// SplitMix64: used to expand a single `u64` seed into a full generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main PRNG used by generators, property tests and
/// synthetic workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // All-zero state is invalid for xoshiro; SplitMix64 cannot produce
        // four consecutive zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generators are not perf-critical).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-12 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Gaussian with mean/sigma.
    #[inline]
    pub fn gauss(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson-distributed count (Knuth's method; fine for small means used
    /// by the event generators).
    pub fn poisson(&mut self, mean: f64) -> u32 {
        let l = (-mean).exp();
        let mut k = 0u32;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l || k > 10_000 {
                return k;
            }
            k += 1;
        }
    }

    /// Fill a byte slice with uniform random bytes.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Random bytes vector.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        let mut v = vec![0u8; n];
        self.fill_bytes(&mut v);
        v
    }

    /// Choose an index according to `weights` (need not be normalized).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 7, 100, 1 << 33] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(17);
        let n = 10_000;
        let mean_target = 3.5;
        let total: u64 = (0..n).map(|_| r.poisson(mean_target) as u64).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - mean_target).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(19);
        for n in 0..40 {
            let v = r.bytes(n);
            assert_eq!(v.len(), n);
        }
        // Statistical sanity: all byte values eventually appear.
        let v = r.bytes(1 << 16);
        let mut seen = [false; 256];
        for b in v {
            seen[b as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(23);
        for _ in 0..500 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }
}
