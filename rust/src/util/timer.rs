//! Wall-clock timing helpers for the bench harness and pipeline metrics.

use std::time::{Duration, Instant};

/// Time a closure, returning (result, elapsed).
pub fn time<R>(f: impl FnOnce() -> R) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Throughput in MB/s (decimal megabytes, as the paper's figures use).
pub fn mb_per_s(bytes: usize, elapsed: Duration) -> f64 {
    if elapsed.is_zero() {
        return f64::INFINITY;
    }
    bytes as f64 / 1e6 / elapsed.as_secs_f64()
}

/// A simple accumulating stopwatch, usable across pipeline stages.
#[derive(Debug, Default, Clone, Copy)]
pub struct Stopwatch {
    total: Duration,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn measure<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.total += t0.elapsed();
        r
    }

    pub fn add(&mut self, d: Duration) {
        self.total += d;
    }

    pub fn total(&self) -> Duration {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mbps_math() {
        let v = mb_per_s(10_000_000, Duration::from_secs(1));
        assert!((v - 10.0).abs() < 1e-9);
        assert!(mb_per_s(1, Duration::ZERO).is_infinite());
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.add(Duration::from_millis(5));
        sw.add(Duration::from_millis(7));
        assert_eq!(sw.total(), Duration::from_millis(12));
        let out = sw.measure(|| 41 + 1);
        assert_eq!(out, 42);
        assert!(sw.total() >= Duration::from_millis(12));
    }
}
