//! Filesystem helpers: crash-safe writes.
//!
//! Output files that feed later runs (read profiles, reports) must never
//! be observable half-written: a crash mid-`fs::write` leaves a truncated
//! file that the strict parsers reject, bricking the feedback loop. The
//! classic fix is [`atomic_write`]: write a temp file in the same
//! directory, flush it, then `rename(2)` over the destination.

use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// Write `bytes` to `path` atomically: temp file in the same directory,
/// flushed to disk, then renamed over the destination. Readers see either
/// the old contents or the new ones, never a torn file; on failure the
/// destination is untouched and the temp file is removed.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let file_name = path.file_name().ok_or_else(|| {
        anyhow::anyhow!("atomic write target {} has no file name", path.display())
    })?;
    // Same directory as the target: rename() is only atomic within a
    // filesystem, and temp_dir may sit on another mount.
    let mut tmp = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => std::path::PathBuf::new(),
    };
    tmp.push(format!(".{}.tmp.{}", file_name.to_string_lossy(), std::process::id()));
    let write_all = std::fs::File::create(&tmp).and_then(|mut f| {
        f.write_all(bytes)?;
        // rename() publishes the name atomically, but only data already
        // flushed survives a power cut — sync before the swap.
        f.sync_all()
    });
    if let Err(e) = write_all {
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("writing temp file {}", tmp.display()));
    }
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        anyhow::anyhow!("renaming {} over {}: {e}", tmp.display(), path.display())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_write_creates_replaces_and_leaves_no_litter() {
        let mut path = std::env::temp_dir();
        path.push(format!("rootio_fsio_{}.txt", std::process::id()));
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer contents").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer contents");
        let dir = path.parent().unwrap();
        let litter = std::fs::read_dir(dir).unwrap().filter_map(|e| e.ok()).any(|e| {
            let n = e.file_name().to_string_lossy().into_owned();
            n.contains("rootio_fsio") && n.contains(".tmp.")
        });
        assert!(!litter, "temp file left behind");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn atomic_write_rejects_bad_targets() {
        // Unwritable directory: the temp-file create fails, nothing is
        // left behind, and the (nonexistent) destination stays absent.
        let bad = Path::new("/nonexistent-rootio-dir/profile.txt");
        assert!(atomic_write(bad, b"x").is_err());
        assert!(!bad.exists());
        // Target without a file name.
        assert!(atomic_write(Path::new(".."), b"x").is_err());
    }
}
