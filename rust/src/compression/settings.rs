//! ROOT-style compression settings.
//!
//! ROOT exposes "a single tunable parameter (which ROOT refers to as
//! 'compression level')" per algorithm (paper §2) and packs both into one
//! integer: `setting = 100 * algorithm + level` (e.g. 101 = ZLIB-1,
//! 404 = LZ4-4, 505 = ZSTD-5; 0 = uncompressed). We reproduce that scheme
//! and extend it with an explicit preconditioner field — the paper's §3
//! future-work item about easing "the switch between compression algorithms
//! and settings for different use cases".

use crate::precond::Precond;
use crate::zstd::EntropyMode;

/// Compression algorithm family, numbered like ROOT's
/// `ECompressionAlgorithm` (1 = ZLIB, 2 = LZMA, 3 = old/legacy, 4 = LZ4,
/// 5 = ZSTD) plus our explicit CF-ZLIB entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// No compression (level 0).
    None,
    /// Reference zlib.
    Zlib,
    /// LZMA-style range coder.
    Lzma,
    /// Legacy 1990s ROOT codec (backward compatibility only).
    OldRoot,
    /// LZ4 (fast at levels <=3, HC above).
    Lz4,
    /// ZSTD-style codec.
    Zstd,
    /// Cloudflare-tuned zlib (the ROOT 6.18.00 patch set).
    CfZlib,
}

impl Algorithm {
    /// ROOT algorithm index.
    pub fn index(&self) -> u16 {
        match self {
            Algorithm::None => 0,
            Algorithm::Zlib => 1,
            Algorithm::Lzma => 2,
            Algorithm::OldRoot => 3,
            Algorithm::Lz4 => 4,
            Algorithm::Zstd => 5,
            Algorithm::CfZlib => 6,
        }
    }

    pub fn from_index(i: u16) -> Option<Self> {
        Some(match i {
            0 => Algorithm::None,
            1 => Algorithm::Zlib,
            2 => Algorithm::Lzma,
            3 => Algorithm::OldRoot,
            4 => Algorithm::Lz4,
            5 => Algorithm::Zstd,
            6 => Algorithm::CfZlib,
            _ => return None,
        })
    }

    /// Two-character record tag (ROOT writes "ZL", "XZ", "L4", "ZS", "CS").
    pub fn tag(&self) -> [u8; 2] {
        match self {
            Algorithm::None => *b"RW",
            Algorithm::Zlib => *b"ZL",
            Algorithm::Lzma => *b"XZ",
            Algorithm::OldRoot => *b"CS",
            Algorithm::Lz4 => *b"L4",
            Algorithm::Zstd => *b"ZS",
            Algorithm::CfZlib => *b"CF",
        }
    }

    pub fn from_tag(tag: [u8; 2]) -> Option<Self> {
        Some(match &tag {
            b"RW" => Algorithm::None,
            b"ZL" => Algorithm::Zlib,
            b"XZ" => Algorithm::Lzma,
            b"CS" => Algorithm::OldRoot,
            b"L4" => Algorithm::Lz4,
            b"ZS" => Algorithm::Zstd,
            b"CF" => Algorithm::CfZlib,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Algorithm::None => "none",
            Algorithm::Zlib => "ZLIB",
            Algorithm::Lzma => "LZMA",
            Algorithm::OldRoot => "OLD",
            Algorithm::Lz4 => "LZ4",
            Algorithm::Zstd => "ZSTD",
            Algorithm::CfZlib => "CF-ZLIB",
        }
    }

    /// All real algorithms (the Fig-2 survey set).
    pub fn survey() -> [Algorithm; 6] {
        [
            Algorithm::Zlib,
            Algorithm::CfZlib,
            Algorithm::Lzma,
            Algorithm::Lz4,
            Algorithm::Zstd,
            Algorithm::OldRoot,
        ]
    }
}

/// A full compression setting: algorithm + level + optional preconditioner
/// + ZSTD entropy-lane choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Settings {
    pub algorithm: Algorithm,
    /// 0 disables compression; 1 fastest .. 9 best ratio (paper §2).
    pub level: u8,
    pub precond: Precond,
    /// Entropy lanes for [`Algorithm::Zstd`] (ignored elsewhere). A
    /// write-time knob: the RZS1 stream is self-describing, so this is
    /// neither packed into `to_root_setting` nor stored in file metadata.
    pub entropy: EntropyMode,
}

impl Default for Settings {
    fn default() -> Self {
        // ROOT's historical default: ZLIB-1 (kZLIB, level 1).
        Self {
            algorithm: Algorithm::Zlib,
            level: 1,
            precond: Precond::None,
            entropy: EntropyMode::default(),
        }
    }
}

impl Settings {
    pub fn new(algorithm: Algorithm, level: u8) -> Self {
        Self { algorithm, level, precond: Precond::None, entropy: EntropyMode::default() }
    }

    pub fn with_precond(mut self, p: Precond) -> Self {
        self.precond = p;
        self
    }

    pub fn with_entropy(mut self, mode: EntropyMode) -> Self {
        self.entropy = mode;
        self
    }

    /// ROOT packed form: `100 * algorithm + level`.
    pub fn to_root_setting(&self) -> u16 {
        if self.level == 0 {
            return 0;
        }
        100 * self.algorithm.index() + self.level.min(99) as u16
    }

    /// Parse a ROOT packed setting (no preconditioner information — ROOT
    /// has none; our record header carries it instead).
    pub fn from_root_setting(v: u16) -> Option<Self> {
        if v == 0 {
            return Some(Settings::new(Algorithm::None, 0));
        }
        let algorithm = Algorithm::from_index(v / 100)?;
        let level = (v % 100).min(9) as u8;
        Some(Settings::new(algorithm, level))
    }

    pub fn label(&self) -> String {
        let base = format!("{}-{}", self.algorithm.label(), self.level);
        match self.precond {
            Precond::None => base,
            p => format!("{base}+{}", p.label()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn root_packing_roundtrip() {
        for alg in Algorithm::survey() {
            for level in 1..=9u8 {
                let s = Settings::new(alg, level);
                let packed = s.to_root_setting();
                assert_eq!(packed, 100 * alg.index() + level as u16);
                let back = Settings::from_root_setting(packed).unwrap();
                assert_eq!(back.algorithm, alg);
                assert_eq!(back.level, level);
            }
        }
        assert_eq!(Settings::new(Algorithm::Zlib, 1).to_root_setting(), 101);
        assert_eq!(Settings::new(Algorithm::Lz4, 4).to_root_setting(), 404);
        assert_eq!(Settings::new(Algorithm::Zstd, 5).to_root_setting(), 505);
    }

    #[test]
    fn tag_roundtrip() {
        for alg in Algorithm::survey() {
            assert_eq!(Algorithm::from_tag(alg.tag()), Some(alg));
        }
        assert_eq!(Algorithm::from_tag(*b"??"), None);
    }

    #[test]
    fn level_zero_is_uncompressed() {
        let s = Settings::new(Algorithm::Zstd, 0);
        assert_eq!(s.to_root_setting(), 0);
    }

    #[test]
    fn entropy_mode_is_not_packed() {
        // The packed ROOT setting carries algorithm + level only; the
        // entropy lane is a write-time knob and must not leak into it.
        let base = Settings::new(Algorithm::Zstd, 5);
        for mode in [EntropyMode::Fse2, EntropyMode::Fse4, EntropyMode::Huff0] {
            assert_eq!(base.with_entropy(mode).to_root_setting(), base.to_root_setting());
        }
        assert_eq!(Settings::from_root_setting(505).unwrap().entropy, EntropyMode::default());
    }
}
