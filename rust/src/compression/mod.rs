//! Unified compression subsystem: ROOT-style settings, the 16 MiB-capped
//! record framing every compressed basket uses on disk, and the engine
//! dispatching to the from-scratch codecs.

pub mod engine;
pub mod record;
pub mod settings;

pub use engine::{compress, decompress, Engine, EngineError};
pub use record::{RecordHeader, HEADER_LEN, MAX_SPAN};
pub use settings::{Algorithm, Settings};
