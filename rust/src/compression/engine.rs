//! The unified compression engine: ROOT's `R__zipMultipleAlgorithm` /
//! `R__unzip` equivalents. Applies the preconditioner, dispatches to the
//! codec selected by [`Settings`], frames the output in (possibly several)
//! 16 MiB-capped records, and inverts the whole thing on read.
//!
//! All per-basket scratch state lives in [`Engine`], so the pipeline's hot
//! loop performs no allocations beyond output buffers.

use super::record::{read_header, write_header, RecordHeader, HEADER_LEN, MAX_SPAN};
use super::settings::{Algorithm, Settings};
use crate::deflate::matcher::Matcher as DeflateMatcher;
use crate::deflate::matcher::Token;
use crate::deflate::zlib::zlib_compress_with;
use crate::deflate::Flavor;
use crate::lz4::{method_for_level, Lz4Encoder};
use crate::lzma::{lzma_compress, lzma_decompress};
use crate::legacy::{legacy_compress, legacy_decompress};
use crate::zstd::{zstd_decompress_dict, ZstdEncoder};

/// Engine errors (compression never fails; decompression is over untrusted
/// bytes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineError(pub String);

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine: {}", self.0)
    }
}
impl std::error::Error for EngineError {}

fn err(e: impl std::fmt::Display) -> EngineError {
    EngineError(e.to_string())
}

/// Hard output cap for a single record's uncompressed span.
const MAX_OUT: usize = MAX_SPAN + 1;

/// Reusable engine: owns all codec scratch state.
#[derive(Default)]
pub struct Engine {
    deflate_matcher: DeflateMatcher,
    deflate_tokens: Vec<Token>,
    lz4: Lz4Encoder,
    zstd: ZstdEncoder,
    precond_buf: Vec<u8>,
    /// LZ4 decode scratch (§Perf): its *length* is preserved across calls
    /// so the wild-copy decoder's pre-sizing only zero-extends a capacity
    /// shortfall instead of memsetting the whole output every basket.
    lz4_scratch: Vec<u8>,
    /// Optional dictionary (ZSTD-style only; paper §2.3).
    dictionary: Vec<u8>,
}

impl Engine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install a dictionary used by ZSTD-family settings.
    pub fn set_dictionary(&mut self, dict: Vec<u8>) {
        self.dictionary = dict;
    }

    pub fn dictionary(&self) -> &[u8] {
        &self.dictionary
    }

    /// Compress `data` under `settings` into a framed byte vector.
    pub fn compress(&mut self, data: &[u8], settings: &Settings) -> Vec<u8> {
        let mut out = Vec::with_capacity(data.len() / 2 + HEADER_LEN);
        self.compress_append(data, settings, &mut out);
        out
    }

    /// Compress `data` under `settings`, appending the framed records to
    /// `out` (§Perf: the zero-alloc pipeline variant — `out` is typically a
    /// recycled buffer from a [`crate::util::pool::BufferPool`]).
    pub fn compress_append(&mut self, data: &[u8], settings: &Settings, out: &mut Vec<u8>) {
        // 1. Precondition into the engine's reusable scratch. `mem::take`
        // moves the scratch out of `self` so the span chunks (which borrow
        // it) can coexist with the `&mut self` codec calls below — this
        // removes the per-span copy the previous implementation paid.
        let mut pre = std::mem::take(&mut self.precond_buf);
        let use_pre = settings.precond != crate::precond::Precond::None;
        if use_pre {
            pre.resize(data.len(), 0);
            match settings.precond {
                crate::precond::Precond::Shuffle(s) => {
                    crate::precond::shuffle_into(data, s as usize, &mut pre)
                }
                crate::precond::Precond::BitShuffle(s) => {
                    crate::precond::bitshuffle_into(data, s as usize, &mut pre)
                }
                crate::precond::Precond::Delta(s) => {
                    pre.copy_from_slice(data);
                    crate::precond::delta_in_place(&mut pre, s as usize);
                }
                crate::precond::Precond::None => unreachable!(),
            }
        }
        let view: &[u8] = if use_pre { &pre } else { data };

        // 2. Split into <=16MiB spans, compress each, frame.
        out.reserve(view.len() / 2 + HEADER_LEN);
        let mut pos = 0usize;
        loop {
            let end = (pos + MAX_SPAN).min(view.len());
            let chunk = &view[pos..end];
            let (algorithm, level, payload) = self.compress_span(chunk, settings);
            let h = RecordHeader {
                algorithm,
                level,
                precond: settings.precond,
                compressed_len: payload.as_ref().map_or(chunk.len(), |p| p.len()),
                uncompressed_len: chunk.len(),
            };
            write_header(out, &h);
            match payload {
                Some(p) => out.extend_from_slice(&p),
                // Raw fallback: copy the span bytes straight into the frame.
                None => out.extend_from_slice(chunk),
            }
            if end == view.len() {
                break;
            }
            pos = end;
        }
        self.precond_buf = pre;
    }

    /// Compress one span. Returns `None` as the payload when the span
    /// should be stored raw — codec output would expand (ROOT's
    /// kUncompressed fallback) or compression is disabled — so the caller
    /// copies the input bytes exactly once, into the output frame.
    fn compress_span(&mut self, chunk: &[u8], settings: &Settings) -> (Algorithm, u8, Option<Vec<u8>>) {
        let level = settings.level;
        if level == 0 || settings.algorithm == Algorithm::None {
            return (Algorithm::None, 0, None);
        }
        let payload = match settings.algorithm {
            Algorithm::None => unreachable!("handled by the raw fallback above"),
            Algorithm::Zlib if self.dictionary.is_empty() => zlib_compress_with(
                chunk,
                Flavor::Reference,
                level,
                &mut self.deflate_matcher,
                &mut self.deflate_tokens,
            ),
            Algorithm::CfZlib if self.dictionary.is_empty() => zlib_compress_with(
                chunk,
                Flavor::Cloudflare,
                level,
                &mut self.deflate_matcher,
                &mut self.deflate_tokens,
            ),
            Algorithm::Zlib => {
                crate::deflate::zlib::zlib_compress_dict(chunk, &self.dictionary, Flavor::Reference, level)
            }
            Algorithm::CfZlib => {
                crate::deflate::zlib::zlib_compress_dict(chunk, &self.dictionary, Flavor::Cloudflare, level)
            }
            Algorithm::Lzma => lzma_compress(chunk, level),
            Algorithm::OldRoot => legacy_compress(chunk, level),
            Algorithm::Lz4 => {
                let dict = std::mem::take(&mut self.dictionary);
                let r = self.lz4.compress_dict(chunk, &dict, method_for_level(level));
                self.dictionary = dict;
                r
            }
            Algorithm::Zstd => {
                // Clone borrow dance: dictionary is read-only during encode.
                let dict = std::mem::take(&mut self.dictionary);
                let r = self.zstd.compress_dict_mode(chunk, &dict, level, settings.entropy);
                self.dictionary = dict;
                r
            }
        };
        if payload.len() >= chunk.len() {
            // Store raw: decompression speed matters more than a negative
            // ratio; ROOT falls back to kUncompressed spans identically.
            (Algorithm::None, 0, None)
        } else {
            (settings.algorithm, level, Some(payload))
        }
    }

    /// Decompress a framed buffer produced by [`Engine::compress`].
    ///
    /// ```
    /// use rootio::compression::{Algorithm, Engine, Settings};
    ///
    /// let mut engine = Engine::new();
    /// let data: Vec<u8> = (1u32..=4096).flat_map(|i| i.to_be_bytes()).collect();
    /// let framed = engine.compress(&data, &Settings::new(Algorithm::Zstd, 5));
    /// assert!(framed.len() < data.len());
    /// assert_eq!(engine.decompress(&framed).unwrap(), data);
    /// ```
    pub fn decompress(&mut self, data: &[u8]) -> Result<Vec<u8>, EngineError> {
        let mut out = Vec::new();
        self.decompress_into(data, &mut out)?;
        Ok(out)
    }

    /// Decompress a framed buffer into a caller-owned buffer (§Perf: the
    /// zero-alloc read-pipeline variant, mirroring [`Engine::compress_append`]
    /// on the write side). `out` is cleared first; read-pipeline workers pass
    /// a recycled buffer whose grown capacity survives across baskets.
    pub fn decompress_into(&mut self, mut data: &[u8], out: &mut Vec<u8>) -> Result<(), EngineError> {
        out.clear();
        let mut precond = crate::precond::Precond::None;
        while !data.is_empty() {
            let h = read_header(data).map_err(err)?;
            let body = data
                .get(HEADER_LEN..HEADER_LEN + h.compressed_len)
                .ok_or_else(|| err("record body truncated"))?;
            precond = h.precond;
            match h.algorithm {
                // Raw span: copy straight into the output, no scratch needed.
                Algorithm::None => {
                    if body.len() != h.uncompressed_len {
                        return Err(err("uncompressed size mismatch"));
                    }
                    out.extend_from_slice(body);
                }
                _ => {
                    let chunk = match h.algorithm {
                        Algorithm::None => unreachable!("handled above"),
                        Algorithm::Zlib | Algorithm::CfZlib => {
                            crate::deflate::zlib::zlib_decompress_dict(
                                body,
                                &self.dictionary,
                                h.uncompressed_len,
                                MAX_OUT,
                            )
                            .map_err(err)?
                        }
                        Algorithm::Lzma => lzma_decompress(body, MAX_OUT).map_err(err)?,
                        Algorithm::OldRoot => {
                            legacy_decompress(body, h.uncompressed_len).map_err(err)?
                        }
                        Algorithm::Lz4 => {
                            // Reuse the engine scratch with its length intact:
                            // the decoder only zero-extends the shortfall
                            // (§Perf). On every error path the scratch is
                            // parked back, so one corrupt basket doesn't cost
                            // the warmed buffer for the rest of the stream.
                            let mut buf = std::mem::take(&mut self.lz4_scratch);
                            if body.len() < 4 {
                                self.lz4_scratch = buf;
                                return Err(err("lz4 frame too short"));
                            }
                            if let Err(e) = crate::lz4::decompress_block_dict_into(
                                &body[4..],
                                &self.dictionary,
                                h.uncompressed_len,
                                &mut buf,
                            ) {
                                self.lz4_scratch = buf;
                                return Err(err(e));
                            }
                            // Verify the frame checksum (first 4 bytes).
                            let expect = u32::from_le_bytes(body[..4].try_into().unwrap());
                            if crate::checksum::crc32(&buf) != expect {
                                self.lz4_scratch = buf;
                                return Err(err("lz4 content checksum mismatch"));
                            }
                            buf
                        }
                        Algorithm::Zstd => {
                            let dict = std::mem::take(&mut self.dictionary);
                            let r = zstd_decompress_dict(body, &dict, MAX_OUT).map_err(err);
                            self.dictionary = dict;
                            r?
                        }
                    };
                    if chunk.len() != h.uncompressed_len {
                        return Err(err("uncompressed size mismatch"));
                    }
                    out.extend_from_slice(&chunk);
                    // Park whichever chunk buffer this span produced as the
                    // LZ4 scratch; its preserved length keeps the next LZ4
                    // decode's pre-sizing memset-free.
                    self.lz4_scratch = chunk;
                }
            }
            data = &data[HEADER_LEN + h.compressed_len..];
        }
        // Invert the preconditioner over the whole logical buffer, staging
        // through the engine's reusable scratch so no allocation survives
        // steady state.
        match precond {
            crate::precond::Precond::None => {}
            crate::precond::Precond::Delta(s) => {
                crate::precond::undelta_in_place(out, s as usize);
            }
            p => {
                let mut pre = std::mem::take(&mut self.precond_buf);
                pre.clear();
                pre.extend_from_slice(out);
                match p {
                    crate::precond::Precond::Shuffle(s) => {
                        crate::precond::unshuffle_into(&pre, s as usize, out)
                    }
                    crate::precond::Precond::BitShuffle(s) => {
                        crate::precond::unbitshuffle_into(&pre, s as usize, out)
                    }
                    _ => unreachable!("None and Delta handled above"),
                }
                self.precond_buf = pre;
            }
        }
        Ok(())
    }
}

/// Convenience one-shots (tests, examples).
pub fn compress(data: &[u8], settings: &Settings) -> Vec<u8> {
    Engine::new().compress(data, settings)
}

pub fn decompress(data: &[u8]) -> Result<Vec<u8>, EngineError> {
    Engine::new().decompress(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::Precond;
    use crate::util::rng::Rng;

    fn all_settings() -> Vec<Settings> {
        let mut v = Vec::new();
        for alg in Algorithm::survey() {
            for level in [1u8, 6, 9] {
                v.push(Settings::new(alg, level));
            }
        }
        v.push(Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)));
        v.push(Settings::new(Algorithm::Lz4, 9).with_precond(Precond::Shuffle(4)));
        v.push(Settings::new(Algorithm::Zstd, 5).with_precond(Precond::Delta(4)));
        v.push(Settings::new(Algorithm::Zlib, 6).with_precond(Precond::BitShuffle(8)));
        for mode in [
            crate::zstd::EntropyMode::Fse2,
            crate::zstd::EntropyMode::Fse4,
            crate::zstd::EntropyMode::Huff0,
        ] {
            v.push(Settings::new(Algorithm::Zstd, 3).with_entropy(mode));
        }
        v.push(Settings::new(Algorithm::None, 0));
        v
    }

    #[test]
    fn roundtrip_every_setting() {
        let mut rng = Rng::new(0xE46);
        let mut corpus: Vec<Vec<u8>> = vec![
            vec![],
            b"x".to_vec(),
            (1u32..=5000).flat_map(|i| i.to_be_bytes()).collect(),
            vec![0u8; 30_000],
        ];
        corpus.push(rng.bytes(20_000));
        let mut engine = Engine::new();
        for data in &corpus {
            for s in all_settings() {
                let c = engine.compress(data, &s);
                let d = engine.decompress(&c).unwrap_or_else(|e| panic!("{}: {e}", s.label()));
                assert_eq!(&d, data, "{}", s.label());
            }
        }
    }

    #[test]
    fn incompressible_falls_back_to_raw() {
        let mut rng = Rng::new(0xE47);
        let data = rng.bytes(10_000);
        let mut engine = Engine::new();
        for s in all_settings() {
            let c = engine.compress(&data, &s);
            assert!(
                c.len() <= data.len() + HEADER_LEN,
                "{}: expanded to {}",
                s.label(),
                c.len()
            );
        }
    }

    #[test]
    fn bitshuffle_lz4_beats_plain_lz4_on_offsets() {
        // The Fig-6 headline through the full engine path.
        let data: Vec<u8> = (1u32..=50_000).flat_map(|i| (i * 3).to_be_bytes()).collect();
        let mut engine = Engine::new();
        let plain = engine.compress(&data, &Settings::new(Algorithm::Lz4, 1));
        let shuf = engine.compress(
            &data,
            &Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        );
        let zlib = engine.compress(&data, &Settings::new(Algorithm::Zlib, 1));
        assert!(shuf.len() * 2 < plain.len(), "shuf {} plain {}", shuf.len(), plain.len());
        assert!(shuf.len() < zlib.len(), "shuf {} zlib {}", shuf.len(), zlib.len());
        assert_eq!(engine.decompress(&shuf).unwrap(), data);
    }

    #[test]
    fn multi_record_spans() {
        // > 16 MiB forces multiple records.
        let mut rng = Rng::new(0xE48);
        let mut data = vec![0u8; MAX_SPAN + 100_000];
        // Sprinkle structure so it compresses.
        for i in (0..data.len()).step_by(1000) {
            let b = rng.bytes(8);
            data[i..i + 8].copy_from_slice(&b);
        }
        let mut engine = Engine::new();
        let c = engine.compress(&data, &Settings::new(Algorithm::Lz4, 1));
        assert_eq!(engine.decompress(&c).unwrap(), data);
    }

    #[test]
    fn dictionary_roundtrip_through_engine() {
        let corpus = crate::zstd::dict::synthetic_corpus(100, 300, 5);
        let dict = crate::zstd::dict::train_from_corpus(&corpus, 4096);
        let mut engine = Engine::new();
        engine.set_dictionary(dict.clone());
        let sample = &corpus[0];
        let c = engine.compress(sample, &Settings::new(Algorithm::Zstd, 6));
        assert_eq!(&engine.decompress(&c).unwrap(), sample);
        // A dict-less engine must fail or mis-decode.
        let mut other = Engine::new();
        match other.decompress(&c) {
            Ok(d) => assert_ne!(&d, sample),
            Err(_) => {}
        }
    }

    #[test]
    fn garbage_rejected() {
        let mut rng = Rng::new(0xE49);
        let mut engine = Engine::new();
        for _ in 0..200 {
            let n = rng.range(0, 100);
            let g = rng.bytes(n);
            let _ = engine.decompress(&g); // must not panic
        }
    }
}
