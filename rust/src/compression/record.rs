//! ROOT's 9-byte compressed-record header, reproduced byte-for-byte in
//! spirit: every compressed span in a ROOT file is framed as
//!
//! ```text
//! [0..2)  2-char algorithm tag ("ZL", "XZ", "L4", "ZS", "CS", ...)
//! [2]     method byte  (we pack: low nibble = level, high bits = precond)
//! [3..6)  compressed   size, 3-byte little-endian
//! [6..9)  uncompressed size, 3-byte little-endian
//! ```
//!
//! The 3-byte size fields cap a span at 16 MiB − 1 (ROOT's
//! `kMaxCompressedBlockSize`); larger baskets are split into multiple
//! records back-to-back, exactly as ROOT does. Because the preconditioner
//! must be invertible on read without out-of-band metadata, we encode it in
//! a second method byte that follows the classic header (making our record
//! header 10 bytes; documented format deviation, same structure).

use super::settings::Algorithm;
use crate::precond::Precond;

/// Max bytes representable in the 3-byte size fields.
pub const MAX_SPAN: usize = (1 << 24) - 1;
/// Header length: ROOT's 9 bytes + 1 precond byte.
pub const HEADER_LEN: usize = 10;

/// Parsed record header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecordHeader {
    pub algorithm: Algorithm,
    pub level: u8,
    pub precond: Precond,
    pub compressed_len: usize,
    pub uncompressed_len: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecordError(pub &'static str);

impl std::fmt::Display for RecordError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "record: {}", self.0)
    }
}
impl std::error::Error for RecordError {}

/// Write a record header.
pub fn write_header(out: &mut Vec<u8>, h: &RecordHeader) {
    debug_assert!(h.compressed_len <= MAX_SPAN);
    debug_assert!(h.uncompressed_len <= MAX_SPAN);
    let tag = h.algorithm.tag();
    out.push(tag[0]);
    out.push(tag[1]);
    out.push(h.level & 0x0F);
    out.push((h.compressed_len & 0xFF) as u8);
    out.push(((h.compressed_len >> 8) & 0xFF) as u8);
    out.push(((h.compressed_len >> 16) & 0xFF) as u8);
    out.push((h.uncompressed_len & 0xFF) as u8);
    out.push(((h.uncompressed_len >> 8) & 0xFF) as u8);
    out.push(((h.uncompressed_len >> 16) & 0xFF) as u8);
    let (ptag, pstride) = h.precond.encode();
    out.push((ptag << 4) | (pstride & 0x0F));
}

/// Parse a record header from the front of `data`.
pub fn read_header(data: &[u8]) -> Result<RecordHeader, RecordError> {
    if data.len() < HEADER_LEN {
        return Err(RecordError("truncated record header"));
    }
    let algorithm =
        Algorithm::from_tag([data[0], data[1]]).ok_or(RecordError("unknown algorithm tag"))?;
    let level = data[2] & 0x0F;
    let compressed_len =
        data[3] as usize | (data[4] as usize) << 8 | (data[5] as usize) << 16;
    let uncompressed_len =
        data[6] as usize | (data[7] as usize) << 8 | (data[8] as usize) << 16;
    let ptag = data[9] >> 4;
    let pstride = data[9] & 0x0F;
    let precond =
        Precond::decode(ptag, pstride).ok_or(RecordError("unknown preconditioner"))?;
    Ok(RecordHeader { algorithm, level, precond, compressed_len, uncompressed_len })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let cases = [
            RecordHeader {
                algorithm: Algorithm::Zlib,
                level: 6,
                precond: Precond::None,
                compressed_len: 12345,
                uncompressed_len: 67890,
            },
            RecordHeader {
                algorithm: Algorithm::Lz4,
                level: 9,
                precond: Precond::BitShuffle(4),
                compressed_len: MAX_SPAN,
                uncompressed_len: 1,
            },
            RecordHeader {
                algorithm: Algorithm::None,
                level: 0,
                precond: Precond::Shuffle(8),
                compressed_len: 0,
                uncompressed_len: 0,
            },
        ];
        for h in cases {
            let mut buf = Vec::new();
            write_header(&mut buf, &h);
            assert_eq!(buf.len(), HEADER_LEN);
            assert_eq!(read_header(&buf).unwrap(), h);
        }
    }

    #[test]
    fn rejects_bad() {
        assert!(read_header(&[0u8; 5]).is_err());
        let mut buf = Vec::new();
        write_header(
            &mut buf,
            &RecordHeader {
                algorithm: Algorithm::Zstd,
                level: 5,
                precond: Precond::None,
                compressed_len: 10,
                uncompressed_len: 10,
            },
        );
        buf[0] = b'?';
        assert!(read_header(&buf).is_err());
    }
}
