//! Dictionary training for the ZSTD-style codec (paper §2.3 and §3 future
//! work: "the dictionary generation found in the ZSTD could provide
//! significant gains in compression ratios ... the generated dictionaries
//! are useable for ZLIB and LZ4 as well. Work, however, is needed, to
//! better understand the optimal dictionary sizes").
//!
//! Training is a simplified COVER-style procedure: count frequent k-byte
//! shingles across the sample corpus, score candidate segments by the sum
//! of their shingle frequencies (favoring segments that recur across
//! samples), and concatenate the best non-overlapping segments up to the
//! dictionary budget. The most valuable content goes at the *end* of the
//! dictionary, nearest the window, where short offsets reach it — the same
//! layout logic zstd uses.

use crate::util::rng::Rng;
use std::collections::HashMap;

/// Shingle width for frequency analysis.
const K: usize = 8;
/// Candidate segment length.
const SEG: usize = 64;

/// Train a dictionary of at most `budget` bytes from `samples`.
///
/// Deterministic for a given sample set and budget.
pub fn train(samples: &[&[u8]], budget: usize) -> Vec<u8> {
    if budget == 0 {
        return Vec::new();
    }
    // 1. Count shingle frequencies (hash -> count), sampled for large inputs.
    let mut counts: HashMap<u64, u32> = HashMap::new();
    let total_len: usize = samples.iter().map(|s| s.len()).sum();
    let step = (total_len / 2_000_000).max(1); // cap work on huge corpora
    for s in samples {
        if s.len() < K {
            continue;
        }
        let mut i = 0;
        while i + K <= s.len() {
            let h = shingle_hash(&s[i..i + K]);
            *counts.entry(h).or_insert(0) += 1;
            i += step;
        }
    }

    // 2. Score candidate segments from each sample.
    #[derive(Clone)]
    struct Cand {
        score: u64,
        sample: usize,
        pos: usize,
    }
    let mut cands: Vec<Cand> = Vec::new();
    for (si, s) in samples.iter().enumerate() {
        if s.len() < SEG {
            continue;
        }
        let mut pos = 0usize;
        while pos + SEG <= s.len() {
            let mut score = 0u64;
            let mut j = pos;
            while j + K <= pos + SEG {
                if let Some(&c) = counts.get(&shingle_hash(&s[j..j + K])) {
                    // Only repeated shingles contribute.
                    if c > 1 {
                        score += c as u64;
                    }
                }
                j += 4;
            }
            cands.push(Cand { score, sample: si, pos });
            pos += SEG / 2;
        }
    }
    cands.sort_by(|a, b| b.score.cmp(&a.score).then(a.sample.cmp(&b.sample)).then(a.pos.cmp(&b.pos)));

    // 3. Greedily take the best segments, dropping near-duplicates.
    let mut dict_segments: Vec<&[u8]> = Vec::new();
    let mut taken = 0usize;
    let mut seen: HashMap<u64, ()> = HashMap::new();
    for c in &cands {
        if taken + SEG > budget {
            break;
        }
        let seg = &samples[c.sample][c.pos..c.pos + SEG];
        let key = shingle_hash(seg);
        if seen.contains_key(&key) {
            continue;
        }
        seen.insert(key, ());
        dict_segments.push(seg);
        taken += SEG;
    }

    // 4. Most valuable content last (closest to the window).
    dict_segments.reverse();
    let mut dict = Vec::with_capacity(taken);
    for seg in dict_segments {
        dict.extend_from_slice(seg);
    }
    dict
}

/// Train from equally-sized synthetic baskets (convenience used by the
/// dict-study bench).
pub fn train_from_corpus(corpus: &[Vec<u8>], budget: usize) -> Vec<u8> {
    let refs: Vec<&[u8]> = corpus.iter().map(|v| v.as_slice()).collect();
    train(&refs, budget)
}

#[inline]
fn shingle_hash(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Generate a small-basket corpus for tests/benches: records sharing
/// structure (field names, common prefixes) with per-record noise.
pub fn synthetic_corpus(n: usize, record_len: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    let fields = [
        &b"Muon_pt="[..],
        b"Muon_eta=",
        b"Jet_mass=",
        b"MET_sumEt=",
        b"nElectron=",
        b"HLT_IsoMu24=",
    ];
    (0..n)
        .map(|_| {
            let mut rec = Vec::with_capacity(record_len);
            while rec.len() < record_len {
                let f = fields[rng.range(0, fields.len() - 1)];
                rec.extend_from_slice(f);
                let v = rng.f32();
                rec.extend_from_slice(format!("{v:.4};").as_bytes());
            }
            rec.truncate(record_len);
            rec
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zstd::compress::{zstd_compress_dict, zstd_decompress_dict};

    #[test]
    fn deterministic() {
        let corpus = synthetic_corpus(50, 256, 7);
        let d1 = train_from_corpus(&corpus, 4096);
        let d2 = train_from_corpus(&corpus, 4096);
        assert_eq!(d1, d2);
        assert!(!d1.is_empty());
        assert!(d1.len() <= 4096);
    }

    #[test]
    fn trained_dict_improves_small_buffers() {
        let corpus = synthetic_corpus(200, 300, 11);
        let dict = train_from_corpus(&corpus[..150], 8192);
        // Held-out samples (151..).
        let mut plain_total = 0usize;
        let mut dict_total = 0usize;
        for sample in &corpus[150..] {
            let plain = zstd_compress_dict(sample, &[], 6);
            let with = zstd_compress_dict(sample, &dict, 6);
            assert_eq!(
                zstd_decompress_dict(&with, &dict, 1 << 20).unwrap(),
                *sample
            );
            plain_total += plain.len();
            dict_total += with.len();
        }
        assert!(
            (dict_total as f64) < 0.9 * plain_total as f64,
            "dict {dict_total} vs plain {plain_total}"
        );
    }

    #[test]
    fn zero_budget_empty() {
        let corpus = synthetic_corpus(10, 100, 3);
        assert!(train_from_corpus(&corpus, 0).is_empty());
    }

    #[test]
    fn tiny_samples_no_panic() {
        let samples: Vec<&[u8]> = vec![b"ab", b"", b"xyz"];
        let d = train(&samples, 1024);
        assert!(d.len() <= 1024);
    }
}
