//! Huff0-style multi-stream block Huffman for RZS1 literals (§Perf).
//!
//! One shared canonical Huffman table (built with the length-limited
//! constructor from `crate::deflate::huffman`, capped at
//! [`MAX_HUFF_BITS`] bits like real zstd's Huff0), with the payload split
//! into **four independent bitstreams**: the input is cut into 4
//! contiguous segments of `ceil(len / 4)` bytes and each segment is coded
//! into its own LSB-first stream. A 3×u16 little-endian jump header
//! records the byte sizes of streams 0–2 (stream 3 is the remainder), so
//! a decoder can keep four refill chains in flight — the same trick as
//! zstd's `HUF_compress4X` / ans_flex's `hufflpuff`.
//!
//! Blob layout (embedded as RZS1 literal-section mode 4; all multi-byte
//! integers little-endian):
//!
//! ```text
//! [uvarint n]                   alphabet bound: highest used symbol + 1
//! [n code lengths]              u8 each (0 = unused, 1..=11);
//!                               a 0 is followed by u8 extra_run =
//!                               count of additional zero symbols
//! [u16 j0][u16 j1][u16 j2]      byte sizes of streams 0..2
//! [stream0][stream1][stream2][stream3]
//! ```
//!
//! Oracle discipline: [`compress`] (word-flush [`BitWriter`], interleaved
//! 4-at-a-time decode in [`decompress`]) is property-tested
//! **byte-identical** to [`reference::compress_naive`] (byte-at-a-time
//! [`NaiveBitWriter`](crate::util::bitio::reference::NaiveBitWriter),
//! stream-at-a-time decode), with the same accept/reject set on
//! truncated or corrupted blobs — see the in-file tests and
//! `rust/tests/conformance_entropy.rs`.

use super::fse;
use crate::deflate::huffman::{build_code_lengths, canonical_codes, Decoder, INVALID_SYM};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::varint::{put_uvarint, Cursor};

/// Max Huffman code length — zstd's Huff0 limit, not DEFLATE's 15;
/// shorter codes keep the decode table L1-resident.
pub const MAX_HUFF_BITS: usize = 11;

/// Number of independent bitstreams per block.
pub const N_STREAMS: usize = 4;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Huff0Error(pub &'static str);

impl std::fmt::Display for Huff0Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "huff0: {}", self.0)
    }
}
impl std::error::Error for Huff0Error {}

const E: fn(&'static str) -> Huff0Error = Huff0Error;

/// Segment length for a block of `len` bytes (streams 0..2 cover full
/// segments; stream 3 covers the remainder).
#[inline]
fn segment_len(len: usize) -> usize {
    (len + N_STREAMS - 1) / N_STREAMS
}

/// Per-stream symbol counts for a block of `len` bytes.
#[inline]
fn stream_counts(len: usize) -> [usize; N_STREAMS] {
    let seg = segment_len(len);
    let mut counts = [0usize; N_STREAMS];
    for (i, c) in counts.iter_mut().enumerate() {
        *c = len.saturating_sub(i * seg).min(seg);
    }
    counts
}

/// Serialize the code-length table (shared by fast and naive encoders).
fn write_table(out: &mut Vec<u8>, lengths: &[u8]) {
    let n = lengths
        .iter()
        .rposition(|&l| l > 0)
        .map(|p| p + 1)
        .unwrap_or(0);
    put_uvarint(out, n as u64);
    let mut sym = 0usize;
    while sym < n {
        let l = lengths[sym];
        out.push(l);
        sym += 1;
        if l == 0 {
            let mut run = 0usize;
            while sym < n && lengths[sym] == 0 && run < 255 {
                run += 1;
                sym += 1;
            }
            out.push(run as u8);
        }
    }
}

/// Parse the code-length table back into a 256-entry length array.
fn read_table(c: &mut Cursor) -> Result<Vec<u8>, Huff0Error> {
    let n = c.uvarint().ok_or(E("truncated table len"))? as usize;
    if n == 0 || n > 256 {
        return Err(E("bad alphabet size"));
    }
    let mut lengths = vec![0u8; n];
    let mut sym = 0usize;
    while sym < n {
        let l = c.u8().ok_or(E("truncated code length"))?;
        if l as usize > MAX_HUFF_BITS {
            return Err(E("code length too long"));
        }
        lengths[sym] = l;
        sym += 1;
        if l == 0 {
            let run = c.u8().ok_or(E("truncated zero run"))? as usize;
            if sym + run > n {
                return Err(E("zero run overflows alphabet"));
            }
            sym += run;
        }
    }
    Ok(lengths)
}

/// Build the shared table for `data`; `None` if Huffman coding cannot
/// help (fewer than 2 distinct byte values — RLE territory).
fn build_table(hist: &[u32; 256]) -> Option<(Vec<u8>, Vec<u16>)> {
    if hist.iter().filter(|&&c| c > 0).count() < 2 {
        return None;
    }
    let freqs: Vec<u64> = hist.iter().map(|&c| c as u64).collect();
    let lengths = build_code_lengths(&freqs, MAX_HUFF_BITS);
    let codes = canonical_codes(&lengths);
    Some((lengths, codes))
}

/// Compress `data` into a 4-stream Huff0 blob. Returns `None` when the
/// input is degenerate (< 2 distinct bytes) or any stream's byte size
/// exceeds the u16 jump-header range; the caller falls back to another
/// literal mode. Never fails on valid input — size arbitration (is the
/// blob smaller than raw?) is the caller's job.
pub fn compress(data: &[u8]) -> Option<Vec<u8>> {
    let hist = fse::histogram(data);
    let (lengths, codes) = build_table(&hist)?;

    let seg = segment_len(data.len());
    let mut streams: [Vec<u8>; N_STREAMS] = Default::default();
    for (i, stream) in streams.iter_mut().enumerate() {
        let start = (i * seg).min(data.len());
        let end = ((i + 1) * seg).min(data.len());
        let mut w = BitWriter::with_capacity(end - start + 8);
        for &b in &data[start..end] {
            w.write_bits(codes[b as usize] as u64, lengths[b as usize] as u32);
        }
        *stream = w.finish();
        if stream.len() > u16::MAX as usize {
            return None;
        }
    }

    let mut out = Vec::with_capacity(data.len() / 2 + 64);
    write_table(&mut out, &lengths);
    for s in &streams[..N_STREAMS - 1] {
        out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    }
    for s in &streams {
        out.extend_from_slice(s);
    }
    Some(out)
}

/// Split the post-table region of a blob into the four streams using the
/// jump header. Shared by the fast and naive decoders so both reject
/// exactly the same malformed headers.
fn split_streams<'a>(c: &mut Cursor<'a>) -> Result<[&'a [u8]; N_STREAMS], Huff0Error> {
    let mut sizes = [0usize; N_STREAMS - 1];
    for s in sizes.iter_mut() {
        let b = c.bytes(2).ok_or(E("truncated jump header"))?;
        *s = u16::from_le_bytes([b[0], b[1]]) as usize;
    }
    let total: usize = sizes.iter().sum();
    let rest = c.bytes(c.remaining()).unwrap_or(&[]);
    if total > rest.len() {
        return Err(E("jump header exceeds payload"));
    }
    let (s0, r) = rest.split_at(sizes[0]);
    let (s1, r) = r.split_at(sizes[1]);
    let (s2, s3) = r.split_at(sizes[2]);
    Ok([s0, s1, s2, s3])
}

/// Decompress a Huff0 blob into exactly `len` bytes.
///
/// §Perf: the four bit readers are advanced **interleaved**, one symbol
/// per stream per iteration, so four table lookups and four 57-bit
/// refills are in flight at once; the tail (streams of unequal symbol
/// count) finishes stream-at-a-time. Truncation is detected after the
/// fact via [`BitReader::overflowed`], like every other lane.
pub fn decompress(blob: &[u8], len: usize) -> Result<Vec<u8>, Huff0Error> {
    let mut c = Cursor::new(blob);
    let lengths = read_table(&mut c)?;
    let dec = Decoder::from_lengths(&lengths).map_err(|_| E("bad code"))?;
    let streams = split_streams(&mut c)?;

    let counts = stream_counts(len);
    let seg = segment_len(len);
    let mut readers: Vec<BitReader> = streams.iter().map(|s| BitReader::new(s)).collect();
    let mut out = vec![0u8; len];

    // Batch loop: all four streams still have symbols left.
    let min_count = counts[N_STREAMS - 1];
    for j in 0..min_count {
        for (i, r) in readers.iter_mut().enumerate() {
            let sym = dec.decode_fast(r);
            if sym == INVALID_SYM {
                return Err(E("invalid code word"));
            }
            out[i * seg + j] = sym as u8;
        }
    }
    // Tail: per-stream finish (stream i may hold up to seg symbols).
    for (i, r) in readers.iter_mut().enumerate() {
        for j in min_count..counts[i] {
            let sym = dec.decode_fast(r);
            if sym == INVALID_SYM {
                return Err(E("invalid code word"));
            }
            out[i * seg + j] = sym as u8;
        }
        if r.overflowed() {
            return Err(E("bitstream exhausted"));
        }
    }
    Ok(out)
}

/// Pre-optimization reference implementations, kept in-tree as oracles:
/// `compress` must stay **byte-identical** to [`reference::compress_naive`]
/// and `decompress` must accept exactly the blobs
/// [`reference::decompress_naive`] accepts, with identical output.
pub mod reference {
    use super::*;
    use crate::util::bitio::reference::NaiveBitWriter;

    /// Single-symbol-at-a-time encoder over the byte-at-a-time bit
    /// writer and the naive histogram; same blob layout.
    pub fn compress_naive(data: &[u8]) -> Option<Vec<u8>> {
        let hist = fse::reference::histogram_naive(data);
        let (lengths, codes) = build_table(&hist)?;

        let seg = segment_len(data.len());
        let mut streams: [Vec<u8>; N_STREAMS] = Default::default();
        for (i, stream) in streams.iter_mut().enumerate() {
            let start = (i * seg).min(data.len());
            let end = ((i + 1) * seg).min(data.len());
            let mut w = NaiveBitWriter::new();
            for &b in &data[start..end] {
                w.write_bits(codes[b as usize] as u64, lengths[b as usize] as u32);
            }
            *stream = w.finish();
            if stream.len() > u16::MAX as usize {
                return None;
            }
        }

        let mut out = Vec::new();
        write_table(&mut out, &lengths);
        for s in &streams[..N_STREAMS - 1] {
            out.extend_from_slice(&(s.len() as u16).to_le_bytes());
        }
        for s in &streams {
            out.extend_from_slice(s);
        }
        Some(out)
    }

    /// Stream-at-a-time decoder using the `Result`-returning
    /// [`Decoder::decode`]; same accept/reject set as the interleaved
    /// fast path.
    pub fn decompress_naive(blob: &[u8], len: usize) -> Result<Vec<u8>, Huff0Error> {
        let mut c = Cursor::new(blob);
        let lengths = read_table(&mut c)?;
        let dec = Decoder::from_lengths(&lengths).map_err(|_| E("bad code"))?;
        let streams = split_streams(&mut c)?;

        let counts = stream_counts(len);
        let mut out = Vec::with_capacity(len);
        for (i, stream) in streams.iter().enumerate() {
            let mut r = BitReader::new(stream);
            for _ in 0..counts[i] {
                let sym = dec.decode(&mut r).map_err(|_| E("invalid code word"))?;
                out.push(sym as u8);
            }
            if r.overflowed() {
                return Err(E("bitstream exhausted"));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_corpus(rng: &mut Rng) -> Vec<Vec<u8>> {
        let mut text = Vec::new();
        while text.len() < 50_000 {
            text.extend_from_slice(b"nanoAOD Muon_pt Jet_eta high-entropy literals lane. ");
            text.push((rng.next_u64() & 0x7F) as u8);
        }
        let skew: Vec<u8> = (0..30_000)
            .map(|_| {
                if rng.chance(0.8) {
                    (rng.next_u64() & 0x3) as u8
                } else {
                    (rng.next_u64() & 0xFF) as u8
                }
            })
            .collect();
        vec![text, rng.bytes(40_000), skew, rng.bytes(37)]
    }

    #[test]
    fn roundtrip_and_matches_naive() {
        let mut rng = Rng::new(0xB0F0);
        for data in sample_corpus(&mut rng) {
            let fast = compress(&data).expect("compressible input");
            let naive = reference::compress_naive(&data).expect("naive");
            assert_eq!(fast, naive, "blob must be byte-identical (n={})", data.len());
            assert_eq!(decompress(&fast, data.len()).unwrap(), data);
            assert_eq!(reference::decompress_naive(&fast, data.len()).unwrap(), data);
        }
    }

    #[test]
    fn small_and_uneven_lengths() {
        // Exercise every len % 4 tail shape, including streams with zero
        // symbols (len < 4) and single-symbol streams.
        let mut rng = Rng::new(0xB0F1);
        for n in 2..70usize {
            let data: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0x0F) as u8).collect();
            if data.iter().all(|&b| b == data[0]) {
                assert!(compress(&data).is_none(), "single-symbol must bail n={n}");
                continue;
            }
            let fast = compress(&data).expect("table");
            assert_eq!(fast, reference::compress_naive(&data).unwrap(), "n={n}");
            assert_eq!(decompress(&fast, n).unwrap(), data, "n={n}");
        }
    }

    #[test]
    fn degenerate_inputs_bail() {
        assert!(compress(&[]).is_none());
        assert!(compress(&[7]).is_none());
        assert!(compress(&vec![42u8; 10_000]).is_none());
        assert!(reference::compress_naive(&[]).is_none());
        assert!(reference::compress_naive(&vec![42u8; 10_000]).is_none());
    }

    #[test]
    fn oversize_stream_bails() {
        // Incompressible data: each of the 4 streams needs ~ len/4 bytes,
        // so 400 KB blows the u16 jump header and both encoders refuse.
        let mut rng = Rng::new(0xB0F2);
        let data = rng.bytes(400_000);
        assert!(compress(&data).is_none());
        assert!(reference::compress_naive(&data).is_none());
    }

    #[test]
    fn truncation_rejection_parity() {
        let mut rng = Rng::new(0xB0F3);
        let data = rng.bytes(5_000);
        let blob = compress(&data).unwrap();
        for cut in [0, 1, 3, blob.len() / 2, blob.len() - 1] {
            let fast = decompress(&blob[..cut], data.len());
            let naive = reference::decompress_naive(&blob[..cut], data.len());
            assert_eq!(fast.is_ok(), naive.is_ok(), "cut={cut}");
            assert!(fast.is_err(), "cut={cut} must be rejected");
        }
    }

    #[test]
    fn bit_flip_parity() {
        // Corruption may still decode (to wrong bytes) — but the fast and
        // naive decoders must agree on accept/reject and on the output.
        let mut rng = Rng::new(0xB0F4);
        let data = rng.bytes(3_000);
        let blob = compress(&data).unwrap();
        for _ in 0..200 {
            let mut bad = blob.clone();
            let byte = rng.range(0, bad.len() - 1);
            bad[byte] ^= 1 << rng.range(0, 7);
            let fast = decompress(&bad, data.len());
            let naive = reference::decompress_naive(&bad, data.len());
            // Error *values* may differ (the interleaved loop can hit an
            // invalid code in stream 1 before noticing stream 0 ran dry);
            // the accept/reject decision and any accepted bytes must not.
            match (fast, naive) {
                (Ok(a), Ok(b)) => assert_eq!(a, b),
                (Err(_), Err(_)) => {}
                (a, b) => panic!("accept/reject mismatch: {a:?} vs {b:?}"),
            }
        }
    }
}
