//! ZSTD-style codec (paper §2.3): LZ77 with a 256 KiB window + tANS (FSE)
//! entropy stage + dictionary support. Implements the three levers the
//! paper credits for ZSTD's advantage; the container format is our own
//! ("RZS1"), not RFC 8478 bit-compatible — see DESIGN.md's honesty box.
//!
//! # §Perf fast paths (LZ4/ZSTD hot-lane overhaul)
//!
//! Each optimized loop keeps an in-tree naive reference it is
//! property-tested against in `rust/tests/prop_codecs.rs`, the same
//! discipline `crate::deflate` established in PR 1:
//!
//! * **Interleaved multi-state FSE** (`fse::EncTable::encode_interleaved`
//!   + `encode_interleaved4` / `fse::DecTable::decode_interleaved` +
//!   `decode_interleaved4`): two or four ANS states alternate over
//!   consecutive symbols (the real-zstd / ans_flex trick), removing the
//!   serial state dependency so table lookups and the 57-bit-refill bit
//!   I/O pipeline; the decode batch loop emits a symbol pair (quad) per
//!   iteration with the exhaustion check hoisted out. Which width the
//!   encoder emits is the [`EntropyMode`] knob (dual-state = the RFIL-v2
//!   stream, quad-state = the v3 default). Oracles:
//!   `fse::reference::{encode,decode}_interleaved_naive` and
//!   `{encode,decode}_interleaved4_naive` — compressed bytes **identical**
//!   on encode, symbols identical on decode, same accept/reject set on
//!   truncation.
//! * **Huff0-style 4-stream Huffman literals** (`huff0::compress` /
//!   `huff0::decompress`, picked by [`EntropyMode::Huff0`] for
//!   high-entropy branches): one shared canonical table, payload split
//!   into four independent LSB-first bitstreams behind a 3×u16 jump
//!   header, so the decoder keeps four refill chains in flight. Oracles:
//!   `huff0::reference::{compress,decompress}_naive` (byte-identical
//!   blob, same accept/reject set).
//! * **4-lane histogram** (`fse::histogram`): single pass, four count
//!   arrays, 8 bytes per iteration, feeding `fse::normalize_counts`.
//!   Oracle: `fse::reference::histogram_naive` (equal counts).
//! * **Shared chain matcher** (`matcher::ChainMatcher` over
//!   `crate::util::match_finder::ChainTable`): SWAR `common_prefix`
//!   extension, quick-reject on the best-extending byte, `nice_len` early
//!   exit, and zlib-style `good_length` budget shortening — one substrate
//!   shared with `crate::lz4::hc`. Matcher output is validated by
//!   `matcher::execute_seqs` roundtrips rather than bit-frozen (parse
//!   policy may evolve; decoded bytes must not).
//!
//! Equivalence guarantee: the RZS1 *decoder* accepts exactly the streams
//! the naive-reference pipeline accepts and yields identical bytes; the
//! encoder's FSE sections are byte-identical to the naive entropy coder
//! given the same parse.

pub mod compress;
pub mod dict;
pub mod fse;
pub mod huff0;
pub mod matcher;

pub use compress::{
    zstd_compress, zstd_compress_dict, zstd_compress_mode, zstd_decompress, zstd_decompress_dict,
    EntropyMode, ZstdEncoder, ZstdError,
};
