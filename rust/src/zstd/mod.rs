//! ZSTD-style codec (paper §2.3): LZ77 with a 256 KiB window + tANS (FSE)
//! entropy stage + dictionary support. Implements the three levers the
//! paper credits for ZSTD's advantage; the container format is our own
//! ("RZS1"), not RFC 8478 bit-compatible — see DESIGN.md's honesty box.

pub mod compress;
pub mod dict;
pub mod fse;
pub mod matcher;

pub use compress::{
    zstd_compress, zstd_compress_dict, zstd_decompress, zstd_decompress_dict, ZstdEncoder,
    ZstdError,
};
