//! Large-window LZ77 match finder for the ZSTD-style codec.
//!
//! The paper (§2.3) credits ZSTD's 256 KiB window — "eight times larger than
//! the ZLIB window" — for much of its ratio advantage; this matcher searches
//! that window with hash chains and optional single-step lazy parsing, and
//! supports a *dictionary prefix*: content prepended to the window that
//! matches may reference but that is not emitted (the mechanism behind
//! ZSTD-style dictionary compression on small baskets).
//!
//! §Perf: the chain walk itself (SWAR `common_prefix` extension, quick
//! reject on the best-extending byte, `nice_len` early exit and zlib-style
//! `good_length` chain shortening) lives in the shared
//! [`crate::util::match_finder::ChainTable`]; this module keeps only the
//! parse policy (greedy/lazy, dictionary pre-insert).

use crate::util::match_finder::{ChainTable, SearchCfg};

/// 256 KiB window (8× zlib), as the paper describes.
pub const WINDOW_LOG: u32 = 18;
pub const WINDOW_SIZE: usize = 1 << WINDOW_LOG;
pub const MIN_MATCH: usize = 3;
/// Cap match length (fits the value-code scheme comfortably).
pub const MAX_MATCH: usize = 1 << 16;

/// One LZ sequence: emit `lit_len` literals, then copy `match_len` bytes
/// from `offset` back. A trailing literal run (after the last sequence) is
/// carried separately by the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Seq {
    pub lit_len: u32,
    pub match_len: u32,
    pub offset: u32,
}

/// Per-level search parameters.
#[derive(Debug, Clone, Copy)]
pub struct SearchParams {
    pub depth: u32,
    pub lazy: bool,
    pub nice_len: usize,
    /// zlib-style `good_length`: once a match at least this long is held,
    /// further searching (in-chain and the lazy lookahead) runs on a
    /// quartered budget.
    pub good_len: usize,
}

impl SearchParams {
    /// Map ROOT-style levels 1..=9.
    pub fn for_level(level: u8) -> Self {
        match level.clamp(1, 9) {
            1 => Self { depth: 4, lazy: false, nice_len: 48, good_len: 16 },
            2 => Self { depth: 8, lazy: false, nice_len: 64, good_len: 16 },
            3 => Self { depth: 16, lazy: false, nice_len: 96, good_len: 24 },
            4 => Self { depth: 16, lazy: true, nice_len: 96, good_len: 24 },
            5 => Self { depth: 32, lazy: true, nice_len: 128, good_len: 32 },
            6 => Self { depth: 64, lazy: true, nice_len: 256, good_len: 64 },
            7 => Self { depth: 128, lazy: true, nice_len: 512, good_len: 128 },
            8 => Self { depth: 512, lazy: true, nice_len: 1024, good_len: 256 },
            _ => Self { depth: 2048, lazy: true, nice_len: MAX_MATCH, good_len: 1024 },
        }
    }

    fn cfg(&self) -> SearchCfg {
        SearchCfg {
            depth: self.depth,
            nice_len: self.nice_len,
            good_len: self.good_len,
            min_match: MIN_MATCH,
        }
    }
}

const HASH_LOG: u32 = 17;

/// Reusable chain matcher (parse policy over the shared [`ChainTable`]).
pub struct ChainMatcher {
    chains: ChainTable,
}

impl Default for ChainMatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl ChainMatcher {
    pub fn new() -> Self {
        Self { chains: ChainTable::new(HASH_LOG) }
    }

    /// Parse `data[start..]` into sequences (`data[..start]` is the
    /// dictionary prefix, matchable but not emitted). Returns the sequences
    /// and appends all literal bytes (in order) to `literals`; the final
    /// literal run length is `data.len() - start - covered`.
    pub fn parse(
        &mut self,
        data: &[u8],
        start: usize,
        params: &SearchParams,
        seqs: &mut Vec<Seq>,
        literals: &mut Vec<u8>,
    ) {
        seqs.clear();
        literals.clear();
        let n = data.len();
        self.chains.reset(n);

        if n < MIN_MATCH + 1 || n - start == 0 {
            literals.extend_from_slice(&data[start..]);
            return;
        }
        let hash_end = n.saturating_sub(4);
        let cfg = params.cfg();

        // Pre-insert the dictionary prefix so matches can reach into it.
        let mut inserted = 0usize;
        macro_rules! insert_up_to {
            ($end:expr) => {
                let e = $end;
                while inserted < e && inserted <= hash_end {
                    self.chains.insert(data, inserted);
                    inserted += 1;
                }
                if inserted < e {
                    inserted = e;
                }
            };
        }
        insert_up_to!(start);

        let mut anchor = start;
        let mut i = start;
        while i < n {
            insert_up_to!(i + 1);
            let (len, dist) = self.find(data, i, &cfg, None);
            if len < MIN_MATCH {
                i += 1;
                continue;
            }
            let (mut best_len, mut best_dist, mut pos) = (len, dist, i);
            if params.lazy && len < params.nice_len && i + 1 < n {
                insert_up_to!(i + 2);
                // good_length discipline: holding a long match already,
                // spend only a quarter of the budget probing i+1.
                let lookahead_depth = if len >= params.good_len {
                    Some((params.depth / 4).max(1))
                } else {
                    None
                };
                let (len2, dist2) = self.find(data, i + 1, &cfg, lookahead_depth);
                if len2 > best_len + 1 {
                    best_len = len2;
                    best_dist = dist2;
                    pos = i + 1;
                }
            }
            // Emit literals [anchor, pos) then the match.
            literals.extend_from_slice(&data[anchor..pos]);
            seqs.push(Seq {
                lit_len: (pos - anchor) as u32,
                match_len: best_len as u32,
                offset: best_dist as u32,
            });
            i = pos + best_len;
            anchor = i;
            insert_up_to!(i.min(hash_end + 1));
        }
        literals.extend_from_slice(&data[anchor..]);
    }

    fn find(&self, data: &[u8], i: usize, cfg: &SearchCfg, depth_override: Option<u32>) -> (usize, usize) {
        let cap = (data.len() - i).min(MAX_MATCH);
        self.chains.find(data, i, cap, WINDOW_SIZE, cfg, depth_override)
    }
}

/// Rebuild bytes from sequences + literals (oracle for tests & decoder core).
pub fn execute_seqs(
    seqs: &[Seq],
    literals: &[u8],
    dict: &[u8],
    expected_len: usize,
) -> Result<Vec<u8>, &'static str> {
    let mut out = Vec::with_capacity(dict.len() + expected_len);
    out.extend_from_slice(dict);
    let mut lit_pos = 0usize;
    for s in seqs {
        let ll = s.lit_len as usize;
        if lit_pos + ll > literals.len() {
            return Err("literal underflow");
        }
        out.extend_from_slice(&literals[lit_pos..lit_pos + ll]);
        lit_pos += ll;
        let dist = s.offset as usize;
        let ml = s.match_len as usize;
        if dist == 0 || dist > out.len() {
            return Err("bad offset");
        }
        if out.len() + ml > dict.len() + expected_len {
            return Err("output overflow");
        }
        let start = out.len() - dist;
        if dist >= ml {
            out.extend_from_within(start..start + ml);
        } else {
            let mut rem = ml;
            let mut src = start;
            while rem > 0 {
                let chunk = rem.min(out.len() - src);
                out.extend_from_within(src..src + chunk);
                src += chunk;
                rem -= chunk;
            }
        }
    }
    out.extend_from_slice(&literals[lit_pos..]);
    out.drain(..dict.len());
    if out.len() != expected_len {
        return Err("size mismatch");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8], dict: &[u8], level: u8) {
        let mut m = ChainMatcher::new();
        let mut buf = Vec::with_capacity(dict.len() + data.len());
        buf.extend_from_slice(dict);
        buf.extend_from_slice(data);
        let mut seqs = Vec::new();
        let mut lits = Vec::new();
        m.parse(&buf, dict.len(), &SearchParams::for_level(level), &mut seqs, &mut lits);
        let out = execute_seqs(&seqs, &lits, dict, data.len()).expect("execute");
        assert_eq!(out, data, "level {level} n={} dict={}", data.len(), dict.len());
    }

    #[test]
    fn basic_roundtrips() {
        for level in [1u8, 5, 9] {
            roundtrip(b"", b"", level);
            roundtrip(b"a", b"", level);
            roundtrip(b"abcabcabcabcabcabc", b"", level);
            roundtrip(&vec![7u8; 50_000], b"", level);
        }
    }

    #[test]
    fn long_window_matches_found() {
        // Repeat at distance ~100k: inside our 256K window, outside zlib's 32K.
        let mut rng = Rng::new(0x2E57);
        let chunk = rng.bytes(1000);
        let mut data = chunk.clone();
        data.extend(rng.bytes(100_000));
        data.extend_from_slice(&chunk);
        let mut m = ChainMatcher::new();
        let mut seqs = Vec::new();
        let mut lits = Vec::new();
        m.parse(&data, 0, &SearchParams::for_level(9), &mut seqs, &mut lits);
        let far = seqs.iter().any(|s| s.offset > 32_768 && s.match_len > 500);
        assert!(far, "no long-range match found: {:?}", seqs.iter().map(|s| (s.offset, s.match_len)).collect::<Vec<_>>());
        let out = execute_seqs(&seqs, &lits, b"", data.len()).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn dictionary_prefix_matchable() {
        let mut rng = Rng::new(0x2E58);
        let dict = rng.bytes(2000);
        // Small payload largely made of dictionary content.
        let mut data = Vec::new();
        for _ in 0..5 {
            let a = rng.range(0, 1500);
            data.extend_from_slice(&dict[a..a + 300]);
        }
        let mut m = ChainMatcher::new();
        let mut buf = dict.clone();
        buf.extend_from_slice(&data);
        let mut seqs = Vec::new();
        let mut lits = Vec::new();
        m.parse(&buf, dict.len(), &SearchParams::for_level(6), &mut seqs, &mut lits);
        // Nearly all of the payload should come from dictionary matches.
        assert!(lits.len() < data.len() / 4, "lits {} of {}", lits.len(), data.len());
        let out = execute_seqs(&seqs, &lits, &dict, data.len()).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn fuzz_roundtrip() {
        let mut rng = Rng::new(0x2E59);
        for round in 0..60 {
            let n = rng.range(0, 30_000);
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                match rng.range(0, 2) {
                    0 => {
                        let b = (rng.next_u64() & 0xFF) as u8;
                        let r = rng.range(1, 300);
                        data.extend(std::iter::repeat(b).take(r));
                    }
                    1 => {
                        let k = rng.range(1, 60);
                        let b = rng.bytes(k);
                        data.extend_from_slice(&b);
                    }
                    _ => data.extend_from_slice(b"ZSTD_window_"),
                }
            }
            data.truncate(n);
            let dict_len = if round % 3 == 0 { rng.range(0, 500) } else { 0 };
            let dict = rng.bytes(dict_len);
            roundtrip(&data, &dict, [1u8, 5, 9][round % 3]);
        }
    }
}
