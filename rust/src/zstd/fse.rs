//! Finite State Entropy — tabled asymmetric numeral system (tANS) coding,
//! the entropy stage the paper credits for ZSTD's win over ZLIB's Huffman
//! pass (§2.3: "Finite State Encoding ... outperforms ZLIB's Huffman coding
//! pass in terms of compression ratio and speed").
//!
//! This is a from-scratch tANS implementation following the zstd/FSE
//! construction: normalize symbol counts to a power-of-two table, spread
//! symbols with the coprime-step walk, then encode by state transitions
//! emitting `nb_bits` per symbol. Unlike Huffman, per-symbol cost is
//! fractional (state carries the remainder), so skewed alphabets code below
//! 1 bit/symbol.
//!
//! Stream convention: symbols are encoded in reverse and the emitted bit
//! chunks are flushed in reverse, so the decoder reads the bitstream
//! *forward* with the shared LSB-first [`BitReader`]. The final encoder
//! state is stored in the stream header; decode recovers symbols in the
//! original order.
//!
//! # §Perf: interleaved multi-state coding
//!
//! The production streams run multiple ANS states that alternate over
//! consecutive symbols, the same trick real zstd and the ans_flex
//! reproduction use: the state chains carry no data dependency on each
//! other, so the table lookups and the shared 57-bit-refill bit I/O
//! pipeline instead of serializing. Two widths are implemented:
//!
//! * **Dual-state** ([`EncTable::encode_interleaved`] /
//!   [`DecTable::decode_interleaved`]) — even indices on lane 0, odd on
//!   lane 1; the RFIL v2 stream layout (kept for v2 compatibility and as
//!   the [`crate::zstd::EntropyMode::Fse2`] write mode).
//! * **Quad-state** ([`EncTable::encode_interleaved4`] /
//!   [`DecTable::decode_interleaved4`]) — lane `i & 3`, four initial
//!   states in the section header; the RFIL v3 default
//!   ([`crate::zstd::EntropyMode::Fse4`]), keeping four refill chains in
//!   flight per block.
//!
//! Each lane absorbs its final symbol into its transmitted initial state
//! (one header state per lane instead of one total). All four directions
//! keep a deliberately straightforward oracle in [`reference`] that they
//! are property-tested **byte-identical** against
//! (`rust/tests/prop_codecs.rs`, `rust/tests/conformance_entropy.rs`),
//! mirroring the PR-1 fast-path pattern. Histogramming, the other hot
//! encoder pass, is the 4-lane [`histogram`] with the scalar
//! [`reference::histogram_naive`] oracle.

use crate::util::bitio::{BitReader, BitWriter};

/// Byte histogram feeding [`normalize_counts`] (§Perf): four interleaved
/// count arrays over an 8-byte-per-iteration walk, so the store-to-load
/// dependency on a repeated byte hits a different lane three times out of
/// four (`hist`-crate / ans_flex idiom). Property-tested equal to
/// [`reference::histogram_naive`].
pub fn histogram(data: &[u8]) -> [u32; 256] {
    let mut c0 = [0u32; 256];
    let mut c1 = [0u32; 256];
    let mut c2 = [0u32; 256];
    let mut c3 = [0u32; 256];
    let mut iter = data.chunks_exact(8);
    for ch in &mut iter {
        c0[ch[0] as usize] += 1;
        c1[ch[1] as usize] += 1;
        c2[ch[2] as usize] += 1;
        c3[ch[3] as usize] += 1;
        c0[ch[4] as usize] += 1;
        c1[ch[5] as usize] += 1;
        c2[ch[6] as usize] += 1;
        c3[ch[7] as usize] += 1;
    }
    for &b in iter.remainder() {
        c0[b as usize] += 1;
    }
    for i in 0..256 {
        c0[i] += c1[i] + c2[i] + c3[i];
    }
    c0
}

/// Symbol types the FSE coder accepts directly (avoids widening copies of
/// literal buffers on the encode hot path).
pub trait Symbol: Copy {
    fn as_u16(self) -> u16;
}
impl Symbol for u8 {
    #[inline]
    fn as_u16(self) -> u16 {
        self as u16
    }
}
impl Symbol for u16 {
    #[inline]
    fn as_u16(self) -> u16 {
        self
    }
}

/// Errors from table construction or decoding (untrusted inputs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FseError(pub &'static str);

impl std::fmt::Display for FseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "fse: {}", self.0)
    }
}
impl std::error::Error for FseError {}

const E: fn(&'static str) -> FseError = FseError;

/// Max table log we ever use (zstd default max is 12 for literals).
pub const MAX_TABLE_LOG: u32 = 12;

/// Normalize `hist` so the counts sum to `1 << table_log`, every present
/// symbol keeping a count ≥ 1 (zstd's fast normalization + correction).
pub fn normalize_counts(hist: &[u32], total: u64, table_log: u32) -> Result<Vec<u16>, FseError> {
    if table_log > MAX_TABLE_LOG {
        return Err(E("table log too large"));
    }
    let size = 1u64 << table_log;
    if total == 0 {
        return Err(E("empty input"));
    }
    let present = hist.iter().filter(|&&c| c > 0).count();
    if present == 0 {
        return Err(E("no symbols"));
    }
    if present as u64 > size {
        return Err(E("table too small for alphabet"));
    }
    let mut norm = vec![0u16; hist.len()];
    if present == 1 {
        // Degenerate: callers should use RLE mode, but keep it legal by
        // giving the single symbol the whole table.
        let sym = hist.iter().position(|&c| c > 0).unwrap();
        norm[sym] = size as u16;
        return Ok(norm);
    }

    // First pass: scaled counts, rounding to nearest, floor 1.
    let mut assigned: i64 = 0;
    for (s, &c) in hist.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let scaled = ((c as u128 * size as u128) / total as u128) as u64;
        let v = scaled.max(1).min(size - 1);
        norm[s] = v as u16;
        assigned += v as i64;
    }
    let mut rest = size as i64 - assigned;
    if rest > 0 {
        // Distribute remainder to the largest symbols (cheapest distortion).
        while rest > 0 {
            let s = (0..hist.len()).max_by_key(|&s| (norm[s], hist[s])).unwrap();
            let add = rest.min(size as i64 / 8).max(1) as u16;
            norm[s] += add;
            rest -= add as i64;
        }
    } else if rest < 0 {
        // Take back from over-represented symbols, never below 1.
        while rest < 0 {
            let mut best: Option<(f64, usize)> = None;
            for s in 0..hist.len() {
                if norm[s] > 1 {
                    // Overrepresentation ratio.
                    let ratio = norm[s] as f64 * total as f64 / (hist[s].max(1) as f64 * size as f64);
                    if best.map_or(true, |(r, _)| ratio > r) {
                        best = Some((ratio, s));
                    }
                }
            }
            let (_, s) = best.ok_or(E("normalization failed"))?;
            norm[s] -= 1;
            rest += 1;
        }
    }
    debug_assert_eq!(norm.iter().map(|&v| v as u64).sum::<u64>(), size);
    Ok(norm)
}

/// zstd's symbol-spread: walk the table with step `(5/8)size + 3`, which is
/// coprime with the power-of-two size, placing each symbol `norm[s]` times.
fn spread_symbols(norm: &[u16], table_log: u32) -> Vec<u16> {
    let size = 1usize << table_log;
    let mut table = vec![0u16; size];
    let step = (size >> 1) + (size >> 3) + 3;
    let mask = size - 1;
    let mut pos = 0usize;
    for (sym, &count) in norm.iter().enumerate() {
        for _ in 0..count {
            table[pos] = sym as u16;
            pos = (pos + step) & mask;
        }
    }
    debug_assert_eq!(pos, 0);
    table
}

/// Encoder tables (zstd layout: per-symbol deltaNbBits / deltaFindState +
/// a state transition table).
pub struct EncTable {
    table_log: u32,
    /// next_state[i]: for the i-th occurrence slot of a symbol.
    next_state: Vec<u16>,
    /// per symbol: (delta_find_state, delta_nb_bits)
    sym: Vec<(i32, u32)>,
    /// per symbol: a valid seed state (first spread slot), for the last
    /// symbol of a stream which is absorbed into the initial state.
    seed: Vec<u16>,
}

impl EncTable {
    pub fn new(norm: &[u16], table_log: u32) -> Result<Self, FseError> {
        let size = 1usize << table_log;
        let spread = spread_symbols(norm, table_log);

        // cumul[s] = first slot index for symbol s in the sorted layout.
        let mut cumul = vec![0u32; norm.len() + 1];
        for s in 0..norm.len() {
            cumul[s + 1] = cumul[s] + norm[s] as u32;
        }

        // next_state table: walking the spread table in order, the k-th slot
        // of symbol s (in spread order) maps state (size + k') where k'
        // counts occurrences. zstd builds: for position p in spread order,
        // tableU16[cumul[sym]++] = size + p.
        let mut next_state = vec![0u16; size];
        let mut cursor = cumul.clone();
        for (p, &sym) in spread.iter().enumerate() {
            let c = &mut cursor[sym as usize];
            next_state[*c as usize] = (size + p) as u16;
            *c += 1;
        }

        // Per-symbol deltas + seed states.
        let mut sym = vec![(0i32, 0u32); norm.len()];
        let mut seed = vec![0u16; norm.len()];
        let mut total = 0u32;
        for (s, &count) in norm.iter().enumerate() {
            let count = count as u32;
            if count == 0 {
                continue;
            }
            seed[s] = next_state[total as usize];
            if count == 1 {
                sym[s] = (total as i32 - 1, (table_log << 16) - (1 << table_log));
            } else {
                // max_bits_out = table_log - floor(log2(count-1));
                let max_bits = table_log - (31 - (count - 1).leading_zeros());
                let min_state_plus = count << max_bits;
                sym[s] = (
                    total as i32 - count as i32,
                    (max_bits << 16) - min_state_plus,
                );
            }
            total += count;
        }
        Ok(Self { table_log, next_state, sym, seed })
    }

    pub fn table_log(&self) -> u32 {
        self.table_log
    }

    /// Encode `symbols` (forward order); the decoder will recover the same
    /// order reading the returned bits forward. Returns (payload, final
    /// state) — state must be transmitted.
    pub fn encode(&self, symbols: impl DoubleEndedIterator<Item = u16> + ExactSizeIterator) -> (Vec<u8>, u16) {
        // tANS encodes in reverse; stack the (bits, nbits) chunks and flush
        // them reversed so decode reads forward.
        let mut chunks: Vec<(u32, u32)> = Vec::with_capacity(symbols.len());
        // Initial state: encode the first (in reverse order) symbol from the
        // canonical start. zstd seeds state via the first symbol's table; we
        // use state = first occurrence slot, which is always valid.
        let mut state: u32 = 0;
        let mut first = true;
        for s in symbols.rev() {
            if first {
                // The last stream symbol is absorbed into the seed state —
                // the decoder emits it from the final state without reading
                // further bits.
                state = self.seed[s as usize] as u32;
                first = false;
                continue;
            }
            let (delta_find, delta_nb) = self.sym[s as usize];
            let nb_bits = (delta_nb.wrapping_add(state)) >> 16;
            chunks.push((state & ((1 << nb_bits) - 1), nb_bits));
            let idx = ((state >> nb_bits) as i32 + delta_find) as usize;
            state = self.next_state[idx] as u32;
        }
        let mut w = BitWriter::with_capacity(chunks.len() / 2 + 8);
        for &(bits, nb) in chunks.iter().rev() {
            w.write_bits(bits as u64, nb);
        }
        (w.finish(), state as u16)
    }

    /// §Perf hot path: encode `symbols` with two interleaved states — even
    /// indices on lane 0, odd on lane 1 — so consecutive transitions are
    /// independent and pipeline. Each lane's last symbol is absorbed into
    /// its returned initial state. Byte-identical to
    /// [`reference::encode_interleaved_naive`] (property-tested); decode
    /// with [`DecTable::decode_interleaved`].
    ///
    /// The chunk stack packs `(bits, nb_bits)` into one `u32`
    /// (`bits | nb << 12`; both fit 12 bits since `table_log <= 12`), and
    /// the reversed flush goes through the word-flush [`BitWriter`] — the
    /// two deliberately-cheap differences from the naive oracle.
    pub fn encode_interleaved<S: Symbol>(&self, symbols: &[S]) -> (Vec<u8>, [u16; 2]) {
        let size = 1u32 << self.table_log;
        // Lanes a symbol never seeds keep `size`: a valid (ignored) state.
        let mut states = [size, size];
        let mut seeded = [false; 2];
        let mut chunks: Vec<u32> = Vec::with_capacity(symbols.len());
        let mut i = symbols.len();
        while i > 0 {
            i -= 1;
            let s = symbols[i].as_u16() as usize;
            let lane = i & 1;
            if !seeded[lane] {
                states[lane] = self.seed[s] as u32;
                seeded[lane] = true;
                continue;
            }
            let (delta_find, delta_nb) = self.sym[s];
            let st = states[lane];
            let nb = delta_nb.wrapping_add(st) >> 16;
            chunks.push((st & ((1u32 << nb) - 1)) | (nb << 12));
            states[lane] = self.next_state[((st >> nb) as i32 + delta_find) as usize] as u32;
        }
        let mut w = BitWriter::with_capacity(chunks.len() + 8);
        for &c in chunks.iter().rev() {
            w.write_bits((c & 0xFFF) as u64, c >> 12);
        }
        (w.finish(), [states[0] as u16, states[1] as u16])
    }

    /// §Perf hot path, RFIL v3 width: encode `symbols` with **four**
    /// interleaved states — symbol `i` on lane `i & 3` — so four state
    /// chains pipeline per block (the zstd/Huff0 stream-count sweet
    /// spot). Each lane's last symbol is absorbed into its returned
    /// initial state; a lane the input never seeds (fewer than four
    /// symbols) returns the always-valid state `1 << table_log`.
    /// Byte-identical to [`reference::encode_interleaved4_naive`]
    /// (property-tested); decode with [`DecTable::decode_interleaved4`].
    ///
    /// Same chunk packing as [`EncTable::encode_interleaved`]:
    /// `(bits, nb_bits)` in one `u32` (`bits | nb << 12`, both ≤ 12 bits),
    /// reversed flush through the word-flush [`BitWriter`].
    pub fn encode_interleaved4<S: Symbol>(&self, symbols: &[S]) -> (Vec<u8>, [u16; 4]) {
        let size = 1u32 << self.table_log;
        // Lanes a symbol never seeds keep `size`: a valid (ignored) state.
        let mut states = [size, size, size, size];
        let mut seeded = [false; 4];
        let mut chunks: Vec<u32> = Vec::with_capacity(symbols.len());
        let mut i = symbols.len();
        while i > 0 {
            i -= 1;
            let s = symbols[i].as_u16() as usize;
            let lane = i & 3;
            if !seeded[lane] {
                states[lane] = self.seed[s] as u32;
                seeded[lane] = true;
                continue;
            }
            let (delta_find, delta_nb) = self.sym[s];
            let st = states[lane];
            let nb = delta_nb.wrapping_add(st) >> 16;
            chunks.push((st & ((1u32 << nb) - 1)) | (nb << 12));
            states[lane] = self.next_state[((st >> nb) as i32 + delta_find) as usize] as u32;
        }
        let mut w = BitWriter::with_capacity(chunks.len() + 8);
        for &c in chunks.iter().rev() {
            w.write_bits((c & 0xFFF) as u64, c >> 12);
        }
        (
            w.finish(),
            [states[0] as u16, states[1] as u16, states[2] as u16, states[3] as u16],
        )
    }
}

/// Decoder table entry.
#[derive(Clone, Copy, Default)]
struct DecEntry {
    symbol: u16,
    nb_bits: u8,
    base: u16,
}

/// Decoder table.
pub struct DecTable {
    table_log: u32,
    entries: Vec<DecEntry>,
}

impl DecTable {
    pub fn new(norm: &[u16], table_log: u32) -> Result<Self, FseError> {
        let size = 1usize << table_log;
        let total: u64 = norm.iter().map(|&v| v as u64).sum();
        if total != size as u64 {
            return Err(E("counts don't sum to table size"));
        }
        let spread = spread_symbols(norm, table_log);
        let mut occurrences = vec![0u16; norm.len()];
        let mut entries = vec![DecEntry::default(); size];
        for (p, &sym) in spread.iter().enumerate() {
            let s = sym as usize;
            let count = norm[s] as u32;
            let k = occurrences[s] as u32; // occurrence index of this slot
            occurrences[s] += 1;
            // This slot is reached from states [ (count + k) << nb , ... ).
            let x = count + k;
            let nb_bits = table_log - (31 - x.leading_zeros());
            let base = (x << nb_bits) - size as u32;
            entries[p] = DecEntry { symbol: sym, nb_bits: nb_bits as u8, base: base as u16 };
        }
        Ok(Self { table_log, entries })
    }

    /// Decode `count` symbols, starting from `init_state` (the encoder's
    /// final state), reading extra bits forward.
    pub fn decode(
        &self,
        r: &mut BitReader,
        init_state: u16,
        count: usize,
        out: &mut Vec<u16>,
    ) -> Result<(), FseError> {
        let size = 1u32 << self.table_log;
        let mut state = init_state as u32;
        if state < size || state >= 2 * size {
            return Err(E("invalid initial state"));
        }
        for k in 0..count {
            let e = self.entries[(state - size) as usize];
            out.push(e.symbol);
            if k + 1 == count {
                break; // last symbol: no trailing bits (absorbed at seed)
            }
            let bits = r.read_bits(e.nb_bits as u32) as u32;
            state = size + e.base as u32 + bits;
            if r.overflowed() {
                return Err(E("bitstream exhausted"));
            }
        }
        Ok(())
    }

    /// §Perf hot path: decode `count` symbols produced by
    /// [`EncTable::encode_interleaved`]. The batch loop emits one symbol
    /// from each lane per iteration with no per-symbol exhaustion checks —
    /// state transitions keep states in `[size, 2*size)` by construction
    /// even on garbage bits, and the single [`BitReader::overflowed`] check
    /// after the loop rejects truncated payloads exactly like the
    /// per-symbol check in [`reference::decode_interleaved_naive`] (same
    /// accept/reject set; identical symbols on accept — property-tested).
    pub fn decode_interleaved(
        &self,
        r: &mut BitReader,
        init: [u16; 2],
        count: usize,
        out: &mut Vec<u16>,
    ) -> Result<(), FseError> {
        let size = 1u32 << self.table_log;
        let mut sa = init[0] as u32;
        let mut sb = init[1] as u32;
        for &s in &[sa, sb] {
            if s < size || s >= 2 * size {
                return Err(E("invalid initial state"));
            }
        }
        out.reserve(count);
        let entries = &self.entries[..];
        let mut k = 0usize;
        // Batch loop: symbol k reads bits iff k + 2 < count (each lane's
        // final symbol was absorbed into its initial state), so a pair at
        // (k, k+1) is check-free when k + 3 < count.
        while k + 3 < count {
            let ea = entries[(sa - size) as usize];
            out.push(ea.symbol);
            sa = size + ea.base as u32 + r.read_bits(ea.nb_bits as u32) as u32;
            let eb = entries[(sb - size) as usize];
            out.push(eb.symbol);
            sb = size + eb.base as u32 + r.read_bits(eb.nb_bits as u32) as u32;
            k += 2;
        }
        // Careful tail (≤ 3 symbols): per-symbol read guards.
        while k < count {
            let st = if k & 1 == 0 { &mut sa } else { &mut sb };
            let e = entries[(*st - size) as usize];
            out.push(e.symbol);
            if k + 2 < count {
                *st = size + e.base as u32 + r.read_bits(e.nb_bits as u32) as u32;
            }
            k += 1;
        }
        if r.overflowed() {
            return Err(E("bitstream exhausted"));
        }
        Ok(())
    }

    /// §Perf hot path, RFIL v3 width: decode `count` symbols produced by
    /// [`EncTable::encode_interleaved4`]. The batch loop emits one symbol
    /// from each of the four lanes per iteration with no per-symbol
    /// exhaustion checks — state transitions keep states in
    /// `[size, 2*size)` by construction even on garbage bits, and the
    /// single [`BitReader::overflowed`] check after the loop rejects
    /// truncated payloads exactly like the per-symbol check in
    /// [`reference::decode_interleaved4_naive`] (same accept/reject set;
    /// identical symbols on accept — property-tested).
    pub fn decode_interleaved4(
        &self,
        r: &mut BitReader,
        init: [u16; 4],
        count: usize,
        out: &mut Vec<u16>,
    ) -> Result<(), FseError> {
        let size = 1u32 << self.table_log;
        let mut states = [init[0] as u32, init[1] as u32, init[2] as u32, init[3] as u32];
        for &s in &states {
            if s < size || s >= 2 * size {
                return Err(E("invalid initial state"));
            }
        }
        out.reserve(count);
        let entries = &self.entries[..];
        let mut k = 0usize;
        // Batch loop: symbol k reads bits iff k + 4 < count (each lane's
        // final symbol was absorbed into its initial state), so a quad at
        // (k .. k+3) is check-free when k + 7 < count.
        while k + 7 < count {
            for st in states.iter_mut() {
                let e = entries[(*st - size) as usize];
                out.push(e.symbol);
                *st = size + e.base as u32 + r.read_bits(e.nb_bits as u32) as u32;
            }
            k += 4;
        }
        // Careful tail (≤ 7 symbols): per-symbol read guards.
        while k < count {
            let st = &mut states[k & 3];
            let e = entries[(*st - size) as usize];
            out.push(e.symbol);
            if k + 4 < count {
                *st = size + e.base as u32 + r.read_bits(e.nb_bits as u32) as u32;
            }
            k += 1;
        }
        if r.overflowed() {
            return Err(E("bitstream exhausted"));
        }
        Ok(())
    }
}

/// Deliberately straightforward oracles for the §Perf fast paths above.
/// Same stream format, naive loops: the property suite asserts the fast
/// encoder is byte-identical and the fast decoder symbol-identical.
#[doc(hidden)]
pub mod reference {
    use super::*;
    use crate::util::bitio::reference::NaiveBitWriter;

    /// Scalar byte histogram (oracle for [`super::histogram`]).
    pub fn histogram_naive(data: &[u8]) -> [u32; 256] {
        let mut hist = [0u32; 256];
        for &b in data {
            hist[b as usize] += 1;
        }
        hist
    }

    /// One-symbol-at-a-time interleaved encoder using the byte-at-a-time
    /// bit writer (oracle for [`EncTable::encode_interleaved`]).
    pub fn encode_interleaved_naive(table: &EncTable, symbols: &[u16]) -> (Vec<u8>, [u16; 2]) {
        let size = 1u32 << table.table_log;
        let mut states = [size, size];
        let mut seeded = [false; 2];
        let mut chunks: Vec<(u32, u32)> = Vec::new();
        for i in (0..symbols.len()).rev() {
            let s = symbols[i] as usize;
            let lane = i % 2;
            if !seeded[lane] {
                states[lane] = table.seed[s] as u32;
                seeded[lane] = true;
                continue;
            }
            let (delta_find, delta_nb) = table.sym[s];
            let st = states[lane];
            let nb = delta_nb.wrapping_add(st) >> 16;
            chunks.push((st & ((1u32 << nb) - 1), nb));
            states[lane] = table.next_state[((st >> nb) as i32 + delta_find) as usize] as u32;
        }
        let mut w = NaiveBitWriter::new();
        for &(bits, nb) in chunks.iter().rev() {
            w.write_bits(bits as u64, nb);
        }
        (w.finish(), [states[0] as u16, states[1] as u16])
    }

    /// Per-symbol interleaved decoder with an exhaustion check after every
    /// read (oracle for [`DecTable::decode_interleaved`]).
    pub fn decode_interleaved_naive(
        table: &DecTable,
        r: &mut BitReader,
        init: [u16; 2],
        count: usize,
        out: &mut Vec<u16>,
    ) -> Result<(), FseError> {
        let size = 1u32 << table.table_log;
        let mut states = [init[0] as u32, init[1] as u32];
        for &s in &states {
            if s < size || s >= 2 * size {
                return Err(E("invalid initial state"));
            }
        }
        for k in 0..count {
            let lane = k % 2;
            let e = table.entries[(states[lane] - size) as usize];
            out.push(e.symbol);
            if k + 2 < count {
                let bits = r.read_bits(e.nb_bits as u32) as u32;
                states[lane] = size + e.base as u32 + bits;
                if r.overflowed() {
                    return Err(E("bitstream exhausted"));
                }
            }
        }
        Ok(())
    }

    /// One-symbol-at-a-time quad-lane encoder using the byte-at-a-time bit
    /// writer (oracle for [`EncTable::encode_interleaved4`]).
    pub fn encode_interleaved4_naive(table: &EncTable, symbols: &[u16]) -> (Vec<u8>, [u16; 4]) {
        let size = 1u32 << table.table_log;
        let mut states = [size, size, size, size];
        let mut seeded = [false; 4];
        let mut chunks: Vec<(u32, u32)> = Vec::new();
        for i in (0..symbols.len()).rev() {
            let s = symbols[i] as usize;
            let lane = i % 4;
            if !seeded[lane] {
                states[lane] = table.seed[s] as u32;
                seeded[lane] = true;
                continue;
            }
            let (delta_find, delta_nb) = table.sym[s];
            let st = states[lane];
            let nb = delta_nb.wrapping_add(st) >> 16;
            chunks.push((st & ((1u32 << nb) - 1), nb));
            states[lane] = table.next_state[((st >> nb) as i32 + delta_find) as usize] as u32;
        }
        let mut w = NaiveBitWriter::new();
        for &(bits, nb) in chunks.iter().rev() {
            w.write_bits(bits as u64, nb);
        }
        (
            w.finish(),
            [states[0] as u16, states[1] as u16, states[2] as u16, states[3] as u16],
        )
    }

    /// Per-symbol quad-lane decoder with an exhaustion check after every
    /// read (oracle for [`DecTable::decode_interleaved4`]).
    pub fn decode_interleaved4_naive(
        table: &DecTable,
        r: &mut BitReader,
        init: [u16; 4],
        count: usize,
        out: &mut Vec<u16>,
    ) -> Result<(), FseError> {
        let size = 1u32 << table.table_log;
        let mut states = [init[0] as u32, init[1] as u32, init[2] as u32, init[3] as u32];
        for &s in &states {
            if s < size || s >= 2 * size {
                return Err(E("invalid initial state"));
            }
        }
        for k in 0..count {
            let lane = k % 4;
            let e = table.entries[(states[lane] - size) as usize];
            out.push(e.symbol);
            if k + 4 < count {
                let bits = r.read_bits(e.nb_bits as u32) as u32;
                states[lane] = size + e.base as u32 + bits;
                if r.overflowed() {
                    return Err(E("bitstream exhausted"));
                }
            }
        }
        Ok(())
    }
}

/// Serialize normalized counts (compact): uvarint alphabet size, then for
/// each symbol a uvarint count (0 allowed, cheap due to varint).
pub fn write_norm(out: &mut Vec<u8>, norm: &[u16], table_log: u32) {
    use crate::util::varint::put_uvarint;
    out.push(table_log as u8);
    let last = norm.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
    put_uvarint(out, last as u64);
    let mut zeros = 0u64;
    for &c in &norm[..last] {
        if c == 0 {
            zeros += 1;
            continue;
        }
        if zeros > 0 {
            // 0 marker followed by zero-run length.
            put_uvarint(out, 0);
            put_uvarint(out, zeros);
            zeros = 0;
        }
        put_uvarint(out, c as u64);
    }
}

/// Deserialize normalized counts; returns (norm, table_log).
pub fn read_norm(c: &mut crate::util::varint::Cursor) -> Result<(Vec<u16>, u32), FseError> {
    let table_log = c.u8().ok_or(E("truncated table log"))? as u32;
    if table_log == 0 || table_log > MAX_TABLE_LOG {
        return Err(E("bad table log"));
    }
    let n = c.uvarint().ok_or(E("truncated alphabet size"))? as usize;
    if n == 0 || n > 4096 {
        return Err(E("bad alphabet size"));
    }
    let mut norm = vec![0u16; n];
    let mut i = 0usize;
    let size = 1u64 << table_log;
    let mut total = 0u64;
    while i < n {
        let v = c.uvarint().ok_or(E("truncated counts"))?;
        if v == 0 {
            let run = c.uvarint().ok_or(E("truncated zero run"))? as usize;
            if run == 0 || i + run > n {
                return Err(E("bad zero run"));
            }
            i += run;
        } else {
            if v > size {
                return Err(E("count too large"));
            }
            norm[i] = v as u16;
            total += v;
            i += 1;
        }
    }
    if total != size {
        return Err(E("counts don't sum to table size"));
    }
    Ok((norm, table_log))
}

/// Pick a table log for `total` symbols over `alphabet` present symbols
/// (zstd's FSE_optimalTableLog flavor).
pub fn optimal_table_log(total: usize, present: usize, max_log: u32) -> u32 {
    let mut log = if total > 1 { (usize::BITS - 1 - (total - 1).leading_zeros()).saturating_sub(2) } else { 5 };
    let min_for_alphabet = (usize::BITS - (present.max(2) - 1).leading_zeros()) + 1;
    log = log.max(min_for_alphabet).max(5).min(max_log);
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::varint::Cursor;

    fn roundtrip_syms(symbols: &[u16], alphabet: usize) {
        let mut hist = vec![0u32; alphabet];
        for &s in symbols {
            hist[s as usize] += 1;
        }
        let present = hist.iter().filter(|&&c| c > 0).count();
        if present < 2 {
            return; // RLE territory, not FSE
        }
        let log = optimal_table_log(symbols.len(), present, 11);
        let norm = normalize_counts(&hist, symbols.len() as u64, log).unwrap();
        let enc = EncTable::new(&norm, log).unwrap();
        let (payload, state) = enc.encode(symbols.iter().copied());
        let dec = DecTable::new(&norm, log).unwrap();
        let mut r = BitReader::new(&payload);
        let mut out = Vec::with_capacity(symbols.len());
        dec.decode(&mut r, state, symbols.len(), &mut out).unwrap();
        assert_eq!(out, symbols);
    }

    #[test]
    fn roundtrip_uniform() {
        let mut rng = Rng::new(0xF5E);
        let syms: Vec<u16> = (0..10_000).map(|_| rng.range(0, 255) as u16).collect();
        roundtrip_syms(&syms, 256);
    }

    #[test]
    fn roundtrip_skewed() {
        let mut rng = Rng::new(0xF5F);
        let syms: Vec<u16> = (0..20_000)
            .map(|_| {
                if rng.chance(0.9) {
                    0u16
                } else if rng.chance(0.7) {
                    1
                } else {
                    rng.range(2, 40) as u16
                }
            })
            .collect();
        roundtrip_syms(&syms, 41);
        // Compression sanity: skewed stream codes well below 8 bits/sym.
        let mut hist = vec![0u32; 41];
        for &s in &syms {
            hist[s as usize] += 1;
        }
        let log = optimal_table_log(syms.len(), 41, 11);
        let norm = normalize_counts(&hist, syms.len() as u64, log).unwrap();
        let enc = EncTable::new(&norm, log).unwrap();
        let (payload, _) = enc.encode(syms.iter().copied());
        let bits_per_sym = payload.len() as f64 * 8.0 / syms.len() as f64;
        assert!(bits_per_sym < 1.2, "bits/sym = {bits_per_sym}");
    }

    #[test]
    fn roundtrip_two_symbols() {
        let syms: Vec<u16> = (0..999).map(|i| (i % 5 == 0) as u16).collect();
        roundtrip_syms(&syms, 2);
    }

    #[test]
    fn roundtrip_tiny_streams() {
        for n in 2..30usize {
            let syms: Vec<u16> = (0..n).map(|i| (i % 3) as u16).collect();
            roundtrip_syms(&syms, 3);
        }
    }

    #[test]
    fn fuzz_random_alphabets() {
        let mut rng = Rng::new(0xF60);
        for _ in 0..60 {
            let alphabet = rng.range(2, 300);
            let n = rng.range(2, 5000);
            // Zipf-ish distribution.
            let syms: Vec<u16> = (0..n)
                .map(|_| {
                    let r = rng.f64();
                    let v = ((alphabet as f64).powf(r) - 1.0) as usize;
                    v.min(alphabet - 1) as u16
                })
                .collect();
            roundtrip_syms(&syms, alphabet);
        }
    }

    fn tables_for(symbols: &[u16], alphabet: usize, max_log: u32) -> Option<(EncTable, DecTable)> {
        let mut hist = vec![0u32; alphabet];
        for &s in symbols {
            hist[s as usize] += 1;
        }
        let present = hist.iter().filter(|&&c| c > 0).count();
        if present < 2 {
            return None;
        }
        let log = optimal_table_log(symbols.len(), present, max_log);
        let norm = normalize_counts(&hist, symbols.len() as u64, log).unwrap();
        Some((EncTable::new(&norm, log).unwrap(), DecTable::new(&norm, log).unwrap()))
    }

    #[test]
    fn interleaved_roundtrip_and_matches_naive() {
        let mut rng = Rng::new(0xF62);
        for round in 0..80 {
            let alphabet = rng.range(2, 260);
            let n = rng.range(2, 4000);
            let syms: Vec<u16> = (0..n)
                .map(|_| {
                    let r = rng.f64();
                    (((alphabet as f64).powf(r) - 1.0) as usize).min(alphabet - 1) as u16
                })
                .collect();
            let Some((enc, dec)) = tables_for(&syms, alphabet, 11) else { continue };
            let (fast_payload, fast_states) = enc.encode_interleaved(&syms);
            let (naive_payload, naive_states) = reference::encode_interleaved_naive(&enc, &syms);
            assert_eq!(fast_payload, naive_payload, "round {round} n {n}");
            assert_eq!(fast_states, naive_states, "round {round}");
            let mut out = Vec::new();
            dec.decode_interleaved(&mut BitReader::new(&fast_payload), fast_states, syms.len(), &mut out)
                .unwrap();
            assert_eq!(out, syms, "round {round}");
            let mut out2 = Vec::new();
            reference::decode_interleaved_naive(
                &dec,
                &mut BitReader::new(&fast_payload),
                fast_states,
                syms.len(),
                &mut out2,
            )
            .unwrap();
            assert_eq!(out2, syms, "round {round} (naive decode)");
        }
    }

    #[test]
    fn interleaved_tiny_streams() {
        for n in 2..40usize {
            let syms: Vec<u16> = (0..n).map(|i| (i % 3) as u16).collect();
            let Some((enc, dec)) = tables_for(&syms, 3, 9) else { continue };
            let (payload, states) = enc.encode_interleaved(&syms);
            let mut out = Vec::new();
            dec.decode_interleaved(&mut BitReader::new(&payload), states, n, &mut out).unwrap();
            assert_eq!(out, syms, "n={n}");
        }
    }

    #[test]
    fn interleaved_u8_symbols_match_u16() {
        let mut rng = Rng::new(0xF63);
        let bytes: Vec<u8> = (0..5000).map(|_| (rng.next_u64() & 0x1F) as u8).collect();
        let wide: Vec<u16> = bytes.iter().map(|&b| b as u16).collect();
        let (enc, dec) = tables_for(&wide, 256, 11).unwrap();
        let (pa, sa) = enc.encode_interleaved(&bytes);
        let (pb, sb) = enc.encode_interleaved(&wide);
        assert_eq!(pa, pb);
        assert_eq!(sa, sb);
        let mut out = Vec::new();
        dec.decode_interleaved(&mut BitReader::new(&pa), sa, bytes.len(), &mut out).unwrap();
        assert_eq!(out, wide);
    }

    #[test]
    fn interleaved_truncation_rejected() {
        let syms: Vec<u16> = (0..4000).map(|i| (i % 7) as u16).collect();
        let (enc, dec) = tables_for(&syms, 7, 9).unwrap();
        let (payload, states) = enc.encode_interleaved(&syms);
        for cut in [0usize, 1, payload.len() / 2] {
            let mut out = Vec::new();
            let r = dec.decode_interleaved(&mut BitReader::new(&payload[..cut]), states, syms.len(), &mut out);
            assert!(r.is_err(), "cut {cut} accepted");
            let mut out2 = Vec::new();
            let rn = reference::decode_interleaved_naive(
                &dec,
                &mut BitReader::new(&payload[..cut]),
                states,
                syms.len(),
                &mut out2,
            );
            assert!(rn.is_err(), "cut {cut} accepted by naive");
        }
    }

    #[test]
    fn interleaved4_roundtrip_and_matches_naive() {
        let mut rng = Rng::new(0xF65);
        for round in 0..80 {
            let alphabet = rng.range(2, 260);
            let n = rng.range(2, 4000);
            let syms: Vec<u16> = (0..n)
                .map(|_| {
                    let r = rng.f64();
                    (((alphabet as f64).powf(r) - 1.0) as usize).min(alphabet - 1) as u16
                })
                .collect();
            let Some((enc, dec)) = tables_for(&syms, alphabet, 11) else { continue };
            let (fast_payload, fast_states) = enc.encode_interleaved4(&syms);
            let (naive_payload, naive_states) = reference::encode_interleaved4_naive(&enc, &syms);
            assert_eq!(fast_payload, naive_payload, "round {round} n {n}");
            assert_eq!(fast_states, naive_states, "round {round}");
            let mut out = Vec::new();
            dec.decode_interleaved4(&mut BitReader::new(&fast_payload), fast_states, syms.len(), &mut out)
                .unwrap();
            assert_eq!(out, syms, "round {round}");
            let mut out2 = Vec::new();
            reference::decode_interleaved4_naive(
                &dec,
                &mut BitReader::new(&fast_payload),
                fast_states,
                syms.len(),
                &mut out2,
            )
            .unwrap();
            assert_eq!(out2, syms, "round {round} (naive decode)");
        }
    }

    #[test]
    fn interleaved4_tiny_streams() {
        // Covers every lane-seeding shape: streams shorter than the lane
        // count, exactly the lane count, and every tail length mod 4.
        for n in 2..40usize {
            let syms: Vec<u16> = (0..n).map(|i| (i % 3) as u16).collect();
            let Some((enc, dec)) = tables_for(&syms, 3, 9) else { continue };
            let (payload, states) = enc.encode_interleaved4(&syms);
            let mut out = Vec::new();
            dec.decode_interleaved4(&mut BitReader::new(&payload), states, n, &mut out).unwrap();
            assert_eq!(out, syms, "n={n}");
        }
    }

    #[test]
    fn interleaved4_u8_symbols_match_u16() {
        let mut rng = Rng::new(0xF66);
        let bytes: Vec<u8> = (0..5000).map(|_| (rng.next_u64() & 0x1F) as u8).collect();
        let wide: Vec<u16> = bytes.iter().map(|&b| b as u16).collect();
        let (enc, dec) = tables_for(&wide, 256, 11).unwrap();
        let (pa, sa) = enc.encode_interleaved4(&bytes);
        let (pb, sb) = enc.encode_interleaved4(&wide);
        assert_eq!(pa, pb);
        assert_eq!(sa, sb);
        let mut out = Vec::new();
        dec.decode_interleaved4(&mut BitReader::new(&pa), sa, bytes.len(), &mut out).unwrap();
        assert_eq!(out, wide);
    }

    #[test]
    fn interleaved4_truncation_rejected() {
        let syms: Vec<u16> = (0..4000).map(|i| (i % 7) as u16).collect();
        let (enc, dec) = tables_for(&syms, 7, 9).unwrap();
        let (payload, states) = enc.encode_interleaved4(&syms);
        for cut in [0usize, 1, payload.len() / 2] {
            let mut out = Vec::new();
            let r = dec.decode_interleaved4(&mut BitReader::new(&payload[..cut]), states, syms.len(), &mut out);
            assert!(r.is_err(), "cut {cut} accepted");
            let mut out2 = Vec::new();
            let rn = reference::decode_interleaved4_naive(
                &dec,
                &mut BitReader::new(&payload[..cut]),
                states,
                syms.len(),
                &mut out2,
            );
            assert!(rn.is_err(), "cut {cut} accepted by naive");
        }
    }

    #[test]
    fn interleaved4_bad_initial_states_rejected() {
        let syms: Vec<u16> = (0..200).map(|i| (i % 5) as u16).collect();
        let (enc, dec) = tables_for(&syms, 5, 9).unwrap();
        let (payload, states) = enc.encode_interleaved4(&syms);
        for lane in 0..4 {
            for bad in [0u16, (1 << 9) - 1, 2 << 9] {
                let mut s = states;
                s[lane] = bad;
                let mut out = Vec::new();
                assert!(
                    dec.decode_interleaved4(&mut BitReader::new(&payload), s, syms.len(), &mut out)
                        .is_err(),
                    "lane {lane} state {bad} accepted"
                );
            }
        }
    }

    #[test]
    fn histogram_matches_naive() {
        let mut rng = Rng::new(0xF64);
        for _ in 0..60 {
            let n = rng.range(0, 10_000);
            let data = rng.bytes(n);
            assert_eq!(histogram(&data), reference::histogram_naive(&data));
        }
        // Alignment/remainder edges.
        for n in 0..32usize {
            let data: Vec<u8> = (0..n as u8).collect();
            assert_eq!(histogram(&data), reference::histogram_naive(&data));
        }
    }

    #[test]
    fn norm_counts_serialize() {
        let hist = [100u32, 0, 0, 0, 50, 3, 0, 1];
        let norm = normalize_counts(&hist, 154, 8).unwrap();
        let mut buf = Vec::new();
        write_norm(&mut buf, &norm, 8);
        let mut cur = Cursor::new(&buf);
        let (norm2, log2) = read_norm(&mut cur).unwrap();
        assert_eq!(log2, 8);
        assert_eq!(&norm2[..], &norm[..norm2.len()]);
        assert_eq!(norm[norm2.len()..].iter().map(|&v| v as u32).sum::<u32>(), 0);
    }

    #[test]
    fn read_norm_rejects_bad() {
        // Counts not summing to table size.
        let mut buf = Vec::new();
        buf.push(8u8); // log
        crate::util::varint::put_uvarint(&mut buf, 2); // 2 symbols
        crate::util::varint::put_uvarint(&mut buf, 100);
        crate::util::varint::put_uvarint(&mut buf, 100);
        let mut cur = Cursor::new(&buf);
        assert!(read_norm(&mut cur).is_err());
    }

    #[test]
    fn normalize_preserves_presence() {
        let mut rng = Rng::new(0xF61);
        for _ in 0..50 {
            let n = rng.range(2, 200);
            let mut hist = vec![0u32; n];
            for h in hist.iter_mut() {
                if rng.chance(0.6) {
                    *h = rng.below(10_000) as u32 + 1;
                }
            }
            let present = hist.iter().filter(|&&c| c > 0).count();
            if present < 2 {
                continue;
            }
            let total: u64 = hist.iter().map(|&c| c as u64).sum();
            let log = optimal_table_log(total as usize, present, 12);
            let norm = normalize_counts(&hist, total, log).unwrap();
            assert_eq!(norm.iter().map(|&v| v as u64).sum::<u64>(), 1 << log);
            for (h, n) in hist.iter().zip(&norm) {
                assert_eq!(*h > 0, *n > 0);
            }
        }
    }
}
