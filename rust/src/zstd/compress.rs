//! RZS1 block format: the ZSTD-style container combining the large-window
//! LZ parse with FSE entropy coding of literals and sequence codes.
//!
//! Layout (all integers uvarint unless noted):
//!
//! ```text
//! [raw_len][n_seq]
//! literals:  [mode u8] 0=raw:   [len][bytes]
//!                      1=rle:   [len][byte]
//!                      2=fse:   [len][norm table][state0][state1][payload_len][payload]
//!                      3=fse4:  [len][norm table][state0..state3][payload_len][payload]
//!                      4=huff0: [len][blob_len][huff0 blob]      (literals only)
//! if n_seq > 0, three code sections (ll, ml, of), each:
//!            [mode u8] 0=raw:   [codes as bytes]        (len = n_seq)
//!                      1=rle:   [code byte]
//!                      2=fse:   [norm table][state0][state1][payload_len][payload]
//!                      3=fse4:  [norm table][state0..state3][payload_len][payload]
//!
//! Mode 2 sections carry **two** initial states (the dual-lane
//! `fse::EncTable::encode_interleaved` — even symbol indices on lane 0,
//! odd on lane 1); mode 3 carries **four** (`encode_interleaved4`, lane
//! `i & 3`); mode 4 embeds a 4-stream Huffman blob (`huff0::compress`).
//! Which modes the *encoder* emits is selected by [`EntropyMode`]
//! (decoders accept all of them unconditionally): `Fse2` reproduces the
//! RFIL-v2 streams byte-identically, `Fse4` (default) upgrades FSE
//! sections to mode 3, `Huff0` additionally tries mode 4 for literals.
//! Every lane keeps its byte-identical naive oracle in
//! `fse::reference` / `huff0::reference`.
//! extras:    [payload_len][bit payload]   (ll, ml, of extra bits per seq)
//! ```
//!
//! Value coding: `v` maps to code `k` = bit-length of `v` (0 → code 0),
//! with `k-1` extra bits storing `v - 2^(k-1)`. Sequence fields: ll = lit
//! run, ml = match_len - 3, of = offset - 1.

use super::fse;
use super::huff0;
use super::matcher::{ChainMatcher, SearchParams, Seq, MIN_MATCH};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::varint::{put_uvarint, Cursor};

/// Which entropy lanes the *encoder* uses for RZS1 sections. A write-time
/// knob only: the decoder accepts every mode unconditionally, and the
/// choice is not recorded in file metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum EntropyMode {
    /// Dual-state interleaved FSE everywhere (mode-2 sections): exactly
    /// the streams RFIL-v2 writers produced, byte-for-byte.
    Fse2,
    /// 4-state interleaved FSE (mode-3 sections): four decode chains in
    /// flight. The default for new files.
    #[default]
    Fse4,
    /// Like [`EntropyMode::Fse4`], but literals additionally try the
    /// 4-stream Huffman lane (mode 4) — the planner picks this for
    /// high-entropy branches where per-symbol ANS cost dominates.
    Huff0,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ZstdError(pub &'static str);

impl std::fmt::Display for ZstdError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rzs1: {}", self.0)
    }
}
impl std::error::Error for ZstdError {}

const E: fn(&'static str) -> ZstdError = ZstdError;

/// Max symbols for the code alphabets (value codes ≤ 32).
const CODE_ALPHABET: usize = 33;

#[inline]
pub(crate) fn value_code(v: u32) -> (u16, u32, u32) {
    if v == 0 {
        (0, 0, 0)
    } else {
        let k = 32 - v.leading_zeros();
        (k as u16, v - (1 << (k - 1)), k - 1)
    }
}

#[inline]
pub(crate) fn value_decode(code: u16, extra: u32) -> u32 {
    if code == 0 {
        0
    } else {
        (1 << (code - 1)) + extra
    }
}

/// Reusable encoder state.
#[derive(Default)]
pub struct ZstdEncoder {
    matcher: ChainMatcher,
    seqs: Vec<Seq>,
    literals: Vec<u8>,
    concat: Vec<u8>,
}

impl ZstdEncoder {
    pub fn new() -> Self {
        Self {
            matcher: ChainMatcher::new(),
            ..Default::default()
        }
    }

    /// Compress without a dictionary.
    pub fn compress(&mut self, src: &[u8], level: u8) -> Vec<u8> {
        self.compress_dict(src, &[], level)
    }

    /// Compress with a dictionary prefix (decoder must supply the same).
    pub fn compress_dict(&mut self, src: &[u8], dict: &[u8], level: u8) -> Vec<u8> {
        self.compress_dict_mode(src, dict, level, EntropyMode::default())
    }

    /// Compress with a dictionary prefix and an explicit entropy-lane
    /// choice (decoder must supply the same dictionary; the entropy mode
    /// is self-describing in the stream).
    pub fn compress_dict_mode(
        &mut self,
        src: &[u8],
        dict: &[u8],
        level: u8,
        mode: EntropyMode,
    ) -> Vec<u8> {
        let params = SearchParams::for_level(level);
        let start = if dict.is_empty() {
            self.matcher.parse(src, 0, &params, &mut self.seqs, &mut self.literals);
            0
        } else {
            self.concat.clear();
            self.concat.extend_from_slice(dict);
            self.concat.extend_from_slice(src);
            self.matcher.parse(&self.concat, dict.len(), &params, &mut self.seqs, &mut self.literals);
            dict.len()
        };
        let _ = start;

        let mut out = Vec::with_capacity(src.len() / 2 + 64);
        put_uvarint(&mut out, src.len() as u64);
        put_uvarint(&mut out, self.seqs.len() as u64);

        // Literals section.
        write_byte_section(&mut out, &self.literals, mode);

        if !self.seqs.is_empty() {
            // Code streams.
            let mut ll = Vec::with_capacity(self.seqs.len());
            let mut ml = Vec::with_capacity(self.seqs.len());
            let mut of = Vec::with_capacity(self.seqs.len());
            let mut extras = BitWriter::new();
            for s in &self.seqs {
                let (lc, le, ln) = value_code(s.lit_len);
                let (mc, me, mn) = value_code(s.match_len - MIN_MATCH as u32);
                let (oc, oe, on) = value_code(s.offset - 1);
                ll.push(lc);
                ml.push(mc);
                of.push(oc);
                extras.write_bits(le as u64, ln);
                extras.write_bits(me as u64, mn);
                extras.write_bits(oe as u64, on);
            }
            write_code_section(&mut out, &ll, mode);
            write_code_section(&mut out, &ml, mode);
            write_code_section(&mut out, &of, mode);
            let eb = extras.finish();
            put_uvarint(&mut out, eb.len() as u64);
            out.extend_from_slice(&eb);
        }
        out
    }
}

/// One-shot helpers.
pub fn zstd_compress(src: &[u8], level: u8) -> Vec<u8> {
    ZstdEncoder::new().compress(src, level)
}

pub fn zstd_compress_dict(src: &[u8], dict: &[u8], level: u8) -> Vec<u8> {
    ZstdEncoder::new().compress_dict(src, dict, level)
}

pub fn zstd_compress_mode(src: &[u8], level: u8, mode: EntropyMode) -> Vec<u8> {
    ZstdEncoder::new().compress_dict_mode(src, &[], level, mode)
}

const MODE_RAW: u8 = 0;
const MODE_RLE: u8 = 1;
const MODE_FSE: u8 = 2;
const MODE_FSE4: u8 = 3;
const MODE_HUFF: u8 = 4;

/// Encode the chosen FSE variant into `section`; returns false if the
/// table could not be built. `Fse2` emits the dual-state layout (two
/// uvarint states — the RFIL-v2 stream, byte-identical); `Fse4`/`Huff0`
/// emit the quad-state layout (four uvarint states).
fn fse_section<S: fse::Symbol>(
    section: &mut Vec<u8>,
    data: &[S],
    hist: &[u32],
    present: usize,
    max_log: u32,
    mode: EntropyMode,
) -> bool {
    let log = fse::optimal_table_log(data.len(), present, max_log);
    let norm = match fse::normalize_counts(hist, data.len() as u64, log) {
        Ok(n) => n,
        Err(_) => return false,
    };
    let enc = match fse::EncTable::new(&norm, log) {
        Ok(e) => e,
        Err(_) => return false,
    };
    fse::write_norm(section, &norm, log);
    let payload = if mode == EntropyMode::Fse2 {
        let (payload, states) = enc.encode_interleaved(data);
        put_uvarint(section, states[0] as u64);
        put_uvarint(section, states[1] as u64);
        payload
    } else {
        let (payload, states) = enc.encode_interleaved4(data);
        for &s in &states {
            put_uvarint(section, s as u64);
        }
        payload
    };
    put_uvarint(section, payload.len() as u64);
    section.extend_from_slice(&payload);
    true
}

#[inline]
fn fse_mode_byte(mode: EntropyMode) -> u8 {
    if mode == EntropyMode::Fse2 {
        MODE_FSE
    } else {
        MODE_FSE4
    }
}

/// Literals: choose raw / rle / huff0 / fse by mode and measured size.
fn write_byte_section(out: &mut Vec<u8>, data: &[u8], mode: EntropyMode) {
    if data.is_empty() {
        out.push(MODE_RAW);
        put_uvarint(out, 0);
        return;
    }
    if data.iter().all(|&b| b == data[0]) {
        out.push(MODE_RLE);
        put_uvarint(out, data.len() as u64);
        out.push(data[0]);
        return;
    }
    // Huff0 lane: 4-stream block Huffman for high-entropy literals.
    if mode == EntropyMode::Huff0 && data.len() >= 32 {
        if let Some(blob) = huff0::compress(data) {
            if blob.len() + 4 < data.len() {
                out.push(MODE_HUFF);
                put_uvarint(out, data.len() as u64);
                put_uvarint(out, blob.len() as u64);
                out.extend_from_slice(&blob);
                return;
            }
        }
    }
    // FSE (§Perf: 4-lane histogram + interleaved multi-state encode).
    let hist = fse::histogram(data);
    let present = hist.iter().filter(|&&c| c > 0).count();
    if present >= 2 && data.len() >= 32 {
        let mut section = Vec::with_capacity(data.len() / 2 + 64);
        if fse_section(&mut section, data, &hist, present, 11, mode)
            && section.len() + 2 < data.len()
        {
            out.push(fse_mode_byte(mode));
            put_uvarint(out, data.len() as u64);
            out.extend_from_slice(&section);
            return;
        }
    }
    out.push(MODE_RAW);
    put_uvarint(out, data.len() as u64);
    out.extend_from_slice(data);
}

/// Code stream (u16 codes < CODE_ALPHABET); length is known (n_seq).
fn write_code_section(out: &mut Vec<u8>, codes: &[u16], mode: EntropyMode) {
    debug_assert!(!codes.is_empty());
    if codes.iter().all(|&c| c == codes[0]) {
        out.push(MODE_RLE);
        out.push(codes[0] as u8);
        return;
    }
    let mut hist = vec![0u32; CODE_ALPHABET];
    for &c in codes {
        hist[c as usize] += 1;
    }
    let present = hist.iter().filter(|&&c| c > 0).count();
    if codes.len() >= 16 {
        let mut section = Vec::with_capacity(codes.len() / 2 + 32);
        if fse_section(&mut section, codes, &hist, present, 9, mode)
            && section.len() < codes.len()
        {
            out.push(fse_mode_byte(mode));
            out.extend_from_slice(&section);
            return;
        }
    }
    out.push(MODE_RAW);
    for &c in codes {
        out.push(c as u8);
    }
}

fn read_byte_section(c: &mut Cursor, max_out: usize) -> Result<Vec<u8>, ZstdError> {
    let mode = c.u8().ok_or(E("truncated literal mode"))?;
    let len = c.uvarint().ok_or(E("truncated literal len"))? as usize;
    if len > max_out {
        return Err(E("literals exceed output limit"));
    }
    match mode {
        MODE_RAW => {
            let bytes = c.bytes(len).ok_or(E("truncated raw literals"))?;
            Ok(bytes.to_vec())
        }
        MODE_RLE => {
            let b = c.u8().ok_or(E("truncated rle literal"))?;
            Ok(vec![b; len])
        }
        MODE_FSE | MODE_FSE4 => {
            let (norm, log) = fse::read_norm(c).map_err(|_| E("bad literal table"))?;
            let n_states = if mode == MODE_FSE { 2 } else { 4 };
            let mut states = [0u16; 4];
            for s in states.iter_mut().take(n_states) {
                *s = c.uvarint().ok_or(E("truncated literal state"))? as u16;
            }
            let plen = c.uvarint().ok_or(E("truncated literal payload len"))? as usize;
            let payload = c.bytes(plen).ok_or(E("truncated literal payload"))?;
            let dec = fse::DecTable::new(&norm, log).map_err(|_| E("bad literal table"))?;
            let mut r = BitReader::new(payload);
            let mut syms = Vec::with_capacity(len);
            if mode == MODE_FSE {
                dec.decode_interleaved(&mut r, [states[0], states[1]], len, &mut syms)
                    .map_err(|_| E("literal decode failed"))?;
            } else {
                dec.decode_interleaved4(&mut r, states, len, &mut syms)
                    .map_err(|_| E("literal decode failed"))?;
            }
            Ok(syms.into_iter().map(|s| s as u8).collect())
        }
        MODE_HUFF => {
            let blen = c.uvarint().ok_or(E("truncated huff0 blob len"))? as usize;
            let blob = c.bytes(blen).ok_or(E("truncated huff0 blob"))?;
            huff0::decompress(blob, len).map_err(|_| E("literal decode failed"))
        }
        _ => Err(E("bad literal mode")),
    }
}

fn read_code_section(c: &mut Cursor, n: usize) -> Result<Vec<u16>, ZstdError> {
    let mode = c.u8().ok_or(E("truncated code mode"))?;
    match mode {
        MODE_RAW => {
            let bytes = c.bytes(n).ok_or(E("truncated raw codes"))?;
            let codes: Vec<u16> = bytes.iter().map(|&b| b as u16).collect();
            if codes.iter().any(|&v| v as usize >= CODE_ALPHABET) {
                return Err(E("code out of range"));
            }
            Ok(codes)
        }
        MODE_RLE => {
            let b = c.u8().ok_or(E("truncated rle code"))?;
            if b as usize >= CODE_ALPHABET {
                return Err(E("code out of range"));
            }
            Ok(vec![b as u16; n])
        }
        MODE_FSE | MODE_FSE4 => {
            let (norm, log) = fse::read_norm(c).map_err(|_| E("bad code table"))?;
            if norm.len() > CODE_ALPHABET {
                return Err(E("code alphabet too large"));
            }
            let n_states = if mode == MODE_FSE { 2 } else { 4 };
            let mut states = [0u16; 4];
            for s in states.iter_mut().take(n_states) {
                *s = c.uvarint().ok_or(E("truncated code state"))? as u16;
            }
            let plen = c.uvarint().ok_or(E("truncated code payload len"))? as usize;
            let payload = c.bytes(plen).ok_or(E("truncated code payload"))?;
            let dec = fse::DecTable::new(&norm, log).map_err(|_| E("bad code table"))?;
            let mut r = BitReader::new(payload);
            let mut syms = Vec::with_capacity(n);
            if mode == MODE_FSE {
                dec.decode_interleaved(&mut r, [states[0], states[1]], n, &mut syms)
                    .map_err(|_| E("code decode failed"))?;
            } else {
                dec.decode_interleaved4(&mut r, states, n, &mut syms)
                    .map_err(|_| E("code decode failed"))?;
            }
            Ok(syms)
        }
        _ => Err(E("bad code mode")),
    }
}

/// Decompress an RZS1 block (optionally with the dictionary used at
/// compression time). `max_out` bounds memory for untrusted input.
pub fn zstd_decompress(src: &[u8], max_out: usize) -> Result<Vec<u8>, ZstdError> {
    zstd_decompress_dict(src, &[], max_out)
}

pub fn zstd_decompress_dict(src: &[u8], dict: &[u8], max_out: usize) -> Result<Vec<u8>, ZstdError> {
    let mut c = Cursor::new(src);
    let raw_len = c.uvarint().ok_or(E("truncated raw len"))? as usize;
    if raw_len > max_out {
        return Err(E("output limit exceeded"));
    }
    let n_seq = c.uvarint().ok_or(E("truncated n_seq"))? as usize;
    if n_seq > raw_len.max(1) {
        return Err(E("implausible sequence count"));
    }
    let literals = read_byte_section(&mut c, raw_len)?;

    let mut out = Vec::with_capacity(dict.len() + raw_len);
    out.extend_from_slice(dict);
    let mut lit_pos = 0usize;

    if n_seq > 0 {
        let ll = read_code_section(&mut c, n_seq)?;
        let ml = read_code_section(&mut c, n_seq)?;
        let of = read_code_section(&mut c, n_seq)?;
        let elen = c.uvarint().ok_or(E("truncated extras len"))? as usize;
        let extras = c.bytes(elen).ok_or(E("truncated extras"))?;
        let mut r = BitReader::new(extras);
        let limit = dict.len() + raw_len;
        for k in 0..n_seq {
            let lit_len = read_value(&mut r, ll[k])? as usize;
            let match_len = read_value(&mut r, ml[k])? as usize + MIN_MATCH;
            let offset = read_value(&mut r, of[k])? as usize + 1;
            if r.overflowed() {
                return Err(E("extras exhausted"));
            }
            if lit_pos + lit_len > literals.len() {
                return Err(E("literal underflow"));
            }
            if out.len() + lit_len + match_len > limit {
                return Err(E("output overflow"));
            }
            out.extend_from_slice(&literals[lit_pos..lit_pos + lit_len]);
            lit_pos += lit_len;
            if offset > out.len() {
                return Err(E("offset beyond output"));
            }
            copy_match(&mut out, offset, match_len);
        }
    }
    // Trailing literals.
    let rest = &literals[lit_pos..];
    if out.len() + rest.len() != dict.len() + raw_len {
        return Err(E("size mismatch"));
    }
    out.extend_from_slice(rest);
    out.drain(..dict.len());
    Ok(out)
}

#[inline]
fn read_value(r: &mut BitReader, code: u16) -> Result<u32, ZstdError> {
    if code == 0 {
        return Ok(0);
    }
    if code > 32 {
        return Err(E("code out of range"));
    }
    let extra = r.read_bits((code - 1) as u32) as u32;
    Ok(value_decode(code, extra))
}

#[inline]
fn copy_match(out: &mut Vec<u8>, dist: usize, len: usize) {
    let start = out.len() - dist;
    if dist >= len {
        out.extend_from_within(start..start + len);
    } else if dist == 1 {
        let b = out[out.len() - 1];
        let target = out.len() + len;
        out.resize(target, b);
    } else {
        let mut rem = len;
        let mut src = start;
        while rem > 0 {
            let chunk = rem.min(out.len() - src);
            out.extend_from_within(src..src + chunk);
            src += chunk;
            rem -= chunk;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const MAX: usize = 64 << 20;

    fn roundtrip(data: &[u8], level: u8) {
        let c = zstd_compress(data, level);
        let d = zstd_decompress(&c, MAX).expect("decompress");
        assert_eq!(d, data, "level {level} n={}", data.len());
    }

    #[test]
    fn value_code_roundtrip() {
        for v in [0u32, 1, 2, 3, 7, 8, 100, 65_535, 1 << 20, u32::MAX / 2] {
            let (c, e, n) = value_code(v);
            assert!(e < (1u32 << n) || n == 0);
            assert_eq!(value_decode(c, e), v, "v={v}");
        }
    }

    #[test]
    fn roundtrip_corpus() {
        let mut rng = Rng::new(0x257D);
        let mut corpus: Vec<Vec<u8>> = vec![
            vec![],
            b"z".to_vec(),
            b"zstd zstd zstd zstd zstd".to_vec(),
            vec![0u8; 150_000],
        ];
        corpus.push((0u32..40_000).flat_map(|i| i.to_be_bytes()).collect());
        corpus.push(rng.bytes(80_000));
        let mut text = Vec::new();
        while text.len() < 90_000 {
            text.extend_from_slice(b"Zstandard: How Facebook increased compression speed. ");
        }
        corpus.push(text);
        for data in &corpus {
            for level in [1u8, 5, 9] {
                roundtrip(data, level);
            }
        }
    }

    #[test]
    fn beats_or_matches_window_limited_codecs_on_long_range() {
        // Long-range redundancy at 100 KiB distance: inside our 256K window.
        let mut rng = Rng::new(0x257E);
        let chunk = rng.bytes(30_000);
        let mut data = Vec::new();
        data.extend_from_slice(&chunk);
        data.extend(rng.bytes(90_000));
        data.extend_from_slice(&chunk);
        let z = zstd_compress(&data, 6);
        let g = crate::deflate::zlib_compress(&data, crate::deflate::Flavor::Cloudflare, 6);
        assert!(
            z.len() as f64 <= 0.85 * g.len() as f64,
            "zstd {} vs zlib {}",
            z.len(),
            g.len()
        );
        roundtrip(&data, 6);
    }

    #[test]
    fn dictionary_helps_small_buffers() {
        // Paper §2.3: dictionaries raise ratio "particularly when
        // compressing a small amount of data (such as a few hundred bytes)".
        let mut rng = Rng::new(0x257F);
        let dict: Vec<u8> = {
            let mut d = Vec::new();
            while d.len() < 4096 {
                d.extend_from_slice(b"\"Muon_pt\":[],\"Muon_eta\":[],\"Jet_mass\":[]");
                d.extend_from_slice(&rng.bytes(4));
            }
            d
        };
        let small = b"\"Muon_pt\":[],\"Muon_eta\":[],\"Jet_mass\":[1.5]".to_vec();
        let plain = zstd_compress_dict(&small, &[], 6);
        let with_dict = zstd_compress_dict(&small, &dict, 6);
        assert!(
            with_dict.len() < plain.len(),
            "dict {} vs plain {}",
            with_dict.len(),
            plain.len()
        );
        let d = zstd_decompress_dict(&with_dict, &dict, MAX).unwrap();
        assert_eq!(d, small);
        // Wrong dictionary must not silently succeed with wrong content.
        let wrong = rng.bytes(dict.len());
        match zstd_decompress_dict(&with_dict, &wrong, MAX) {
            Ok(d2) => assert_ne!(d2, small),
            Err(_) => {}
        }
    }

    #[test]
    fn fuzz_roundtrip() {
        let mut rng = Rng::new(0x2580);
        for round in 0..50 {
            let n = rng.range(0, 40_000);
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                match rng.range(0, 3) {
                    0 => {
                        let b = (rng.next_u64() & 0xFF) as u8;
                        let r = rng.range(1, 400);
                        data.extend(std::iter::repeat(b).take(r));
                    }
                    1 => data.extend_from_slice(b"CaloJet_"),
                    2 => data.extend_from_slice(&rng.next_u32().to_be_bytes()),
                    _ => {
                        let k = rng.range(1, 100);
                        let b = rng.bytes(k);
                        data.extend_from_slice(&b);
                    }
                }
            }
            data.truncate(n);
            roundtrip(&data, [1u8, 3, 6, 9][round % 4]);
        }
    }

    /// Literal-section mode byte of a compressed stream (follows the
    /// raw_len and n_seq uvarints).
    fn literal_mode(stream: &[u8]) -> u8 {
        let mut c = Cursor::new(stream);
        c.uvarint().unwrap();
        c.uvarint().unwrap();
        c.u8().unwrap()
    }

    #[test]
    fn all_entropy_modes_roundtrip() {
        let mut rng = Rng::new(0x2582);
        let mut text = Vec::new();
        while text.len() < 60_000 {
            text.extend_from_slice(b"Events/Muon_pt basket payload, skewed literals. ");
        }
        let corpus = [
            text,
            rng.bytes(50_000),
            (0u32..10_000).flat_map(|i| i.to_be_bytes()).collect(),
            b"tiny".to_vec(),
        ];
        for data in &corpus {
            for mode in [EntropyMode::Fse2, EntropyMode::Fse4, EntropyMode::Huff0] {
                let c = zstd_compress_mode(data, 5, mode);
                let d = zstd_decompress(&c, MAX).expect("decompress");
                assert_eq!(&d, data, "mode {mode:?} n={}", data.len());
            }
        }
    }

    #[test]
    fn entropy_mode_selects_expected_literal_section() {
        // Skewed draws from a wide alphabet: few LZ matches (literals carry
        // the block) but plenty of Huffman headroom.
        let mut rng = Rng::new(0x2583);
        let data: Vec<u8> = (0..40_000)
            .map(|_| {
                let r = rng.next_u64();
                if r & 1 == 0 { (r >> 1) as u8 % 24 } else { (r >> 1) as u8 }
            })
            .collect();
        let f2 = zstd_compress_mode(&data, 1, EntropyMode::Fse2);
        let f4 = zstd_compress_mode(&data, 1, EntropyMode::Fse4);
        let h = zstd_compress_mode(&data, 1, EntropyMode::Huff0);
        assert_eq!(literal_mode(&f2), 2, "Fse2 → dual-state section");
        assert_eq!(literal_mode(&f4), 3, "Fse4 → quad-state section");
        assert_eq!(literal_mode(&h), 4, "Huff0 → multi-stream Huffman section");
        for c in [&f2, &f4, &h] {
            assert_eq!(zstd_decompress(c, MAX).unwrap(), data);
        }
    }

    #[test]
    fn garbage_never_panics() {
        let mut rng = Rng::new(0x2581);
        for _ in 0..400 {
            let n = rng.range(0, 400);
            let garbage = rng.bytes(n);
            let _ = zstd_decompress(&garbage, 1 << 20);
        }
    }

    #[test]
    fn truncation_rejected() {
        let data: Vec<u8> = (0u32..5000).flat_map(|i| i.to_be_bytes()).collect();
        let c = zstd_compress(&data, 6);
        for cut in [1, c.len() / 3, c.len() - 1] {
            assert!(zstd_decompress(&c[..cut], MAX).is_err(), "cut {cut}");
        }
    }
}
