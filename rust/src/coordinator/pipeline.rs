//! The L3 coordination contribution: a parallel basket-compression pipeline
//! with bounded-queue backpressure and strictly ordered commit.
//!
//! ROOT compresses baskets implicitly on the thread that fills them; the
//! paper's Fig-1 discussion points at "a number of advanced compression or
//! decompression possibilities such as simultaneous read and decompression
//! for the multiple physics events". This module makes that explicit:
//!
//! ```text
//!  fill thread ──submit──▶ [bounded job queue] ──▶ N compression workers
//!                                                        │ (Engine each)
//!                                  [bounded done queue] ◀┘
//!                                        │
//!                               committer thread: reorders by sequence
//!                               number, writes records, tracks BasketLocs
//! ```
//!
//! Invariants (property-tested in rust/tests/integration_pipeline.rs):
//!  * the committed file is byte-identical in content to a serial write
//!    (same baskets, same order);
//!  * no basket is lost or duplicated for any worker count / queue depth;
//!  * submission blocks (backpressure) rather than queueing unboundedly.

use crate::compression::{Engine, Settings};
use crate::coordinator::metrics::Metrics;
use crate::rfile::writer::{frame_basket_record_prefix, BasketSink, RecordWriter};
use crate::rfile::{basket::encode_basket_into, BasketLoc, PendingBasket};
use crate::rfile::format::RecordKind;
use crate::util::pool::{BufferPool, OffsetPool};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub workers: usize,
    /// Bounded queue depth between fill → workers (backpressure knob).
    pub queue_depth: usize,
    /// Dictionary for ZSTD-family settings (cloned into each worker).
    pub dictionary: Vec<u8>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .saturating_sub(1)
            .max(1);
        Self { workers, queue_depth: 2 * workers, dictionary: Vec::new() }
    }
}

struct Job {
    seq: u64,
    basket: PendingBasket,
    settings: Settings,
}

struct Done {
    seq: u64,
    branch_id: u32,
    basket_index: u32,
    first_entry: u64,
    n_entries: u32,
    uncompressed_len: u32,
    payload: Vec<u8>,
}

/// A [`BasketSink`] that compresses on a worker pool and commits in
/// submission order.
pub struct ParallelSink {
    job_tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    committer: Option<JoinHandle<Result<(Vec<BasketLoc>, RecordWriter)>>>,
    seq: u64,
    finished_writer: Option<RecordWriter>,
    pub metrics: Arc<Metrics>,
    /// §Perf (ROADMAP follow-up): consumed `PendingBasket` data/offset
    /// buffers flow back from the workers through these pools to the fill
    /// thread via [`BasketSink::recycle_buffers`], closing the last
    /// per-basket allocation loop (payload buffers were already pooled).
    basket_data_pool: BufferPool,
    basket_offset_pool: OffsetPool,
}

impl ParallelSink {
    pub fn new(writer: RecordWriter, config: PipelineConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let (job_tx, job_rx) = std::sync::mpsc::sync_channel::<Job>(config.queue_depth.max(1));
        let (done_tx, done_rx) = std::sync::mpsc::sync_channel::<Done>(config.queue_depth.max(1) * 2);
        let job_rx = Arc::new(std::sync::Mutex::new(job_rx));

        // §Perf: one shared pool; workers rent payload buffers, the
        // committer returns them after the bytes hit the file. Steady state
        // performs no payload allocations at all. Caps bound worst-case
        // retention: at most in-flight-count buffers parked, and any buffer
        // grown past 4 MiB (a jumbo basket, vs the 32 KiB default) is freed
        // rather than pinned for the sink's lifetime.
        let pool = BufferPool::new(config.queue_depth.max(1) * 2 + config.workers, 4 << 20);
        // Basket accumulation buffers: bounded like the payload pool; a
        // data buffer is ~basket_size, offsets ~basket_size/4 entries.
        let basket_data_pool = BufferPool::new(config.queue_depth.max(1) * 2 + config.workers, 4 << 20);
        let basket_offset_pool = OffsetPool::new(config.queue_depth.max(1) * 2 + config.workers, 1 << 20);

        let mut workers = Vec::with_capacity(config.workers);
        for _ in 0..config.workers.max(1) {
            let rx = Arc::clone(&job_rx);
            let tx = done_tx.clone();
            let m = Arc::clone(&metrics);
            let dict = config.dictionary.clone();
            let pool = pool.clone();
            let data_pool = basket_data_pool.clone();
            let offset_pool = basket_offset_pool.clone();
            workers.push(std::thread::spawn(move || {
                let mut engine = Engine::new();
                // Worker-local scratch, reused across every basket.
                let mut logical_scratch: Vec<u8> = Vec::new();
                if !dict.is_empty() {
                    engine.set_dictionary(dict);
                }
                loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(job) = job else { break };
                    let t0 = Instant::now();
                    let uncompressed_len = job.basket.logical_len() as u32;
                    let mut payload = pool.get();
                    frame_basket_record_prefix(&mut payload, job.basket.branch_id, job.basket.basket_index);
                    encode_basket_into(
                        &job.basket,
                        &job.settings,
                        &mut engine,
                        &mut logical_scratch,
                        &mut payload,
                    );
                    m.record_basket(uncompressed_len as usize, payload.len(), t0.elapsed());
                    let done = Done {
                        seq: job.seq,
                        branch_id: job.basket.branch_id,
                        basket_index: job.basket.basket_index,
                        first_entry: job.basket.first_entry,
                        n_entries: job.basket.n_entries,
                        uncompressed_len,
                        payload,
                    };
                    // Recycle the consumed basket's accumulation buffers
                    // back to the fill thread (§Perf).
                    let (data, offsets) = job.basket.into_buffers();
                    data_pool.put(data);
                    offset_pool.put(offsets);
                    if tx.send(done).is_err() {
                        break;
                    }
                }
            }));
        }
        drop(done_tx);

        let commit_pool = pool.clone();
        let committer = std::thread::spawn(move || commit_loop(writer, done_rx, commit_pool));

        Self {
            job_tx: Some(job_tx),
            workers,
            committer: Some(committer),
            seq: 0,
            finished_writer: None,
            metrics,
            basket_data_pool,
            basket_offset_pool,
        }
    }

    /// (reuses, fresh allocations) of the basket accumulation buffers —
    /// observability hook for the zero-alloc steady-state claim.
    pub fn basket_pool_stats(&self) -> ((u64, u64), (u64, u64)) {
        (self.basket_data_pool.stats(), self.basket_offset_pool.stats())
    }

    /// After `finish()`, retrieve the writer to close the file.
    pub fn take_writer(&mut self) -> Option<RecordWriter> {
        self.finished_writer.take()
    }

    /// Drain the pipeline; returns (locations, writer) for file close.
    fn shutdown(&mut self) -> Result<(Vec<BasketLoc>, RecordWriter)> {
        drop(self.job_tx.take());
        for w in self.workers.drain(..) {
            w.join().map_err(|_| anyhow::anyhow!("worker panicked"))?;
        }
        let committer = self
            .committer
            .take()
            .context("pipeline already shut down")?;
        committer
            .join()
            .map_err(|_| anyhow::anyhow!("committer panicked"))?
    }
}

/// Reorders by sequence number and writes records in order; returns each
/// payload buffer to the pool once written.
fn commit_loop(
    mut writer: RecordWriter,
    done_rx: Receiver<Done>,
    pool: BufferPool,
) -> Result<(Vec<BasketLoc>, RecordWriter)> {
    let mut next_seq = 0u64;
    let mut pending: BTreeMap<u64, Done> = BTreeMap::new();
    let mut locs = Vec::new();
    let mut write = |writer: &mut RecordWriter, d: Done, locs: &mut Vec<BasketLoc>| -> Result<()> {
        let off = writer.append(RecordKind::Basket, &d.payload)?;
        locs.push(BasketLoc {
            branch_id: d.branch_id,
            basket_index: d.basket_index,
            first_entry: d.first_entry,
            n_entries: d.n_entries,
            file_offset: off,
            compressed_len: d.payload.len() as u32,
            uncompressed_len: d.uncompressed_len,
        });
        pool.put(d.payload);
        Ok(())
    };
    while let Ok(done) = done_rx.recv() {
        pending.insert(done.seq, done);
        while let Some(d) = pending.remove(&next_seq) {
            write(&mut writer, d, &mut locs)?;
            next_seq += 1;
        }
    }
    // Channel closed: everything must have committed.
    if !pending.is_empty() {
        bail!("pipeline lost sequence numbers; {} baskets stranded", pending.len());
    }
    Ok((locs, writer))
}

impl BasketSink for ParallelSink {
    fn submit(&mut self, basket: PendingBasket, settings: Settings) -> Result<()> {
        let job = Job { seq: self.seq, basket, settings };
        self.seq += 1;
        self.job_tx
            .as_ref()
            .context("pipeline is shut down")?
            .send(job)
            .map_err(|_| anyhow::anyhow!("pipeline workers gone"))
    }

    fn finish(&mut self) -> Result<Vec<BasketLoc>> {
        let (locs, writer) = self.shutdown()?;
        self.finished_writer = Some(writer);
        Ok(locs)
    }

    fn recycle_buffers(&mut self) -> Option<(Vec<u8>, Vec<u32>)> {
        // Early in the run the pools are empty and `get()` hands back fresh
        // (zero-capacity) Vecs — identical to the allocate-on-demand path.
        Some((self.basket_data_pool.get(), self.basket_offset_pool.get()))
    }
}

/// Write a whole tree through the parallel pipeline.
pub fn write_tree_parallel(
    path: &std::path::Path,
    name: &str,
    branches: Vec<crate::rfile::BranchDef>,
    default_settings: Settings,
    basket_size: usize,
    config: PipelineConfig,
    events: impl Iterator<Item = Vec<crate::rfile::Value>>,
) -> Result<(crate::rfile::TreeMeta, crate::coordinator::metrics::Snapshot)> {
    let writer = RecordWriter::create(path)?;
    let dict = config.dictionary.clone();
    let sink = ParallelSink::new(writer, config);
    let metrics = Arc::clone(&sink.metrics);
    let mut tw = crate::rfile::TreeWriter::new(name, branches, default_settings, basket_size, sink);
    for ev in events {
        tw.fill(&ev)?;
    }
    let (mut meta, mut sink) = tw.finalize()?;
    let mut writer = sink.take_writer().context("pipeline writer missing")?;
    // Write the dictionary record if present, then close.
    if !dict.is_empty() {
        let off = writer.append(RecordKind::Dictionary, &dict)?;
        meta.dictionary_offset = Some(off);
    }
    writer.close(&meta)?;
    Ok((meta, metrics.snapshot()))
}
