//! The read-side twin of [`pipeline`](super::pipeline): a parallel basket
//! **read** pipeline with bounded read-ahead and strictly ordered delivery.
//!
//! "Increasing Parallelism in the ROOT I/O Subsystem" (arXiv:1804.03326)
//! found ROOT's biggest read-side wins in cluster/basket-parallel
//! decompression; the CHEP-2019 survey's Fig-3 motivation (LZ4 for
//! analysis reads) only pays off if decompression keeps up with the
//! storage. This module makes that explicit:
//!
//! ```text
//!  prefetch thread ──raw basket bytes──▶ [bounded job queue] ──▶ N workers
//!  (one File, sequential seeks,                                  │ (Engine each:
//!   pooled payload buffers)                                      │  decompress,
//!                                                                │  invert precond,
//!                                        [bounded done queue] ◀──┘  verify checksums)
//!                                              │
//!                                   consumer: reorders by sequence number,
//!                                   yields (BasketLoc, BasketContent) in
//!                                   submission order, recycles buffers
//! ```
//!
//! Invariants (property-tested in `rust/tests/integration_read_pipeline.rs`):
//!  * decoded baskets are **byte-identical** to the serial
//!    [`TreeReader`](crate::rfile::TreeReader) oracle, for any worker count
//!    and queue depth, across every codec × preconditioner;
//!  * a file the serial reader rejects (truncation, corrupted checksum,
//!    basket identity mismatch) is rejected by the pipeline too — errors
//!    surface on the consumer thread in delivery order;
//!  * prefetch is bounded: the job queue holds at most `depth` raw
//!    baskets, so read-ahead memory scales with the queue depth plus
//!    transient worker skew, never the whole file;
//!  * steady-state reads recycle every per-basket buffer (raw payload,
//!    decoded data, offset array) through the same
//!    [`Pool<T>`](crate::util::pool::Pool) free lists the write pipeline
//!    uses ([`BufferPool`] / [`OffsetPool`]).
//!
//! Checksum verification (the LZ4 frame CRC-32 and every codec's internal
//! consistency checks) happens inside the workers' [`Engine::decompress_into`]
//! calls — off the consumer's critical path, unlike the serial reader where
//! it serializes with everything else.
//!
//! `scan` accepts *any* basket list, which is the multi-branch plumbing the
//! columnar projection layer ([`super::projection`]) builds on: it merges
//! several branches' directories into one offset-sorted prefetch plan and
//! re-routes this pipeline's submission-order delivery back into per-branch
//! event-order streams.

use crate::compression::Engine;
use crate::coordinator::metrics::{Metrics, Snapshot};
use crate::rfile::basket::{decode_basket_into, BasketContent};
use crate::rfile::format::{self, RecordKind};
use crate::rfile::meta::{BasketLoc, TreeMeta};
use crate::rfile::reader::{decode_values, TreeReader};
use crate::rfile::branch::Value;
use crate::util::pool::{BufferPool, OffsetPool};
use crate::util::varint::Cursor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::BufReader;
use std::path::{Path, PathBuf};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Read-ahead configuration: how many decode workers to run and how many
/// raw baskets may be prefetched ahead of the consumer (the backpressure
/// knob bounding read-ahead memory).
#[derive(Debug, Clone, Copy)]
pub struct ReadAhead {
    /// Decompression worker threads.
    pub workers: usize,
    /// Bounded queue depth between prefetcher → workers.
    pub depth: usize,
}

impl Default for ReadAhead {
    fn default() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .saturating_sub(1)
            .max(1);
        Self { workers, depth: 2 * workers }
    }
}

impl ReadAhead {
    /// Config with `workers` decode threads and a proportional read-ahead.
    pub fn with_workers(workers: usize) -> Self {
        let workers = workers.max(1);
        Self { workers, depth: 2 * workers }
    }
}

/// A raw basket record travelling prefetcher → worker. The payload is the
/// record body read at `loc.file_offset` (rented from the raw-buffer pool);
/// prefetch-side failures travel as `Err` so they surface in delivery order.
struct RawJob {
    seq: u64,
    loc: BasketLoc,
    payload: Result<Vec<u8>, String>,
}

/// A decoded basket travelling worker → consumer.
struct Done {
    seq: u64,
    loc: BasketLoc,
    result: Result<BasketContent, String>,
}

/// An in-order stream of decoded baskets from a [`ParallelTreeReader`]
/// scan. Iterate (or call [`BasketScan::next_basket`]) to receive
/// `(BasketLoc, BasketContent)` pairs in exactly the order the basket list
/// was submitted; hand finished contents back via [`BasketScan::recycle`]
/// to keep the steady state allocation-free.
pub struct BasketScan {
    done_rx: Option<Receiver<Done>>,
    pending: BTreeMap<u64, Done>,
    next_seq: u64,
    total: u64,
    prefetcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    data_pool: BufferPool,
    offset_pool: OffsetPool,
}

impl BasketScan {
    /// Next basket in submission order, or `None` when the scan is done.
    /// Worker and prefetcher failures surface here, on the basket whose
    /// decode failed, exactly like the serial reader's per-basket errors.
    pub fn next_basket(&mut self) -> Option<Result<(BasketLoc, BasketContent)>> {
        if self.next_seq >= self.total {
            self.join_threads();
            return None;
        }
        loop {
            if let Some(d) = self.pending.remove(&self.next_seq) {
                self.next_seq += 1;
                return Some(match d.result {
                    Ok(c) => Ok((d.loc, c)),
                    Err(e) => Err(anyhow::anyhow!(
                        "basket ({},{}) at offset {}: {e}",
                        d.loc.branch_id,
                        d.loc.basket_index,
                        d.loc.file_offset
                    )),
                });
            }
            let recv = match self.done_rx.as_ref() {
                Some(rx) => rx.recv().map_err(|_| ()),
                None => Err(()),
            };
            match recv {
                Ok(d) => {
                    self.pending.insert(d.seq, d);
                }
                Err(()) => {
                    // Workers died before delivering everything. Report it
                    // once, then terminate the stream: the next call falls
                    // into the `None` arm above instead of re-yielding this
                    // error forever (Iterator consumers that skip errors
                    // must still reach the end).
                    let delivered = self.next_seq;
                    self.next_seq = self.total;
                    self.done_rx = None;
                    return Some(Err(anyhow::anyhow!(
                        "read pipeline workers exited early ({delivered} of {} baskets delivered)",
                        self.total
                    )));
                }
            }
        }
    }

    /// Return a consumed basket's buffers to the scan's pools so the next
    /// basket decode reuses their capacity (§Perf: closes the last
    /// per-basket allocation loop on the read side).
    pub fn recycle(&self, content: BasketContent) {
        self.data_pool.put(content.data);
        self.offset_pool.put(content.offsets);
    }

    /// (reuses, fresh allocations) of the decoded-content buffers —
    /// observability hook for the zero-alloc steady-state claim.
    pub fn content_pool_stats(&self) -> ((u64, u64), (u64, u64)) {
        (self.data_pool.stats(), self.offset_pool.stats())
    }

    fn join_threads(&mut self) {
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(p) = self.prefetcher.take() {
            let _ = p.join();
        }
    }
}

impl Iterator for BasketScan {
    type Item = Result<(BasketLoc, BasketContent)>;
    fn next(&mut self) -> Option<Self::Item> {
        self.next_basket()
    }
}

impl Drop for BasketScan {
    fn drop(&mut self) {
        // Dropping the done receiver makes blocked workers' sends fail, the
        // workers then drop the job receiver, which unblocks the prefetcher:
        // an early-abandoned scan (error, partial read) winds down without
        // deadlock.
        self.done_rx.take();
        self.join_threads();
    }
}

/// Parallel tree reader: the read-side twin of
/// [`write_tree_parallel`](super::pipeline::write_tree_parallel). Opens an
/// RFIL file's metadata once, then serves branch/event reads by streaming
/// raw baskets from disk and fanning decompression out across workers.
///
/// The serial [`TreeReader`] remains the oracle: every read method here is
/// property-tested byte-identical to its serial counterpart.
///
/// ```
/// use rootio::compression::{Algorithm, Settings};
/// use rootio::coordinator::{ParallelTreeReader, ReadAhead};
/// use rootio::gen::synthetic;
/// use rootio::rfile::write_tree_serial;
///
/// let path = std::env::temp_dir().join(format!("rootio_doc_par_{}.rfil", std::process::id()));
/// let events = synthetic::events(200, 7);
/// write_tree_serial(&path, "Events", synthetic::schema(),
///                   Settings::new(Algorithm::Lz4, 1), 4096, events.iter().cloned()).unwrap();
///
/// let reader = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
/// assert_eq!(reader.read_all_events().unwrap(), events);
/// std::fs::remove_file(&path).ok();
/// ```
pub struct ParallelTreeReader {
    path: PathBuf,
    pub meta: TreeMeta,
    dictionary: Vec<u8>,
    config: ReadAhead,
    metrics: Arc<Metrics>,
}

impl ParallelTreeReader {
    /// Open `path`, loading metadata and the dictionary through the same
    /// code path as the serial reader (so header/trailer rejection behaves
    /// identically).
    pub fn open(path: &Path, config: ReadAhead) -> Result<Self> {
        let serial = TreeReader::open(path)?;
        Ok(Self::from_parts(
            path.to_path_buf(),
            serial.meta.clone(),
            serial.dictionary().to_vec(),
            config,
        ))
    }

    /// Build from already-loaded metadata (used by
    /// [`TreeReader::read_ahead`], which has the file open and parsed).
    pub fn from_parts(path: PathBuf, meta: TreeMeta, dictionary: Vec<u8>, config: ReadAhead) -> Self {
        Self { path, meta, dictionary, config, metrics: Arc::new(Metrics::new()) }
    }

    /// Branch id for a branch name (same [`TreeMeta`] query the serial
    /// reader uses).
    pub fn branch_id(&self, name: &str) -> Option<u32> {
        self.meta.branch_id(name)
    }

    /// Basket directory for one branch (ordered by basket_index).
    pub fn baskets_for(&self, branch_id: u32) -> Vec<BasketLoc> {
        self.meta.baskets_for(branch_id)
    }

    /// Decode metrics aggregated across every scan this reader served:
    /// `bytes_in` = logical (uncompressed) bytes, `bytes_out` = compressed
    /// record bytes, `compress_nanos` = worker decode CPU time.
    pub fn metrics_snapshot(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    /// Start a pipelined scan over `locs`, delivering decoded baskets in
    /// exactly that order. The prefetcher reads raw records sequentially on
    /// one thread; `config.workers` workers decompress concurrently.
    pub fn scan(&self, locs: Vec<BasketLoc>) -> Result<BasketScan> {
        let total = locs.len() as u64;
        let workers_n = self.config.workers.max(1);
        let depth = self.config.depth.max(1);
        let file = File::open(&self.path)
            .with_context(|| format!("opening {}", self.path.display()))?;

        let (job_tx, job_rx) = std::sync::mpsc::sync_channel::<RawJob>(depth);
        let (done_tx, done_rx) = std::sync::mpsc::sync_channel::<Done>(depth * 2);
        let job_rx = Arc::new(std::sync::Mutex::new(job_rx));

        // §Perf: pools sized to the in-flight bound. Raw payload buffers
        // cycle prefetcher → worker → prefetcher; decoded data/offset
        // buffers cycle worker → consumer → (via recycle) worker. The 4 MiB
        // capacity cap keeps one jumbo basket from pinning memory for the
        // scan's lifetime, same policy as the write side.
        let raw_pool = BufferPool::new(depth * 2 + workers_n, 4 << 20);
        let data_pool = BufferPool::new(depth * 2 + workers_n, 4 << 20);
        let offset_pool = OffsetPool::new(depth * 2 + workers_n, 1 << 20);

        let mut workers = Vec::with_capacity(workers_n);
        for _ in 0..workers_n {
            let rx = Arc::clone(&job_rx);
            let tx = done_tx.clone();
            let m = Arc::clone(&self.metrics);
            let dict = self.dictionary.clone();
            let raw_pool = raw_pool.clone();
            let data_pool = data_pool.clone();
            let offset_pool = offset_pool.clone();
            workers.push(std::thread::spawn(move || {
                let mut engine = Engine::new();
                if !dict.is_empty() {
                    engine.set_dictionary(dict);
                }
                // Worker-local scratch, reused across every basket.
                let mut logical_scratch: Vec<u8> = Vec::new();
                loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(job) = job else { break };
                    let done = match job.payload {
                        Err(e) => Done { seq: job.seq, loc: job.loc, result: Err(e) },
                        Ok(raw) => {
                            let t0 = Instant::now();
                            let mut content = BasketContent {
                                n_entries: 0,
                                data: data_pool.get(),
                                offsets: offset_pool.get(),
                            };
                            let r = decode_raw_basket(
                                &raw,
                                &job.loc,
                                &mut engine,
                                &mut logical_scratch,
                                &mut content,
                            );
                            let raw_len = raw.len();
                            raw_pool.put(raw);
                            match r {
                                Ok(()) => {
                                    m.record_basket(
                                        content.data.len() + 4 * content.offsets.len(),
                                        raw_len,
                                        t0.elapsed(),
                                    );
                                    Done { seq: job.seq, loc: job.loc, result: Ok(content) }
                                }
                                Err(e) => {
                                    // Failed decode: the rented buffers go
                                    // straight back to the pools.
                                    data_pool.put(content.data);
                                    offset_pool.put(content.offsets);
                                    Done { seq: job.seq, loc: job.loc, result: Err(e) }
                                }
                            }
                        }
                    };
                    if tx.send(done).is_err() {
                        break;
                    }
                }
            }));
        }
        drop(done_tx);

        let prefetch_raw_pool = raw_pool.clone();
        let prefetcher = std::thread::spawn(move || {
            let mut file = BufReader::new(file);
            for (seq, loc) in locs.into_iter().enumerate() {
                let mut buf = prefetch_raw_pool.get();
                let payload = match format::read_record_at_into(&mut file, loc.file_offset, &mut buf)
                {
                    Ok(RecordKind::Basket) => Ok(buf),
                    Ok(kind) => {
                        prefetch_raw_pool.put(buf);
                        Err(format!(
                            "expected basket record at {}, found {kind:?}",
                            loc.file_offset
                        ))
                    }
                    Err(e) => {
                        prefetch_raw_pool.put(buf);
                        Err(format!("{e:#}"))
                    }
                };
                if job_tx.send(RawJob { seq: seq as u64, loc, payload }).is_err() {
                    // Workers gone (scan dropped early): stop prefetching.
                    return;
                }
            }
        });

        Ok(BasketScan {
            done_rx: Some(done_rx),
            pending: BTreeMap::new(),
            next_seq: 0,
            total,
            prefetcher: Some(prefetcher),
            workers,
            data_pool,
            offset_pool,
        })
    }

    /// Read an entire branch back as per-entry values — the parallel
    /// equivalent of [`TreeReader::read_branch`], byte-identical output.
    pub fn read_branch(&self, branch_id: u32) -> Result<Vec<Value>> {
        let ty = self
            .meta
            .branches
            .get(branch_id as usize)
            .ok_or_else(|| anyhow::anyhow!("no branch {branch_id}"))?
            .ty;
        let locs = self.baskets_for(branch_id);
        let mut scan = self.scan(locs)?;
        let mut out = Vec::with_capacity(self.meta.n_entries as usize);
        while let Some(item) = scan.next_basket() {
            let (_, content) = item?;
            decode_values(&content, ty, &mut out)?;
            scan.recycle(content);
        }
        if out.len() as u64 != self.meta.n_entries {
            bail!(
                "branch {branch_id}: {} entries decoded, tree has {}",
                out.len(),
                self.meta.n_entries
            );
        }
        Ok(out)
    }

    /// Read one branch over the entry window `[range.start, range.end)`
    /// only — the parallel equivalent of [`TreeReader::read_range`],
    /// byte-identical output. Only the baskets whose entry spans overlap
    /// the window are prefetched and decoded; head/tail rows of boundary
    /// baskets are trimmed. The range is clamped to the tree (past-EOF and
    /// empty windows yield zero values, not errors).
    pub fn read_range(&self, branch_id: u32, range: std::ops::Range<u64>) -> Result<Vec<Value>> {
        let ty = self
            .meta
            .branches
            .get(branch_id as usize)
            .ok_or_else(|| anyhow::anyhow!("no branch {branch_id}"))?
            .ty;
        let (start, end) = self.meta.clamp_entry_range(range.start, range.end);
        let locs = self.meta.baskets_for_range(branch_id, start, end);
        let mut scan = self.scan(locs)?;
        let mut out = Vec::with_capacity((end - start) as usize);
        let mut scratch = Vec::new();
        while let Some(item) = scan.next_basket() {
            let (loc, content) = item?;
            let (from, to) = loc.trim_bounds(start, end);
            if from == 0 && to == loc.n_entries as usize {
                decode_values(&content, ty, &mut out)?;
            } else {
                scratch.clear();
                decode_values(&content, ty, &mut scratch)?;
                out.extend(scratch.drain(..to).skip(from));
            }
            scan.recycle(content);
        }
        if out.len() as u64 != end - start {
            bail!(
                "branch {branch_id}: {} entries decoded for range [{start}, {end}), expected {}",
                out.len(),
                end - start
            );
        }
        Ok(out)
    }

    /// Row-wise reconstruction across all branches — the parallel
    /// equivalent of [`TreeReader::read_all_events`]. One scan covers the
    /// whole basket directory (branch-major order, so columns fill
    /// sequentially), instead of one scan per branch.
    pub fn read_all_events(&self) -> Result<Vec<Vec<Value>>> {
        let n_branches = self.meta.branches.len();
        let n = self.meta.n_entries as usize;
        let mut columns: Vec<Vec<Value>> = (0..n_branches).map(|_| Vec::with_capacity(n)).collect();
        let mut scan = self.scan(self.meta.baskets.clone())?;
        while let Some(item) = scan.next_basket() {
            let (loc, content) = item?;
            let ty = self
                .meta
                .branches
                .get(loc.branch_id as usize)
                .ok_or_else(|| anyhow::anyhow!("basket for unknown branch {}", loc.branch_id))?
                .ty;
            decode_values(&content, ty, &mut columns[loc.branch_id as usize])?;
            scan.recycle(content);
        }
        for (b, col) in columns.iter().enumerate() {
            if col.len() as u64 != self.meta.n_entries {
                bail!(
                    "branch {b}: {} entries decoded, tree has {}",
                    col.len(),
                    self.meta.n_entries
                );
            }
        }
        // (vec![..; n] would clone away the capacity — Vec::clone starts
        // from an empty buffer.)
        let mut events: Vec<Vec<Value>> = (0..n).map(|_| Vec::with_capacity(n_branches)).collect();
        for col in columns {
            for (ev, v) in events.iter_mut().zip(col) {
                ev.push(v);
            }
        }
        Ok(events)
    }
}

/// Decode one raw basket record body against its directory entry: parse the
/// framing prefix, check identity, decompress, check the entry count — the
/// exact checks [`TreeReader::read_basket`] performs serially.
fn decode_raw_basket(
    raw: &[u8],
    loc: &BasketLoc,
    engine: &mut Engine,
    logical_scratch: &mut Vec<u8>,
    content: &mut BasketContent,
) -> Result<(), String> {
    let mut c = Cursor::new(raw);
    let branch_id = c.uvarint().ok_or("basket branch id truncated")? as u32;
    let basket_index = c.uvarint().ok_or("basket index truncated")? as u32;
    if branch_id != loc.branch_id || basket_index != loc.basket_index {
        return Err(format!(
            "basket identity mismatch: found ({branch_id},{basket_index}), expected ({},{})",
            loc.branch_id, loc.basket_index
        ));
    }
    decode_basket_into(&raw[c.pos()..], engine, logical_scratch, content)
        .map_err(|e| format!("basket decode: {e}"))?;
    if content.n_entries != loc.n_entries {
        return Err("basket entry count mismatch".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Algorithm, Settings};
    use crate::gen::synthetic;
    use crate::rfile::write_tree_serial;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rootio_rpipe_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn scan_delivers_in_order_and_recycles() {
        let path = tmp("order");
        let events = synthetic::events(300, 3);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Lz4, 1),
            1024,
            events.iter().cloned(),
        )
        .unwrap();
        let reader = ParallelTreeReader::open(&path, ReadAhead { workers: 3, depth: 2 }).unwrap();
        let locs = reader.meta.baskets.clone();
        assert!(locs.len() > 10, "want many baskets, got {}", locs.len());
        let mut scan = reader.scan(locs.clone()).unwrap();
        let mut n = 0usize;
        while let Some(item) = scan.next_basket() {
            let (loc, content) = item.unwrap();
            // Delivery order is exactly submission order.
            assert_eq!(
                (loc.branch_id, loc.basket_index),
                (locs[n].branch_id, locs[n].basket_index)
            );
            assert_eq!(content.n_entries, loc.n_entries);
            scan.recycle(content);
            n += 1;
        }
        assert_eq!(n, locs.len());
        // Steady state reuses buffers: fresh allocations track the
        // in-flight window (queue depth + workers + transient skew), not
        // the basket count. Generous bound to stay robust on loaded CI.
        let ((data_reuse, data_alloc), _) = scan.content_pool_stats();
        assert_eq!(data_reuse + data_alloc, locs.len() as u64);
        assert!(
            data_reuse > 0 && data_alloc <= locs.len() as u64 / 2,
            "expected pooled reuse, got {data_alloc} fresh allocations over {} baskets",
            locs.len()
        );
        let snap = reader.metrics_snapshot();
        assert_eq!(snap.baskets, locs.len() as u64);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn early_drop_does_not_deadlock() {
        let path = tmp("drop");
        let events = synthetic::events(400, 5);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Zstd, 1),
            512,
            events.iter().cloned(),
        )
        .unwrap();
        let reader = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 1 }).unwrap();
        let mut scan = reader.scan(reader.meta.baskets.clone()).unwrap();
        // Consume a couple of baskets, then drop the scan mid-flight.
        for _ in 0..2 {
            let (_, content) = scan.next_basket().unwrap().unwrap();
            scan.recycle(content);
        }
        drop(scan);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bogus_offset_errors_like_serial() {
        let path = tmp("bogus");
        let events = synthetic::events(50, 9);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Zlib, 1),
            4096,
            events.iter().cloned(),
        )
        .unwrap();
        let reader = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
        let mut locs = reader.meta.baskets.clone();
        // Point one basket at the trailer: both readers must reject it.
        locs[0].file_offset = u64::MAX / 2;
        let mut scan = reader.scan(locs).unwrap();
        assert!(scan.next_basket().unwrap().is_err());
        std::fs::remove_file(&path).ok();
    }
}
