//! The read-side twin of [`pipeline`](super::pipeline): a parallel basket
//! **read** pipeline with bounded read-ahead and strictly ordered delivery.
//!
//! "Increasing Parallelism in the ROOT I/O Subsystem" (arXiv:1804.03326)
//! found ROOT's biggest read-side wins in cluster/basket-parallel
//! decompression; the CHEP-2019 survey's Fig-3 motivation (LZ4 for
//! analysis reads) only pays off if decompression keeps up with the
//! storage. This module makes that explicit:
//!
//! ```text
//!  prefetch thread ──raw basket bytes──▶ [bounded job queue] ──▶ N workers
//!  (one File, sequential seeks,                                  │ (Engine each:
//!   pooled payload buffers)                                      │  decompress,
//!                                                                │  invert precond,
//!                                        [bounded done queue] ◀──┘  verify checksums)
//!                                              │
//!                                   consumer: reorders by sequence number,
//!                                   yields (BasketLoc, BasketContent) in
//!                                   submission order, recycles buffers
//! ```
//!
//! Invariants (property-tested in `rust/tests/integration_read_pipeline.rs`):
//!  * decoded baskets are **byte-identical** to the serial
//!    [`TreeReader`](crate::rfile::TreeReader) oracle, for any worker count
//!    and queue depth, across every codec × preconditioner;
//!  * a file the serial reader rejects (truncation, corrupted checksum,
//!    basket identity mismatch) is rejected by the pipeline too — errors
//!    surface on the consumer thread in delivery order;
//!  * prefetch is bounded: the job queue holds at most `depth` raw
//!    baskets, so read-ahead memory scales with the queue depth plus
//!    transient worker skew, never the whole file;
//!  * steady-state reads recycle every per-basket buffer (raw payload,
//!    decoded data, offset array) through the same
//!    [`Pool<T>`](crate::util::pool::Pool) free lists the write pipeline
//!    uses ([`BufferPool`] / [`OffsetPool`]).
//!
//! Checksum verification (the LZ4 frame CRC-32 and every codec's internal
//! consistency checks) happens inside the workers' [`Engine::decompress_into`]
//! calls — off the consumer's critical path, unlike the serial reader where
//! it serializes with everything else.
//!
//! `scan` accepts *any* basket list, which is the multi-branch plumbing the
//! columnar projection layer ([`super::projection`]) builds on: it merges
//! several branches' directories into one offset-sorted prefetch plan and
//! re-routes this pipeline's submission-order delivery back into per-branch
//! event-order streams.
//!
//! The prefetcher reads through the
//! [`RangeSource`](crate::rfile::RangeSource) seam
//! ([`crate::rfile::source`]): a plain
//! [`FileSource`](crate::rfile::FileSource) in production, optionally
//! wrapped by a deterministic [`FaultSource`](crate::rfile::FaultSource)
//! (test substrate), one of the pluggable I/O backends
//! ([`IoBackend`](crate::rfile::IoBackend), selected via
//! [`ParallelTreeReader::with_io`]: plan-aware request coalescing, a
//! simulated memory map, or a simulated high-latency remote store whose
//! throughput the prefetch depth recovers), and a
//! [`RetrySource`](crate::rfile::RetrySource) that transparently replays
//! *transient* failures with bounded exponential backoff
//! ([`ParallelTreeReader::with_retry`]).
//! On top of that sits [`ScanMode::Salvage`]: instead of failing the scan,
//! a permanently-unreadable or checksum-rejected basket is skipped and
//! reported as a [`DamageRecord`], and degraded branch reads
//! ([`ParallelTreeReader::read_branch_salvage`]) return the intact values
//! plus explicit [`GapSpan`]s for what was lost.

use crate::compression::Engine;
use crate::coordinator::metrics::{Metrics, Snapshot};
use crate::rfile::basket::{decode_basket_into, BasketContent};
use crate::rfile::format::RecordKind;
use crate::rfile::meta::{push_gap, BasketLoc, GapSpan, TreeMeta};
use crate::rfile::reader::{decode_values, TreeReader};
use crate::rfile::branch::Value;
use crate::rfile::source::{
    compose_chain, read_record_from, FaultSpec, FaultStats, IoConfig, IoStats, RemotePacing,
    RetryPolicy,
};
use crate::util::pool::{BufferPool, OffsetPool};
use crate::util::varint::Cursor;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Read-ahead configuration: how many decode workers to run and how many
/// raw baskets may be prefetched ahead of the consumer (the backpressure
/// knob bounding read-ahead memory).
#[derive(Debug, Clone, Copy)]
pub struct ReadAhead {
    /// Decompression worker threads.
    pub workers: usize,
    /// Bounded queue depth between prefetcher → workers.
    pub depth: usize,
}

impl Default for ReadAhead {
    fn default() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .saturating_sub(1)
            .max(1);
        Self { workers, depth: 2 * workers }
    }
}

impl ReadAhead {
    /// Config with `workers` decode threads and a proportional read-ahead.
    pub fn with_workers(workers: usize) -> Self {
        let workers = workers.max(1);
        Self { workers, depth: 2 * workers }
    }
}

/// How a scan treats a basket that cannot be read or decoded.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScanMode {
    /// A damaged basket fails the scan — identical to the serial reader's
    /// behaviour (the default).
    #[default]
    Strict,
    /// Damaged baskets are skipped and reported: the scan delivers every
    /// basket that is still intact plus a [`DamageRecord`] per casualty,
    /// so a partially-corrupted file still yields its readable data.
    Salvage,
}

/// One unreadable or undecodable basket observed by a scan.
#[derive(Debug, Clone)]
pub struct DamageRecord {
    /// Directory entry of the damaged basket.
    pub loc: BasketLoc,
    /// Branch name, resolved from the tree metadata.
    pub branch: String,
    /// The underlying read/decode error.
    pub error: String,
}

impl std::fmt::Display for DamageRecord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "basket {} of branch '{}' (id {}) at file offset {}: {}",
            self.loc.basket_index,
            self.branch,
            self.loc.branch_id,
            self.loc.file_offset,
            self.error
        )
    }
}

/// A decoded basket payload as delivered by a scan. Single-scan pipelines
/// ([`BasketScan`]) deliver `Owned` contents whose buffers recycle through
/// the scan's pools; the concurrent scheduler
/// ([`super::scheduler::ScanServer`]) delivers `Shared` contents straight
/// out of the decoded-basket cache — refcounted, so cache eviction never
/// invalidates a basket an in-flight scan is still reading.
///
/// `Deref<Target = BasketContent>` means consumers read fields and call
/// [`decode_values`] without caring which variant they hold; only
/// `recycle` distinguishes them (shared payloads are not pooled — dropping
/// the `Arc` is the whole protocol).
#[derive(Debug)]
pub enum DecodedBasket {
    /// Exclusively-owned content; its buffers return to the scan's pools.
    Owned(BasketContent),
    /// Cache-resident content shared with other scans (and the cache).
    Shared(Arc<BasketContent>),
}

impl std::ops::Deref for DecodedBasket {
    type Target = BasketContent;
    fn deref(&self) -> &BasketContent {
        match self {
            DecodedBasket::Owned(c) => c,
            DecodedBasket::Shared(c) => c,
        }
    }
}

impl PartialEq for DecodedBasket {
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

impl PartialEq<BasketContent> for DecodedBasket {
    fn eq(&self, other: &BasketContent) -> bool {
        **self == *other
    }
}

/// One item from [`BasketScan::next_delivery`], in submission order.
pub enum Delivery {
    /// An intact, decoded basket.
    Basket(BasketLoc, DecodedBasket),
    /// A damaged basket's report (salvage mode only — strict scans turn
    /// damage into an `Err` instead).
    Damaged(DamageRecord),
}

/// The delivery surface shared by single-scan pipelines ([`BasketScan`])
/// and per-query streams from the concurrent scheduler
/// ([`super::scheduler::ServeStream`]). The projection layer is generic
/// over this trait, so the same reorder/latch machinery serves both the
/// one-reader path and the serving layer.
pub trait BasketStream {
    /// Next delivery in submission order (`None` when the stream is done).
    fn next_delivery(&mut self) -> Option<Result<Delivery>>;

    /// Hand back a consumed payload (pools owned buffers; drops shared).
    fn recycle(&self, content: DecodedBasket);

    /// The stream's failure-handling mode.
    fn mode(&self) -> ScanMode;

    /// Damage reports accumulated so far (always empty in strict mode).
    fn damage(&self) -> &[DamageRecord];

    /// Next intact basket, skipping damage reports in salvage mode.
    fn next_basket(&mut self) -> Option<Result<(BasketLoc, DecodedBasket)>> {
        loop {
            match self.next_delivery()? {
                Ok(Delivery::Basket(loc, content)) => return Some(Ok((loc, content))),
                Ok(Delivery::Damaged(_)) => continue,
                Err(e) => return Some(Err(e)),
            }
        }
    }
}

/// Result of a degraded (salvage-mode) branch read: every decodable value
/// in entry order, plus explicit gap spans (absolute entry ids) where
/// damaged baskets used to be, plus the damage reports themselves.
/// Invariant: `values.len() + entries_skipped()` equals the number of
/// entries the equivalent strict read would have returned.
#[derive(Debug, Clone)]
pub struct SalvageColumn {
    /// Values from intact baskets, in entry order (gaps elided).
    pub values: Vec<Value>,
    /// Entry spans lost to damage, sorted, merged when adjacent.
    pub gaps: Vec<GapSpan>,
    /// Per-basket damage reports, in delivery order.
    pub damage: Vec<DamageRecord>,
}

impl SalvageColumn {
    /// Entries lost to damage (the sum of the gap spans).
    pub fn entries_skipped(&self) -> u64 {
        self.gaps.iter().map(|g| g.n_entries).sum()
    }
}

/// A raw basket record travelling prefetcher → worker. The payload is the
/// record body read at `loc.file_offset` (rented from the raw-buffer pool);
/// prefetch-side failures travel as `Err` so they surface in delivery order.
struct RawJob {
    seq: u64,
    loc: BasketLoc,
    payload: Result<Vec<u8>, String>,
}

/// A decoded basket travelling worker → consumer.
struct Done {
    seq: u64,
    loc: BasketLoc,
    result: Result<BasketContent, String>,
}

/// An in-order stream of decoded baskets from a [`ParallelTreeReader`]
/// scan. Iterate (or call [`BasketScan::next_basket`]) to receive
/// `(BasketLoc, BasketContent)` pairs in exactly the order the basket list
/// was submitted; hand finished contents back via [`BasketScan::recycle`]
/// to keep the steady state allocation-free.
pub struct BasketScan {
    done_rx: Option<Receiver<Done>>,
    pending: BTreeMap<u64, Done>,
    next_seq: u64,
    total: u64,
    mode: ScanMode,
    branch_names: Arc<Vec<String>>,
    damage: Vec<DamageRecord>,
    prefetcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    data_pool: BufferPool,
    offset_pool: OffsetPool,
    read_retries: Arc<AtomicU64>,
}

impl BasketScan {
    /// Transient read failures retried while serving *this scan only* —
    /// the counter is created fresh per source chain, so concurrent scans
    /// of one file never bleed into each other's numbers.
    pub fn read_retries(&self) -> u64 {
        self.read_retries.load(Ordering::Relaxed)
    }

    /// Next delivery in submission order: an intact basket, or (salvage
    /// mode) a damage report. `None` when the scan is done. In strict mode
    /// a damaged basket surfaces as `Err` — on the basket whose decode
    /// failed, exactly like the serial reader's per-basket errors — and
    /// the scan continues with the next basket afterwards; only a dead
    /// worker pool is terminal.
    pub fn next_delivery(&mut self) -> Option<Result<Delivery>> {
        if self.next_seq >= self.total {
            self.join_threads();
            return None;
        }
        loop {
            if let Some(d) = self.pending.remove(&self.next_seq) {
                self.next_seq += 1;
                return Some(match d.result {
                    Ok(c) => Ok(Delivery::Basket(d.loc, DecodedBasket::Owned(c))),
                    Err(e) => {
                        let branch = self
                            .branch_names
                            .get(d.loc.branch_id as usize)
                            .cloned()
                            .unwrap_or_else(|| format!("#{}", d.loc.branch_id));
                        let rec = DamageRecord { loc: d.loc, branch, error: e };
                        match self.mode {
                            ScanMode::Strict => Err(anyhow::anyhow!("{rec}")),
                            ScanMode::Salvage => {
                                self.damage.push(rec.clone());
                                Ok(Delivery::Damaged(rec))
                            }
                        }
                    }
                });
            }
            let recv = match self.done_rx.as_ref() {
                Some(rx) => rx.recv().map_err(|_| ()),
                None => Err(()),
            };
            match recv {
                Ok(d) => {
                    self.pending.insert(d.seq, d);
                }
                Err(()) => {
                    // Workers died before delivering everything. Report it
                    // once, then terminate the stream: the next call falls
                    // into the `None` arm above instead of re-yielding this
                    // error forever (Iterator consumers that skip errors
                    // must still reach the end).
                    let delivered = self.next_seq;
                    self.next_seq = self.total;
                    self.done_rx = None;
                    return Some(Err(anyhow::anyhow!(
                        "read pipeline workers exited early ({delivered} of {} baskets delivered)",
                        self.total
                    )));
                }
            }
        }
    }

    /// Next intact basket in submission order, or `None` when the scan is
    /// done. In salvage mode damaged baskets are silently skipped here
    /// (inspect them via [`BasketScan::damage`]); in strict mode they
    /// surface as `Err`.
    pub fn next_basket(&mut self) -> Option<Result<(BasketLoc, DecodedBasket)>> {
        loop {
            match self.next_delivery()? {
                Ok(Delivery::Basket(loc, content)) => return Some(Ok((loc, content))),
                Ok(Delivery::Damaged(_)) => continue,
                Err(e) => return Some(Err(e)),
            }
        }
    }

    /// Damage reports accumulated so far (always empty in strict mode).
    pub fn damage(&self) -> &[DamageRecord] {
        &self.damage
    }

    /// Take ownership of the accumulated damage reports.
    pub fn take_damage(&mut self) -> Vec<DamageRecord> {
        std::mem::take(&mut self.damage)
    }

    /// The scan's failure-handling mode.
    pub fn mode(&self) -> ScanMode {
        self.mode
    }

    /// Return a consumed basket's buffers to the scan's pools so the next
    /// basket decode reuses their capacity (§Perf: closes the last
    /// per-basket allocation loop on the read side). Shared (cache-backed)
    /// payloads are simply dropped — their storage belongs to the cache.
    pub fn recycle(&self, content: DecodedBasket) {
        if let DecodedBasket::Owned(content) = content {
            self.data_pool.put(content.data);
            self.offset_pool.put(content.offsets);
        }
    }

    /// (reuses, fresh allocations) of the decoded-content buffers —
    /// observability hook for the zero-alloc steady-state claim.
    pub fn content_pool_stats(&self) -> ((u64, u64), (u64, u64)) {
        (self.data_pool.stats(), self.offset_pool.stats())
    }

    fn join_threads(&mut self) {
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(p) = self.prefetcher.take() {
            let _ = p.join();
        }
    }
}

impl BasketStream for BasketScan {
    fn next_delivery(&mut self) -> Option<Result<Delivery>> {
        BasketScan::next_delivery(self)
    }
    fn recycle(&self, content: DecodedBasket) {
        BasketScan::recycle(self, content)
    }
    fn mode(&self) -> ScanMode {
        BasketScan::mode(self)
    }
    fn damage(&self) -> &[DamageRecord] {
        BasketScan::damage(self)
    }
}

impl Iterator for BasketScan {
    type Item = Result<(BasketLoc, DecodedBasket)>;
    fn next(&mut self) -> Option<Self::Item> {
        self.next_basket()
    }
}

impl Drop for BasketScan {
    fn drop(&mut self) {
        // Dropping the done receiver makes blocked workers' sends fail, the
        // workers then drop the job receiver, which unblocks the prefetcher:
        // an early-abandoned scan (error, partial read) winds down without
        // deadlock.
        self.done_rx.take();
        self.join_threads();
    }
}

/// Parallel tree reader: the read-side twin of
/// [`write_tree_parallel`](super::pipeline::write_tree_parallel). Opens an
/// RFIL file's metadata once, then serves branch/event reads by streaming
/// raw baskets from disk and fanning decompression out across workers.
///
/// The serial [`TreeReader`] remains the oracle: every read method here is
/// property-tested byte-identical to its serial counterpart.
///
/// ```
/// use rootio::compression::{Algorithm, Settings};
/// use rootio::coordinator::{ParallelTreeReader, ReadAhead};
/// use rootio::gen::synthetic;
/// use rootio::rfile::write_tree_serial;
///
/// let path = std::env::temp_dir().join(format!("rootio_doc_par_{}.rfil", std::process::id()));
/// let events = synthetic::events(200, 7);
/// write_tree_serial(&path, "Events", synthetic::schema(),
///                   Settings::new(Algorithm::Lz4, 1), 4096, events.iter().cloned()).unwrap();
///
/// let reader = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
/// assert_eq!(reader.read_all_events().unwrap(), events);
/// std::fs::remove_file(&path).ok();
/// ```
pub struct ParallelTreeReader {
    path: PathBuf,
    pub meta: TreeMeta,
    dictionary: Vec<u8>,
    config: ReadAhead,
    metrics: Arc<Metrics>,
    io: IoConfig,
    fault_stats: Arc<FaultStats>,
    io_stats: Arc<IoStats>,
    retry_counter: Arc<AtomicU64>,
}

impl ParallelTreeReader {
    /// Open `path`, loading metadata and the dictionary through the same
    /// code path as the serial reader (so header/trailer rejection behaves
    /// identically).
    pub fn open(path: &Path, config: ReadAhead) -> Result<Self> {
        let serial = TreeReader::open(path)?;
        Ok(Self::from_parts(
            path.to_path_buf(),
            serial.meta.clone(),
            serial.dictionary().to_vec(),
            config,
        ))
    }

    /// Build from already-loaded metadata (used by
    /// [`TreeReader::read_ahead`], which has the file open and parsed).
    pub fn from_parts(path: PathBuf, meta: TreeMeta, dictionary: Vec<u8>, config: ReadAhead) -> Self {
        Self {
            path,
            meta,
            dictionary,
            config,
            metrics: Arc::new(Metrics::new()),
            io: IoConfig { retry: RetryPolicy::default(), ..IoConfig::default() },
            fault_stats: Arc::new(FaultStats::default()),
            io_stats: Arc::new(IoStats::default()),
            retry_counter: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Replace the transient-failure retry policy (builder style). The
    /// default policy retries transient read errors a few times with
    /// bounded exponential backoff; [`RetryPolicy::disabled`] makes every
    /// transient failure surface immediately, like the serial reader.
    pub fn with_retry(mut self, policy: RetryPolicy) -> Self {
        self.io.retry = policy;
        self
    }

    /// Inject a seeded deterministic fault schedule *under* the retry
    /// layer (builder style) — the substrate the fault-tolerance property
    /// tests drive. Production readers never set this.
    pub fn with_faults(mut self, spec: FaultSpec) -> Self {
        self.io.faults = Some(spec);
        self
    }

    /// Select the I/O backend and its knobs (builder style):
    /// `pread` (default), plan-aware `coalesced` reads, a simulated
    /// `mmap` image, or the `remote-sim` high-latency store. Fault
    /// injection and retry policy keep their own builders
    /// ([`with_faults`](Self::with_faults) /
    /// [`with_retry`](Self::with_retry)) — whatever they configured is
    /// preserved across this call.
    pub fn with_io(mut self, io: IoConfig) -> Self {
        self.io = IoConfig { faults: self.io.faults, retry: self.io.retry, ..io };
        self
    }

    /// Counters for faults injected by [`with_faults`](Self::with_faults)
    /// (all zero when fault injection is off).
    pub fn fault_stats(&self) -> Arc<FaultStats> {
        Arc::clone(&self.fault_stats)
    }

    /// Physical-I/O counters (syscalls issued, requests coalesced, bytes
    /// served from merge buffers) aggregated across every scan this
    /// reader served — also folded into the metrics snapshot.
    pub fn io_stats(&self) -> Arc<IoStats> {
        Arc::clone(&self.io_stats)
    }

    /// Transient read failures retried so far, across every scan this
    /// reader served (also folded into [`Snapshot::read_retries`]).
    pub fn read_retries(&self) -> u64 {
        self.retry_counter.load(Ordering::Relaxed)
    }

    /// Branch id for a branch name (same [`TreeMeta`] query the serial
    /// reader uses).
    pub fn branch_id(&self, name: &str) -> Option<u32> {
        self.meta.branch_id(name)
    }

    /// Basket directory for one branch (ordered by basket_index).
    pub fn baskets_for(&self, branch_id: u32) -> Vec<BasketLoc> {
        self.meta.baskets_for(branch_id)
    }

    /// Decode metrics aggregated across every scan this reader served:
    /// `bytes_in` = logical (uncompressed) bytes, `bytes_out` = compressed
    /// record bytes, `compress_nanos` = worker decode CPU time.
    pub fn metrics_snapshot(&self) -> Snapshot {
        self.metrics.set_read_retries(self.retry_counter.load(Ordering::Relaxed));
        self.metrics.set_io_counters(
            self.io_stats.syscalls(),
            self.io_stats.bytes_merged(),
            self.io_stats.requests_coalesced(),
        );
        self.metrics.snapshot()
    }

    /// Start a pipelined scan over `locs`, delivering decoded baskets in
    /// exactly that order. The prefetcher reads raw records sequentially on
    /// one thread; `config.workers` workers decompress concurrently.
    /// Strict mode: any damaged basket fails its delivery.
    pub fn scan(&self, locs: Vec<BasketLoc>) -> Result<BasketScan> {
        self.scan_with_mode(locs, ScanMode::Strict)
    }

    /// [`scan`](Self::scan) with an explicit failure-handling `mode`
    /// ([`ScanMode::Salvage`] skips and reports damaged baskets instead of
    /// failing deliveries).
    pub fn scan_with_mode(&self, locs: Vec<BasketLoc>, mode: ScanMode) -> Result<BasketScan> {
        let total = locs.len() as u64;
        let workers_n = self.config.workers.max(1);
        let depth = self.config.depth.max(1);
        // Open before spawning so open errors surface to the caller, then
        // assemble the prefetcher's source chain:
        // FileSource → [FaultSource] → backend → [RetrySource].
        // The plan (exact record extents, offset-sorted by the caller's
        // sweep) feeds the coalescing backend; the prefetch depth doubles
        // as the remote backend's pipeline window. Sleep pacing is correct
        // here because the prefetcher is this scan's own thread — blocking
        // it charges only this scan.
        let plan: Vec<(u64, u64)> = locs.iter().map(|l| l.record_span()).collect();
        let chain = compose_chain(
            &self.path,
            &self.io,
            &plan,
            depth,
            RemotePacing::Sleep,
            Arc::clone(&self.io_stats),
            Arc::clone(&self.fault_stats),
            &[Arc::clone(&self.retry_counter)],
        )?;
        let source = chain.source;
        let scan_retries = chain.retries;

        let (job_tx, job_rx) = std::sync::mpsc::sync_channel::<RawJob>(depth);
        let (done_tx, done_rx) = std::sync::mpsc::sync_channel::<Done>(depth * 2);
        let job_rx = Arc::new(std::sync::Mutex::new(job_rx));

        // §Perf: pools sized to the in-flight bound. Raw payload buffers
        // cycle prefetcher → worker → prefetcher; decoded data/offset
        // buffers cycle worker → consumer → (via recycle) worker. The 4 MiB
        // capacity cap keeps one jumbo basket from pinning memory for the
        // scan's lifetime, same policy as the write side.
        let raw_pool = BufferPool::new(depth * 2 + workers_n, 4 << 20);
        let data_pool = BufferPool::new(depth * 2 + workers_n, 4 << 20);
        let offset_pool = OffsetPool::new(depth * 2 + workers_n, 1 << 20);

        let mut workers = Vec::with_capacity(workers_n);
        for _ in 0..workers_n {
            let rx = Arc::clone(&job_rx);
            let tx = done_tx.clone();
            let m = Arc::clone(&self.metrics);
            let dict = self.dictionary.clone();
            let raw_pool = raw_pool.clone();
            let data_pool = data_pool.clone();
            let offset_pool = offset_pool.clone();
            workers.push(std::thread::spawn(move || {
                let mut engine = Engine::new();
                if !dict.is_empty() {
                    engine.set_dictionary(dict);
                }
                // Worker-local scratch, reused across every basket.
                let mut logical_scratch: Vec<u8> = Vec::new();
                loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(job) = job else { break };
                    let done = match job.payload {
                        Err(e) => Done { seq: job.seq, loc: job.loc, result: Err(e) },
                        Ok(raw) => {
                            let t0 = Instant::now();
                            let mut content = BasketContent {
                                n_entries: 0,
                                data: data_pool.get(),
                                offsets: offset_pool.get(),
                            };
                            let r = decode_raw_basket(
                                &raw,
                                &job.loc,
                                &mut engine,
                                &mut logical_scratch,
                                &mut content,
                            );
                            let raw_len = raw.len();
                            raw_pool.put(raw);
                            match r {
                                Ok(()) => {
                                    m.record_basket(
                                        content.data.len() + 4 * content.offsets.len(),
                                        raw_len,
                                        t0.elapsed(),
                                    );
                                    Done { seq: job.seq, loc: job.loc, result: Ok(content) }
                                }
                                Err(e) => {
                                    // Failed decode: the rented buffers go
                                    // straight back to the pools.
                                    data_pool.put(content.data);
                                    offset_pool.put(content.offsets);
                                    Done { seq: job.seq, loc: job.loc, result: Err(e) }
                                }
                            }
                        }
                    };
                    if tx.send(done).is_err() {
                        break;
                    }
                }
            }));
        }
        drop(done_tx);

        let branch_names: Arc<Vec<String>> =
            Arc::new(self.meta.branches.iter().map(|b| b.name.clone()).collect());

        let prefetch_raw_pool = raw_pool.clone();
        let prefetcher = std::thread::spawn(move || {
            let mut source = source;
            for (seq, loc) in locs.into_iter().enumerate() {
                let mut buf = prefetch_raw_pool.get();
                let payload = match read_record_from(&mut source, loc.file_offset, &mut buf) {
                    Ok(RecordKind::Basket) => Ok(buf),
                    Ok(kind) => {
                        prefetch_raw_pool.put(buf);
                        Err(format!(
                            "expected basket record at {}, found {kind:?}",
                            loc.file_offset
                        ))
                    }
                    Err(e) => {
                        prefetch_raw_pool.put(buf);
                        Err(e.to_string())
                    }
                };
                if job_tx.send(RawJob { seq: seq as u64, loc, payload }).is_err() {
                    // Workers gone (scan dropped early): stop prefetching.
                    return;
                }
            }
        });

        Ok(BasketScan {
            done_rx: Some(done_rx),
            pending: BTreeMap::new(),
            next_seq: 0,
            total,
            mode,
            branch_names,
            damage: Vec::new(),
            prefetcher: Some(prefetcher),
            workers,
            data_pool,
            offset_pool,
            read_retries: scan_retries,
        })
    }

    /// Read an entire branch back as per-entry values — the parallel
    /// equivalent of [`TreeReader::read_branch`], byte-identical output.
    pub fn read_branch(&self, branch_id: u32) -> Result<Vec<Value>> {
        let ty = self
            .meta
            .branches
            .get(branch_id as usize)
            .ok_or_else(|| anyhow::anyhow!("no branch {branch_id}"))?
            .ty;
        let locs = self.baskets_for(branch_id);
        let mut scan = self.scan(locs)?;
        let mut out = Vec::with_capacity(self.meta.n_entries as usize);
        while let Some(item) = scan.next_basket() {
            let (_, content) = item?;
            decode_values(&content, ty, &mut out)?;
            scan.recycle(content);
        }
        if out.len() as u64 != self.meta.n_entries {
            bail!(
                "branch {branch_id}: {} entries decoded, tree has {}",
                out.len(),
                self.meta.n_entries
            );
        }
        Ok(out)
    }

    /// Read one branch over the entry window `[range.start, range.end)`
    /// only — the parallel equivalent of [`TreeReader::read_range`],
    /// byte-identical output. Only the baskets whose entry spans overlap
    /// the window are prefetched and decoded; head/tail rows of boundary
    /// baskets are trimmed. The range is clamped to the tree (past-EOF and
    /// empty windows yield zero values, not errors).
    pub fn read_range(&self, branch_id: u32, range: std::ops::Range<u64>) -> Result<Vec<Value>> {
        let ty = self
            .meta
            .branches
            .get(branch_id as usize)
            .ok_or_else(|| anyhow::anyhow!("no branch {branch_id}"))?
            .ty;
        let (start, end) = self.meta.clamp_entry_range(range.start, range.end);
        let locs = self.meta.baskets_for_range(branch_id, start, end);
        let mut scan = self.scan(locs)?;
        let mut out = Vec::with_capacity((end - start) as usize);
        let mut scratch = Vec::new();
        while let Some(item) = scan.next_basket() {
            let (loc, content) = item?;
            let (from, to) = loc.trim_bounds(start, end);
            if from == 0 && to == loc.n_entries as usize {
                decode_values(&content, ty, &mut out)?;
            } else {
                scratch.clear();
                decode_values(&content, ty, &mut scratch)?;
                out.extend(scratch.drain(..to).skip(from));
            }
            scan.recycle(content);
        }
        if out.len() as u64 != end - start {
            bail!(
                "branch {branch_id}: {} entries decoded for range [{start}, {end}), expected {}",
                out.len(),
                end - start
            );
        }
        Ok(out)
    }

    /// Degraded-mode branch read: every basket that can still be read and
    /// decoded contributes its values; damaged baskets become explicit
    /// [`GapSpan`]s (absolute entry ids) and [`DamageRecord`]s instead of
    /// failing the read. `values.len() + entries_skipped()` always equals
    /// the branch's entry count.
    pub fn read_branch_salvage(&self, branch_id: u32) -> Result<SalvageColumn> {
        self.read_range_salvage(branch_id, 0..self.meta.n_entries)
    }

    /// Salvage twin of [`read_range`](Self::read_range) over the entry
    /// window `[range.start, range.end)` (clamped to the tree). Gap spans
    /// are clamped to the window too.
    pub fn read_range_salvage(
        &self,
        branch_id: u32,
        range: std::ops::Range<u64>,
    ) -> Result<SalvageColumn> {
        let ty = self
            .meta
            .branches
            .get(branch_id as usize)
            .ok_or_else(|| anyhow::anyhow!("no branch {branch_id}"))?
            .ty;
        let (start, end) = self.meta.clamp_entry_range(range.start, range.end);
        let locs = self.meta.baskets_for_range(branch_id, start, end);
        let mut scan = self.scan_with_mode(locs, ScanMode::Salvage)?;
        let mut values = Vec::with_capacity((end - start) as usize);
        let mut gaps: Vec<GapSpan> = Vec::new();
        let mut damage: Vec<DamageRecord> = Vec::new();
        let mut scratch = Vec::new();
        while let Some(item) = scan.next_delivery() {
            match item? {
                Delivery::Basket(loc, content) => {
                    let (from, to) = loc.trim_bounds(start, end);
                    // Decode into scratch first: decode_values can fail
                    // midway through a corrupt offset array, and a partial
                    // append must not leak into the salvage output.
                    scratch.clear();
                    match decode_values(&content, ty, &mut scratch) {
                        Ok(()) => values.extend(scratch.drain(..to).skip(from)),
                        Err(e) => {
                            let branch = self
                                .meta
                                .branches
                                .get(loc.branch_id as usize)
                                .map(|b| b.name.clone())
                                .unwrap_or_else(|| format!("#{}", loc.branch_id));
                            damage.push(DamageRecord {
                                loc,
                                branch,
                                error: format!("{e:#}"),
                            });
                            if let Some(g) = loc.gap_within(start, end) {
                                push_gap(&mut gaps, g);
                            }
                        }
                    }
                    scan.recycle(content);
                }
                Delivery::Damaged(rec) => {
                    if let Some(g) = rec.loc.gap_within(start, end) {
                        push_gap(&mut gaps, g);
                    }
                    damage.push(rec);
                }
            }
        }
        let skipped: u64 = gaps.iter().map(|g| g.n_entries).sum();
        if values.len() as u64 + skipped != end - start {
            bail!(
                "branch {branch_id}: salvage accounting broken — {} values + {skipped} skipped \
                 != {} entries in [{start}, {end})",
                values.len(),
                end - start
            );
        }
        Ok(SalvageColumn { values, gaps, damage })
    }

    /// Row-wise reconstruction across all branches — the parallel
    /// equivalent of [`TreeReader::read_all_events`]. One scan covers the
    /// whole basket directory (branch-major order, so columns fill
    /// sequentially), instead of one scan per branch.
    pub fn read_all_events(&self) -> Result<Vec<Vec<Value>>> {
        let n_branches = self.meta.branches.len();
        let n = self.meta.n_entries as usize;
        let mut columns: Vec<Vec<Value>> = (0..n_branches).map(|_| Vec::with_capacity(n)).collect();
        let mut scan = self.scan(self.meta.baskets.clone())?;
        while let Some(item) = scan.next_basket() {
            let (loc, content) = item?;
            let ty = self
                .meta
                .branches
                .get(loc.branch_id as usize)
                .ok_or_else(|| anyhow::anyhow!("basket for unknown branch {}", loc.branch_id))?
                .ty;
            decode_values(&content, ty, &mut columns[loc.branch_id as usize])?;
            scan.recycle(content);
        }
        for (b, col) in columns.iter().enumerate() {
            if col.len() as u64 != self.meta.n_entries {
                bail!(
                    "branch {b}: {} entries decoded, tree has {}",
                    col.len(),
                    self.meta.n_entries
                );
            }
        }
        // (vec![..; n] would clone away the capacity — Vec::clone starts
        // from an empty buffer.)
        let mut events: Vec<Vec<Value>> = (0..n).map(|_| Vec::with_capacity(n_branches)).collect();
        for col in columns {
            for (ev, v) in events.iter_mut().zip(col) {
                ev.push(v);
            }
        }
        Ok(events)
    }
}

/// Decode one raw basket record body against its directory entry: parse the
/// framing prefix, check identity, decompress, check the entry count — the
/// exact checks [`TreeReader::read_basket`] performs serially. Shared with
/// the concurrent scheduler's workers ([`super::scheduler`]).
pub(crate) fn decode_raw_basket(
    raw: &[u8],
    loc: &BasketLoc,
    engine: &mut Engine,
    logical_scratch: &mut Vec<u8>,
    content: &mut BasketContent,
) -> Result<(), String> {
    let mut c = Cursor::new(raw);
    let branch_id = c.uvarint().ok_or("basket branch id truncated")? as u32;
    let basket_index = c.uvarint().ok_or("basket index truncated")? as u32;
    if branch_id != loc.branch_id || basket_index != loc.basket_index {
        return Err(format!(
            "basket identity mismatch: found ({branch_id},{basket_index}), expected ({},{})",
            loc.branch_id, loc.basket_index
        ));
    }
    decode_basket_into(&raw[c.pos()..], engine, logical_scratch, content)
        .map_err(|e| format!("basket decode: {e}"))?;
    if content.n_entries != loc.n_entries {
        return Err("basket entry count mismatch".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Algorithm, Settings};
    use crate::gen::synthetic;
    use crate::rfile::source::IoBackend;
    use crate::rfile::write_tree_serial;
    use std::time::Duration;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rootio_rpipe_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn scan_delivers_in_order_and_recycles() {
        let path = tmp("order");
        let events = synthetic::events(300, 3);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Lz4, 1),
            1024,
            events.iter().cloned(),
        )
        .unwrap();
        let reader = ParallelTreeReader::open(&path, ReadAhead { workers: 3, depth: 2 }).unwrap();
        let locs = reader.meta.baskets.clone();
        assert!(locs.len() > 10, "want many baskets, got {}", locs.len());
        let mut scan = reader.scan(locs.clone()).unwrap();
        let mut n = 0usize;
        while let Some(item) = scan.next_basket() {
            let (loc, content) = item.unwrap();
            // Delivery order is exactly submission order.
            assert_eq!(
                (loc.branch_id, loc.basket_index),
                (locs[n].branch_id, locs[n].basket_index)
            );
            assert_eq!(content.n_entries, loc.n_entries);
            scan.recycle(content);
            n += 1;
        }
        assert_eq!(n, locs.len());
        // Steady state reuses buffers: fresh allocations track the
        // in-flight window (queue depth + workers + transient skew), not
        // the basket count. Generous bound to stay robust on loaded CI.
        let ((data_reuse, data_alloc), _) = scan.content_pool_stats();
        assert_eq!(data_reuse + data_alloc, locs.len() as u64);
        assert!(
            data_reuse > 0 && data_alloc <= locs.len() as u64 / 2,
            "expected pooled reuse, got {data_alloc} fresh allocations over {} baskets",
            locs.len()
        );
        let snap = reader.metrics_snapshot();
        assert_eq!(snap.baskets, locs.len() as u64);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn early_drop_does_not_deadlock() {
        let path = tmp("drop");
        let events = synthetic::events(400, 5);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Zstd, 1),
            512,
            events.iter().cloned(),
        )
        .unwrap();
        let reader = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 1 }).unwrap();
        let mut scan = reader.scan(reader.meta.baskets.clone()).unwrap();
        // Consume a couple of baskets, then drop the scan mid-flight.
        for _ in 0..2 {
            let (_, content) = scan.next_basket().unwrap().unwrap();
            scan.recycle(content);
        }
        drop(scan);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn salvage_skips_damage_and_reports_gaps() {
        let path = tmp("salvage");
        let events = synthetic::events(300, 11);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Lz4, 1),
            1024,
            events.iter().cloned(),
        )
        .unwrap();
        let reader = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 2 }).unwrap();
        let locs = reader.baskets_for(0);
        assert!(locs.len() >= 3, "want several baskets, got {}", locs.len());
        let victim = locs[1];
        // Flip bits in the basket's identity varint (first payload byte):
        // deterministic frame-level damage regardless of codec.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[victim.file_offset as usize + 5] ^= 0x3F;
        std::fs::write(&path, bytes).unwrap();

        // Strict mode rejects, naming the casualty.
        let err = reader.read_branch(0).unwrap_err().to_string();
        assert!(err.contains("basket 1 of branch"), "{err}");
        assert!(err.contains(&format!("file offset {}", victim.file_offset)), "{err}");

        // Salvage returns exactly the intact complement plus the gap.
        let col = reader.read_branch_salvage(0).unwrap();
        let hole = victim.first_entry..victim.first_entry + victim.n_entries as u64;
        let expected: Vec<Value> = events
            .iter()
            .enumerate()
            .filter(|(i, _)| !hole.contains(&(*i as u64)))
            .map(|(_, ev)| ev[0].clone())
            .collect();
        assert_eq!(col.values, expected);
        assert_eq!(
            col.gaps,
            vec![GapSpan { first_entry: victim.first_entry, n_entries: victim.n_entries as u64 }]
        );
        assert_eq!(col.damage.len(), 1);
        assert_eq!(col.damage[0].loc.basket_index, 1);
        assert_eq!(col.entries_skipped(), victim.n_entries as u64);

        // A windowed salvage clamps the gap to the window.
        let lo = victim.first_entry + 1;
        let win = reader.read_range_salvage(0, lo..lo + 1).unwrap();
        assert!(win.values.is_empty());
        assert_eq!(win.gaps, vec![GapSpan { first_entry: lo, n_entries: 1 }]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn injected_transient_faults_recover_with_retry() {
        let path = tmp("faults");
        let events = synthetic::events(200, 13);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Zstd, 1),
            1024,
            events.iter().cloned(),
        )
        .unwrap();
        let spec = FaultSpec {
            seed: 42,
            transient: 0.4,
            short_read: 0.3,
            max_consecutive: 2,
            ..FaultSpec::default()
        };
        let policy = RetryPolicy {
            max_attempts: 4, // > max_consecutive, so recovery is guaranteed
            base_delay: Duration::ZERO,
            backoff: 1.0,
            max_delay: Duration::ZERO,
        };
        let reader = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 2 })
            .unwrap()
            .with_faults(spec)
            .with_retry(policy);
        assert_eq!(reader.read_all_events().unwrap(), events);
        assert!(reader.fault_stats().total() > 0, "fault plan never fired");
        assert!(reader.read_retries() > 0, "retries never observed");
        assert!(reader.metrics_snapshot().read_retries > 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn disabled_retry_surfaces_injected_faults() {
        let path = tmp("noretry");
        let events = synthetic::events(60, 17);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Lz4, 1),
            2048,
            events.iter().cloned(),
        )
        .unwrap();
        let spec = FaultSpec { seed: 1, transient: 1.0, max_consecutive: 2, ..FaultSpec::default() };
        let reader = ParallelTreeReader::open(&path, ReadAhead::with_workers(2))
            .unwrap()
            .with_faults(spec)
            .with_retry(RetryPolicy::disabled());
        let err = reader.read_branch(0).unwrap_err().to_string();
        assert!(err.contains("injected transient I/O error"), "{err}");
        assert_eq!(reader.read_retries(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn coalesced_backend_matches_pread_with_far_fewer_syscalls() {
        let path = tmp("coalesce");
        let events = synthetic::events(400, 21);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Lz4, 1),
            1024,
            events.iter().cloned(),
        )
        .unwrap();

        let pread = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 4 }).unwrap();
        let baseline = pread.read_all_events().unwrap();
        assert_eq!(baseline, events);
        let pread_syscalls = pread.metrics_snapshot().io_syscalls;
        // pread issues two reads per record (5-byte frame header + body);
        // short reads can only push the count higher.
        let baskets = pread.meta.baskets.len() as u64;
        assert!(pread_syscalls >= 2 * baskets, "{pread_syscalls} < {}", 2 * baskets);

        let reader = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 4 })
            .unwrap()
            .with_io(IoConfig::for_backend(IoBackend::Coalesced));
        assert_eq!(reader.read_all_events().unwrap(), events);
        let snap = reader.metrics_snapshot();
        // A full projection sweep's plan entries are near-adjacent by
        // construction, so k plan entries collapse into a handful of
        // merged fills — far below the 2-per-basket pread floor.
        assert!(
            snap.io_syscalls * 4 <= pread_syscalls,
            "coalescing barely helped: {} vs pread {}",
            snap.io_syscalls,
            pread_syscalls
        );
        assert!(snap.io_requests_coalesced > 0, "no request was served from a merged buffer");
        assert!(snap.io_bytes_merged > 0);

        // The other backends stay byte-identical too.
        for backend in [IoBackend::Mmap, IoBackend::RemoteSim] {
            let r = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 4 })
                .unwrap()
                .with_io(IoConfig::for_backend(backend));
            assert_eq!(r.read_all_events().unwrap(), events, "{backend} diverged");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn per_scan_retry_counters_are_isolated() {
        let path = tmp("scanretries");
        let events = synthetic::events(120, 23);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Lz4, 1),
            1024,
            events.iter().cloned(),
        )
        .unwrap();
        let spec = FaultSpec {
            seed: 7,
            transient: 0.5,
            max_consecutive: 2,
            ..FaultSpec::default()
        };
        let policy = RetryPolicy {
            max_attempts: 4,
            base_delay: Duration::ZERO,
            backoff: 1.0,
            max_delay: Duration::ZERO,
        };
        let reader = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 2 })
            .unwrap()
            .with_faults(spec)
            .with_retry(policy);
        let locs = reader.meta.baskets.clone();
        let mut first = 0u64;
        for round in 0..2 {
            let mut scan = reader.scan(locs.clone()).unwrap();
            while let Some(item) = scan.next_basket() {
                let (_, content) = item.unwrap();
                scan.recycle(content);
            }
            let this_scan = scan.read_retries();
            assert!(this_scan > 0, "round {round}: fault plan never fired");
            if round == 0 {
                first = this_scan;
            } else {
                // Per-chain counter restarts from zero each scan while the
                // reader-lifetime cumulative keeps the running total.
                assert_eq!(reader.read_retries(), first + this_scan);
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bogus_offset_errors_like_serial() {
        let path = tmp("bogus");
        let events = synthetic::events(50, 9);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Zlib, 1),
            4096,
            events.iter().cloned(),
        )
        .unwrap();
        let reader = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
        let mut locs = reader.meta.baskets.clone();
        // Point one basket at the trailer: both readers must reject it.
        locs[0].file_offset = u64::MAX / 2;
        let mut scan = reader.scan(locs).unwrap();
        assert!(scan.next_basket().unwrap().is_err());
        std::fs::remove_file(&path).ok();
    }
}
