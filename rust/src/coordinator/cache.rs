//! Sharded, byte-budgeted LRU cache of **decoded** basket payloads — the
//! cross-scan decode-sharing layer under the concurrent scheduler
//! ([`super::scheduler`]).
//!
//! The paper's analysis workload is "millions of users hitting the same
//! hot NanoAOD branches": N concurrent projection scans over one corpus
//! repeatedly decode the *same* baskets. Caching the decoded payload (not
//! the compressed record — decompression is the expensive half, Fig 3)
//! turns that duplicated CPU into a hash lookup.
//!
//! Design points:
//!
//! * **Key identity** — [`CacheKey`] is `(file_id, branch_id,
//!   basket_index)`. [`FileId`](crate::rfile::FileId) hashes device/inode
//!   + length + mtime, so a rewritten file never serves stale baskets and
//!   two paths to the same file share entries.
//! * **Sharding** — the key hash picks one of `n_shards` (power of two)
//!   independently-locked shards, so concurrent scans touching different
//!   baskets don't serialize on a global mutex. The byte budget is split
//!   evenly across shards.
//! * **Refcounted payloads** — entries hold `Arc<BasketContent>`; a `get`
//!   clones the `Arc`. Eviction drops the cache's reference only, so an
//!   in-flight scan keeps reading its (now-evicted) basket safely.
//! * **LRU by logical tick** — each shard keeps a `tick → key` index; a
//!   hit reassigns the entry's tick (O(log n) in the resident count).
//!   Eviction pops the minimum tick until the shard is back under budget.
//! * **Oversize rejection** — a payload larger than one shard's budget is
//!   never inserted (it would evict the whole shard for a single-use
//!   basket); the insert is counted in [`CacheStats::rejected`].
//! * A `budget_bytes` of 0 disables caching entirely: every lookup
//!   misses, every insert is rejected, and the scheduler falls back to
//!   decode-per-scan.
//!
//! Accounting invariant (asserted by the concurrent integration suite):
//! `hits + misses == lookups`, always.

use crate::rfile::basket::BasketContent;
use crate::rfile::FileId;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Identity of one decoded basket across the corpus.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey {
    /// Content identity of the owning file ([`FileId::of_path`]).
    pub file: FileId,
    /// Branch id within that file's tree.
    pub branch_id: u32,
    /// Basket sequence number within the branch.
    pub basket_index: u32,
}

/// Counters describing cache behaviour since construction. Monotonic
/// except `resident_*`, which snapshot the current contents.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Total `get` calls.
    pub lookups: u64,
    /// Lookups that returned a payload.
    pub hits: u64,
    /// Lookups that found nothing (`lookups - hits`).
    pub misses: u64,
    /// Payloads accepted by `insert`.
    pub insertions: u64,
    /// Entries evicted to make room (refcounted — in-flight readers of an
    /// evicted payload are unaffected).
    pub evictions: u64,
    /// Inserts refused because the payload exceeds one shard's budget
    /// (or the cache is disabled).
    pub rejected: u64,
    /// Logical bytes served to scans out of the cache (hit payload sizes).
    pub bytes_from_cache: u64,
    /// Bytes currently resident across all shards.
    pub resident_bytes: u64,
    /// Entries currently resident across all shards.
    pub resident_entries: u64,
}

/// One cache entry: the shared payload plus its LRU bookkeeping.
struct Entry {
    content: Arc<BasketContent>,
    bytes: u64,
    /// Position in the shard's `lru` index (reassigned on every touch).
    tick: u64,
}

/// One independently-locked shard: key → entry map plus a tick-ordered
/// LRU index and the shard's running byte total.
struct Shard {
    map: HashMap<CacheKey, Entry>,
    /// tick → key, oldest first. Ticks are unique within a shard.
    lru: BTreeMap<u64, CacheKey>,
    bytes: u64,
    next_tick: u64,
}

impl Shard {
    fn new() -> Self {
        Shard { map: HashMap::new(), lru: BTreeMap::new(), bytes: 0, next_tick: 0 }
    }

    fn touch(&mut self, key: &CacheKey) -> Option<Arc<BasketContent>> {
        let tick = self.next_tick;
        let e = self.map.get_mut(key)?;
        self.lru.remove(&e.tick);
        e.tick = tick;
        self.next_tick += 1;
        self.lru.insert(tick, *key);
        Some(Arc::clone(&e.content))
    }

    /// Evict oldest entries until `bytes <= budget`. Returns evictions.
    fn evict_to(&mut self, budget: u64) -> u64 {
        let mut evicted = 0;
        while self.bytes > budget {
            let Some((&tick, &key)) = self.lru.iter().next() else { break };
            self.lru.remove(&tick);
            if let Some(e) = self.map.remove(&key) {
                self.bytes -= e.bytes;
                evicted += 1;
            }
        }
        evicted
    }
}

/// The sharded LRU cache. Cheap to share (`Arc` internally per shard is
/// unnecessary — the whole cache lives in one `Arc` inside the server).
pub struct BasketCache {
    shards: Vec<Mutex<Shard>>,
    /// Per-shard byte budget (total / n_shards).
    shard_budget: u64,
    /// Shard index mask (`n_shards` is a power of two).
    mask: u64,
    lookups: AtomicU64,
    hits: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    rejected: AtomicU64,
    bytes_from_cache: AtomicU64,
}

impl BasketCache {
    /// Cache with `budget_bytes` total capacity split over `n_shards`
    /// (rounded up to a power of two, min 1). `budget_bytes == 0` disables
    /// caching.
    pub fn new(budget_bytes: u64, n_shards: usize) -> Self {
        let n = n_shards.max(1).next_power_of_two();
        BasketCache {
            shards: (0..n).map(|_| Mutex::new(Shard::new())).collect(),
            shard_budget: budget_bytes / n as u64,
            mask: n as u64 - 1,
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            bytes_from_cache: AtomicU64::new(0),
        }
    }

    /// The decoded size charged against the budget for a payload.
    pub fn payload_bytes(content: &BasketContent) -> u64 {
        (content.data.len() + 4 * content.offsets.len()) as u64
    }

    fn shard_of(&self, key: &CacheKey) -> &Mutex<Shard> {
        // FNV-1a over the key words; independent of HashMap's hasher so a
        // pathological basket distribution can't alias both levels.
        let mut h: u64 = 0xcbf29ce484222325;
        for w in [key.file.0, key.branch_id as u64, key.basket_index as u64] {
            for b in w.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
        &self.shards[(h & self.mask) as usize]
    }

    /// Look up a decoded basket. A hit refreshes the entry's LRU position
    /// and returns a refcounted payload that outlives any later eviction.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<BasketContent>> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let found = self.shard_of(key).lock().unwrap().touch(key);
        if let Some(content) = &found {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.bytes_from_cache.fetch_add(Self::payload_bytes(content), Ordering::Relaxed);
        }
        found
    }

    /// Insert a decoded basket, evicting oldest entries in its shard as
    /// needed. Payloads larger than one shard's budget are rejected (and
    /// counted); re-inserting a resident key refreshes its payload.
    /// Returns whether the payload is now resident.
    pub fn insert(&self, key: CacheKey, content: Arc<BasketContent>) -> bool {
        let bytes = Self::payload_bytes(&content);
        if bytes > self.shard_budget {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let mut shard = self.shard_of(&key).lock().unwrap();
        let tick = shard.next_tick;
        shard.next_tick += 1;
        if let Some(old) = shard.map.insert(key, Entry { content, bytes, tick }) {
            shard.lru.remove(&old.tick);
            shard.bytes -= old.bytes;
        }
        shard.lru.insert(tick, key);
        shard.bytes += bytes;
        let evicted = shard.evict_to(self.shard_budget);
        drop(shard);
        self.insertions.fetch_add(1, Ordering::Relaxed);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        true
    }

    /// Whether a key is currently resident (no LRU touch, no counters) —
    /// test/introspection hook.
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.shard_of(key).lock().unwrap().map.contains_key(key)
    }

    /// Snapshot the counters plus current residency.
    pub fn stats(&self) -> CacheStats {
        let lookups = self.lookups.load(Ordering::Relaxed);
        let hits = self.hits.load(Ordering::Relaxed);
        let (mut resident_bytes, mut resident_entries) = (0u64, 0u64);
        for s in &self.shards {
            let s = s.lock().unwrap();
            resident_bytes += s.bytes;
            resident_entries += s.map.len() as u64;
        }
        CacheStats {
            lookups,
            hits,
            misses: lookups - hits,
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            bytes_from_cache: self.bytes_from_cache.load(Ordering::Relaxed),
            resident_bytes,
            resident_entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(file: u64, branch: u32, basket: u32) -> CacheKey {
        CacheKey { file: FileId(file), branch_id: branch, basket_index: basket }
    }

    fn payload(n: usize, fill: u8) -> Arc<BasketContent> {
        Arc::new(BasketContent { n_entries: n as u32, data: vec![fill; n], offsets: Vec::new() })
    }

    #[test]
    fn hits_and_misses_account_exactly() {
        let cache = BasketCache::new(1 << 20, 4);
        assert!(cache.get(&key(1, 0, 0)).is_none());
        cache.insert(key(1, 0, 0), payload(100, 7));
        assert_eq!(cache.get(&key(1, 0, 0)).unwrap().data, vec![7u8; 100]);
        assert!(cache.get(&key(1, 0, 1)).is_none(), "different basket");
        assert!(cache.get(&key(2, 0, 0)).is_none(), "different file");
        let s = cache.stats();
        assert_eq!(s.lookups, 4);
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 3);
        assert_eq!(s.hits + s.misses, s.lookups);
        assert_eq!(s.bytes_from_cache, 100);
        assert_eq!(s.resident_entries, 1);
        assert_eq!(s.resident_bytes, 100);
    }

    #[test]
    fn lru_evicts_oldest_and_hits_refresh_recency() {
        // One shard, budget for exactly two 100-byte payloads.
        let cache = BasketCache::new(200, 1);
        cache.insert(key(1, 0, 0), payload(100, 0));
        cache.insert(key(1, 0, 1), payload(100, 1));
        // Touch basket 0 so basket 1 becomes the LRU victim.
        assert!(cache.get(&key(1, 0, 0)).is_some());
        cache.insert(key(1, 0, 2), payload(100, 2));
        assert!(cache.contains(&key(1, 0, 0)), "recently-touched entry survives");
        assert!(!cache.contains(&key(1, 0, 1)), "LRU entry evicted");
        assert!(cache.contains(&key(1, 0, 2)));
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.resident_entries, 2);
        assert!(s.resident_bytes <= 200);
    }

    #[test]
    fn eviction_never_invalidates_a_held_payload() {
        let cache = BasketCache::new(100, 1);
        cache.insert(key(1, 0, 0), payload(100, 9));
        let held = cache.get(&key(1, 0, 0)).unwrap();
        // This insert evicts basket 0 entirely.
        cache.insert(key(1, 0, 1), payload(100, 3));
        assert!(!cache.contains(&key(1, 0, 0)));
        // The refcounted payload is still intact.
        assert_eq!(held.data, vec![9u8; 100]);
    }

    #[test]
    fn oversize_payloads_are_rejected_not_thrashed() {
        let cache = BasketCache::new(400, 4); // 100 bytes per shard
        cache.insert(key(1, 0, 0), payload(50, 1));
        assert!(!cache.insert(key(1, 0, 1), payload(500, 2)), "bigger than a shard");
        assert_eq!(cache.stats().rejected, 1);
        assert!(cache.contains(&key(1, 0, 0)), "resident entries untouched by a rejection");
        assert!(!cache.contains(&key(1, 0, 1)));
    }

    #[test]
    fn zero_budget_disables_caching() {
        let cache = BasketCache::new(0, 8);
        assert!(!cache.insert(key(1, 0, 0), payload(1, 0)));
        assert!(cache.get(&key(1, 0, 0)).is_none());
        let s = cache.stats();
        assert_eq!((s.insertions, s.rejected, s.hits), (0, 1, 0));
        assert_eq!(s.resident_entries, 0);
    }

    #[test]
    fn reinsert_replaces_without_leaking_bytes() {
        let cache = BasketCache::new(1 << 20, 1);
        cache.insert(key(1, 2, 3), payload(100, 1));
        cache.insert(key(1, 2, 3), payload(60, 2));
        let s = cache.stats();
        assert_eq!(s.resident_entries, 1);
        assert_eq!(s.resident_bytes, 60, "old payload's bytes released");
        assert_eq!(cache.get(&key(1, 2, 3)).unwrap().data, vec![2u8; 60]);
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        for n in [1usize, 2, 3, 5, 16, 17] {
            let cache = BasketCache::new(1 << 20, n);
            assert!(cache.shards.len().is_power_of_two());
            assert!(cache.shards.len() >= n.min(32));
        }
    }

    #[test]
    fn keys_spread_across_shards() {
        let cache = BasketCache::new(1 << 20, 8);
        let mut used = std::collections::HashSet::new();
        for basket in 0..64u32 {
            let k = key(42, 0, basket);
            let shard = cache.shard_of(&k) as *const _ as usize;
            used.insert(shard);
        }
        assert!(used.len() >= 4, "64 keys landed in only {} of 8 shards", used.len());
    }
}
