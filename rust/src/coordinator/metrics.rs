//! Pipeline metrics: throughput, ratios, per-stage timing, and latency
//! histograms — what a production I/O framework exports, and what the
//! figure harnesses read back.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Lock-free accumulating counters (shared across workers).
#[derive(Debug, Default)]
pub struct Metrics {
    pub baskets: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub compress_nanos: AtomicU64,
    pub commit_nanos: AtomicU64,
    pub analyze_nanos: AtomicU64,
    /// Latency histogram buckets (basket compress time): <100us, <1ms,
    /// <10ms, <100ms, >=100ms.
    pub lat_buckets: [AtomicU64; 5],
    /// Transient read failures that were retried by the read pipeline's
    /// [`RetryPolicy`](crate::rfile::RetryPolicy) layer (0 on the write
    /// path and whenever retries are disabled).
    pub read_retries: AtomicU64,
    /// Decoded-basket cache hits (serving layer; 0 outside a
    /// [`ScanServer`](crate::coordinator::ScanServer)).
    pub cache_hits: AtomicU64,
    /// Decoded-basket cache misses (serving layer).
    pub cache_misses: AtomicU64,
    /// Physical reads issued to the underlying file by the I/O backend
    /// (see [`IoStats`](crate::rfile::IoStats); 0 on the write path).
    pub io_syscalls: AtomicU64,
    /// Bytes served out of coalesced merge buffers instead of dedicated
    /// reads (0 unless the `coalesced` backend is selected).
    pub io_bytes_merged: AtomicU64,
    /// Requests satisfied from a coalesced merge buffer.
    pub io_requests_coalesced: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_basket(&self, bytes_in: usize, bytes_out: usize, compress: Duration) {
        self.baskets.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes_in as u64, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes_out as u64, Ordering::Relaxed);
        let nanos = compress.as_nanos() as u64;
        self.compress_nanos.fetch_add(nanos, Ordering::Relaxed);
        let idx = match nanos {
            n if n < 100_000 => 0,
            n if n < 1_000_000 => 1,
            n if n < 10_000_000 => 2,
            n if n < 100_000_000 => 3,
            _ => 4,
        };
        self.lat_buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Fold retry attempts observed by a scan's retry layer into the
    /// counters. `store` (not add): callers pass the cumulative value of
    /// a per-reader counter, so re-snapshotting stays idempotent.
    pub fn set_read_retries(&self, n: u64) {
        self.read_retries.store(n, Ordering::Relaxed);
    }

    /// Fold the decoded-basket cache's cumulative hit/miss counters in.
    /// Same idempotent-store contract as [`Metrics::set_read_retries`].
    pub fn set_cache_counters(&self, hits: u64, misses: u64) {
        self.cache_hits.store(hits, Ordering::Relaxed);
        self.cache_misses.store(misses, Ordering::Relaxed);
    }

    /// Fold the I/O backend's cumulative physical-read counters in. Same
    /// idempotent-store contract as [`Metrics::set_read_retries`].
    pub fn set_io_counters(&self, syscalls: u64, bytes_merged: u64, requests_coalesced: u64) {
        self.io_syscalls.store(syscalls, Ordering::Relaxed);
        self.io_bytes_merged.store(bytes_merged, Ordering::Relaxed);
        self.io_requests_coalesced.store(requests_coalesced, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            baskets: self.baskets.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            compress_nanos: self.compress_nanos.load(Ordering::Relaxed),
            commit_nanos: self.commit_nanos.load(Ordering::Relaxed),
            analyze_nanos: self.analyze_nanos.load(Ordering::Relaxed),
            lat_buckets: [
                self.lat_buckets[0].load(Ordering::Relaxed),
                self.lat_buckets[1].load(Ordering::Relaxed),
                self.lat_buckets[2].load(Ordering::Relaxed),
                self.lat_buckets[3].load(Ordering::Relaxed),
                self.lat_buckets[4].load(Ordering::Relaxed),
            ],
            read_retries: self.read_retries.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            io_syscalls: self.io_syscalls.load(Ordering::Relaxed),
            io_bytes_merged: self.io_bytes_merged.load(Ordering::Relaxed),
            io_requests_coalesced: self.io_requests_coalesced.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Snapshot {
    pub baskets: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub compress_nanos: u64,
    pub commit_nanos: u64,
    pub analyze_nanos: u64,
    pub lat_buckets: [u64; 5],
    /// Transient read failures retried by the read path (see
    /// [`Metrics::read_retries`]).
    pub read_retries: u64,
    /// Decoded-basket cache hits (see [`Metrics::cache_hits`]).
    pub cache_hits: u64,
    /// Decoded-basket cache misses (see [`Metrics::cache_misses`]).
    pub cache_misses: u64,
    /// Physical reads issued by the I/O backend (see
    /// [`Metrics::io_syscalls`]).
    pub io_syscalls: u64,
    /// Bytes served from coalesced merge buffers (see
    /// [`Metrics::io_bytes_merged`]).
    pub io_bytes_merged: u64,
    /// Requests satisfied from a coalesced merge buffer (see
    /// [`Metrics::io_requests_coalesced`]).
    pub io_requests_coalesced: u64,
}

impl Snapshot {
    /// Overall compression ratio (uncompressed / compressed).
    pub fn ratio(&self) -> f64 {
        if self.bytes_out == 0 {
            return 0.0;
        }
        self.bytes_in as f64 / self.bytes_out as f64
    }

    /// Aggregate compression throughput over CPU time spent compressing.
    pub fn compress_mbps(&self) -> f64 {
        if self.compress_nanos == 0 {
            return 0.0;
        }
        self.bytes_in as f64 / 1e6 / (self.compress_nanos as f64 / 1e9)
    }

    pub fn report(&self, label: &str) -> String {
        self.report_kind(label, "compress")
    }

    /// Read-pipeline flavour of [`Snapshot::report`]: same counters, but the
    /// per-basket CPU time is decode time, so label it that way.
    pub fn report_decode(&self, label: &str) -> String {
        self.report_kind(label, "decode")
    }

    fn report_kind(&self, label: &str, verb: &str) -> String {
        let retries = if self.read_retries > 0 {
            format!(" read-retries={}", self.read_retries)
        } else {
            String::new()
        };
        let cache = if self.cache_hits + self.cache_misses > 0 {
            format!(" cache-hits={} cache-misses={}", self.cache_hits, self.cache_misses)
        } else {
            String::new()
        };
        let io = if self.io_syscalls > 0 {
            let merged = if self.io_requests_coalesced > 0 {
                format!(
                    " io-coalesced={} io-merged={:.2}MB",
                    self.io_requests_coalesced,
                    self.io_bytes_merged as f64 / 1e6
                )
            } else {
                String::new()
            };
            format!(" io-syscalls={}{merged}", self.io_syscalls)
        } else {
            String::new()
        };
        format!(
            "{label}: baskets={} in={:.2}MB out={:.2}MB ratio={:.3} cpu-{verb}={:.1}ms ({:.1} MB/s/worker) lat[<.1ms,<1ms,<10ms,<100ms,>=]={:?}{retries}{cache}{io}",
            self.baskets,
            self.bytes_in as f64 / 1e6,
            self.bytes_out as f64 / 1e6,
            self.ratio(),
            self.compress_nanos as f64 / 1e6,
            self.compress_mbps(),
            self.lat_buckets,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_and_throughput() {
        let m = Metrics::new();
        m.record_basket(1000, 250, Duration::from_micros(50));
        m.record_basket(1000, 250, Duration::from_millis(5));
        let s = m.snapshot();
        assert_eq!(s.baskets, 2);
        assert!((s.ratio() - 4.0).abs() < 1e-9);
        assert_eq!(s.lat_buckets[0], 1);
        assert_eq!(s.lat_buckets[2], 1);
        assert!(s.compress_mbps() > 0.0);
    }

    #[test]
    fn read_retries_surface_in_snapshot_and_report() {
        let m = Metrics::new();
        m.record_basket(100, 50, Duration::from_micros(10));
        assert_eq!(m.snapshot().read_retries, 0);
        assert!(!m.snapshot().report_decode("x").contains("read-retries"));
        m.set_read_retries(7);
        m.set_read_retries(7); // idempotent: cumulative store, not add
        let s = m.snapshot();
        assert_eq!(s.read_retries, 7);
        assert!(s.report_decode("x").contains("read-retries=7"));
    }

    #[test]
    fn cache_counters_surface_in_snapshot_and_report() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().cache_hits, 0);
        assert!(!m.snapshot().report_decode("x").contains("cache-hits"));
        m.set_cache_counters(12, 3);
        m.set_cache_counters(12, 3); // idempotent: cumulative store, not add
        let s = m.snapshot();
        assert_eq!((s.cache_hits, s.cache_misses), (12, 3));
        assert!(s.report_decode("x").contains("cache-hits=12 cache-misses=3"));
    }

    #[test]
    fn io_counters_surface_in_snapshot_and_report() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().io_syscalls, 0);
        assert!(!m.snapshot().report_decode("x").contains("io-syscalls"));
        // pread-style run: syscalls only, no coalescing suffix.
        m.set_io_counters(40, 0, 0);
        let s = m.snapshot();
        assert_eq!(s.io_syscalls, 40);
        let r = s.report_decode("x");
        assert!(r.contains("io-syscalls=40"), "{r}");
        assert!(!r.contains("io-coalesced"), "{r}");
        // Coalesced run: idempotent store, full suffix.
        m.set_io_counters(3, 2_000_000, 38);
        m.set_io_counters(3, 2_000_000, 38);
        let s = m.snapshot();
        assert_eq!(
            (s.io_syscalls, s.io_bytes_merged, s.io_requests_coalesced),
            (3, 2_000_000, 38)
        );
        let r = s.report_decode("x");
        assert!(r.contains("io-syscalls=3 io-coalesced=38 io-merged=2.00MB"), "{r}");
    }
}
