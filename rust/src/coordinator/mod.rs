//! L3 coordinator: the parallel basket-compression pipeline (bounded-queue
//! backpressure, ordered commit), its read-side twin (prefetch + parallel
//! decompression + ordered delivery), columnar projection scans over that
//! twin (multi-branch single-pass reads with offset-sorted prefetch), the
//! concurrent serving layer (a shared-worker scan scheduler over a sharded
//! decoded-basket cache), runtime metrics, and the adaptive compression
//! planner served by the XLA runtime.

pub mod adaptive;
pub mod cache;
pub mod metrics;
pub mod pipeline;
pub mod projection;
pub mod read_pipeline;
pub mod scheduler;

pub use adaptive::{FeatureSource, Planner, UseCase};
pub use cache::{BasketCache, CacheKey, CacheStats};
pub use metrics::{Metrics, Snapshot};
pub use pipeline::{write_tree_parallel, ParallelSink, PipelineConfig};
pub use projection::{
    BranchReadStats, PrefetchOrder, ProjectionPlan, ProjectionReader, ProjectionScan, RowBatch,
};
pub use read_pipeline::{
    BasketScan, BasketStream, DamageRecord, DecodedBasket, Delivery, ParallelTreeReader,
    ReadAhead, SalvageColumn, ScanMode,
};
pub use scheduler::{
    CorpusFile, Query, QueryStats, ScanServer, ServeConfig, ServeQuery, ServeStream,
};
