//! L3 coordinator: the parallel basket-compression pipeline (bounded-queue
//! backpressure, ordered commit), its read-side twin (prefetch + parallel
//! decompression + ordered delivery), columnar projection scans over that
//! twin (multi-branch single-pass reads with offset-sorted prefetch),
//! runtime metrics, and the adaptive compression planner served by the XLA
//! runtime.

pub mod adaptive;
pub mod metrics;
pub mod pipeline;
pub mod projection;
pub mod read_pipeline;

pub use adaptive::{FeatureSource, Planner, UseCase};
pub use metrics::{Metrics, Snapshot};
pub use pipeline::{write_tree_parallel, ParallelSink, PipelineConfig};
pub use projection::{
    BranchReadStats, PrefetchOrder, ProjectionPlan, ProjectionReader, ProjectionScan, RowBatch,
};
pub use read_pipeline::{
    BasketScan, DamageRecord, Delivery, ParallelTreeReader, ReadAhead, SalvageColumn, ScanMode,
};
