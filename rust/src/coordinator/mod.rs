//! L3 coordinator: the parallel basket-compression pipeline (bounded-queue
//! backpressure, ordered commit), its read-side twin (prefetch + parallel
//! decompression + ordered delivery), columnar projection scans over that
//! twin (multi-branch single-pass reads with offset-sorted prefetch), the
//! concurrent serving layer (a shared-worker scan scheduler over a sharded
//! decoded-basket cache), runtime metrics, the adaptive compression
//! planner served by the XLA runtime, and the profile-driven repack
//! rewriter that closes the adaptive loop ([`repack`]).

pub mod adaptive;
pub mod cache;
pub mod metrics;
pub mod pipeline;
pub mod projection;
pub mod read_pipeline;
pub mod repack;
pub mod scheduler;

pub use adaptive::{FeatureSource, Planner, RepackDecision, UseCase};
pub use repack::{plan_branches, repack_file, BranchPlan, RepackOptions, RepackReport};
pub use cache::{BasketCache, CacheKey, CacheStats};
pub use metrics::{Metrics, Snapshot};
pub use pipeline::{write_tree_parallel, ParallelSink, PipelineConfig};
pub use projection::{
    BranchReadStats, PrefetchOrder, ProjectionPlan, ProjectionReader, ProjectionScan, RowBatch,
};
pub use read_pipeline::{
    BasketScan, BasketStream, DamageRecord, DecodedBasket, Delivery, ParallelTreeReader,
    ReadAhead, SalvageColumn, ScanMode,
};
pub use scheduler::{
    CorpusFile, Query, QueryStats, ScanServer, ServeConfig, ServeQuery, ServeStream,
};
