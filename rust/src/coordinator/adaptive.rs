//! The adaptive compression planner — the paper's §3 future-work item made
//! concrete: "improvements are needed to the I/O APIs to ease the switch
//! between compression algorithms and settings for different use cases".
//!
//! Per basket, the planner obtains the analyzer feature vector (from the
//! XLA-compiled artifact via [`crate::runtime::Analyzer`], or the native
//! mirror when artifacts are absent) and picks (algorithm, level,
//! preconditioner) according to the declared *use case*:
//!
//! * `Analysis`   — decode-speed-bound (the paper: analysis is "less
//!   sensitive to compression ratio but highly sensitive on decompression
//!   speed") → LZ4 family, preconditioned when the features say BitShuffle
//!   unlocks ratio (Fig 6).
//! * `Production` — ratio-bound with CPU to spare → ZSTD/LZMA family.
//! * `Balanced`   — ZSTD-leaning middle ground (the paper's "might be a
//!   replacement of ZLIB for general purpose work").

use crate::compression::{Algorithm, Settings};
use crate::precond::Precond;
use crate::runtime::analyzer::{analyze_native, bucket_for};
use crate::runtime::{Analyzer, Features};
use crate::zstd::EntropyMode;

/// Smallest basket target [`Planner::repack_basket_bytes`] will choose:
/// below this the per-basket record framing and directory overhead dwarf
/// any window-alignment win.
pub const MIN_REPACK_BASKET: usize = 4 * 1024;

/// Largest basket target [`Planner::repack_basket_bytes`] will choose:
/// beyond this a single boundary basket decodes more excess than any
/// seek it saves.
pub const MAX_REPACK_BASKET: usize = 512 * 1024;

/// One branch's complete repack plan, produced by
/// [`Planner::plan_repack`]: the effective use case (profile-derived or
/// the planner's static label), the codec/preconditioner/entropy
/// settings, and the re-chunk basket-size target in bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RepackDecision {
    /// The use case the settings were decided under.
    pub use_case: UseCase,
    /// Codec + level + preconditioner + entropy lane for the branch.
    pub settings: Settings,
    /// Target logical basket size (bytes) for re-chunking.
    pub basket_bytes: usize,
}

/// The workload profile the user declares (paper §1: production vs
/// analysis have opposite constraints).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UseCase {
    Analysis,
    Production,
    Balanced,
}

/// Feature source: XLA artifact or native mirror.
pub enum FeatureSource {
    Xla(Analyzer),
    Native,
}

impl FeatureSource {
    pub fn features(&mut self, basket: &[u8]) -> Option<Features> {
        match self {
            FeatureSource::Xla(a) => a.analyze(basket).ok().flatten(),
            FeatureSource::Native => bucket_for(basket.len()).and_then(|b| analyze_native(basket, b)),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FeatureSource::Xla(_) => "xla",
            FeatureSource::Native => "native",
        }
    }
}

/// The planner.
pub struct Planner {
    pub use_case: UseCase,
    pub source: FeatureSource,
    /// Element stride assumed by the preconditioner decisions (matches the
    /// analyzer's STRIDE).
    stride: u8,
}

impl Planner {
    pub fn new(use_case: UseCase, source: FeatureSource) -> Self {
        Self { use_case, source, stride: 4 }
    }

    /// Decide settings for one basket. Small baskets (below the analyzer's
    /// smallest bucket) get the use case's static default.
    pub fn plan(&mut self, basket: &[u8]) -> Settings {
        let Some(f) = self.source.features(basket) else {
            return self.default_settings();
        };
        self.plan_from_features(&f)
    }

    /// Pure decision logic (unit-testable without artifacts).
    pub fn plan_from_features(&self, f: &Features) -> Settings {
        Self::decide(self.use_case, self.stride, f)
    }

    /// Plan one branch from analyzer features *weighted by observed read
    /// behaviour* instead of this planner's static use case: `intensity`
    /// is the fraction of the branch's stored bytes a recorded access
    /// profile saw decoded per scan (see
    /// [`ReadFeedback::intensity`](crate::runtime::ReadFeedback::intensity)).
    /// Hot branches get the decode-speed-bound plan regardless of how the
    /// file was written; branches the profile never read get the
    /// ratio-bound plan. Returns the effective use case alongside the
    /// settings so callers can report the decision.
    pub fn plan_from_feedback(&self, f: &Features, intensity: f64) -> (UseCase, Settings) {
        let uc = Self::use_case_for_intensity(intensity);
        (uc, Self::decide(uc, self.stride, f))
    }

    /// Map observed per-scan read intensity to an effective use case:
    /// branches whose bytes are mostly decoded on every scan are
    /// decode-speed-bound (the paper's analysis constraint), branches the
    /// profile never touches are ratio-bound (pure storage), everything
    /// in between gets the balanced middle ground.
    pub fn use_case_for_intensity(intensity: f64) -> UseCase {
        if intensity >= 0.5 {
            UseCase::Analysis
        } else if intensity > 0.05 {
            UseCase::Balanced
        } else {
            UseCase::Production
        }
    }

    /// The decision table shared by the static and feedback-weighted
    /// paths.
    fn decide(use_case: UseCase, stride: u8, f: &Features) -> Settings {
        // Is the basket already incompressible noise? Entropy near 8 in
        // every view → don't waste CPU, fastest codec at level 1. For the
        // ZSTD arms, high entropy also means the LZ stage finds little and
        // the block is literals-dominated — exactly where per-symbol ANS
        // cost dominates, so the Huff0 multi-stream Huffman lane wins
        // (PAPERS.md "Exploring compression techniques for ROOT IO"; the
        // zcif enwik8 numbers in SNIPPETS.md).
        let best_h = f.h_raw.min(f.h_shuffle).min(f.h_bitshuffle).min(f.h_delta);
        if best_h > 7.8 && f.rep_raw < 0.02 {
            return match use_case {
                UseCase::Analysis => Settings::new(Algorithm::Lz4, 1),
                _ => Settings::new(Algorithm::Zstd, 1).with_entropy(EntropyMode::Huff0),
            };
        }
        // Does BitShuffle unlock structure (Fig-6 signature)? A large
        // entropy drop or long runs in the bit planes.
        let bitshuffle_wins = f.h_bitshuffle < 0.75 * f.h_raw
            || (f.zero_bitshuffle > 0.5 && f.h_bitshuffle < f.h_raw);
        let shuffle_wins = !bitshuffle_wins && f.h_shuffle < 0.8 * f.h_raw;
        let precond = if bitshuffle_wins {
            Precond::BitShuffle(stride)
        } else if shuffle_wins {
            Precond::Shuffle(stride)
        } else {
            Precond::None
        };
        match use_case {
            UseCase::Analysis => {
                // LZ4 keeps Fig-3 decode speed; precondition when it helps.
                Settings::new(Algorithm::Lz4, 4).with_precond(precond)
            }
            UseCase::Production => {
                // Ratio-bound: deep-search codecs; preconditioners still
                // help the entropy stage on offset-like data.
                if bitshuffle_wins {
                    Settings::new(Algorithm::Zstd, 9).with_precond(precond)
                } else {
                    Settings::new(Algorithm::Lzma, 6)
                }
            }
            UseCase::Balanced => Settings::new(Algorithm::Zstd, 5).with_precond(precond),
        }
    }

    /// The per-branch repack decision surface
    /// ([`repack_file`](crate::coordinator::repack::repack_file) drives
    /// this once per branch): fold analyzer features, the recorded
    /// profile's read `intensity`, and its observed per-scan window size
    /// into codec settings *and* a re-chunk basket target.
    ///
    /// * `features` — analyzer features of the branch's data (`None` for
    ///   branches whose baskets are all below the smallest analyzer
    ///   bucket; they get the effective use case's static default).
    /// * `intensity` — observed per-scan read fraction from a recorded
    ///   [`ReadFeedback`](crate::runtime::ReadFeedback) (`None` when
    ///   repacking without a profile; the planner's static use case then
    ///   applies to every branch).
    /// * `window_bytes` — the profile's observed per-scan decoded window
    ///   for this branch in logical bytes (`None` when unobserved); see
    ///   [`Planner::repack_basket_bytes`].
    /// * `target_override` — a caller-forced basket target
    ///   (`--target-basket-kb`); floored at 1 KiB, otherwise honored
    ///   verbatim for every branch.
    pub fn plan_repack(
        &self,
        features: Option<&Features>,
        intensity: Option<f64>,
        window_bytes: Option<f64>,
        target_override: Option<usize>,
    ) -> RepackDecision {
        let use_case = match intensity {
            Some(i) => Self::use_case_for_intensity(i),
            None => self.use_case,
        };
        let settings = match features {
            Some(f) => Self::decide(use_case, self.stride, f),
            None => Self::default_settings_for(use_case),
        };
        let basket_bytes = match target_override {
            Some(t) => t.max(1024),
            None => Self::repack_basket_bytes(use_case, window_bytes),
        };
        RepackDecision { use_case, settings, basket_bytes }
    }

    /// Re-chunk target for one branch: start from the use case's base
    /// size — small baskets for decode-speed-bound branches (partial
    /// windows decode less excess), large ones for ratio-bound branches
    /// (better match windows and amortized entropy tables; cluster sizing
    /// is the headline knob in "Optimizing ROOT IO For Analysis",
    /// PAPERS.md) — then, when the profile observed actual reads, pull
    /// the target toward the observed per-scan window so basket
    /// boundaries align with what analyses actually decode. Clamped to
    /// the [`MIN_REPACK_BASKET`]–[`MAX_REPACK_BASKET`] band; ratio-bound
    /// branches never shrink below their base (their reads are rare by
    /// definition, so ratio wins the trade).
    pub fn repack_basket_bytes(use_case: UseCase, window_bytes: Option<f64>) -> usize {
        let base = match use_case {
            UseCase::Analysis => 16 * 1024,
            UseCase::Balanced => 32 * 1024, // DEFAULT_BASKET_SIZE
            UseCase::Production => 128 * 1024,
        };
        let window = match window_bytes {
            Some(w) if w.is_finite() && w >= 1.0 => w as usize,
            _ => return base,
        };
        match use_case {
            UseCase::Analysis | UseCase::Balanced => {
                window.clamp(MIN_REPACK_BASKET, MAX_REPACK_BASKET)
            }
            UseCase::Production => window.clamp(base, MAX_REPACK_BASKET),
        }
    }

    pub fn default_settings(&self) -> Settings {
        Self::default_settings_for(self.use_case)
    }

    /// Static fallback settings for a use case (small baskets below the
    /// analyzer's smallest bucket).
    pub fn default_settings_for(use_case: UseCase) -> Settings {
        match use_case {
            UseCase::Analysis => Settings::new(Algorithm::Lz4, 4),
            UseCase::Production => Settings::new(Algorithm::Zstd, 9),
            UseCase::Balanced => Settings::new(Algorithm::Zstd, 5),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feats(h_raw: f32, h_shuf: f32, h_bits: f32, zero_bits: f32) -> Features {
        Features {
            h_raw,
            h_shuffle: h_shuf,
            h_bitshuffle: h_bits,
            h_delta: h_raw,
            rep_raw: 0.1,
            rep_bitshuffle: 0.5,
            zero_bitshuffle: zero_bits,
            rep_shuffle: 0.2,
        }
    }

    #[test]
    fn offset_like_baskets_get_bitshuffle() {
        let p = Planner::new(UseCase::Analysis, FeatureSource::Native);
        // Offset arrays: raw entropy ~6, bitshuffled ~1.
        let s = p.plan_from_features(&feats(6.0, 4.0, 1.0, 0.9));
        assert_eq!(s.algorithm, Algorithm::Lz4);
        assert_eq!(s.precond, Precond::BitShuffle(4));
    }

    #[test]
    fn noise_gets_fast_low_effort() {
        let p = Planner::new(UseCase::Production, FeatureSource::Native);
        let mut f = feats(7.99, 7.99, 7.99, 0.0);
        f.rep_raw = 0.0;
        let s = p.plan_from_features(&f);
        assert_eq!(s.level, 1);
    }

    /// High-entropy features (the noise row of the decision table).
    fn noise_feats() -> Features {
        let mut f = feats(7.99, 7.99, 7.99, 0.0);
        f.rep_raw = 0.0;
        f
    }

    #[test]
    fn high_entropy_selects_huff0_literals_lane() {
        // Literals-dominated noise: the ZSTD arms must pick the 4-stream
        // Huffman lane; the LZ4 arm has no entropy stage to swap.
        let f = noise_feats();
        for uc in [UseCase::Production, UseCase::Balanced] {
            let s = Planner::new(uc, FeatureSource::Native).plan_from_features(&f);
            assert_eq!(s.algorithm, Algorithm::Zstd, "{uc:?}");
            assert_eq!(s.entropy, EntropyMode::Huff0, "{uc:?}");
        }
        let s = Planner::new(UseCase::Analysis, FeatureSource::Native).plan_from_features(&f);
        assert_eq!(s.algorithm, Algorithm::Lz4);
        assert_eq!(s.entropy, EntropyMode::default());
    }

    #[test]
    fn default_ans_branches_use_quad_state_fse() {
        // Every non-noise ZSTD row rides the EntropyMode default (Fse4):
        // the planner only overrides the entropy lane for the Huff0 case.
        for uc in [UseCase::Production, UseCase::Balanced] {
            for f in [feats(6.0, 4.0, 1.0, 0.9), feats(5.0, 4.9, 4.8, 0.1)] {
                let s = Planner::new(uc, FeatureSource::Native).plan_from_features(&f);
                if s.algorithm == Algorithm::Zstd {
                    assert_eq!(s.entropy, EntropyMode::Fse4, "{uc:?} {f:?}");
                }
            }
            assert_eq!(Planner::default_settings_for(uc).entropy, EntropyMode::Fse4);
        }
    }

    #[test]
    fn feedback_path_reaches_the_new_lanes() {
        // plan_from_feedback must land on the same decision rows: cold or
        // lukewarm high-entropy branches get ZSTD + Huff0 literals, hot
        // ones stay on the LZ4 decode-speed plan.
        let p = Planner::new(UseCase::Production, FeatureSource::Native);
        let f = noise_feats();
        for (intensity, uc) in [(0.0, UseCase::Production), (0.2, UseCase::Balanced)] {
            let (got, s) = p.plan_from_feedback(&f, intensity);
            assert_eq!(got, uc);
            assert_eq!(s.algorithm, Algorithm::Zstd);
            assert_eq!(s.entropy, EntropyMode::Huff0);
        }
        let (uc, s) = p.plan_from_feedback(&f, 0.9);
        assert_eq!(uc, UseCase::Analysis);
        assert_eq!(s.algorithm, Algorithm::Lz4);
    }

    #[test]
    fn production_prefers_ratio_codecs() {
        let p = Planner::new(UseCase::Production, FeatureSource::Native);
        let s = p.plan_from_features(&feats(5.0, 4.9, 4.8, 0.1));
        assert!(matches!(s.algorithm, Algorithm::Lzma | Algorithm::Zstd));
        assert!(s.level >= 6);
    }

    #[test]
    fn analysis_always_lz4_family() {
        let p = Planner::new(UseCase::Analysis, FeatureSource::Native);
        for f in [
            feats(6.0, 4.0, 1.0, 0.9),
            feats(5.0, 4.9, 4.8, 0.1),
            feats(7.99, 7.99, 7.99, 0.0),
        ] {
            let s = p.plan_from_features(&f);
            assert_eq!(s.algorithm, Algorithm::Lz4, "{f:?}");
        }
    }

    #[test]
    fn feedback_overrides_the_static_use_case() {
        // A production-labelled planner still picks the decode-speed plan
        // for a branch the access profile reads on every scan — and the
        // ratio plan for one it never touches.
        let p = Planner::new(UseCase::Production, FeatureSource::Native);
        let f = feats(6.0, 4.0, 1.0, 0.9);
        let (uc, s) = p.plan_from_feedback(&f, 1.0);
        assert_eq!(uc, UseCase::Analysis);
        assert_eq!(s.algorithm, Algorithm::Lz4);
        assert_eq!(s, Planner::new(UseCase::Analysis, FeatureSource::Native).plan_from_features(&f));
        let (uc, s) = p.plan_from_feedback(&f, 0.0);
        assert_eq!(uc, UseCase::Production);
        assert!(matches!(s.algorithm, Algorithm::Lzma | Algorithm::Zstd));
        let (uc, _) = p.plan_from_feedback(&f, 0.2);
        assert_eq!(uc, UseCase::Balanced);
    }

    #[test]
    fn intensity_thresholds() {
        assert_eq!(Planner::use_case_for_intensity(0.0), UseCase::Production);
        assert_eq!(Planner::use_case_for_intensity(0.05), UseCase::Production);
        assert_eq!(Planner::use_case_for_intensity(0.2), UseCase::Balanced);
        assert_eq!(Planner::use_case_for_intensity(0.5), UseCase::Analysis);
        assert_eq!(Planner::use_case_for_intensity(3.0), UseCase::Analysis);
    }

    #[test]
    fn repack_decision_tracks_profile_intensity() {
        // With a profile, the effective use case comes from intensity and
        // the settings match plan_from_feedback's row exactly; without
        // one, the planner's static label applies.
        let p = Planner::new(UseCase::Production, FeatureSource::Native);
        let f = feats(6.0, 4.0, 1.0, 0.9);
        let hot = p.plan_repack(Some(&f), Some(0.9), None, None);
        assert_eq!(hot.use_case, UseCase::Analysis);
        assert_eq!(hot.settings, p.plan_from_feedback(&f, 0.9).1);
        let cold = p.plan_repack(Some(&f), Some(0.0), None, None);
        assert_eq!(cold.use_case, UseCase::Production);
        let unprofiled = p.plan_repack(Some(&f), None, None, None);
        assert_eq!(unprofiled.use_case, UseCase::Production);
        assert_eq!(unprofiled.settings, p.plan_from_features(&f));
        // Small-basket branch (no features): the static default of the
        // effective use case.
        let small = p.plan_repack(None, Some(0.9), None, None);
        assert_eq!(small.settings, Planner::default_settings_for(UseCase::Analysis));
    }

    #[test]
    fn repack_basket_target_follows_observed_window() {
        // No window observed: use-case bases, ordered small → large.
        let a = Planner::repack_basket_bytes(UseCase::Analysis, None);
        let b = Planner::repack_basket_bytes(UseCase::Balanced, None);
        let p = Planner::repack_basket_bytes(UseCase::Production, None);
        assert!(a < b && b < p, "{a} {b} {p}");
        // Hot branches chunk toward the observed per-scan window, within
        // the clamp band.
        assert_eq!(
            Planner::repack_basket_bytes(UseCase::Analysis, Some(10_000.0)),
            10_000
        );
        assert_eq!(
            Planner::repack_basket_bytes(UseCase::Analysis, Some(64.0)),
            MIN_REPACK_BASKET
        );
        assert_eq!(
            Planner::repack_basket_bytes(UseCase::Balanced, Some(1e12)),
            MAX_REPACK_BASKET
        );
        // Ratio-bound branches never shrink below their base.
        assert_eq!(
            Planner::repack_basket_bytes(UseCase::Production, Some(64.0)),
            128 * 1024
        );
        // Degenerate windows fall back to the base.
        assert_eq!(Planner::repack_basket_bytes(UseCase::Analysis, Some(f64::NAN)), a);
        assert_eq!(Planner::repack_basket_bytes(UseCase::Analysis, Some(0.0)), a);
    }

    #[test]
    fn repack_override_wins_and_is_floored() {
        let p = Planner::new(UseCase::Balanced, FeatureSource::Native);
        let d = p.plan_repack(None, Some(0.9), Some(1e9), Some(8 * 1024));
        assert_eq!(d.basket_bytes, 8 * 1024);
        // The override is honored verbatim above 1 KiB, floored below it.
        assert_eq!(p.plan_repack(None, None, None, Some(1)).basket_bytes, 1024);
        assert_eq!(
            p.plan_repack(None, None, None, Some(4 << 20)).basket_bytes,
            4 << 20
        );
    }

    #[test]
    fn native_source_end_to_end() {
        let mut p = Planner::new(UseCase::Analysis, FeatureSource::Native);
        let offsets: Vec<u8> = (1u32..=4096).flat_map(|i| i.to_be_bytes()).collect();
        let s = p.plan(&offsets);
        assert_eq!(s.precond, Precond::BitShuffle(4), "{s:?}");
        // Tiny basket: default.
        let s = p.plan(&[0u8; 64]);
        assert_eq!(s, p.default_settings());
    }
}
